"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The production target is TPU v5e pods:
16 x 16 = 256 chips per pod, 2 pods = 512 chips for the multi-pod
dry-run.  On real hardware ``jax.make_mesh`` maps axes onto the physical
torus; under ``--xla_force_host_platform_device_count`` the same code
builds the mesh from host placeholder devices.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # Test hook: shrink the mesh (e.g. "2x4" / "2x2x4") without changing
    # any production code path.
    import os
    env = os.environ.get(
        "REPRO_MESH_SHAPE_MULTI" if multi_pod else "REPRO_MESH_SHAPE")
    if env:
        shape = tuple(int(x) for x in env.split("x"))
        assert len(shape) == len(axes), (shape, axes)
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh from a device-count prefix (tests, small dry-runs)."""
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
