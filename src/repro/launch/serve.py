"""Serving driver: prefill + continuous-batching decode over a reduced
or full config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 16 --batch 4 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import get_config, get_smoke_config
from repro.serve import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    rng = np.random.default_rng(args.seed)

    cache = M.init_cache(cfg, args.batch, args.max_seq)
    queue = [rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(4, 16)))
             for _ in range(args.requests)]
    cur = jnp.zeros((args.batch,), jnp.int32)
    age = np.zeros(args.batch, int)
    active: list = [None] * args.batch
    done = 0
    next_id = 0

    def admit(slot):
        nonlocal cur, next_id
        if not queue:
            active[slot] = None
            return
        prompt = queue.pop(0)
        active[slot] = [next_id, list(prompt), 0]
        next_id += 1
        age[slot] = 0
        cur = cur.at[slot].set(int(prompt[0]))

    for s in range(args.batch):
        admit(s)

    t0 = time.time()
    steps = 0
    while done < args.requests and steps < 100_000:
        tok, cache = serve(params, cache, cur, jnp.int32(int(age.max())))
        tok = np.asarray(tok)
        steps += 1
        for s in range(args.batch):
            if active[s] is None:
                continue
            rid, prompt, ngen = active[s]
            age[s] += 1
            if age[s] < len(prompt):
                cur = cur.at[s].set(int(prompt[age[s]]))
                continue
            active[s][2] = ngen + 1
            if active[s][2] >= args.max_new or int(tok[s]) == 0:
                done += 1
                admit(s)
            else:
                cur = cur.at[s].set(int(tok[s]))
    dt = time.time() - t0
    print(f"[serve] {done}/{args.requests} requests, {steps} decode steps, "
          f"{steps * args.batch / dt:.1f} tok/s (batch={args.batch})")


if __name__ == "__main__":
    main()
