import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first initialization.  (Tests may pre-set REPRO_DRYRUN_DEVICES
# to use a smaller placeholder pool.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct inputs; record memory analysis, cost
analysis and collective traffic.

Per cell:
  * ``--mode full``  — the production config (scan-over-layers) is
    lowered and compiled; ``memory_analysis()`` proves the program fits,
    ``cost_analysis()`` and the partitioned HLO feed §Roofline.
  * ``--mode fit``   — two small *unrolled* variants (depth L1, L2) are
    compiled and the per-layer FLOPs/bytes/collective-bytes are
    extrapolated affinely to the true depth (XLA cost analysis counts a
    while-loop body once, so scanned programs under-report by the trip
    count; layers are homogeneous, so the affine fit is exact).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k \
      --mesh single --mode both --out reports/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro import models as M
from repro.configs import get_config
from repro.distributed import sharding as sh
from repro.launch import specs as SP
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models.config import SHAPES_BY_NAME, shapes_for
from repro.optim import AdamWConfig
from repro.serve import make_prefill_step, make_serve_step
from repro.train import make_train_step, state_logical_axes, state_spec
from repro.utils.hlo import collective_stats


def _rules_for(mesh, args):
    return sh.make_rules(
        fsdp=not args.no_fsdp,
        seq_shard_cache=not args.no_seqshard,
        expert_parallel=not args.no_ep,
        data_axes=data_axes(mesh))


def _shardings(shape_tree, axes_tree, mesh, rules):
    return sh.tree_shardings_for(shape_tree, axes_tree, mesh, rules)


def _repl(mesh):
    return NamedSharding(mesh, PS())


def lower_cell(cfg, shape, mesh, args):
    """Build + lower + compile one cell; returns (compiled, aux_info)."""
    rules = _rules_for(mesh, args)
    params_shape = state_spec(cfg).params
    params_ax = state_logical_axes(cfg).params
    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig(),
                               microbatches=args.microbatches)
        st_shape = state_spec(cfg)
        st_sh = _shardings(st_shape, state_logical_axes(cfg), mesh, rules)
        b_shape = SP.batch_specs(cfg, shape)
        b_sh = _shardings(b_shape, SP.batch_logical_axes(cfg), mesh, rules)
        jf = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        lowered = jf.lower(st_shape, b_shape)
    elif shape.kind == "prefill":
        pstep = make_prefill_step(cfg, shape.seq_len)
        p_sh = _shardings(params_shape, params_ax, mesh, rules)
        b_shape = SP.batch_specs(cfg, shape)
        b_ax = SP.batch_logical_axes(cfg)
        b_sh = _shardings(b_shape, b_ax, mesh, rules)
        fi = b_shape.get("frontend_inputs")
        if fi is not None:
            jf = jax.jit(pstep, in_shardings=(p_sh, b_sh["tokens"],
                                              b_sh["frontend_inputs"]))
            lowered = jf.lower(params_shape, b_shape["tokens"], fi)
        else:
            jf = jax.jit(pstep, in_shardings=(p_sh, b_sh["tokens"]))
            lowered = jf.lower(params_shape, b_shape["tokens"])
    else:  # decode
        sstep = make_serve_step(cfg)
        p_sh = _shardings(params_shape, params_ax, mesh, rules)
        d_shape = SP.decode_specs(cfg, shape)
        d_ax = SP.decode_logical_axes(cfg)
        c_sh = _shardings(d_shape["cache"], d_ax["cache"], mesh, rules)
        t_sh = _shardings(d_shape["tokens"], d_ax["tokens"], mesh, rules)
        jf = jax.jit(sstep,
                     in_shardings=(p_sh, c_sh, t_sh, _repl(mesh)),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,))
        lowered = jf.lower(params_shape, d_shape["cache"],
                           d_shape["tokens"], d_shape["pos"])
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, {"compile_s": time.time() - t0}


def analyze(compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    return {
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll.as_dict(),
    }


def _fit_depths(cfg):
    """Two small depths for the affine fit, honoring pattern groups."""
    if cfg.family == "hybrid":
        plen = len(cfg.block_pattern)
        _, groups, tail = (
            plen, cfg.num_layers // plen,
            cfg.num_layers % plen)
        l1, l2 = plen + tail, 2 * plen + tail
        per_units = (cfg.num_layers - tail) // plen
        return l1, l2, per_units, 1, 2
    return 2, 3, cfg.num_layers, 2, 3


def run_fit(cfg, shape, mesh, args) -> dict:
    """Affine-in-depth extrapolation of flops/bytes/collectives.

    Fit variants are unrolled (scan bodies are costed once by XLA) and use
    microbatches=1 (the grad-accumulation scan would hide a trip-count
    factor the same way).  cost_analysis numbers are per-device.
    """
    l1, l2, units, u1, u2 = _fit_depths(cfg)
    fit_args = argparse.Namespace(**{**vars(args), "microbatches": 1})
    results = []
    for ldepth in (l1, l2):
        c = dataclasses.replace(cfg, num_layers=ldepth, scan_layers=False)
        compiled, _ = lower_cell(c, shape, mesh, fit_args)
        results.append(analyze(compiled))
        del compiled
    def extrap(f):
        a, b = f(results[0]), f(results[1])
        slope = (b - a) / (u2 - u1)
        return a + slope * (units - u1)
    coll_kinds = results[0]["collectives"]["result_bytes"].keys()
    return {
        "depths": [l1, l2], "units": units,
        "flops": extrap(lambda r: r["flops"]),
        "bytes_accessed": extrap(lambda r: r["bytes_accessed"]),
        "collective_result_bytes": {
            k: extrap(lambda r, k=k: r["collectives"]["result_bytes"][k])
            for k in coll_kinds},
        "collective_wire_bytes": {
            k: extrap(lambda r, k=k: r["collectives"]["wire_bytes"][k])
            for k in coll_kinds},
        "small_runs": results,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, args) -> dict:
    overrides = {"kernel_impl": "xla"}
    if args.remat:
        overrides["remat"] = args.remat
    if getattr(args, "moe_impl", ""):
        overrides["moe_impl"] = args.moe_impl
    if getattr(args, "moe_pad", 0):
        overrides["moe_expert_pad"] = args.moe_pad
    if getattr(args, "remat_block", 0):
        overrides["remat_block"] = args.remat_block
    if getattr(args, "sp", False):
        overrides["seq_parallel"] = True
    if getattr(args, "ring", False):
        overrides["ring_attention"] = True
    cfg = get_config(arch, **overrides)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in shapes_for(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(full-attention arch; see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape),
           "params": M.count_params(cfg),
           "active_params": M.count_active_params(cfg),
           "model_flops": M.model_flops(
               cfg, shape.tokens if shape.kind != "decode"
               else shape.global_batch, shape.kind)}
    try:
        from repro.distributed.ctx import axis_rules
        rules = _rules_for(mesh, args)
        if args.mode in ("full", "both"):
            with mesh, axis_rules(mesh, rules):
                compiled, info = lower_cell(cfg, shape, mesh, args)
                out["full"] = analyze(compiled)
                out["full"].update(info)
                del compiled
        if args.mode in ("fit", "both") and mesh_kind == "single":
            with mesh, axis_rules(mesh, rules):
                out["fit"] = run_fit(cfg, shape, mesh, args)
        out["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        out["status"] = "error"
        out["error"] = f"{type(e).__name__}: {e}"
        out["traceback"] = traceback.format_exc()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--mode", choices=("full", "fit", "both"), default="both")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--remat", default="")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seqshard", action="store_true")
    ap.add_argument("--no-ep", action="store_true")
    ap.add_argument("--moe-impl", default="", dest="moe_impl")
    ap.add_argument("--moe-pad", type=int, default=0, dest="moe_pad")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--ring", action="store_true")
    ap.add_argument("--remat-block", type=int, default=0, dest="remat_block")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    res = run_cell(args.arch, args.shape, args.mesh, args)
    os.makedirs(args.out, exist_ok=True)
    tag = f".{args.tag}" if args.tag else ""
    path = os.path.join(
        args.out, f"{args.arch}_{args.shape}_{args.mesh}{tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    status = res["status"]
    extra = ""
    if status == "ok" and "full" in res:
        mem = res["full"]["memory"]
        per_dev = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
        extra = f" mem/dev={per_dev:.2f}GiB compile={res['full']['compile_s']:.0f}s"
    print(f"[dryrun] {args.arch} {args.shape} {args.mesh}: {status}{extra}")
    if status == "error":
        print(res["error"])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
