"""Production training driver.

Single-host CPU runs use reduced configs (--smoke); on a real pod the
same driver shards over the production mesh.  Integrates: data pipeline,
AdamW, blocked-remat train step, ZonedCheckpointStore (the paper
technique), restart-from-latest, and the failure/straggler policies.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig
from repro.runtime import RestartBudget, ZonedCheckpointStore
from repro.train import TrainState, make_train_step


def build(args):
    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.d_model:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_ff or args.d_model * 3,
            num_layers=args.layers or cfg.num_layers,
            head_dim=args.d_model // cfg.num_heads)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch,
                      num_codebooks=cfg.num_codebooks)
    opt = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                      total_steps=args.steps)
    return cfg, dcfg, opt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, dcfg, opt = build(args)
    from repro import models as M
    n = M.count_params(cfg)
    print(f"[train] arch={cfg.name} params={n/1e6:.1f}M "
          f"tokens/step={dcfg.seq_len * dcfg.global_batch}")

    data = TokenPipeline(dcfg)
    state = TrainState.create(cfg, jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=(0,))
    store = None
    if args.ckpt_dir:
        store = ZonedCheckpointStore(args.ckpt_dir, n_hosts=1)
        latest = store.latest_step()
        if latest is not None:
            like = {"params": jax.tree.map(np.asarray, state.params),
                    "opt": jax.tree.map(np.asarray, state.opt),
                    "step": np.asarray(state.step)}
            restored, manifest = store.restore(latest, like)
            state = TrainState(step=jnp.asarray(restored["step"]),
                               params=jax.tree.map(jnp.asarray,
                                                   restored["params"]),
                               opt=jax.tree.map(jnp.asarray,
                                                restored["opt"]))
            data.load_state_dict(manifest["meta"]["data"])
            print(f"[train] restored step {latest} "
                  f"(modeled ckpt wall {manifest['modeled_wall_seconds']:.2f}s)")

    budget = RestartBudget()
    t0 = time.time()
    losses = []
    start_step = int(state.step)
    for i in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, next(data))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            tps = dcfg.seq_len * dcfg.global_batch * args.log_every \
                / (time.time() - t0)
            t0 = time.time()
            print(f"[train] step {i+1} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} tok/s={tps:.0f}")
        if store and (i + 1) % args.ckpt_every == 0:
            out = store.save(
                i + 1,
                {"params": jax.tree.map(np.asarray, state.params),
                 "opt": jax.tree.map(np.asarray, state.opt),
                 "step": np.asarray(state.step)},
                extra_meta={"data": data.state_dict()})
            store.gc(keep_last=2)
            print(f"[train] ckpt@{i+1} modeled_wall={out['wall_seconds']:.2f}s"
                  f" (zns append path)")
    print(f"[train] done: first-5 loss {np.mean(losses[:5]):.4f} -> "
          f"last-5 {np.mean(losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
