"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
against these.  The modality frontends are stubs per the assignment:
``vision_stub`` supplies precomputed patch embeddings, ``audio_stub``
supplies EnCodec codebook token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import models as M
from repro.models.config import ModelConfig, ShapeConfig


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training / prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.num_codebooks > 1:
        tokens = jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_stub":
        batch["frontend_inputs"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def batch_logical_axes(cfg: ModelConfig) -> dict:
    axes = {"tokens": ("batch", "seq", None) if cfg.num_codebooks > 1
            else ("batch", "seq")}
    if cfg.frontend == "vision_stub":
        axes["frontend_inputs"] = ("batch", "seq", "act_embed")
    return axes


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """serve_step inputs: cache + one new token per sequence."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.num_codebooks > 1:
        tokens = jax.ShapeDtypeStruct((b, cfg.num_codebooks), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return {
        "cache": M.cache_spec(cfg, b, s),
        "tokens": tokens,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "cache": M.cache_logical_axes(cfg),
        "tokens": ("batch", None) if cfg.num_codebooks > 1 else ("batch",),
        "pos": None,
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape)
    return decode_specs(cfg, shape)
