"""Roofline aggregation: dry-run JSONs -> three-term roofline table.

Terms (seconds per step, per chip — cost_analysis numbers are already
per-partition):

  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = collective_wire_bytes / ICI_BW

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve forward); the
ratio MODEL_FLOPS / (HLO_FLOPs x chips) measures how much compiled
compute is useful (remat recompute, attention quadratic terms, and
dispatch overheads push it below 1).

Usage: PYTHONPATH=src python -m repro.launch.roofline --in reports/dryrun
       [--fit-override reports/dryrun_fitfix] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def load_cells(dirs: list[str]) -> dict:
    cells = {}
    for d in dirs:
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(path) as f:
                r = json.load(f)
            key = (r["arch"], r["shape"], r["mesh"])
            base = cells.get(key, {})
            # later dirs override 'fit'; keep 'full' from the first seen
            merged = dict(base)
            for k, v in r.items():
                if k == "full" and "full" in merged:
                    continue
                merged[k] = v
            cells[key] = merged
    return cells


def roofline_row(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    fit = r.get("fit")
    src = fit if fit and fit.get("flops", 0) > 0 else r.get("full")
    if not src:
        return None
    chips = 1
    for v in r.get("mesh_shape", {}).values():
        chips *= v
    flops = src["flops"]
    hbytes = src["bytes_accessed"]
    if fit and "collective_wire_bytes" in fit:
        cbytes = sum(fit["collective_wire_bytes"].values())
    else:
        cbytes = r["full"]["collectives"]["total_wire_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = hbytes / HBM_BW
    t_coll = cbytes / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    bound = max(t_comp, t_mem, t_coll)
    useful = r["model_flops"] / max(flops * chips, 1.0)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "bound_s": bound,
        "model_flops": r["model_flops"],
        "hlo_flops_per_chip": flops,
        "useful_flop_ratio": useful,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        "mem_gib_per_dev": (r["full"]["memory"]["argument_bytes"]
                            + r["full"]["memory"]["temp_bytes"]) / 2**30
        if "full" in r else float("nan"),
        "source": "fit" if src is fit else "full",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="indirs", nargs="+",
                    default=["reports/dryrun"])
    ap.add_argument("--csv", default="")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load_cells(args.indirs)
    rows = []
    for key in sorted(cells):
        if key[2] != args.mesh:
            continue
        row = roofline_row(cells[key])
        if row:
            rows.append(row)
    hdr = ("arch,shape,chips,t_compute_s,t_memory_s,t_collective_s,"
           "dominant,useful_flop_ratio,roofline_fraction,mem_gib_per_dev,"
           "source")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['chips']},"
            f"{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},"
            f"{r['t_collective_s']:.4e},{r['dominant']},"
            f"{r['useful_flop_ratio']:.3f},{r['roofline_fraction']:.3f},"
            f"{r['mem_gib_per_dev']:.2f},{r['source']}")
    text = "\n".join(lines)
    print(text)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
