"""Differential oracle: greedy event-engine simulation of a cluster.

Runs the same :class:`~repro.cluster.compiler.ClusterGraph` the chain
compiler lowers, but as a classical discrete-event simulation — a
priority queue of ready events keyed ``(ready, issue, index)`` (the
chain compiler's FIFO tie-breaking), one min-heap of server free times
per FIFO resource, and the fixed DAG (flow paths, gate edges, and the
sequential-log lag edges) as precedence.  Because a completion is never
earlier than its predecessors' ready times, pop order is nondecreasing
in ``ready`` and the greedy schedule is the exact M-server FIFO
solution — the reference the compiled program must match to float
tolerance on every config whose replayed chains froze
(``order_stable``), multi-class service mixes included (see
``tests/test_cluster.py``).  The oracle is a *test oracle only*: no
production path falls back to it.

This is the "per-server Python composition loop" the cluster bench
gates against: O(n log n) Python per config, versus one vectorized
fused-fixpoint solve for the whole concatenated sweep.
"""
from __future__ import annotations

import heapq
from typing import Dict, List

import numpy as np

from .compiler import ClusterGraph, _quantize


def simulate_graph(graph: ClusterGraph) -> np.ndarray:
    """Greedy completions (us, per event) of a cluster event graph."""
    n = graph.n
    edges = graph.dag_edges()
    succs: List[List[int]] = [[] for _ in range(n)]
    indeg = np.zeros(n, dtype=np.int64)
    for a, b in edges:
        succs[a].append(int(b))
        indeg[b] += 1
    # FIFO resource membership (at most one per event in this model;
    # ordered resources are already lag edges in the DAG).
    res_of = np.full(n, -1, dtype=np.int64)
    heaps: List[List[float]] = []
    for res in graph.resources:
        if res.ordered:
            continue
        rid = len(heaps)
        heaps.append([0.0] * res.cap)
        for m in res.members:
            if res_of[m] != -1:
                raise ValueError(
                    f"event {m} belongs to two FIFO resources; the "
                    f"oracle models at most one per event")
            res_of[m] = rid
    issue, svc = graph.issue, graph.svc
    q = _quantize               # shared pop-key grid (see compiler)
    ready = issue.copy()
    comp = np.zeros(n, dtype=np.float64)
    pq = [(float(q(issue[e])), issue[e], e)
          for e in range(n) if indeg[e] == 0]
    heapq.heapify(pq)
    done = 0
    while pq:
        _key, _isu, e = heapq.heappop(pq)
        start = ready[e]
        rid = res_of[e]
        if rid != -1:
            free = heapq.heappop(heaps[rid])
            start = max(start, free)
        c = start + svc[e]
        comp[e] = c
        if rid != -1:
            heapq.heappush(heaps[rid], c)
        done += 1
        for s in succs[e]:
            ready[s] = max(ready[s], c)
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(
                    pq, (float(q(max(ready[s], issue[s]))), issue[s], s))
    if done != n:
        raise ValueError(f"cluster graph has a dependency cycle: only "
                         f"{done}/{n} events completed")
    return comp


def oracle_op_latencies(graph: ClusterGraph) -> np.ndarray:
    """Per-object-op latencies under the greedy oracle schedule."""
    from .compiler import op_latencies
    return op_latencies(graph, simulate_graph(graph))


def touched_servers(graph: ClusterGraph, op_seq: int) -> set:
    """Servers an op's shard requests touch (for the degraded-mode
    blast-radius property: EC reconstruction adds exactly m)."""
    return {sh.server for sh in graph.plans[op_seq].shards}


def per_server_event_counts(graph: ClusterGraph) -> Dict[int, int]:
    """Device-event count per server (reads + flush appends)."""
    out = {r: 0 for r in range(len(graph.servers))}
    for res in graph.resources:
        if res.label.startswith(("dev_read/r", "dev_append/r")):
            out[int(res.label.split("/r")[1])] += len(res.members)
    return out
