"""Striping and redundancy codecs: how an object becomes shards.

Two families of :class:`RedundancyScheme`:

* ``rep`` — ``k``-way striping with ``m`` extra full copies per data
  shard (``n = k * (m + 1)`` shard slots, no codec cost);
* ``ec`` — ``k + m`` systematic erasure coding (``k`` data shards plus
  ``m`` parity shards; encode on PUT, decode only on reconstruction).

The byte layout is the same for both: an object of ``B`` bytes is cut
into ``k`` logical data shards of ``ceil(B / k)`` bytes each (the last
may be short; shards are padded to the uniform size on the wire and on
flash so every service class stays homogeneous).  ``shard_ranges``
partitions ``[0, B)`` — every object byte lives in exactly one data
shard, which the property suite in ``tests/test_cluster.py`` asserts.

Example::

    >>> from repro.cluster import erasure, replication
    >>> ec = erasure(4, 2)
    >>> ec.name, ec.n_shards
    ('ec4+2', 6)
    >>> ec.shard_ranges(10)          # 10 bytes over k=4 data shards
    [(0, 3), (3, 6), (6, 9), (9, 10)]
    >>> rep = replication(2, copies=3)
    >>> rep.name, rep.n_shards       # 2 stripes x 3 copies
    ('rep3-k2', 6)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RedundancyScheme:
    """``kind="rep"``: ``m`` extra copies; ``kind="ec"``: ``m`` parity."""

    kind: str        # "rep" | "ec"
    k: int           # stripe width (logical data shards)
    m: int           # redundancy degree (extra copies / parity shards)

    def __post_init__(self):
        if self.kind not in ("rep", "ec"):
            raise ValueError(f"unknown scheme kind {self.kind!r}")
        if self.k < 1:
            raise ValueError("stripe width k must be >= 1")
        if self.m < 0:
            raise ValueError("redundancy m must be >= 0")

    @property
    def n_shards(self) -> int:
        """Physical shard slots per object (placement-map row width)."""
        if self.kind == "rep":
            return self.k * (self.m + 1)
        return self.k + self.m

    @property
    def name(self) -> str:
        if self.kind == "rep":
            return f"rep{self.m + 1}-k{self.k}"
        return f"ec{self.k}+{self.m}"

    # -- byte layout --------------------------------------------------
    def shard_bytes(self, nbytes: int) -> int:
        """Uniform (padded) per-shard size on the wire and on flash."""
        return -(-int(nbytes) // self.k) if nbytes > 0 else 0

    def shard_ranges(self, nbytes: int) -> List[Tuple[int, int]]:
        """Partition of ``[0, nbytes)`` into the k logical data shards
        (half-open byte ranges; tail shards may be empty)."""
        sb = self.shard_bytes(nbytes)
        return [(min(j * sb, nbytes), min((j + 1) * sb, nbytes))
                for j in range(self.k)]

    def shard_of_byte(self, nbytes: int, offset: int) -> int:
        """Logical data shard holding object byte ``offset``."""
        if not 0 <= offset < nbytes:
            raise ValueError(f"offset {offset} outside object [0, {nbytes})")
        return int(offset) // self.shard_bytes(nbytes)

    # -- slot geometry ------------------------------------------------
    # Slot s of the placement row holds: rep -> copy (s % (m+1)) of data
    # shard (s // (m+1)); ec -> data shard s when s < k, else parity.
    def slot_is_data(self, slot: int) -> bool:
        if self.kind == "rep":
            return slot % (self.m + 1) == 0   # canonical (primary) copy
        return slot < self.k

    def copy_slots(self, j: int) -> List[int]:
        """Slots holding (a copy of) logical data shard ``j``."""
        if self.kind == "rep":
            base = j * (self.m + 1)
            return list(range(base, base + self.m + 1))
        return [j]

    # -- request planning ---------------------------------------------
    def write_slots(self, servers, down: Optional[int] = None) -> List[int]:
        """Slots a PUT writes: all of them, minus a down server's
        (degraded writes land on the survivors at reduced durability)."""
        return [s for s in range(self.n_shards)
                if down is None or servers[s] != down]

    def read_slots(self, servers, down: Optional[int] = None
                   ) -> Tuple[List[int], bool]:
        """``(slots, decode)`` a GET reads.

        Normal mode reads the k primary data slots.  Degraded mode
        (server ``down`` holds one of them): ``rep`` fails over to the
        next surviving copy of the affected shard; ``ec`` falls back to
        a conservative full-stripe reconstruction read of every
        surviving slot (k-1 data + m parity) plus a decode — touching
        exactly ``m`` servers beyond the normal-mode set.
        """
        primary = [self.copy_slots(j)[0] for j in range(self.k)]
        if down is None or all(servers[s] != down for s in primary):
            return primary, False
        if self.kind == "rep":
            out = []
            for j in range(self.k):
                alive = [s for s in self.copy_slots(j) if servers[s] != down]
                if not alive:
                    raise ValueError(f"data shard {j} unrecoverable: every "
                                     f"copy lives on down server {down}")
                out.append(alive[0])
            return out, False
        if self.m == 0:
            raise ValueError("ec with m=0 cannot reconstruct a lost shard")
        survivors = [s for s in range(self.n_shards) if servers[s] != down]
        return survivors, True


def parse_scheme(name: str) -> RedundancyScheme:
    """Inverse of :attr:`RedundancyScheme.name`.

    >>> parse_scheme("ec4+2")
    RedundancyScheme(kind='ec', k=4, m=2)
    >>> parse_scheme("rep3-k2").name
    'rep3-k2'
    """
    s = name.strip().lower()
    try:
        if s.startswith("ec"):
            k, m = s[2:].split("+")
            return erasure(int(k), int(m))
        if s.startswith("rep"):
            copies, k = s[3:].split("-k")
            return replication(int(k), copies=int(copies))
    except (ValueError, TypeError):
        pass
    raise ValueError(
        f"unknown scheme {name!r}; expected 'ec<k>+<m>' (e.g. ec4+2) or "
        f"'rep<copies>-k<k>' (e.g. rep3-k2)")


def erasure(k: int, m: int) -> RedundancyScheme:
    """``k`` data + ``m`` parity systematic erasure code."""
    return RedundancyScheme(kind="ec", k=k, m=m)


def replication(k: int, copies: int = 2) -> RedundancyScheme:
    """``k``-way striping, each data shard stored ``copies`` times."""
    if copies < 1:
        raise ValueError("copies must be >= 1")
    return RedundancyScheme(kind="rep", k=k, m=copies - 1)
