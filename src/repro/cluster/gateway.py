"""Gateway: object PUT/GET/DELETE streams -> striped shard requests.

The gateway is the cluster's protocol head: it owns the placement map
(policy-driven, see :mod:`repro.cluster.placement`), cuts each object
op into per-server :class:`ShardOp`\\ s under the cluster's
:class:`~repro.cluster.codec.RedundancyScheme`, and charges the EC
codec cost (encode on PUT, decode on reconstruction GET).  The result
is a pure *plan* — a list of :class:`OpPlan` — consumed identically by
the chain-program compiler and the event-engine oracle, so both model
the same cluster by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import MiB

from .placement import placement_map
from .spec import OP_DELETE, OP_GET, OP_PUT, ClusterSpec, ObjectOp


@dataclasses.dataclass(frozen=True)
class ShardOp:
    """One shard-granular request from a gateway to a storage server."""

    op_seq: int         # owning object op (index into the op stream)
    slot: int           # slot in the object's placement row
    server: int
    write: bool         # True: shard write (PUT); False: shard read (GET)
    nbytes: int         # padded shard payload bytes (0 = metadata-only)


@dataclasses.dataclass(frozen=True)
class OpPlan:
    """An object op resolved to its shard fan-out + codec costs."""

    op: ObjectOp
    shards: Tuple[ShardOp, ...]
    encode_us: float    # gateway-side EC encode (PUT), 0 otherwise
    decode_us: float    # gateway-side EC reconstruction decode (GET)


class Gateway:
    """Plans object ops against a fixed placement map.

    ``down`` (a server id) switches the gateway to degraded mode:
    PUTs skip the dead server's slot, GETs fail over per the scheme
    (replica failover, or full-stripe EC reconstruction reads).
    """

    def __init__(self, spec: ClusterSpec, rows: Dict[int, np.ndarray]):
        self.spec = spec
        self.rows = rows            # object id -> placement row

    def plan(self, op: ObjectOp, *, down: Optional[int] = None) -> OpPlan:
        scheme = self.spec.scheme
        servers = self.rows[op.obj]
        sb = scheme.shard_bytes(op.nbytes)
        if op.kind == OP_PUT:
            slots = scheme.write_slots(servers, down)
            enc = (self.spec.gateway.encode_us_per_mib * op.nbytes / MiB
                   if scheme.kind == "ec" and scheme.m > 0 else 0.0)
            shards = tuple(ShardOp(op.seq, s, int(servers[s]), True, sb)
                           for s in slots)
            return OpPlan(op=op, shards=shards, encode_us=enc, decode_us=0.0)
        if op.kind == OP_GET:
            slots, decode = scheme.read_slots(servers, down)
            dec = (self.spec.gateway.decode_us_per_mib * op.nbytes / MiB
                   if decode else 0.0)
            shards = tuple(ShardOp(op.seq, s, int(servers[s]), False, sb)
                           for s in slots)
            return OpPlan(op=op, shards=shards, encode_us=0.0, decode_us=dec)
        if op.kind == OP_DELETE:
            slots = scheme.write_slots(servers, down)   # all live replicas
            shards = tuple(ShardOp(op.seq, s, int(servers[s]), True, 0)
                           for s in slots)
            return OpPlan(op=op, shards=shards, encode_us=0.0, decode_us=0.0)
        raise ValueError(f"unknown op kind {op.kind}")


def plan_workload(spec: ClusterSpec, ops: Sequence[ObjectOp], *,
                  seed: int = 0, down: Optional[int] = None) -> List[OpPlan]:
    """Placement + shard planning for a whole op stream.

    Returns one :class:`OpPlan` per op, in canonical op order.  The
    placement map is computed once over the distinct object ids, so a
    GET sees exactly the row its PUT wrote.
    """
    if down is not None and not 0 <= down < spec.n_servers:
        raise ValueError(f"down server {down} outside [0, {spec.n_servers})")
    objs = sorted({op.obj for op in ops})
    rows_arr = placement_map(objs, spec.scheme.n_shards, spec.n_servers,
                             policy=spec.placement, seed=seed)
    rows = {obj: rows_arr[i] for i, obj in enumerate(objs)}
    gw = Gateway(spec, rows)
    return [gw.plan(op, down=down) for op in ops]
