"""Capacity planning: users-per-rack at a p99 latency SLO.

The planner compiles every point of a (stripe width x redundancy scheme
x placement policy) x load-ladder x (normal | degraded) sweep to its
own :class:`~repro.core.ChainProgram`, concatenates them with
:func:`repro.core.concat_programs`, and solves the whole rack sweep in
**one** :func:`repro.core.solve_program` call.  Per-config curves are
then sliced back out, the p99-vs-load curve is interpolated against
the SLO (log-space in latency), and configurations are ranked by the
load the rack can serve inside the SLO — with a degraded-mode row
(one server down, reconstruction reads) next to every normal row.

The ladder comes in two flavours:

* ``users_ladder`` — closed-loop: each rung scales ``n_users`` and the
  figure of merit is **users-at-SLO**;
* ``rate_ladder`` — open-loop: each rung keeps the user population
  fixed but stamps Poisson arrivals (``ClusterWorkload.arrival``) at
  that offered rate (objects/s) with ``qd >= ops_per_user`` so the
  closed-loop edges vanish; the figure of merit becomes
  **arrival-rate-at-SLO**.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import PoissonArrivals, concat_programs, solve_program
from repro.core.metrics import DEFAULT_SLO_US, LatencyStats, violation_rate

from .cluster import Cluster
from .codec import RedundancyScheme
from .compiler import CompiledCluster, build_graph, compile_graph, \
    op_latencies
from .spec import ClusterSpec, ClusterWorkload


def _op_digest(graph, i: int) -> bytes:
    """Content digest of op ``i``'s event slice: stage labels and
    service times.  Two rungs map an op onto each other only when
    these agree — same stages, same service demands.  Issue times are
    deliberately excluded: a rate ladder re-stamps every arrival, yet
    the op is still the same work (and the warm solve re-derives any
    slot the new clock makes stale)."""
    s, e = graph.op_slices[i]
    h = hashlib.sha1()
    h.update("|".join(graph.labels[s:e]).encode())
    h.update(np.ascontiguousarray(graph.svc[s:e]).tobytes())
    return h.digest()


def _rung_comp0(prev_graph, prev_comp: np.ndarray, graph
                ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
    """Warm-start arrays for the next ladder rung, mapped per-op from
    the previous rung's completions: ``(cand, seed)``.

    ``cand`` joins ops on their ``(client, slot)`` identity and accepts
    a slice only when the op's content digest (:func:`_op_digest`)
    matches — the shared clients of a users-ladder rung re-draw
    identical op streams, but e.g. a GET's device-read stage can appear
    or vanish as the global interleave shifts flush timing, and
    open-loop rate ladders re-stamp every arrival.  Unmatched slots
    stay ``-inf`` (the solver's additive identity), so a partial join
    is still a usable candidate for the verified completion warm start.

    ``seed`` additionally estimates the *new* clients' slots from
    their modulo twin (client ``c % prev_n_users``, same slot, no
    digest required) so every op sits on the previous rung's time
    scale — that is what makes it a usable FIFO pop-*order* seed,
    unlike ``cand``, whose unmatched ``-inf`` slots would interleave
    bootstrap-scale events into previous-rung-scale queues.

    ``(None, None)`` when nothing matches at all."""
    if prev_graph.op_slices is None or graph.op_slices is None or \
            prev_graph.op_keys is None or graph.op_keys is None:
        return None, None
    prev_by_key = {k: i for i, k in enumerate(prev_graph.op_keys)}
    prev_users = 1 + max(c for c, _ in prev_graph.op_keys)
    comp0 = np.full(graph.n, -np.inf)
    seed = np.full(graph.n, -np.inf)
    hits = 0
    for i, (client, slot) in enumerate(graph.op_keys):
        s, e = graph.op_slices[i]
        j = prev_by_key.get((client, slot))
        if j is not None:
            ps, pe = prev_graph.op_slices[j]
            if e - s == pe - ps and _op_digest(graph, i) == \
                    _op_digest(prev_graph, j):
                comp0[s:e] = prev_comp[ps:pe]
                seed[s:e] = prev_comp[ps:pe]
                hits += 1
                continue
        j = prev_by_key.get((client % prev_users, slot))
        if j is not None:
            ps, pe = prev_graph.op_slices[j]
            if e - s == pe - ps:
                seed[s:e] = prev_comp[ps:pe]
    if not hits:
        return None, None
    return comp0, seed


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One ranked configuration: a redundancy scheme + placement."""

    scheme: RedundancyScheme
    placement: str

    @property
    def name(self) -> str:
        return f"{self.scheme.name}/{self.placement}"


@dataclasses.dataclass(frozen=True)
class CapacityPoint:
    """One solved sweep point (a config at one load-ladder rung).

    ``offered_rate`` is the open-loop arrival rate (objects/s) of a
    ``rate_ladder`` rung; ``None`` on closed-loop (users-ladder) points.
    """

    users: int
    objects_per_sec: float
    lat: LatencyStats
    slo_violation_rate: float
    converged: bool
    offered_rate: Optional[float] = None

    def to_json(self) -> Dict[str, float]:
        out = {"users": self.users,
               "objects_per_sec": self.objects_per_sec,
               "p50_us": self.lat.p50_us, "p99_us": self.lat.p99_us,
               "p999_us": self.lat.p999_us,
               "slo_violation_rate": self.slo_violation_rate,
               "converged": self.converged}
        if self.offered_rate is not None:
            out["offered_rate"] = self.offered_rate
        return out


@dataclasses.dataclass(frozen=True)
class CapacityCurve:
    """The p99-vs-load curve of one (config, mode).

    ``rate_at_slo`` (objects/s) is set on open-loop (``rate_ladder``)
    sweeps and becomes the ranking key; ``users_at_slo`` keeps its
    closed-loop meaning otherwise.
    """

    config: ClusterConfig
    degraded: bool
    points: Tuple[CapacityPoint, ...]
    users_at_slo: float
    rate_at_slo: Optional[float] = None

    @property
    def load_at_slo(self) -> float:
        """The curve's figure of merit: offered rate at the SLO when
        open-loop, users at the SLO otherwise."""
        return self.rate_at_slo if self.rate_at_slo is not None \
            else self.users_at_slo

    def to_json(self) -> Dict:
        out = {"config": self.config.name, "degraded": self.degraded,
               "users_at_slo": self.users_at_slo,
               "points": [p.to_json() for p in self.points]}
        if self.rate_at_slo is not None:
            out["rate_at_slo"] = self.rate_at_slo
        return out


@dataclasses.dataclass
class CapacityReport:
    """Every curve of a rack sweep + the one-call solve's metadata."""

    curves: List[CapacityCurve]
    slo_us: float
    n_programs: int
    n_events: int
    sweeps_used: int
    converged: bool
    #: Config names whose pop-order refinement exhausted its budget
    #: (``order_stable=False``) — their curves are still reported, but
    #: the underlying programs are approximate, not exact.
    order_unstable: Tuple[str, ...] = ()
    #: Warm-ladder telemetry: rung compiles whose previous-rung warm
    #: start survived the tightness verification / rungs where a warm
    #: start was attempted (0/0 when ``warm_ladder=False``).
    warm_hits: int = 0
    warm_attempts: int = 0

    def ranking(self) -> List[CapacityCurve]:
        """Normal-mode curves, best (most load inside SLO) first —
        offered rate on open-loop sweeps, users otherwise."""
        normal = [c for c in self.curves if not c.degraded]
        return sorted(normal, key=lambda c: -c.load_at_slo)

    def degraded_curve(self, config: ClusterConfig
                       ) -> Optional[CapacityCurve]:
        for c in self.curves:
            if c.degraded and c.config == config:
                return c
        return None

    def to_json(self) -> Dict:
        return {"slo_us": self.slo_us, "n_programs": self.n_programs,
                "n_events": self.n_events, "sweeps_used": self.sweeps_used,
                "converged": self.converged,
                "order_unstable": list(self.order_unstable),
                "warm_hits": self.warm_hits,
                "warm_attempts": self.warm_attempts,
                "curves": [c.to_json() for c in self.curves]}


def _load_at_slo(loads: Sequence[float], p99s: Sequence[float],
                 slo_us: float) -> float:
    """Largest load whose p99 stays inside the SLO, interpolating
    (log-space in latency) between the ladder rungs that straddle it.

    0.0 when even the smallest rung violates; the top rung's load when
    no rung violates (the rack wasn't driven to the SLO).
    """
    if not len(loads):
        return 0.0
    p99 = np.asarray(p99s, dtype=np.float64)
    load = np.asarray(loads, dtype=np.float64)
    over = np.nonzero(p99 > slo_us)[0]
    if len(over) == 0:
        return float(load[-1])
    i = int(over[0])
    if i == 0:
        return 0.0
    lo, hi = p99[i - 1], p99[i]
    if not (hi > lo > 0.0):
        return float(load[i - 1])
    frac = (np.log(slo_us) - np.log(lo)) / (np.log(hi) - np.log(lo))
    return float(load[i - 1] + frac * (load[i] - load[i - 1]))


def users_at_slo(points: Sequence[CapacityPoint], slo_us: float) -> float:
    """Closed-loop figure of merit: user count at the p99 SLO."""
    return _load_at_slo([float(p.users) for p in points],
                        [p.lat.p99_us for p in points], slo_us)


def rate_at_slo(points: Sequence[CapacityPoint], slo_us: float
                ) -> Optional[float]:
    """Open-loop figure of merit: offered arrival rate (objects/s) at
    the p99 SLO; ``None`` unless every point carries an offered rate."""
    if not points or any(p.offered_rate is None for p in points):
        return None
    return _load_at_slo([float(p.offered_rate) for p in points],
                        [p.lat.p99_us for p in points], slo_us)


def _can_degrade(scheme: RedundancyScheme) -> bool:
    return scheme.m >= 1


def plan_capacity(configs: Sequence[ClusterConfig],
                  users_ladder: Sequence[int], *,
                  base_spec: Optional[ClusterSpec] = None,
                  workload: Optional[ClusterWorkload] = None,
                  slo_us: float = DEFAULT_SLO_US,
                  rate_ladder: Optional[Sequence[float]] = None,
                  degraded: bool = True, down_server: int = 0,
                  sweeps: int = 512, fixpoint: str = "loop",
                  scan_backend: str = "auto",
                  max_refine: Optional[int] = None,
                  warm_ladder: bool = False) -> CapacityReport:
    """Compile the whole sweep, solve it as ONE fleet-level program,
    and slice the capacity curves back out.

    ``rate_ladder`` switches the sweep to open-loop offered load: each
    rung keeps the workload's user population but stamps Poisson
    arrivals at that rate (objects/s, ``qd`` raised to ``ops_per_user``
    so the closed-loop edges vanish), ``users_ladder`` is ignored, and
    curves rank by :func:`rate_at_slo` instead of :func:`users_at_slo`.

    ``warm_ladder=True`` threads each rung's completions into the next
    rung's refined solves as ``comp0`` (ops joined per ``(client,
    slot)`` key when their content digests match), seeds the FIFO
    pop-order refinement from the previous rung's orders, and — on
    rate ladders, whose rungs share their entire structure — reuses
    the previous rung's graph with the new arrival clock re-stamped
    instead of rebuilding placement and shard planning from scratch.
    Rung monotonicity is not assumed: the warm solve only sticks when
    the tightness verification proves it equal to the cold result (see
    :func:`repro.cluster.compiler.compile_graph`), so the report is
    identical either way — ``warm_hits`` / ``warm_attempts`` expose
    how often the shortcut landed.  Rate ladders pay best (graph reuse
    plus order carry-over); users ladders rebuild each rung's graph
    and warm only the solves.
    """
    base_spec = base_spec if base_spec is not None else ClusterSpec()
    workload = workload if workload is not None else ClusterWorkload()
    open_loop = rate_ladder is not None
    rungs = [float(r) for r in rate_ladder] if open_loop \
        else [int(u) for u in users_ladder]
    entries: List[Tuple[ClusterConfig, bool, int, Optional[float],
                        CompiledCluster]] = []
    warm_hits = warm_attempts = 0
    for cfg in configs:
        spec = dataclasses.replace(base_spec, scheme=cfg.scheme,
                                   placement=cfg.placement)
        modes = [None] + ([down_server] if degraded
                          and _can_degrade(cfg.scheme) else [])
        for down in modes:
            prev: Optional[Tuple[object, np.ndarray, object]] = None
            # Open-loop rungs thread best top-down: a sparser Poisson
            # clock (lower rate, same seed) only stretches issue times,
            # so the *higher*-rate rung's completions are lower bounds
            # for the next rung almost everywhere.  Curve points are
            # re-sorted by load afterwards, so rung order is free.
            sweep_rungs = sorted(rungs, reverse=True) \
                if warm_ladder and open_loop else rungs
            for rung in sweep_rungs:
                if open_loop:
                    wl = dataclasses.replace(
                        workload,
                        arrival=PoissonArrivals(rate_per_s=float(rung),
                                                seed=workload.seed),
                        qd=max(workload.qd, workload.ops_per_user))
                    users, rate = workload.n_users, float(rung)
                else:
                    wl = dataclasses.replace(workload, n_users=int(rung))
                    users, rate = int(rung), None
                kw = {} if max_refine is None else {"max_refine": max_refine}
                if warm_ladder:
                    chains0 = None
                    if open_loop and prev is not None:
                        # Rate rungs share their entire structure: the
                        # op mix is drawn before the clock is stamped
                        # and placement/shard planning never read issue
                        # times.  Reuse the previous rung's graph with
                        # the new arrival clock re-stamped on the op
                        # heads instead of rebuilding it.
                        times = wl.arrival.issue_times(
                            wl.n_users * wl.ops_per_user,
                            size=wl.object_bytes)
                        issue = prev[0].issue.copy()
                        issue[prev[0].op_head] = times
                        graph = dataclasses.replace(prev[0], issue=issue)
                        # Identical slot indexing: the previous rung's
                        # replayed pop orders are a valid first iterate.
                        chains0 = prev[2]
                    else:
                        ops = wl.build(spec.n_gateways)
                        graph = build_graph(spec, ops, qd=wl.qd,
                                            down=down, seed=wl.seed)
                    comp0, seed = (None, None) if prev is None else \
                        _rung_comp0(prev[0], prev[1], graph)
                    warm_attempts += comp0 is not None
                    compiled = compile_graph(
                        graph, sweeps=sweeps, fixpoint=fixpoint,
                        scan_backend=scan_backend, comp0=comp0,
                        order_seed=seed, chains0=chains0, **kw)
                    warm_hits += compiled.warm_start_used
                    prev = (graph, compiled.comp, compiled.fifo_chains)
                else:
                    compiled = Cluster(spec).compile(
                        wl, down=down, sweeps=sweeps, fixpoint=fixpoint,
                        scan_backend=scan_backend, **kw)
                entries.append((cfg, down is not None, users, rate,
                                compiled))

    # ONE fleet-level call over every config x rung x mode.  The
    # per-entry fixpoints found during compilation are exact lower
    # bounds of the concatenated program, so they seed the fleet solve
    # (comp0) and it converges in one verification sweep.
    program = concat_programs([c.program for *_, c in entries])
    svc = np.concatenate([c.graph.svc for *_, c in entries])
    comp, used, converged = solve_program(
        program, svc, sweeps=sweeps, fixpoint=fixpoint,
        scan_backend=scan_backend, warn=False,
        comp0=np.concatenate([c.comp for *_, c in entries]))

    curves: List[CapacityCurve] = []
    off = 0
    by_key: Dict[Tuple[str, bool], List[CapacityPoint]] = {}
    key_cfg: Dict[Tuple[str, bool], ClusterConfig] = {}
    for cfg, is_degraded, users, rate, compiled in entries:
        g = compiled.graph
        sl = comp[off:off + g.n]
        off += g.n
        lats = op_latencies(g, sl)
        span = float(sl.max()) if len(sl) else 0.0
        point = CapacityPoint(
            users=users,
            objects_per_sec=len(lats) / span * 1e6 if span > 0 else 0.0,
            lat=LatencyStats.from_samples(lats),
            slo_violation_rate=violation_rate(lats, slo_us),
            converged=bool(converged and compiled.converged),
            offered_rate=rate)
        key = (cfg.name, is_degraded)
        by_key.setdefault(key, []).append(point)
        key_cfg[key] = cfg
    for key, points in by_key.items():
        points = sorted(points, key=lambda p: (
            p.offered_rate if p.offered_rate is not None else p.users))
        curves.append(CapacityCurve(
            config=key_cfg[key], degraded=key[1], points=tuple(points),
            users_at_slo=users_at_slo(points, slo_us),
            rate_at_slo=rate_at_slo(points, slo_us)))
    unstable = tuple(sorted({
        cfg.name for cfg, *_, c in entries
        if not c.program.order_stable}))
    return CapacityReport(
        curves=curves, slo_us=slo_us, n_programs=len(entries),
        n_events=program.n_flat, sweeps_used=used,
        converged=bool(converged) and all(
            c.converged for *_, c in entries),
        order_unstable=unstable,
        warm_hits=int(warm_hits), warm_attempts=int(warm_attempts))
