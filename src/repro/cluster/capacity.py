"""Capacity planning: users-per-rack at a p99 latency SLO.

The planner compiles every point of a (stripe width x redundancy scheme
x placement policy) x load-ladder x (normal | degraded) sweep to its
own :class:`~repro.core.ChainProgram`, concatenates them with
:func:`repro.core.concat_programs`, and solves the whole rack sweep in
**one** :func:`repro.core.solve_program` call.  Per-config curves are
then sliced back out, the p99-vs-load curve is interpolated against
the SLO (log-space in latency), and configurations are ranked by the
load the rack can serve inside the SLO — with a degraded-mode row
(one server down, reconstruction reads) next to every normal row.

The ladder comes in two flavours:

* ``users_ladder`` — closed-loop: each rung scales ``n_users`` and the
  figure of merit is **users-at-SLO**;
* ``rate_ladder`` — open-loop: each rung keeps the user population
  fixed but stamps Poisson arrivals (``ClusterWorkload.arrival``) at
  that offered rate (objects/s) with ``qd >= ops_per_user`` so the
  closed-loop edges vanish; the figure of merit becomes
  **arrival-rate-at-SLO**.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import PoissonArrivals, concat_programs, solve_program
from repro.core.metrics import DEFAULT_SLO_US, LatencyStats, violation_rate

from .cluster import Cluster
from .codec import RedundancyScheme
from .compiler import CompiledCluster, op_latencies
from .spec import ClusterSpec, ClusterWorkload


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One ranked configuration: a redundancy scheme + placement."""

    scheme: RedundancyScheme
    placement: str

    @property
    def name(self) -> str:
        return f"{self.scheme.name}/{self.placement}"


@dataclasses.dataclass(frozen=True)
class CapacityPoint:
    """One solved sweep point (a config at one load-ladder rung).

    ``offered_rate`` is the open-loop arrival rate (objects/s) of a
    ``rate_ladder`` rung; ``None`` on closed-loop (users-ladder) points.
    """

    users: int
    objects_per_sec: float
    lat: LatencyStats
    slo_violation_rate: float
    converged: bool
    offered_rate: Optional[float] = None

    def to_json(self) -> Dict[str, float]:
        out = {"users": self.users,
               "objects_per_sec": self.objects_per_sec,
               "p50_us": self.lat.p50_us, "p99_us": self.lat.p99_us,
               "p999_us": self.lat.p999_us,
               "slo_violation_rate": self.slo_violation_rate,
               "converged": self.converged}
        if self.offered_rate is not None:
            out["offered_rate"] = self.offered_rate
        return out


@dataclasses.dataclass(frozen=True)
class CapacityCurve:
    """The p99-vs-load curve of one (config, mode).

    ``rate_at_slo`` (objects/s) is set on open-loop (``rate_ladder``)
    sweeps and becomes the ranking key; ``users_at_slo`` keeps its
    closed-loop meaning otherwise.
    """

    config: ClusterConfig
    degraded: bool
    points: Tuple[CapacityPoint, ...]
    users_at_slo: float
    rate_at_slo: Optional[float] = None

    @property
    def load_at_slo(self) -> float:
        """The curve's figure of merit: offered rate at the SLO when
        open-loop, users at the SLO otherwise."""
        return self.rate_at_slo if self.rate_at_slo is not None \
            else self.users_at_slo

    def to_json(self) -> Dict:
        out = {"config": self.config.name, "degraded": self.degraded,
               "users_at_slo": self.users_at_slo,
               "points": [p.to_json() for p in self.points]}
        if self.rate_at_slo is not None:
            out["rate_at_slo"] = self.rate_at_slo
        return out


@dataclasses.dataclass
class CapacityReport:
    """Every curve of a rack sweep + the one-call solve's metadata."""

    curves: List[CapacityCurve]
    slo_us: float
    n_programs: int
    n_events: int
    sweeps_used: int
    converged: bool
    #: Config names whose pop-order refinement exhausted its budget
    #: (``order_stable=False``) — their curves are still reported, but
    #: the underlying programs are approximate, not exact.
    order_unstable: Tuple[str, ...] = ()

    def ranking(self) -> List[CapacityCurve]:
        """Normal-mode curves, best (most load inside SLO) first —
        offered rate on open-loop sweeps, users otherwise."""
        normal = [c for c in self.curves if not c.degraded]
        return sorted(normal, key=lambda c: -c.load_at_slo)

    def degraded_curve(self, config: ClusterConfig
                       ) -> Optional[CapacityCurve]:
        for c in self.curves:
            if c.degraded and c.config == config:
                return c
        return None

    def to_json(self) -> Dict:
        return {"slo_us": self.slo_us, "n_programs": self.n_programs,
                "n_events": self.n_events, "sweeps_used": self.sweeps_used,
                "converged": self.converged,
                "order_unstable": list(self.order_unstable),
                "curves": [c.to_json() for c in self.curves]}


def _load_at_slo(loads: Sequence[float], p99s: Sequence[float],
                 slo_us: float) -> float:
    """Largest load whose p99 stays inside the SLO, interpolating
    (log-space in latency) between the ladder rungs that straddle it.

    0.0 when even the smallest rung violates; the top rung's load when
    no rung violates (the rack wasn't driven to the SLO).
    """
    if not len(loads):
        return 0.0
    p99 = np.asarray(p99s, dtype=np.float64)
    load = np.asarray(loads, dtype=np.float64)
    over = np.nonzero(p99 > slo_us)[0]
    if len(over) == 0:
        return float(load[-1])
    i = int(over[0])
    if i == 0:
        return 0.0
    lo, hi = p99[i - 1], p99[i]
    if not (hi > lo > 0.0):
        return float(load[i - 1])
    frac = (np.log(slo_us) - np.log(lo)) / (np.log(hi) - np.log(lo))
    return float(load[i - 1] + frac * (load[i] - load[i - 1]))


def users_at_slo(points: Sequence[CapacityPoint], slo_us: float) -> float:
    """Closed-loop figure of merit: user count at the p99 SLO."""
    return _load_at_slo([float(p.users) for p in points],
                        [p.lat.p99_us for p in points], slo_us)


def rate_at_slo(points: Sequence[CapacityPoint], slo_us: float
                ) -> Optional[float]:
    """Open-loop figure of merit: offered arrival rate (objects/s) at
    the p99 SLO; ``None`` unless every point carries an offered rate."""
    if not points or any(p.offered_rate is None for p in points):
        return None
    return _load_at_slo([float(p.offered_rate) for p in points],
                        [p.lat.p99_us for p in points], slo_us)


def _can_degrade(scheme: RedundancyScheme) -> bool:
    return scheme.m >= 1


def plan_capacity(configs: Sequence[ClusterConfig],
                  users_ladder: Sequence[int], *,
                  base_spec: Optional[ClusterSpec] = None,
                  workload: Optional[ClusterWorkload] = None,
                  slo_us: float = DEFAULT_SLO_US,
                  rate_ladder: Optional[Sequence[float]] = None,
                  degraded: bool = True, down_server: int = 0,
                  sweeps: int = 512, fixpoint: str = "loop",
                  scan_backend: str = "auto",
                  max_refine: Optional[int] = None) -> CapacityReport:
    """Compile the whole sweep, solve it as ONE fleet-level program,
    and slice the capacity curves back out.

    ``rate_ladder`` switches the sweep to open-loop offered load: each
    rung keeps the workload's user population but stamps Poisson
    arrivals at that rate (objects/s, ``qd`` raised to ``ops_per_user``
    so the closed-loop edges vanish), ``users_ladder`` is ignored, and
    curves rank by :func:`rate_at_slo` instead of :func:`users_at_slo`.
    """
    base_spec = base_spec if base_spec is not None else ClusterSpec()
    workload = workload if workload is not None else ClusterWorkload()
    open_loop = rate_ladder is not None
    rungs = [float(r) for r in rate_ladder] if open_loop \
        else [int(u) for u in users_ladder]
    entries: List[Tuple[ClusterConfig, bool, int, Optional[float],
                        CompiledCluster]] = []
    for cfg in configs:
        spec = dataclasses.replace(base_spec, scheme=cfg.scheme,
                                   placement=cfg.placement)
        modes = [None] + ([down_server] if degraded
                          and _can_degrade(cfg.scheme) else [])
        for down in modes:
            for rung in rungs:
                if open_loop:
                    wl = dataclasses.replace(
                        workload,
                        arrival=PoissonArrivals(rate_per_s=float(rung),
                                                seed=workload.seed),
                        qd=max(workload.qd, workload.ops_per_user))
                    users, rate = workload.n_users, float(rung)
                else:
                    wl = dataclasses.replace(workload, n_users=int(rung))
                    users, rate = int(rung), None
                kw = {} if max_refine is None else {"max_refine": max_refine}
                compiled = Cluster(spec).compile(
                    wl, down=down, sweeps=sweeps, fixpoint=fixpoint,
                    scan_backend=scan_backend, **kw)
                entries.append((cfg, down is not None, users, rate,
                                compiled))

    # ONE fleet-level call over every config x rung x mode.  The
    # per-entry fixpoints found during compilation are exact lower
    # bounds of the concatenated program, so they seed the fleet solve
    # (comp0) and it converges in one verification sweep.
    program = concat_programs([c.program for *_, c in entries])
    svc = np.concatenate([c.graph.svc for *_, c in entries])
    comp, used, converged = solve_program(
        program, svc, sweeps=sweeps, fixpoint=fixpoint,
        scan_backend=scan_backend, warn=False,
        comp0=np.concatenate([c.comp for *_, c in entries]))

    curves: List[CapacityCurve] = []
    off = 0
    by_key: Dict[Tuple[str, bool], List[CapacityPoint]] = {}
    key_cfg: Dict[Tuple[str, bool], ClusterConfig] = {}
    for cfg, is_degraded, users, rate, compiled in entries:
        g = compiled.graph
        sl = comp[off:off + g.n]
        off += g.n
        lats = op_latencies(g, sl)
        span = float(sl.max()) if len(sl) else 0.0
        point = CapacityPoint(
            users=users,
            objects_per_sec=len(lats) / span * 1e6 if span > 0 else 0.0,
            lat=LatencyStats.from_samples(lats),
            slo_violation_rate=violation_rate(lats, slo_us),
            converged=bool(converged and compiled.converged),
            offered_rate=rate)
        key = (cfg.name, is_degraded)
        by_key.setdefault(key, []).append(point)
        key_cfg[key] = cfg
    for key, points in by_key.items():
        points = sorted(points, key=lambda p: (
            p.offered_rate if p.offered_rate is not None else p.users))
        curves.append(CapacityCurve(
            config=key_cfg[key], degraded=key[1], points=tuple(points),
            users_at_slo=users_at_slo(points, slo_us),
            rate_at_slo=rate_at_slo(points, slo_us)))
    unstable = tuple(sorted({
        cfg.name for cfg, *_, c in entries
        if not c.program.order_stable}))
    return CapacityReport(
        curves=curves, slo_us=slo_us, n_programs=len(entries),
        n_events=program.n_flat, sweeps_used=used,
        converged=bool(converged) and all(
            c.converged for *_, c in entries),
        order_unstable=unstable)
