"""Capacity planning: users-per-rack at a p99 latency SLO.

The planner compiles every point of a (stripe width x redundancy scheme
x placement policy) x users-ladder x (normal | degraded) sweep to its
own :class:`~repro.core.ChainProgram`, concatenates them with
:func:`repro.core.concat_programs`, and solves the whole rack sweep in
**one** :func:`repro.core.solve_program` call.  Per-config curves are
then sliced back out, the p99-vs-users curve is interpolated against
the SLO (log-space in latency), and configurations are ranked by the
user count the rack can serve inside the SLO — with a degraded-mode
row (one server down, reconstruction reads) next to every normal row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import concat_programs, solve_program
from repro.core.metrics import DEFAULT_SLO_US, LatencyStats, violation_rate

from .cluster import Cluster
from .codec import RedundancyScheme
from .compiler import CompiledCluster, op_latencies
from .spec import ClusterSpec, ClusterWorkload


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """One ranked configuration: a redundancy scheme + placement."""

    scheme: RedundancyScheme
    placement: str

    @property
    def name(self) -> str:
        return f"{self.scheme.name}/{self.placement}"


@dataclasses.dataclass(frozen=True)
class CapacityPoint:
    """One solved sweep point (a config at one users-ladder rung)."""

    users: int
    objects_per_sec: float
    lat: LatencyStats
    slo_violation_rate: float
    converged: bool

    def to_json(self) -> Dict[str, float]:
        return {"users": self.users,
                "objects_per_sec": self.objects_per_sec,
                "p50_us": self.lat.p50_us, "p99_us": self.lat.p99_us,
                "p999_us": self.lat.p999_us,
                "slo_violation_rate": self.slo_violation_rate,
                "converged": self.converged}


@dataclasses.dataclass(frozen=True)
class CapacityCurve:
    """The p99-vs-users curve of one (config, mode)."""

    config: ClusterConfig
    degraded: bool
    points: Tuple[CapacityPoint, ...]
    users_at_slo: float

    def to_json(self) -> Dict:
        return {"config": self.config.name, "degraded": self.degraded,
                "users_at_slo": self.users_at_slo,
                "points": [p.to_json() for p in self.points]}


@dataclasses.dataclass
class CapacityReport:
    """Every curve of a rack sweep + the one-call solve's metadata."""

    curves: List[CapacityCurve]
    slo_us: float
    n_programs: int
    n_events: int
    sweeps_used: int
    converged: bool
    #: Config names whose pop-order refinement exhausted its budget
    #: (``order_stable=False``) — their curves are still reported, but
    #: the underlying programs are approximate, not exact.
    order_unstable: Tuple[str, ...] = ()

    def ranking(self) -> List[CapacityCurve]:
        """Normal-mode curves, best (most users inside SLO) first."""
        normal = [c for c in self.curves if not c.degraded]
        return sorted(normal, key=lambda c: -c.users_at_slo)

    def degraded_curve(self, config: ClusterConfig
                       ) -> Optional[CapacityCurve]:
        for c in self.curves:
            if c.degraded and c.config == config:
                return c
        return None

    def to_json(self) -> Dict:
        return {"slo_us": self.slo_us, "n_programs": self.n_programs,
                "n_events": self.n_events, "sweeps_used": self.sweeps_used,
                "converged": self.converged,
                "order_unstable": list(self.order_unstable),
                "curves": [c.to_json() for c in self.curves]}


def users_at_slo(points: Sequence[CapacityPoint], slo_us: float) -> float:
    """Largest user count whose p99 stays inside the SLO, interpolating
    (log-space in latency) between the ladder rungs that straddle it.

    0.0 when even the smallest rung violates; the top rung's user count
    when no rung violates (the rack wasn't driven to the SLO).
    """
    if not points:
        return 0.0
    p99 = np.asarray([p.lat.p99_us for p in points])
    users = np.asarray([float(p.users) for p in points])
    over = np.nonzero(p99 > slo_us)[0]
    if len(over) == 0:
        return float(users[-1])
    i = int(over[0])
    if i == 0:
        return 0.0
    lo, hi = p99[i - 1], p99[i]
    if not (hi > lo > 0.0):
        return float(users[i - 1])
    frac = (np.log(slo_us) - np.log(lo)) / (np.log(hi) - np.log(lo))
    return float(users[i - 1] + frac * (users[i] - users[i - 1]))


def _can_degrade(scheme: RedundancyScheme) -> bool:
    return scheme.m >= 1


def plan_capacity(configs: Sequence[ClusterConfig],
                  users_ladder: Sequence[int], *,
                  base_spec: Optional[ClusterSpec] = None,
                  workload: Optional[ClusterWorkload] = None,
                  slo_us: float = DEFAULT_SLO_US,
                  degraded: bool = True, down_server: int = 0,
                  sweeps: int = 512, fixpoint: str = "loop",
                  scan_backend: str = "auto",
                  max_refine: Optional[int] = None) -> CapacityReport:
    """Compile the whole sweep, solve it as ONE fleet-level program,
    and slice the capacity curves back out."""
    base_spec = base_spec if base_spec is not None else ClusterSpec()
    workload = workload if workload is not None else ClusterWorkload()
    entries: List[Tuple[ClusterConfig, bool, int, CompiledCluster]] = []
    for cfg in configs:
        spec = dataclasses.replace(base_spec, scheme=cfg.scheme,
                                   placement=cfg.placement)
        modes = [None] + ([down_server] if degraded
                          and _can_degrade(cfg.scheme) else [])
        for down in modes:
            for users in users_ladder:
                wl = dataclasses.replace(workload, n_users=int(users))
                kw = {} if max_refine is None else {"max_refine": max_refine}
                compiled = Cluster(spec).compile(
                    wl, down=down, sweeps=sweeps, fixpoint=fixpoint,
                    scan_backend=scan_backend, **kw)
                entries.append((cfg, down is not None, int(users), compiled))

    # ONE fleet-level call over every config x rung x mode.  The
    # per-entry fixpoints found during compilation are exact lower
    # bounds of the concatenated program, so they seed the fleet solve
    # (comp0) and it converges in one verification sweep.
    program = concat_programs([c.program for _, _, _, c in entries])
    svc = np.concatenate([c.graph.svc for _, _, _, c in entries])
    comp, used, converged = solve_program(
        program, svc, sweeps=sweeps, fixpoint=fixpoint,
        scan_backend=scan_backend, warn=False,
        comp0=np.concatenate([c.comp for _, _, _, c in entries]))

    curves: List[CapacityCurve] = []
    off = 0
    by_key: Dict[Tuple[str, bool], List[CapacityPoint]] = {}
    key_cfg: Dict[Tuple[str, bool], ClusterConfig] = {}
    for cfg, is_degraded, users, compiled in entries:
        g = compiled.graph
        sl = comp[off:off + g.n]
        off += g.n
        lats = op_latencies(g, sl)
        span = float(sl.max()) if len(sl) else 0.0
        point = CapacityPoint(
            users=users,
            objects_per_sec=len(lats) / span * 1e6 if span > 0 else 0.0,
            lat=LatencyStats.from_samples(lats),
            slo_violation_rate=violation_rate(lats, slo_us),
            converged=bool(converged and compiled.converged))
        key = (cfg.name, is_degraded)
        by_key.setdefault(key, []).append(point)
        key_cfg[key] = cfg
    for key, points in by_key.items():
        points = sorted(points, key=lambda p: p.users)
        curves.append(CapacityCurve(
            config=key_cfg[key], degraded=key[1], points=tuple(points),
            users_at_slo=users_at_slo(points, slo_us)))
    unstable = tuple(sorted({
        cfg.name for cfg, _, _, c in entries
        if not c.program.order_stable}))
    return CapacityReport(
        curves=curves, slo_us=slo_us, n_programs=len(entries),
        n_events=program.n_flat, sweeps_used=used,
        converged=bool(converged) and all(
            c.converged for _, _, _, c in entries),
        order_unstable=unstable)
