"""Cluster topology + workload declarations.

A cluster is ``n_gateways`` protocol gateways in front of ``n_servers``
storage servers, each server backed by one ZNS device through the host
layer's :class:`repro.host.LogStructuredVolume`.  Every knob the cluster
compiler consumes lives in one frozen :class:`ClusterSpec` so compiled
programs are deterministic in ``(spec, workload, degraded_server)``.

Latency building blocks (all microseconds):

* NIC serialization — ``nbytes * wire_overhead`` over a full-duplex
  link (independent tx/rx lanes, capacity 1 each);
* one-way network latency — a pure-delay hop (infinite parallelism);
* CPU stages — a fixed per-request cost on a ``cpu_cores``-wide pool
  (homogeneous by construction so the compiled pool chains stay inside
  the chain-program exactness envelope; erasure-coding encode/decode
  costs are charged on dedicated no-pool events instead);
* the device itself — the calibrated :mod:`repro.core` latency model,
  via each server's log-structured volume.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import ArrivalProcess, KiB, MiB, ZNSDeviceSpec

from .codec import RedundancyScheme, erasure

#: Per-server device geometry: ZN540 ratios (cap < size, 14 open/active)
#: at 1/32 zone scale, mirroring ``repro.host.HOST_SCENARIO_SPEC`` so a
#: 16-server rack stays cheap to simulate on either backend.
CLUSTER_DEVICE_SPEC = ZNSDeviceSpec(
    name="ZN540-cluster-1/32",
    zone_size_bytes=64 * MiB, zone_cap_bytes=48 * MiB, num_zones=64,
    max_open_zones=14, max_active_zones=14)


def _wire_us(nbytes: float, gbps: float, overhead: float) -> float:
    # bytes -> us at `gbps` line rate: nbytes * 8 bits / (gbps * 1e3 bits/us)
    return float(nbytes) * overhead * 8.0e-3 / float(gbps)


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """NIC + fabric model shared by every hop in the cluster."""

    gw_nic_gbps: float = 100.0      # gateway NIC line rate
    srv_nic_gbps: float = 25.0      # storage-server NIC line rate
    one_way_us: float = 5.0         # fabric latency per direction
    wire_overhead: float = 1.05     # framing/headers on payload bytes
    req_bytes: int = 4 * KiB        # request/ack control-message size

    def gw_tx_us(self, nbytes: float) -> float:
        return _wire_us(nbytes, self.gw_nic_gbps, self.wire_overhead)

    def srv_tx_us(self, nbytes: float) -> float:
        return _wire_us(nbytes, self.srv_nic_gbps, self.wire_overhead)


@dataclasses.dataclass(frozen=True)
class GatewaySpec:
    """Gateway service stages (request parsing, striping, EC codec)."""

    cpu_cores: int = 2
    cpu_us: float = 15.0            # per-op request handling (all op kinds)
    encode_us_per_mib: float = 20.0  # EC encode, charged per object MiB
    decode_us_per_mib: float = 40.0  # EC reconstruct-decode, per object MiB


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Storage-server service stages + writeback buffer."""

    cpu_cores: int = 2
    cpu_us: float = 10.0            # per-shard request handling (all kinds)
    writeback_bytes: int = 32 * MiB  # buffer capacity (inserts stall when full)
    flush_chunk: int = 1 * MiB      # device append granularity of the flusher
    flush_qd: int = 4               # flusher queue depth (lag-qd append chain)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """One rack: gateways, servers, the fabric, and the redundancy plan.

    ``durability`` selects the PUT acknowledgement point:
    ``"writeback"`` acks once the shard is in the server's buffer (the
    flush to flash is asynchronous but still backpressures through the
    buffer-capacity gate); ``"write-through"`` acks only after the
    device append covering the shard's bytes completes.
    """

    n_gateways: int = 2
    n_servers: int = 8
    scheme: RedundancyScheme = erasure(4, 2)
    placement: str = "round-robin"
    network: NetworkSpec = NetworkSpec()
    gateway: GatewaySpec = GatewaySpec()
    server: ServerSpec = ServerSpec()
    device_spec: ZNSDeviceSpec = CLUSTER_DEVICE_SPEC
    durability: str = "writeback"

    def __post_init__(self):
        if self.n_gateways < 1 or self.n_servers < 1:
            raise ValueError("cluster needs >= 1 gateway and >= 1 server")
        if self.scheme.n_shards > self.n_servers:
            raise ValueError(
                f"scheme {self.scheme.name} places {self.scheme.n_shards} "
                f"shards but the cluster has only {self.n_servers} servers")
        if self.durability not in ("writeback", "write-through"):
            raise ValueError(f"unknown durability {self.durability!r}; "
                             f"expected writeback | write-through")
        if self.server.writeback_bytes < 2 * self.server.flush_chunk:
            raise ValueError("writeback buffer must hold >= 2 flush chunks")


# ---------------------------------------------------------------------------
# Workload: closed-loop object op streams
# ---------------------------------------------------------------------------
#: Object-op kinds (compiler-internal integer coding).
OP_PUT, OP_GET, OP_DELETE = 0, 1, 2
OP_NAMES = ("put", "get", "delete")


@dataclasses.dataclass(frozen=True)
class ObjectOp:
    """One client-issued object operation."""

    seq: int            # global op index (canonical order)
    client: int
    gateway: int
    kind: int           # OP_PUT | OP_GET | OP_DELETE
    obj: int            # global object id
    nbytes: int
    issue: float        # earliest issue time (us); closed loop gates the rest


@dataclasses.dataclass(frozen=True)
class ClusterWorkload:
    """Closed-loop users issuing PUT/GET/DELETE object streams.

    Each user (client) runs ``ops_per_user`` operations at queue depth
    ``qd``: the first is always a PUT, later slots draw GET (probability
    ``get_fraction``, over the user's own already-completed objects),
    DELETE (``delete_fraction``), else a fresh PUT.  Object sizes are
    uniform (``object_bytes``) so every network/CPU/device service class
    stays homogeneous and the compiled cluster program is *exact*
    against the event-engine oracle.  Deterministic in ``seed``.

    ``arrival`` stamps *open-loop offered load* onto the op stream: the
    canonical interleaved order gets explicit issue times from the
    :class:`repro.core.ArrivalProcess` instead of ``issue=0`` (pair it
    with ``qd >= ops_per_user`` so the per-client closed-loop edges
    vanish and the rack sees the arrival clock alone — that is what
    :func:`repro.cluster.plan_capacity`'s ``rate_ladder`` mode does).
    """

    n_users: int = 8
    ops_per_user: int = 8
    object_bytes: int = 2 * MiB
    get_fraction: float = 0.4
    delete_fraction: float = 0.0
    qd: int = 1
    seed: int = 0
    arrival: Optional[ArrivalProcess] = None

    def __post_init__(self):
        if self.n_users < 1 or self.ops_per_user < 1:
            raise ValueError("need >= 1 user and >= 1 op per user")
        if self.qd < 1:
            raise ValueError("qd must be >= 1")
        if not 0.0 <= self.get_fraction + self.delete_fraction <= 1.0:
            raise ValueError("get_fraction + delete_fraction must be in "
                             "[0, 1]")

    def build(self, n_gateways: int) -> List[ObjectOp]:
        """Generate the op stream; clients map to gateways round-robin
        and per-client slots interleave across clients so the canonical
        order is fair.  A GET/DELETE only targets objects whose PUT sits
        at least ``qd`` slots earlier on the same client (closed-loop
        read-your-writes: the PUT's completion is guaranteed to gate
        it).  Open-loop streams (``arrival`` set) use a window of one
        slot instead — the op mix must not collapse to all-PUTs when
        the planner raises ``qd`` to disable the closed-loop edges, and
        shard-level consistency is enforced by the compiler's
        ``seq``/``wb_data``/``rd_data`` edges regardless."""
        rng = np.random.default_rng(self.seed)
        window = 1 if self.arrival is not None else self.qd
        per_client: List[List[Tuple[int, int, int]]] = []
        next_obj = 0
        for c in range(self.n_users):
            ops: List[Tuple[int, int, int]] = []
            live: List[Tuple[int, int]] = []     # (obj, put slot)
            for slot in range(self.ops_per_user):
                readable = [o for o, s in live if s <= slot - window]
                r = float(rng.random())
                if slot > 0 and readable and r < self.get_fraction:
                    obj = readable[int(rng.integers(len(readable)))]
                    ops.append((OP_GET, obj, self.object_bytes))
                elif slot > 0 and readable and \
                        r < self.get_fraction + self.delete_fraction:
                    obj = readable[int(rng.integers(len(readable)))]
                    live = [(o, s) for o, s in live if o != obj]
                    ops.append((OP_DELETE, obj, 0))
                else:
                    obj = next_obj
                    next_obj += 1
                    live.append((obj, slot))
                    ops.append((OP_PUT, obj, self.object_bytes))
            per_client.append(ops)
        n_ops = self.n_users * self.ops_per_user
        times = (self.arrival.issue_times(n_ops, size=self.object_bytes)
                 if self.arrival is not None else np.zeros(n_ops))
        out: List[ObjectOp] = []
        for slot in range(self.ops_per_user):
            for c in range(self.n_users):
                kind, obj, nbytes = per_client[c][slot]
                out.append(ObjectOp(
                    seq=len(out), client=c, gateway=c % n_gateways,
                    kind=kind, obj=obj, nbytes=nbytes,
                    issue=float(times[len(out)])))
        return out
