"""Placement policies: object -> the servers holding its shard slots.

A policy is a function ``(obj, n_shards, n_servers, seed) -> row`` of
``n_shards`` **distinct** server ids.  Policies live in a shared
warn-on-collision :class:`repro.core.registry.Registry` (same semantics
as the host layer's policy registries) so experiments and external code
can plug in new layouts::

    >>> from repro.cluster import placement_map, register_placement
    >>> @register_placement("all-on-zero", replace=True)
    ... def _p(obj, n_shards, n_servers, seed):
    ...     return list(range(n_shards))        # ignore obj: slots 0..n-1
    >>> placement_map([7, 8], 3, 8, policy="all-on-zero").tolist()
    [[0, 1, 2], [0, 1, 2]]
    >>> from repro.cluster.placement import PLACEMENTS
    >>> PLACEMENTS.unregister("all-on-zero")

Built-ins:

* ``round-robin`` — slot ``s`` of object ``o`` on server ``(o + s) % S``;
  adjacent objects shift by one, spreading primaries evenly.
* ``strided`` — like round-robin but objects start at ``(o * 7) % S``,
  decorrelating consecutive objects that share a gateway.
* ``grouped`` — servers are carved into ``S // n`` fixed placement
  groups; an object's whole stripe lives in one group (small recovery
  blast radius, worse load spread — the classic copyset trade-off).
* ``hashed`` — pseudo-random distinct servers per object (seeded, so
  runs are reproducible).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.registry import Registry

PLACEMENTS = Registry("placement policy")


def register_placement(name: str, fn=None, *, replace: bool = False):
    """Register a placement policy; usable as a decorator."""
    return PLACEMENTS.register(name, fn, replace=replace)


def available_placements() -> tuple:
    return PLACEMENTS.available()


@register_placement("round-robin")
def _round_robin(obj: int, n_shards: int, n_servers: int, seed: int):
    return (obj + np.arange(n_shards)) % n_servers


@register_placement("strided")
def _strided(obj: int, n_shards: int, n_servers: int, seed: int):
    return ((obj * 7) % n_servers + np.arange(n_shards)) % n_servers


@register_placement("grouped")
def _grouped(obj: int, n_shards: int, n_servers: int, seed: int):
    n_groups = max(n_servers // n_shards, 1)
    start = (obj % n_groups) * n_shards
    return (start + np.arange(n_shards)) % n_servers


@register_placement("hashed")
def _hashed(obj: int, n_shards: int, n_servers: int, seed: int):
    rng = np.random.default_rng([seed, obj])
    return rng.permutation(n_servers)[:n_shards]


def placement_map(objects: Sequence[int], n_shards: int, n_servers: int, *,
                  policy: str = "round-robin", seed: int = 0) -> np.ndarray:
    """``(len(objects), n_shards)`` int array of server ids.

    Validates that every row holds distinct servers (a stripe must not
    co-locate two of its shards, or redundancy is silently lost).
    """
    if n_shards > n_servers:
        raise ValueError(f"cannot place {n_shards} distinct shards on "
                         f"{n_servers} servers")
    fn = PLACEMENTS.get(policy)
    rows = np.empty((len(objects), n_shards), dtype=np.int64)
    for i, obj in enumerate(objects):
        row = np.asarray(fn(int(obj), n_shards, n_servers, seed),
                         dtype=np.int64)
        if row.shape != (n_shards,):
            raise ValueError(f"policy {policy!r} returned shape {row.shape}; "
                             f"expected ({n_shards},)")
        if np.any(row < 0) or np.any(row >= n_servers):
            raise ValueError(f"policy {policy!r} placed object {obj} outside "
                             f"[0, {n_servers})")
        if len(np.unique(row)) != n_shards:
            raise ValueError(f"policy {policy!r} co-located shards of object "
                             f"{obj}: {row.tolist()}")
        rows[i] = row
    return rows
