"""Cluster tier: gateways, storage servers, and the network between
them, with the :mod:`repro.host` volume / :mod:`repro.core` device as
the leaf — all compiled to ONE fleet-level
:class:`repro.core.ChainProgram` per configuration and solved in a
single fused-fixpoint call (differential greedy-engine oracle for
small configs).  See ``docs/cluster.md``.
"""
from .spec import (  # noqa: F401
    CLUSTER_DEVICE_SPEC, OP_DELETE, OP_GET, OP_NAMES, OP_PUT, ClusterSpec,
    ClusterWorkload, GatewaySpec, NetworkSpec, ObjectOp, ServerSpec,
)
from .codec import (  # noqa: F401
    RedundancyScheme, erasure, parse_scheme, replication,
)
from .placement import (  # noqa: F401
    PLACEMENTS, available_placements, placement_map, register_placement,
)
from .gateway import Gateway, OpPlan, ShardOp, plan_workload  # noqa: F401
from .server import StorageServer  # noqa: F401
from .compiler import (  # noqa: F401
    MAX_REFINE, ClusterGraph, CompiledCluster, Resource, build_graph,
    compile_graph, edge_families, op_latencies,
)
from .oracle import oracle_op_latencies, simulate_graph, touched_servers  # noqa: F401
from .cluster import Cluster, ClusterRunResult  # noqa: F401
from .capacity import (  # noqa: F401
    CapacityCurve, CapacityPoint, CapacityReport, ClusterConfig,
    plan_capacity, rate_at_slo, users_at_slo,
)
