"""Storage server: NIC/CPU stages + writeback buffer over one volume.

A :class:`StorageServer` owns the per-server state the cluster compiler
and the event-engine oracle both consume:

* **shard log** — inserts append shard payloads to a per-server byte
  log (``cum`` bytes); each shard's ``[lo, hi)`` range is remembered so
  GETs and durability gates can find the flush that covers it;
* **writeback buffer** — ``writeback_bytes`` of staging RAM.  The
  flusher writes the log to flash in ``flush_chunk`` units (a
  sequential log: flushes retire in log order, ``flush_qd`` deep);
  an insert that would overflow the buffer stalls until enough chunks
  flushed (:meth:`room_gate`);
* **device** — flush chunks land in the server's
  :class:`repro.host.LogStructuredVolume` (zone allocation, open-zone
  limits and capacity enforced live by the host layer); service times
  come from the volume device's calibrated latency model, jitter-free.

The server never schedules anything itself — it answers the structural
questions ("which flush covers byte ``hi``?", "how many chunks must
drain before this insert fits?") from which the compiler builds chain
families and the oracle builds DAG edges.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import OpType, Trace, compute_service_times
from repro.host import LogStructuredVolume

from .spec import ClusterSpec


class StorageServer:
    """Per-server shard log + writeback-buffer geometry + device leaf."""

    def __init__(self, sid: int, spec: ClusterSpec):
        self.sid = sid
        self.spec = spec
        self.volume = LogStructuredVolume(
            spec.device_spec, policy="greedy-open",
            stripe_bytes=spec.server.flush_chunk,
            append_qd=spec.server.flush_qd)
        self.cum = 0                              # bytes inserted so far
        self.inserts: List[int] = []              # cum_after per insert
        self._ranges: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self.n_flush = 0
        self._svc_cache: Dict[Tuple[int, int], float] = {}

    # -- shard log -----------------------------------------------------------
    def insert_shard(self, obj: int, slot: int, nbytes: int
                     ) -> Tuple[int, int]:
        """Append a shard to the log; returns its ``[lo, hi)`` range."""
        lo, hi = self.cum, self.cum + int(nbytes)
        self.cum = hi
        self.inserts.append(hi)
        self._ranges[(obj, slot)] = (lo, hi)
        return lo, hi

    def shard_range(self, obj: int, slot: int) -> Tuple[int, int]:
        return self._ranges[(obj, slot)]

    # -- writeback geometry --------------------------------------------------
    @property
    def chunk(self) -> int:
        return self.spec.server.flush_chunk

    def covering_flush(self, hi: int) -> Optional[int]:
        """Flush index whose completion puts log bytes ``[0, hi)`` on
        flash (``None`` for empty ranges)."""
        return (hi - 1) // self.chunk if hi > 0 else None

    def room_gate(self, cum_after: int) -> Optional[int]:
        """Flush that must complete before the insert ending at
        ``cum_after`` fits in the buffer (``None``: fits immediately)."""
        over = cum_after - self.spec.server.writeback_bytes
        if over <= 0:
            return None
        return -(-over // self.chunk) - 1

    def data_gate_inserts(self) -> np.ndarray:
        """Per flush ``f``: index of the insert whose completion makes
        chunk ``f`` flushable.

        Writeback mode flushes full chunks: the gate is the first
        insert reaching ``min((f+1)*chunk, total)``.  Write-through
        mode force-flushes partials — every insert demands durability,
        so chunk ``f`` is flushable once its *first* byte lands (the
        first insert past ``f*chunk``); this is also what keeps the
        durability ack of an insert from waiting on a later op's bytes
        (which the closed loop may be holding back — a deadlock)."""
        if self.n_flush == 0:
            return np.zeros(0, dtype=np.int64)
        cum = np.asarray(self.inserts, dtype=np.int64)
        f = np.arange(self.n_flush)
        if self.spec.durability == "write-through":
            return np.searchsorted(cum, f * self.chunk, side="right")
        ends = np.minimum((f + 1) * self.chunk, self.cum)
        return np.searchsorted(cum, ends, side="left")

    def chunk_filled(self, hi: int) -> bool:
        """True when the chunk covering log byte ``hi - 1`` is already
        flushable given the inserts *so far* — i.e., a read of that
        byte can be served from flash; otherwise the bytes are still
        writeback-buffer-resident and a read is served from RAM."""
        g = self.covering_flush(hi)
        if g is None:
            return False
        if self.spec.durability == "write-through":
            return self.cum > g * self.chunk
        return self.cum >= (g + 1) * self.chunk

    def finalize(self) -> int:
        """Close the log: fix the flush count and land every chunk in
        the volume (allocator/zone state advances; chunks are padded to
        uniform ``flush_chunk`` so the append pool stays single-class).
        Returns the flush count."""
        self.n_flush = -(-self.cum // self.chunk) if self.cum > 0 else 0
        for f in range(self.n_flush):
            self.volume.write(f"wb-{self.sid}-{f}", self.chunk, stream=0)
        return self.n_flush

    # -- device service times ------------------------------------------------
    def _svc(self, op: OpType, nbytes: int) -> float:
        key = (int(op), int(nbytes))
        if key not in self._svc_cache:
            tr = Trace.build(op=[int(op)], zone=[0], size=[int(nbytes)],
                             issue=[0.0])
            self._svc_cache[key] = float(compute_service_times(
                tr, self.volume.device.lat, jitter=False)[0])
        return self._svc_cache[key]

    def append_svc(self) -> float:
        """Jitter-free device service time of one flush-chunk append."""
        return self._svc(OpType.APPEND, self.chunk)

    def read_svc(self, nbytes: int) -> float:
        """Jitter-free device service time of one shard read."""
        return self._svc(OpType.READ, nbytes)

    def __repr__(self) -> str:
        return (f"StorageServer(sid={self.sid}, cum={self.cum}, "
                f"flushes={self.n_flush})")
