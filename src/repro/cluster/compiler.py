"""Cluster request flows -> one fleet-level :class:`ChainProgram`.

Lowering runs in two steps shared with the differential oracle:

1. :func:`build_graph` — expand every planned object op into its
   per-stage *event graph*: gateway CPU, EC encode, NIC tx, fabric
   link, server NIC rx, server CPU/buffer insert, device read, flush
   appends, ack path, and the op-level join; plus the structural
   couplings (closed-loop clients, writeback data/room gates,
   durability acks, read-after-flush).  The graph is a plain DAG +
   resource declaration — no schedule, no times beyond per-event
   ``issue``/``svc``.
2. :func:`compile_graph` — lower the graph to chain families:

   * each per-shard flow path becomes one chain in a per-slot family
     (``flow/s{j}`` — the op's fan-out head and join appear once per
     slot family, so family-scatter uniqueness holds);
   * every gate edge becomes a 2-chain, greedily colored into
     occurrence-split families (``wb_room/0``, ``wb_room/1``, ...);
   * *ordered* resources (the sequential-log flusher and its device
     append pool: chunks retire in log order) become round-robin
     lag-``cap`` chains in member order — exact for any service times;
   * *FIFO* resources (CPU pools, NIC lanes, device read pool) are
     replayed greedily in event-heap pop order ``(ready, issue,
     index)``: each pop takes the least-loaded server (min free time),
     exactly like the oracle's free-time heaps, and the per-server pop
     sequences become coupling chains.  ``ready`` depends on
     completions, so the compiler iterates: solve, recompute ``ready``
     from the DAG, re-replay, until the chains reach a fixpoint
     (``refine_used`` solves, ``order_stable``).  A stable replay
     reproduces the greedy event engine exactly for *any* service mix
     — multi-class pools included — so ``exact`` is simply
     ``order_stable``; exhaustion warns with the flapping pool labels
     (``unstable_pools``).

The compiled per-config programs are pure data: the capacity planner
concatenates dozens of them (:func:`repro.core.concat_programs`) and
solves the whole rack sweep in ONE :func:`repro.core.solve_program`
call — on the fused fixpoint kernels when JAX/TPU is available.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ChainProgram, build_program, solve_program

from .gateway import OpPlan, plan_workload
from .server import StorageServer
from .spec import ClusterSpec, ObjectOp

#: Refinement budget: pop-order fixpoints on closed-loop cluster flows
#: settle within ~10 solves on contended racks (each solve pushes order
#: corrections one coupling hop further); the cap guards rare ties.
MAX_REFINE = 24

#: FIFO pop keys are snapped to this grid (us) before ordering, in the
#: compiler AND the oracle: the two engines accumulate float64 sums in
#: different orders, so genuinely-tied ready times can differ by ~1e-9
#: us and flip a queue order.  On the shared grid both sides see the
#: same ties and break them identically (issue, then event index).
READY_QUANTUM_US = 1e-6


def _quantize(t: np.ndarray) -> np.ndarray:
    return np.round(np.asarray(t) / READY_QUANTUM_US) * READY_QUANTUM_US


@dataclasses.dataclass
class Resource:
    """A service pool: ``cap`` servers over ``members`` (event ids).

    ``ordered=True`` pins the retire order to the member list (the
    sequential-log flusher and its append pool); otherwise members are
    served FIFO in event-heap pop order.
    """

    label: str
    cap: int
    members: List[int] = dataclasses.field(default_factory=list)
    ordered: bool = False


@dataclasses.dataclass
class ClusterGraph:
    """The shared contract between compiler and oracle."""

    issue: np.ndarray                   # (n,) earliest event issue (us)
    svc: np.ndarray                     # (n,) jitter-free service (us)
    labels: List[str]                   # per-event stage tag (debug)
    paths: List[Tuple[str, List[List[int]]]]   # flow families
    edges: List[Tuple[str, int, int]]   # gate edges (name, pred, succ)
    resources: List[Resource]
    op_head: np.ndarray                 # (n_ops,) first event per op
    op_tail: np.ndarray                 # (n_ops,) completion event per op
    servers: List[StorageServer]
    plans: List[OpPlan]
    #: (n_ops, 2) contiguous [start, end) event slice of each op, in
    #: plan order — the warm-ladder slot mapping joins rungs on these.
    op_slices: Optional[np.ndarray] = None
    #: (client, per-client slot) identity of each op, in plan order.
    op_keys: Optional[List[Tuple[int, int]]] = None

    @property
    def n(self) -> int:
        return len(self.issue)

    def dag_edges(self) -> np.ndarray:
        """All fixed precedence edges ``(pred, succ)``: path links, gate
        edges, and ordered-resource lag edges (deduplicated)."""
        out = []
        for _label, chains in self.paths:
            for c in chains:
                out.extend(zip(c[:-1], c[1:]))
        for _name, a, b in self.edges:
            out.append((a, b))
        for res in self.resources:
            if res.ordered:
                m = res.members
                out.extend((m[i - res.cap], m[i])
                           for i in range(res.cap, len(m)))
        if not out:
            return np.zeros((0, 2), dtype=np.int64)
        return np.unique(np.asarray(out, dtype=np.int64), axis=0)


class _GraphBuilder:
    def __init__(self):
        self.issue: List[float] = []
        self.svc: List[float] = []
        self.labels: List[str] = []
        self.resources: Dict[str, Resource] = {}
        self.edges: List[Tuple[str, int, int]] = []
        self.paths: Dict[str, List[List[int]]] = {}

    def ev(self, label: str, svc: float, *, issue: float = 0.0,
           res: Optional[str] = None, cap: int = 1,
           ordered: bool = False) -> int:
        idx = len(self.issue)
        self.issue.append(float(issue))
        self.svc.append(float(svc))
        self.labels.append(label)
        if res is not None:
            self.join_resource(idx, res, cap, ordered)
        return idx

    def join_resource(self, idx: int, res: str, cap: int,
                      ordered: bool = False) -> None:
        r = self.resources.setdefault(
            res, Resource(label=res, cap=int(cap), ordered=ordered))
        r.members.append(idx)


def build_graph(spec: ClusterSpec, ops: Sequence[ObjectOp], *, qd: int = 1,
                down: Optional[int] = None, seed: int = 0,
                plans: Optional[List[OpPlan]] = None) -> ClusterGraph:
    """Expand planned object ops into the cluster event graph.

    ``qd`` is the clients' closed-loop depth: op ``i`` of a client is
    gated on the ack (join) of its op ``i - qd``.
    """
    if plans is None:
        plans = plan_workload(spec, ops, seed=seed, down=down)
    net, gw, srv = spec.network, spec.gateway, spec.server
    b = _GraphBuilder()
    servers = [StorageServer(r, spec) for r in range(spec.n_servers)]
    op_head = np.zeros(len(ops), dtype=np.int64)
    op_tail = np.zeros(len(ops), dtype=np.int64)
    # Deferred per-server gates, resolved once flush counts are known:
    room_gates: List[Tuple[int, int, int]] = []   # (server, insert_ev, hi)
    ack_gates: List[Tuple[int, int, int]] = []    # (server, stx_ev, hi)
    read_gates: List[Tuple[int, int, int]] = []   # (server, dread_ev, hi)
    insert_evs: Dict[int, List[int]] = {r: [] for r in range(spec.n_servers)}
    op_slices = np.zeros((len(ops), 2), dtype=np.int64)
    op_keys: List[Tuple[int, int]] = [(0, 0)] * len(ops)
    client_slot: Dict[int, int] = {}

    for plan in plans:
        op = plan.op
        slot = client_slot.get(op.client, 0)
        client_slot[op.client] = slot + 1
        op_keys[op.seq] = (int(op.client), slot)
        op_slices[op.seq, 0] = len(b.issue)
        g = op.gateway
        head = b.ev("gw_cpu", gw.cpu_us, issue=op.issue,
                    res=f"gw_cpu/g{g}", cap=gw.cpu_cores)
        op_head[op.seq] = head
        src = head
        if plan.encode_us > 0.0:
            enc = b.ev("enc", plan.encode_us)
            b.edges.append(("enc", head, enc))
            src = enc
        join = b.ev("join", plan.decode_us)
        op_tail[op.seq] = join
        for sh in plan.shards:
            r = sh.server
            sv = servers[r]
            if sh.write:
                payload = sh.nbytes + net.req_bytes
                gtx = b.ev("gw_tx", net.gw_tx_us(payload),
                           res=f"gw_tx/g{g}", cap=1)
                lnk = b.ev("link", net.one_way_us)
                srx = b.ev("srv_rx", net.srv_tx_us(payload),
                           res=f"srv_rx/r{r}", cap=1)
                scpu = b.ev("insert", srv.cpu_us,
                            res=f"srv_cpu/r{r}", cap=srv.cpu_cores)
                stx = b.ev("srv_tx", net.srv_tx_us(net.req_bytes),
                           res=f"srv_tx/r{r}", cap=1)
                if sh.nbytes > 0:
                    if sh.nbytes > srv.writeback_bytes - srv.flush_chunk:
                        raise ValueError(
                            f"shard of {sh.nbytes} bytes cannot stage in "
                            f"a {srv.writeback_bytes}-byte writeback "
                            f"buffer (needs headroom of one flush chunk)")
                    _lo, hi = sv.insert_shard(op.obj, sh.slot, sh.nbytes)
                    insert_evs[r].append(scpu)
                    if sv.room_gate(hi) is not None:
                        room_gates.append((r, scpu, hi))
                    if spec.durability == "write-through":
                        ack_gates.append((r, stx, hi))
                lnk2 = b.ev("link", net.one_way_us)
                grx = b.ev("gw_rx", net.gw_tx_us(net.req_bytes),
                           res=f"gw_rx/g{g}", cap=1)
                chain = [src, gtx, lnk, srx, scpu, stx, lnk2, grx, join]
            else:
                resp = sh.nbytes + net.req_bytes
                gtx = b.ev("gw_tx", net.gw_tx_us(net.req_bytes),
                           res=f"gw_tx/g{g}", cap=1)
                lnk = b.ev("link", net.one_way_us)
                srx = b.ev("srv_rx", net.srv_tx_us(net.req_bytes),
                           res=f"srv_rx/r{r}", cap=1)
                scpu = b.ev("srv_cpu", srv.cpu_us,
                            res=f"srv_cpu/r{r}", cap=srv.cpu_cores)
                _lo, hi = sv.shard_range(op.obj, sh.slot)
                mid = []
                if sv.chunk_filled(hi):
                    # Bytes already flushable: read from flash (gated
                    # on the covering flush below).
                    dread = b.ev("dev_read", sv.read_svc(sh.nbytes),
                                 res=f"dev_read/r{r}",
                                 cap=spec.device_spec.read_parallelism)
                    read_gates.append((r, dread, hi))
                    mid = [dread]
                # else: the shard is still writeback-buffer resident —
                # served from RAM, no device event.
                stx = b.ev("srv_tx", net.srv_tx_us(resp),
                           res=f"srv_tx/r{r}", cap=1)
                lnk2 = b.ev("link", net.one_way_us)
                grx = b.ev("gw_rx", net.gw_tx_us(resp),
                           res=f"gw_rx/g{g}", cap=1)
                chain = [src, gtx, lnk, srx, scpu, *mid, stx, lnk2, grx,
                         join]
            b.paths.setdefault(f"flow/s{sh.slot}", []).append(chain)
        op_slices[op.seq, 1] = len(b.issue)

    # Closed loop: client op i waits for the ack of its op i - qd, and
    # clients prepare requests in program order (op i's gateway stage
    # follows op i-1's) — together these give read-your-writes at any
    # queue depth.
    per_client: Dict[int, List[int]] = {}
    for op in ops:
        per_client.setdefault(op.client, []).append(op.seq)
    for seqs in per_client.values():
        for i in range(1, len(seqs)):
            b.edges.append(("seq", int(op_head[seqs[i - 1]]),
                            int(op_head[seqs[i]])))
        for i in range(qd, len(seqs)):
            b.edges.append(("closed", int(op_tail[seqs[i - qd]]),
                            int(op_head[seqs[i]])))

    # Flushes: sequential log, one append per chunk, retiring in log
    # order (flush_qd deep through the device append pool).
    flush_evs: Dict[int, List[int]] = {}
    for r, sv in enumerate(servers):
        n_flush = sv.finalize()
        evs = []
        for _f in range(n_flush):
            fl = b.ev("flush", sv.append_svc(),
                      res=f"flush_q/r{r}", cap=srv.flush_qd, ordered=True)
            b.join_resource(fl, f"dev_append/r{r}",
                            spec.device_spec.append_parallelism,
                            ordered=True)
            evs.append(fl)
        flush_evs[r] = evs
        # wb_data: chunk f flushable once the insert filling it lands.
        for f, ins_idx in enumerate(sv.data_gate_inserts()):
            b.edges.append(("wb_data", insert_evs[r][int(ins_idx)], evs[f]))
    for r, scpu, hi in room_gates:
        b.edges.append(("wb_room",
                        flush_evs[r][servers[r].room_gate(hi)], scpu))
    for r, stx, hi in ack_gates:
        b.edges.append(("wt_ack",
                        flush_evs[r][servers[r].covering_flush(hi)], stx))
    for r, dread, hi in read_gates:
        b.edges.append(("rd_data",
                        flush_evs[r][servers[r].covering_flush(hi)], dread))

    return ClusterGraph(
        issue=np.asarray(b.issue, dtype=np.float64),
        svc=np.asarray(b.svc, dtype=np.float64),
        labels=b.labels,
        paths=sorted(b.paths.items()),
        edges=b.edges,
        resources=[b.resources[k] for k in sorted(b.resources)],
        op_head=op_head, op_tail=op_tail,
        servers=servers, plans=list(plans),
        op_slices=op_slices, op_keys=op_keys)


def edge_families(edges: Sequence[Tuple[str, int, int]]
                  ) -> List[Tuple[str, List[np.ndarray]]]:
    """Greedy edge coloring: 2-chains grouped into ``{name}/{occ}``
    families so no event repeats within a family."""
    occ: Dict[Tuple[str, int], int] = {}
    fams: Dict[str, List[np.ndarray]] = {}
    for name, a, b in edges:
        o = max(occ.get((name, a), 0), occ.get((name, b), 0))
        fams.setdefault(f"{name}/{o}", []).append(
            np.asarray([a, b], dtype=np.int64))
        occ[(name, a)] = occ[(name, b)] = o + 1
    return sorted(fams.items())


def _lag_chains(members: np.ndarray, cap: int) -> List[np.ndarray]:
    """Round-robin split: lag-``cap`` over the given member order.
    Used for *ordered* resources only, where retiring in member order
    is the resource's definition (the oracle models them as DAG lag
    edges, so round-robin is exact by construction)."""
    return [members[j::cap] for j in range(min(cap, len(members)))]


def _fifo_replay_chains(res: "Resource", graph: ClusterGraph,
                        ready: np.ndarray) -> List[np.ndarray]:
    """Greedy server assignment for one FIFO resource.

    Members are walked in event-heap pop order ``(quantized ready,
    issue, index)``; each pop takes the least-loaded server — min free
    time, exactly the oracle's per-resource free-time heap — and
    pushes ``max(free, ready) + svc`` back.  The per-server pop
    sequences become coupling chains.  Greedy ``min(free)`` depends
    only on the free-time *multiset*, so once ``ready`` is consistent
    with the solved completions the chains reproduce the oracle's
    begins exactly, for any mix of service classes."""
    m = np.asarray(res.members, dtype=np.int64)
    m = m[np.lexsort((m, graph.issue[m], _quantize(ready[m])))]
    heap = [(0.0, j) for j in range(res.cap)]
    chains: List[List[int]] = [[] for _ in range(res.cap)]
    for e, r, s in zip(m.tolist(), ready[m].tolist(),
                       graph.svc[m].tolist()):
        free, j = heap[0]
        heapq.heapreplace(heap, (max(free, r) + s, j))
        chains[j].append(e)
    return [np.asarray(c, dtype=np.int64) for c in chains if c]


def _chains_equal(a: List[np.ndarray], b: List[np.ndarray]) -> bool:
    return len(a) == len(b) and all(np.array_equal(x, y)
                                    for x, y in zip(a, b))


def _graph_ready(graph: ClusterGraph, edges: np.ndarray,
                 comp: np.ndarray) -> np.ndarray:
    """Event-heap pop keys: ``max(issue, DAG predecessors' comps)``."""
    ready = graph.issue.copy()
    if len(edges):
        np.maximum.at(ready, edges[:, 1], comp[edges[:, 0]])
    return ready


@dataclasses.dataclass
class CompiledCluster:
    """One cluster configuration lowered to a solvable program."""

    graph: ClusterGraph
    program: ChainProgram
    comp: np.ndarray          # completions from the final refinement solve
    sweeps_used: int
    converged: bool
    #: True when a caller-provided ``comp0`` warm start survived the
    #: tightness verification (False: cold, or verification fell back).
    warm_start_used: bool = False
    #: Final replayed FIFO pop-order chains (one list per contended
    #: unordered resource, in ``graph.resources`` order).  On a reused
    #: graph (identical slot indexing — e.g. a rate ladder's re-stamped
    #: rung) they are a valid ``chains0`` first iterate for the next
    #: :func:`compile_graph` call.
    fifo_chains: Optional[Tuple[Tuple[np.ndarray, ...], ...]] = None

    def op_latencies(self) -> np.ndarray:
        """Per-object-op latency: join completion minus the instant the
        closed loop let the op issue (``ready`` of its head event)."""
        return op_latencies(self.graph, self.comp)

    def makespan_us(self) -> float:
        return float(self.comp.max()) if len(self.comp) else 0.0


def op_latencies(graph: ClusterGraph, comp: np.ndarray) -> np.ndarray:
    """Per-op latency under completions ``comp`` (program or oracle)."""
    ready = _graph_ready(graph, graph.dag_edges(), comp)
    return comp[graph.op_tail] - ready[graph.op_head]


def _warm_refined_solve(program: ChainProgram, graph: ClusterGraph,
                        boot_comp: np.ndarray, cand: np.ndarray, *,
                        sweeps: int, fixpoint: str, scan_backend: str,
                        max_rounds: int = 4):
    """One refined solve warm-started from ``max(boot_comp, cand)``,
    repaired slot-wise until provably exact.

    The candidate is not a certified lower bound, so the warm result is
    checked for tightness; any unjustified slot is necessarily one the
    candidate pushed above the least fixpoint (``boot_comp`` is a
    certified lower bound and converged scatters are justified by their
    predecessors), so those slots are dropped from the candidate and
    the solve re-runs.  Each round either ends tight — the positive
    service times make a tight point *the* least fixpoint, identical to
    a cold solve — or strictly shrinks the candidate.  After
    ``max_rounds`` (or a non-converged solve) the candidate is
    abandoned and the solve falls back to ``boot_comp`` alone.

    Returns ``(comp, used, converged, cand | None, warm_ok)``; the
    returned candidate keeps the pruning, so later refinement
    iterations skip the slots that already proved anomalous.
    """
    from repro.core.chain_program import unjustified_slots
    for rnd in range(max_rounds):
        comp, used, converged = solve_program(
            program, graph.svc, sweeps=sweeps, fixpoint=fixpoint,
            scan_backend=scan_backend, warn=False,
            comp0=np.maximum(boot_comp, cand))
        if not converged:
            break
        bad = unjustified_slots(program, graph.svc, comp)
        if bad.size == 0:
            return comp, used, converged, cand, True
        cand = np.array(cand, copy=True)
        cand[bad] = -np.inf
        if graph.op_slices is not None and len(graph.op_slices):
            # An anomalous slot rarely travels alone — its op's whole
            # chain is usually inflated with it, and unjustified_slots
            # only exposes the chain's *sources* (the rest is "justified"
            # by an inflated predecessor), which would unravel one slot
            # per round.  Dropping the enclosing op slices collapses the
            # repair to one or two rounds.
            starts = graph.op_slices[:, 0]
            op = np.searchsorted(starts, bad, side="right") - 1
            op = op[(op >= 0) & (bad < graph.op_slices[op, 1])]
            for s, e in graph.op_slices[np.unique(op)]:
                cand[s:e] = -np.inf
        if rnd >= 1:
            # Anomalies surviving a surgical round cascade: pruning an
            # inflated op exposes the next op it was justifying, two
            # slots at a time, past any round budget.  A converged
            # ``comp`` is a topological potential (service times are
            # positive, so every chain edge strictly increases it), so
            # the whole cascade lives at or above the earliest anomaly
            # — drop every candidate entry there in one cut.
            cand[cand >= comp[bad].min()] = -np.inf
    comp, used, converged = solve_program(
        program, graph.svc, sweeps=sweeps, fixpoint=fixpoint,
        scan_backend=scan_backend, warn=False, comp0=boot_comp)
    return comp, used, converged, None, False


def compile_graph(graph: ClusterGraph, *, sweeps: int = 512,
                  fixpoint: str = "loop", scan_backend: str = "auto",
                  max_refine: int = MAX_REFINE,
                  comp0: Optional[np.ndarray] = None,
                  order_seed: Optional[np.ndarray] = None,
                  chains0: Optional[Sequence[Sequence[np.ndarray]]] = None
                  ) -> CompiledCluster:
    """Lower a cluster graph to a ChainProgram, refining FIFO pop
    orders to their fixpoint (see module docstring).

    ``comp0`` carries candidate completion lower bounds (e.g. the
    previous capacity-ladder rung's completions mapped onto this
    graph's events).  The bootstrap solve ignores them — the DAG-only
    fixpoint sits *below* any contended solution, so a previous rung's
    completions would overshoot it — and the candidate instead seeds
    every *refined* solve as ``max(boot_comp, comp0)``.  Ladder rungs
    are not provably monotone (a bigger rung's greedy schedule can
    anomalously finish an op earlier), so each warm refined solve is
    accepted only once it is provably tight: every service time is
    positive, so a tight point is *the* least fixpoint, identical to
    the cold result.  Anomalous candidate slots are pruned and
    re-solved rather than discarding the whole candidate (see
    :func:`_warm_refined_solve`); ``warm_start_used`` reports whether
    the candidate survived.

    ``order_seed`` (completion estimates on this graph's slots, any
    coverage, exactness not required) seeds the initial FIFO pop-order
    estimate so refinement starts near the previous rung's replay
    orders instead of the contention-free bootstrap's.  It biases only
    the refinement *trajectory*, never a solved value.
    Refinement solves always warm-start from at least the bootstrap
    completions: the DAG-only constraints are a subset of every refined
    program's, so the bootstrap fixpoint is a valid lower bound.

    ``chains0`` (a previous compile's ``fifo_chains`` on a graph with
    identical slot indexing, e.g. the re-stamped previous rung of a
    rate ladder) replaces the first iteration's *replayed* chains
    outright, starting the trajectory at the previous rung's actual
    pop orders instead of a time-scale estimate of them (and skipping
    one replay walk).  When the rungs pop identically refinement
    confirms stability in two iterations; when they drift the usual
    replay loop takes over.  Like ``order_seed`` it biases only the
    trajectory: the accepted program still has to replay its own
    chains verbatim.
    """
    static: List[Tuple[str, List[np.ndarray]]] = []
    for label, chains in graph.paths:
        static.append((label, [np.asarray(c, dtype=np.int64)
                               for c in chains]))
    static.extend(edge_families(graph.edges))
    fifo_res: List[Resource] = []
    for res in graph.resources:
        if len(res.members) <= res.cap:
            continue                       # never queues: no chain needed
        if res.ordered:
            static.append((res.label, _lag_chains(
                np.asarray(res.members, dtype=np.int64), res.cap)))
        else:
            fifo_res.append(res)
    # Service-class metadata (diagnostics only: the greedy replay is
    # exact for any mix once the chains freeze).
    multiclass = tuple(sorted(
        res.label for res in fifo_res
        if res.cap > 1 and len(np.unique(graph.svc[res.members])) > 1))
    dag = graph.dag_edges()

    # Bootstrap pop-order estimates from a contention-free solve: the
    # DAG-only program (paths, gates, sequential-log lags — no FIFO
    # chains) is acyclic, so its fixpoint always converges, and its
    # completions order events by pure dependency depth.  Starting the
    # FIFO chains from index order instead can thread a chain against
    # the DAG and make the first refinement solve cyclic (divergent).
    base = build_program(graph.issue, graph.svc, static)
    cand = None if comp0 is None else np.array(comp0, dtype=np.float64)
    warm_used = False
    comp, used, converged = solve_program(
        base, graph.svc, sweeps=sweeps, fixpoint=fixpoint,
        scan_backend=scan_backend, warn=False)
    boot_comp = comp
    # ``order_seed`` seeds the *initial* pop-order estimate: the
    # previous rung's completions rank the contended events far closer
    # to this rung's replay fixpoint than the contention-free bootstrap
    # does, so refinement starts within a hop or two of its fixpoint
    # instead of re-discovering the queue orders from scratch.  The
    # loop's stability criterion (replayed chains reproduce themselves)
    # is unchanged — the seed only moves the starting point.  Slots the
    # seed does not cover fall back to the bootstrap completions.
    ready = _graph_ready(graph, dag, comp if order_seed is None
                         else np.maximum(comp, order_seed))
    prev_chains: Optional[List[List[np.ndarray]]] = None
    program: ChainProgram = base
    refine_used, order_stable = 0, not fifo_res
    for it in range(max_refine + 1):
        if it == 0 and chains0 is not None and len(chains0) == len(fifo_res):
            rchains = [[np.asarray(c, dtype=np.int64) for c in ch]
                       for ch in chains0]
        else:
            rchains = [_fifo_replay_chains(r, graph, ready)
                       for r in fifo_res]
        if prev_chains is not None and \
                all(_chains_equal(a, p)
                    for a, p in zip(rchains, prev_chains)):
            order_stable = True
            break
        fams = list(static)
        for r, ch in zip(fifo_res, rchains):
            fams.append((r.label, ch))
        program = build_program(
            graph.issue, graph.svc, fams,
            exact=False, multiclass_pools=multiclass)
        if cand is None:
            comp, used, converged = solve_program(
                program, graph.svc, sweeps=sweeps, fixpoint=fixpoint,
                scan_backend=scan_backend, warn=False, comp0=boot_comp)
        else:
            comp, used, converged, cand, ok = _warm_refined_solve(
                program, graph, boot_comp, cand, sweeps=sweeps,
                fixpoint=fixpoint, scan_backend=scan_backend)
            warm_used = warm_used or ok
        refine_used = it + 1
        ready = _graph_ready(graph, dag, comp)
        prev_chains = rchains
    unstable: List[str] = []
    if not order_stable:
        # Budget exhausted: report which FIFO pools are still flapping
        # instead of silently downgrading the program to ``exact=False``.
        nxt = [_fifo_replay_chains(r, graph, ready) for r in fifo_res]
        unstable = [r.label for r, a, p in
                    zip(fifo_res, nxt, prev_chains or nxt)
                    if not _chains_equal(a, p)] or \
            [r.label for r in fifo_res]
        warnings.warn(
            f"cluster order refinement exhausted max_refine={max_refine} "
            f"without pop-order fixpoint; unstable FIFO pools: "
            f"{', '.join(unstable)} — program marked order_stable=False "
            f"(raise max_refine on Cluster.run/compile_graph, or pass "
            f"--max-refine on the CLI)", RuntimeWarning, stacklevel=2)
    program = dataclasses.replace(
        program, refine_used=refine_used, order_stable=order_stable,
        exact=bool(order_stable), unstable_pools=tuple(unstable))
    return CompiledCluster(graph=graph, program=program, comp=comp,
                           sweeps_used=used, converged=bool(converged),
                           warm_start_used=warm_used,
                           fifo_chains=tuple(tuple(ch) for ch in rchains))
