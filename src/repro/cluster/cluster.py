"""Cluster facade: spec + workload -> compiled run -> object metrics.

    >>> from repro.cluster import Cluster, ClusterSpec, ClusterWorkload
    >>> from repro.cluster import erasure
    >>> spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    >>> res = Cluster(spec).run(ClusterWorkload(n_users=2, ops_per_user=2))
    >>> res.converged and res.n_ops == 4
    True
    >>> res.latency_stats().n
    4
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import LatencyStats
from repro.core.metrics import violation_rate

from .compiler import (MAX_REFINE, CompiledCluster, build_graph,
                       compile_graph, op_latencies)
from .oracle import simulate_graph
from .spec import ClusterSpec, ClusterWorkload


@dataclasses.dataclass
class ClusterRunResult:
    """Object-level results of one cluster run (program or oracle)."""

    spec: ClusterSpec
    workload: ClusterWorkload
    compiled: CompiledCluster
    comp: np.ndarray            # per-event completions used for metrics
    converged: bool
    sweeps_used: int
    down: Optional[int] = None
    engine: str = "program"     # "program" | "oracle"

    @property
    def n_ops(self) -> int:
        return len(self.compiled.graph.op_tail)

    def op_latencies(self) -> np.ndarray:
        return op_latencies(self.compiled.graph, self.comp)

    def latency_stats(self) -> LatencyStats:
        return LatencyStats.from_samples(self.op_latencies())

    def makespan_us(self) -> float:
        return float(self.comp.max()) if len(self.comp) else 0.0

    def objects_per_sec(self) -> float:
        span = self.makespan_us()
        return self.n_ops / span * 1e6 if span > 0 else 0.0

    def slo_violation_rate(self, threshold_us: float) -> float:
        return violation_rate(self.op_latencies(), threshold_us)

    def summary(self) -> Dict[str, float]:
        lat = self.latency_stats()
        return {
            "n_ops": float(self.n_ops),
            "objects_per_sec": self.objects_per_sec(),
            "makespan_us": self.makespan_us(),
            "lat_mean_us": lat.mean_us, "lat_p50_us": lat.p50_us,
            "lat_p95_us": lat.p95_us, "lat_p99_us": lat.p99_us,
            "lat_p999_us": lat.p999_us,
            "converged": float(self.converged),
        }


class Cluster:
    """One rack, ready to compile and run workloads.

    :meth:`run` lowers the whole request flow to a single
    :class:`repro.core.ChainProgram` and solves it in one fused-fixpoint
    call; :meth:`run_oracle` runs the same event graph through the
    greedy per-server event engine (small configs; differential
    testing).
    """

    def __init__(self, spec: Optional[ClusterSpec] = None):
        self.spec = spec if spec is not None else ClusterSpec()

    def compile(self, workload: ClusterWorkload, *,
                down: Optional[int] = None, sweeps: int = 512,
                fixpoint: str = "loop", scan_backend: str = "auto",
                max_refine: int = MAX_REFINE,
                comp0=None) -> CompiledCluster:
        ops = workload.build(self.spec.n_gateways)
        graph = build_graph(self.spec, ops, qd=workload.qd, down=down,
                            seed=workload.seed)
        return compile_graph(graph, sweeps=sweeps, fixpoint=fixpoint,
                             scan_backend=scan_backend,
                             max_refine=max_refine, comp0=comp0)

    def run(self, workload: ClusterWorkload, *, down: Optional[int] = None,
            sweeps: int = 512, fixpoint: str = "loop",
            scan_backend: str = "auto",
            max_refine: int = MAX_REFINE) -> ClusterRunResult:
        compiled = self.compile(workload, down=down, sweeps=sweeps,
                                fixpoint=fixpoint, scan_backend=scan_backend,
                                max_refine=max_refine)
        return ClusterRunResult(
            spec=self.spec, workload=workload, compiled=compiled,
            comp=compiled.comp, converged=compiled.converged,
            sweeps_used=compiled.sweeps_used, down=down, engine="program")

    def run_oracle(self, workload: ClusterWorkload, *,
                   down: Optional[int] = None) -> ClusterRunResult:
        ops = workload.build(self.spec.n_gateways)
        graph = build_graph(self.spec, ops, qd=workload.qd, down=down,
                            seed=workload.seed)
        comp = simulate_graph(graph)
        compiled = CompiledCluster(graph=graph, program=None, comp=comp,
                                   sweeps_used=0, converged=True)
        return ClusterRunResult(
            spec=self.spec, workload=workload, compiled=compiled, comp=comp,
            converged=True, sweeps_used=0, down=down, engine="oracle")
