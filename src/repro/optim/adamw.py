"""AdamW with decoupled weight decay, global-norm clipping and schedules.

Self-contained (no optax in the container).  Optimizer state is a pytree
mirroring the params (m, v), so it inherits the param shardings —
optimizer-state sharding = FSDP'd exactly like the weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"     # cosine | constant


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
    else:
        warm = jnp.float32(1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule_lr(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.beta1 ** t
    bc2 = 1 - cfg.beta2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_ + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
