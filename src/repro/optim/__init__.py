from .adamw import (  # noqa: F401
    AdamWConfig, adamw_update, clip_by_global_norm, global_norm,
    init_opt_state, schedule_lr,
)
