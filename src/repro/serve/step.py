"""Serve-step factories: prefill and single-token decode with greedy or
temperature sampling.  The decode step donates the cache buffer."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import models as M
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, tokens, frontend_inputs=None):
        logits, cache = M.prefill(cfg, params, tokens, max_seq,
                                  frontend_inputs)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens, pos) -> (next_tokens, new_cache).

    One new token per sequence against the existing KV/recurrent cache —
    this is what the ``decode_*`` / ``long_*`` dry-run cells lower.
    """

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = M.decode_step(cfg, params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step


def greedy_generate(cfg: ModelConfig, params, prompt, *, steps: int,
                    max_seq: int):
    """Reference autoregressive loop (tests/examples; not the hot path)."""
    prefill = make_prefill_step(cfg, max_seq)
    step = make_serve_step(cfg)
    tok, cache = prefill(params, prompt)
    toks = [tok]
    pos = prompt.shape[1]
    for i in range(steps - 1):
        tok, cache = step(params, cache, tok, jnp.int32(pos + i))
        toks.append(tok)
    return jnp.stack(toks, axis=1)
