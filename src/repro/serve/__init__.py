from .step import greedy_generate, make_prefill_step, make_serve_step  # noqa: F401
