"""The paper's 13 observations as registered :class:`Experiment` entries.

Each entry bundles (a) the sweep points that reproduce the measurement,
(b) a metric extractor over the simulated results, and (c) executable
checks of the observation's qualitative claim, calibrated against the
paper's anchors (see :mod:`repro.core.calibration`).  The registry is the
single source of truth: ``benchmarks/fig2..fig8`` and ``table1`` are thin
shims over these entries, `docs/observations.md` tabulates them, and CI's
``experiments-smoke`` job runs a subset.

Checks pass on both the ``event`` and ``vectorized`` backends
(``tests/test_experiments.py``); extraction is deterministic because the
runner defaults to ``jitter=False``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (
    ConvDevice, KiB, LBAFormat, MiB, OpType, Stack, WorkloadSpec,
)
from repro.core import calibration as C

from .registry import Check, Experiment, SweepPoint, register_experiment

_W = OpType.WRITE
_A = OpType.APPEND
_R = OpType.READ


# ---------------------------------------------------------------------------
# Check helpers
# ---------------------------------------------------------------------------
def _approx(name: str, value: float, anchor: float, rel: float,
            unit: str = "") -> Check:
    ok = bool(abs(value - anchor) <= rel * abs(anchor))
    return Check(name, ok,
                 f"{value:.4g}{unit} vs paper {anchor:.4g}{unit} "
                 f"(tol {rel:.0%})")


def _holds(name: str, ok, detail: str) -> Check:
    return Check(name, bool(ok), detail)


def _mean_lat_us(res, op: Optional[OpType] = None) -> float:
    return float(res.latency_stats(op).mean_us)


def _mgmt_mean_ms(res, op: OpType, occ: float) -> float:
    """Mean in-device latency (ms) of mgmt ops at one occupancy level."""
    tr = res.trace
    sel = (tr.op == int(op)) & np.isclose(tr.occupancy, occ)
    return float(np.mean(res.sim.in_device_latency[sel])) / 1e3


def _io(op: OpType, n: int, size: int, **kw) -> WorkloadSpec:
    return WorkloadSpec().stream(op, n=n, size=size, **kw)


# ---------------------------------------------------------------------------
# Obs 1 — LBA format
# ---------------------------------------------------------------------------
def _x01(ctx) -> Dict[str, float]:
    m = {}
    for op, tag in ((_W, "write"), (_A, "append")):
        m[f"{tag}_512_us"] = _mean_lat_us(ctx[f"{tag}_512"])
        m[f"{tag}_4k_us"] = _mean_lat_us(ctx[f"{tag}_4k"])
        m[f"{tag}_ratio"] = m[f"{tag}_512_us"] / m[f"{tag}_4k_us"]
    return m


def _c01(m) -> Tuple[Check, ...]:
    return tuple(
        _holds(f"{tag}_512_slower",
               1.0 < m[f"{tag}_ratio"] <= 2.1,
               f"512B/4KiB latency ratio {m[f'{tag}_ratio']:.2f} "
               f"(paper: slower, 'as much as a factor of two')")
        for tag in ("write", "append"))


register_experiment(Experiment(
    name="obs01_lba_format", obs=1,
    title="The LBA format affects I/O performance",
    claim="Writing with the 512B LBA format is slower than with the 4KiB "
          "format, sometimes by as much as a factor of two.",
    figure="Fig. 2a",
    points=(
        SweepPoint("write_512", _io(_W, 1000, 512).with_format(
            LBAFormat.LBA_512)),
        SweepPoint("write_4k", _io(_W, 1000, 4 * KiB)),
        SweepPoint("append_512", _io(_A, 1000, 512).with_format(
            LBAFormat.LBA_512)),
        SweepPoint("append_4k", _io(_A, 1000, 4 * KiB)),
    ),
    extract=_x01, check=_c01,
    knobs=("LatencyParams.lba512_penalty", "calibration.LBA512_PENALTY"),
    tests=("tests/test_paper_claims.py::test_obs1_lba_format_penalty",),
))


# ---------------------------------------------------------------------------
# Obs 2 — storage stack
# ---------------------------------------------------------------------------
def _x02(ctx) -> Dict[str, float]:
    return {"spdk_us": _mean_lat_us(ctx["spdk"]),
            "kernel_none_us": _mean_lat_us(ctx["kernel_none"]),
            "mq_deadline_us": _mean_lat_us(ctx["mq_deadline"])}


def _c02(m) -> Tuple[Check, ...]:
    return (
        _approx("spdk_anchor", m["spdk_us"], 11.36, 0.02, "us"),
        _approx("kernel_none_anchor", m["kernel_none_us"], 12.62, 0.02, "us"),
        _approx("mq_deadline_anchor", m["mq_deadline_us"], 14.47, 0.02, "us"),
        _holds("spdk_fastest",
               m["spdk_us"] < m["kernel_none_us"] < m["mq_deadline_us"],
               f"{m['spdk_us']:.2f} < {m['kernel_none_us']:.2f} < "
               f"{m['mq_deadline_us']:.2f} us"),
    )


register_experiment(Experiment(
    name="obs02_storage_stack", obs=2,
    title="The host storage stack adds measurable latency",
    claim="SPDK delivers the lowest write latency; the in-kernel path adds "
          "overhead, and an I/O scheduler (mq-deadline) adds more.",
    figure="Fig. 2a",
    points=(
        SweepPoint("spdk", _io(_W, 1000, 4 * KiB).on_stack(Stack.SPDK)),
        SweepPoint("kernel_none",
                   _io(_W, 1000, 4 * KiB).on_stack(Stack.KERNEL_NONE)),
        SweepPoint("mq_deadline",
                   _io(_W, 1000, 4 * KiB).on_stack(Stack.KERNEL_MQ_DEADLINE)),
    ),
    extract=_x02, check=_c02,
    knobs=("LatencyParams.stack_overhead_us", "calibration.STACK_OVERHEAD_US"),
    tests=("tests/test_paper_claims.py::test_obs2_stack_latencies_exact",),
))


# ---------------------------------------------------------------------------
# Obs 3 — request-size dependence
# ---------------------------------------------------------------------------
def _x03(ctx) -> Dict[str, float]:
    m = {"write_4k_kiops": ctx["write_4k"].iops / 1e3,
         "append_4k_kiops": ctx["append_4k"].iops / 1e3,
         "append_8k_kiops": ctx["append_8k"].iops / 1e3,
         "write_4k_mibs": ctx["write_4k"].bandwidth_bytes / MiB,
         "write_32k_mibs": ctx["write_32k"].bandwidth_bytes / MiB}
    return m


def _c03(m) -> Tuple[Check, ...]:
    return (
        _approx("write_4k_kiops", m["write_4k_kiops"], 85.0, 0.05, "K"),
        _approx("append_4k_kiops", m["append_4k_kiops"], 66.0, 0.05, "K"),
        _approx("append_8k_kiops", m["append_8k_kiops"], 69.0, 0.05, "K"),
        _holds("large_requests_higher_bandwidth",
               m["write_32k_mibs"] > 3.0 * m["write_4k_mibs"],
               f"32KiB {m['write_32k_mibs']:.0f} MiB/s vs 4KiB "
               f"{m['write_4k_mibs']:.0f} MiB/s"),
    )


register_experiment(Experiment(
    name="obs03_request_size", obs=3,
    title="QD1 throughput depends on the request size",
    claim="Small requests are IOPS-limited (write 85 KIOPS, append 66-69 "
          "KIOPS); bytes-throughput is highest for large (>=32KiB) "
          "requests.",
    figure="Fig. 3",
    points=(
        SweepPoint("write_4k", _io(_W, 1500, 4 * KiB)),
        SweepPoint("write_32k", _io(_W, 1500, 32 * KiB)),
        SweepPoint("append_4k", _io(_A, 1500, 4 * KiB)),
        SweepPoint("append_8k", _io(_A, 1500, 8 * KiB)),
    ),
    extract=_x03, check=_c03,
    knobs=("LatencyParams.size_anchors", "LatencyParams.io_svc_us",
           "calibration.WRITE_SVC_TABLE_US", "calibration.APPEND_SVC_TABLE_US"),
    tests=("tests/test_paper_claims.py::test_obs3_throughput_vs_size",),
))


# ---------------------------------------------------------------------------
# Obs 4 — append vs write latency
# ---------------------------------------------------------------------------
def _x04(ctx) -> Dict[str, float]:
    w = _mean_lat_us(ctx["write_4k"])
    a = _mean_lat_us(ctx["append_8k"])
    return {"write_us": w, "append_us": a,
            "gap_pct": (a - w) / w * 100.0}


def _c04(m) -> Tuple[Check, ...]:
    return (
        _approx("write_anchor", m["write_us"], 11.36, 0.02, "us"),
        _approx("append_anchor", m["append_us"], 14.02, 0.02, "us"),
        _approx("gap_anchor", m["gap_pct"], 23.42, 0.05, "%"),
        _holds("write_lower", m["write_us"] < m["append_us"],
               f"write {m['write_us']:.2f} < append {m['append_us']:.2f} us"),
    )


register_experiment(Experiment(
    name="obs04_append_vs_write", obs=4,
    title="Appends have higher latency than writes",
    claim="At their best request sizes, writes have up to 23.42% lower "
          "latency than appends.",
    figure="Fig. 2b",
    points=(
        SweepPoint("write_4k", _io(_W, 1500, 4 * KiB)),
        SweepPoint("append_8k", _io(_A, 1500, 8 * KiB)),
    ),
    extract=_x04, check=_c04,
    knobs=("LatencyParams.io_svc_us", "calibration.APPEND_SVC_TABLE_US"),
    tests=("tests/test_paper_claims.py::test_obs4_append_write_gap_exact",),
))


# ---------------------------------------------------------------------------
# Obs 5 — scheduler-dependent write scaling
# ---------------------------------------------------------------------------
def _x05(ctx) -> Dict[str, float]:
    spdk = _mean_lat_us(ctx["spdk_qd1"])
    mq = _mean_lat_us(ctx["mq_qd1"])
    intra = ctx.device.steady_state(_W, 4 * KiB, qd=32,
                                    stack=Stack.KERNEL_MQ_DEADLINE)
    try:
        ctx.device.steady_state(_W, 4 * KiB, qd=2, stack=Stack.SPDK)
        rejected = 0.0
    except ValueError:
        rejected = 1.0
    return {"spdk_qd1_us": spdk, "mq_qd1_us": mq,
            "sched_overhead_us": mq - spdk,
            "intra_mq_qd32_kiops": intra.iops / 1e3,
            "spdk_multi_write_rejected": rejected}


def _c05(m) -> Tuple[Check, ...]:
    return (
        _approx("mq_overhead", m["sched_overhead_us"], 3.11, 0.25, "us"),
        _approx("intra_mq_qd32", m["intra_mq_qd32_kiops"], 293.0, 0.10, "K"),
        _holds("spdk_single_writer_per_zone",
               m["spdk_multi_write_rejected"] == 1.0,
               "QD>1 same-zone writes require an I/O scheduler"),
    )


register_experiment(Experiment(
    name="obs05_scheduler", obs=5,
    title="Intra-zone write scaling needs an I/O scheduler",
    claim="A single zone admits one in-flight write without a scheduler; "
          "mq-deadline merges sequential writes (293 KIOPS at QD32) at the "
          "cost of per-request overhead.",
    figure="Fig. 4a",
    points=(
        SweepPoint("spdk_qd1", _io(_W, 1000, 4 * KiB).on_stack(Stack.SPDK)),
        SweepPoint("mq_qd1",
                   _io(_W, 1000, 4 * KiB).on_stack(Stack.KERNEL_MQ_DEADLINE)),
    ),
    extract=_x05, check=_c05,
    knobs=("calibration.MERGE_MAX", "calibration.WRITE_INTRA_MERGED_IOPS_CAP",
           "LatencyParams.stack_overhead_us"),
    tests=("tests/test_paper_claims.py::test_obs5_obs7_intra_zone_beats_inter_zone",),
))


# ---------------------------------------------------------------------------
# Obs 6 — append concurrency cap
# ---------------------------------------------------------------------------
def _x06(ctx) -> Dict[str, float]:
    return {"qd1_kiops": ctx["qd1"].iops / 1e3,
            "qd4_kiops": ctx["qd4"].iops / 1e3,
            "qd8_kiops": ctx["qd8"].iops / 1e3,
            "inter_z4_kiops": ctx["inter_z4"].iops / 1e3}


def _c06(m) -> Tuple[Check, ...]:
    cap = C.APPEND_IOPS_CAP / 1e3
    return (
        _approx("saturates_at_cap", m["qd4_kiops"], cap, 0.10, "K"),
        _holds("no_gain_past_qd4",
               abs(m["qd8_kiops"] - m["qd4_kiops"]) <= 0.05 * m["qd4_kiops"],
               f"qd8 {m['qd8_kiops']:.0f}K vs qd4 {m['qd4_kiops']:.0f}K"),
        _holds("layout_agnostic",
               abs(m["inter_z4_kiops"] - m["qd4_kiops"])
               <= 0.05 * m["qd4_kiops"],
               f"inter-zone {m['inter_z4_kiops']:.0f}K vs intra "
               f"{m['qd4_kiops']:.0f}K"),
        _holds("scales_from_qd1", m["qd4_kiops"] >= 1.8 * m["qd1_kiops"],
               f"qd1 {m['qd1_kiops']:.0f}K -> qd4 {m['qd4_kiops']:.0f}K"),
    )


register_experiment(Experiment(
    name="obs06_append_concurrency", obs=6,
    title="Append scalability saturates at low concurrency",
    claim="Appends scale only to ~132 KIOPS at concurrency 4, regardless "
          "of intra- vs inter-zone layout.",
    figure="Fig. 4a/4b",
    points=(
        SweepPoint("qd1", _io(_A, 1500, 4 * KiB, qd=1)),
        SweepPoint("qd4", _io(_A, 3000, 4 * KiB, qd=4)),
        SweepPoint("qd8", _io(_A, 3000, 4 * KiB, qd=8)),
        SweepPoint("inter_z4", _io(_A, 3000, 4 * KiB, qd=4, nzones=4)),
    ),
    extract=_x06, check=_c06,
    knobs=("ZNSDeviceSpec.append_parallelism", "calibration.APPEND_IOPS_CAP"),
    tests=("tests/test_paper_claims.py::test_obs6_append_agnostic",),
))


# ---------------------------------------------------------------------------
# Obs 7 — read/write concurrency scaling
# ---------------------------------------------------------------------------
def _x07(ctx) -> Dict[str, float]:
    intra = ctx.device.steady_state(_W, 4 * KiB, qd=32,
                                    stack=Stack.KERNEL_MQ_DEADLINE)
    inter = ctx.device.steady_state(_W, 4 * KiB, zones=14)
    return {"read_qd1_kiops": ctx["read_qd1"].iops / 1e3,
            "read_qd32_kiops": ctx["read_qd32"].iops / 1e3,
            "read_qd128_kiops": ctx["read_qd128"].iops / 1e3,
            "write_intra_mq_kiops": intra.iops / 1e3,
            "write_inter_kiops": inter.iops / 1e3}


def _c07(m) -> Tuple[Check, ...]:
    return (
        _approx("read_peak", m["read_qd128_kiops"],
                C.READ_IOPS_CAP / 1e3, 0.05, "K"),
        _holds("read_scales",
               m["read_qd1_kiops"] < m["read_qd32_kiops"]
               <= m["read_qd128_kiops"] * 1.01,
               f"{m['read_qd1_kiops']:.0f}K -> {m['read_qd32_kiops']:.0f}K "
               f"-> {m['read_qd128_kiops']:.0f}K"),
        _approx("write_inter_cap", m["write_inter_kiops"], 186.0, 0.10, "K"),
        _holds("intra_beats_inter",
               m["write_intra_mq_kiops"] > m["write_inter_kiops"],
               f"intra(mq) {m['write_intra_mq_kiops']:.0f}K vs inter "
               f"{m['write_inter_kiops']:.0f}K"),
    )


register_experiment(Experiment(
    name="obs07_concurrency_scaling", obs=7,
    title="Reads scale intra-zone; intra-zone writes beat inter-zone",
    claim="Reads reach 424 KIOPS at QD128 within one zone; merged "
          "intra-zone writes (293 KIOPS) outperform inter-zone writes "
          "(186 KIOPS).",
    figure="Fig. 4a/4b",
    points=(
        SweepPoint("read_qd1", _io(_R, 2000, 4 * KiB, qd=1)),
        SweepPoint("read_qd32", _io(_R, 6000, 4 * KiB, qd=32)),
        SweepPoint("read_qd128", _io(_R, 8000, 4 * KiB, qd=128)),
    ),
    extract=_x07, check=_c07,
    knobs=("ZNSDeviceSpec.read_parallelism", "calibration.READ_IOPS_CAP",
           "calibration.WRITE_INTER_IOPS_CAP"),
    tests=("tests/test_paper_claims.py::test_obs5_obs7_intra_zone_beats_inter_zone",),
))


# ---------------------------------------------------------------------------
# Obs 8 — large requests saturate device bandwidth
# ---------------------------------------------------------------------------
def _x08(ctx) -> Dict[str, float]:
    inter8 = ctx.device.steady_state(_W, 8 * KiB, zones=4)
    app16 = ctx.device.steady_state(_A, 16 * KiB, qd=4)
    return {"write_32k_qd1_mibs": ctx["write_32k"].bandwidth_bytes / MiB,
            "write_8k_z4_mibs": inter8.bandwidth_bytes / MiB,
            "append_16k_qd4_mibs": app16.bandwidth_bytes / MiB}


def _c08(m) -> Tuple[Check, ...]:
    peak = C.PEAK_WRITE_BW_MIBS
    return (
        _approx("qd1_32k_at_peak", m["write_32k_qd1_mibs"], peak, 0.10,
                " MiB/s"),
        _holds("8k_with_4_zones_at_peak",
               m["write_8k_z4_mibs"] >= 0.85 * peak,
               f"{m['write_8k_z4_mibs']:.0f} MiB/s vs peak {peak:.0f}"),
        _holds("append_16k_qd4_at_peak",
               m["append_16k_qd4_mibs"] >= 0.85 * peak,
               f"{m['append_16k_qd4_mibs']:.0f} MiB/s vs peak {peak:.0f}"),
    )


register_experiment(Experiment(
    name="obs08_bandwidth_saturation", obs=8,
    title="Large requests saturate the device write bandwidth",
    claim="Requests >=32KiB at QD1 (or >=8KiB with 2-4 concurrent zones) "
          "reach the ~1155 MiB/s device write-bandwidth limit.",
    figure="Fig. 4c",
    points=(
        SweepPoint("write_32k", _io(_W, 1500, 32 * KiB)),
    ),
    extract=_x08, check=_c08,
    knobs=("ZNSDeviceSpec.peak_write_bw_bytes",
           "calibration.PEAK_WRITE_BW_MIBS"),
    tests=("tests/test_paper_claims.py::test_obs8_large_requests_saturate",),
))


# ---------------------------------------------------------------------------
# Obs 9 — zone-transition costs
# ---------------------------------------------------------------------------
def _x09(ctx) -> Dict[str, float]:
    res = ctx["transitions"]
    stats = res.per_op_stats()
    p = ctx.device.params
    return {"open_us": stats[OpType.OPEN].mean_us,
            "close_us": stats[OpType.CLOSE].mean_us,
            "implicit_write_us": float(p.implicit_open_us[int(_W)]),
            "implicit_append_us": float(p.implicit_open_us[int(_A)])}


def _c09(m) -> Tuple[Check, ...]:
    return (
        _approx("open_anchor", m["open_us"], C.OPEN_LAT_US, 0.02, "us"),
        _approx("close_anchor", m["close_us"], C.CLOSE_LAT_US, 0.02, "us"),
        _approx("implicit_write",
                m["implicit_write_us"],
                C.IMPLICIT_OPEN_FIRST_WRITE_PENALTY_US, 0.02, "us"),
        _holds("transitions_cheap",
               m["open_us"] < 100.0 and m["close_us"] < 100.0,
               "open/close are microsecond-scale (vs ms-scale reset/finish)"),
    )


register_experiment(Experiment(
    name="obs09_transitions", obs=9,
    title="Explicit zone transitions are cheap",
    claim="Open (9.56us) and close (11.01us) cost microseconds; implicit "
          "opens add only a small first-write penalty.",
    figure="Fig. 5c",
    points=(
        SweepPoint("transitions",
                   WorkloadSpec().opens(n=300).closes(n=300)),
    ),
    extract=_x09, check=_c09,
    knobs=("LatencyParams.open_cost_us", "LatencyParams.close_cost_us",
           "LatencyParams.implicit_open_us"),
    tests=("tests/test_paper_claims.py::test_obs9_open_close_costs",),
))


# ---------------------------------------------------------------------------
# Obs 10 — occupancy-dependent reset/finish costs
# ---------------------------------------------------------------------------
_OCC = (0.0, 0.25, 0.5, 1.0)


def _x10(ctx) -> Dict[str, float]:
    rs = ctx["reset_sweep"]
    fin = ctx["finish_sweep"]
    plain05 = _mgmt_mean_ms(rs, OpType.RESET, 0.5)
    finished05 = _mgmt_mean_ms(ctx["finished_reset"], OpType.RESET, 0.5)
    return {
        "reset_ms_occ025": _mgmt_mean_ms(rs, OpType.RESET, 0.25),
        "reset_ms_occ05": plain05,
        "reset_ms_occ10": _mgmt_mean_ms(rs, OpType.RESET, 1.0),
        "reset_finished_ms_occ05": finished05,
        "finished_discount_pct": (1.0 - finished05 / plain05) * 100.0,
        "finish_ms_low": _mgmt_mean_ms(fin, OpType.FINISH, 0.001),
        "finish_ms_full": _mgmt_mean_ms(fin, OpType.FINISH, 1.0),
    }


def _c10(m) -> Tuple[Check, ...]:
    return (
        _holds("reset_grows_with_occupancy",
               m["reset_ms_occ025"] < m["reset_ms_occ05"]
               < m["reset_ms_occ10"],
               f"{m['reset_ms_occ025']:.2f} < {m['reset_ms_occ05']:.2f} < "
               f"{m['reset_ms_occ10']:.2f} ms"),
        _approx("reset_50pct_anchor", m["reset_ms_occ05"], 11.60, 0.05, "ms"),
        _approx("reset_100pct_anchor", m["reset_ms_occ10"], 16.19, 0.05,
                "ms"),
        _approx("finished_discount", m["finished_discount_pct"], 26.58,
                0.05, "%"),
        _approx("finish_empty_anchor", m["finish_ms_low"], 907.51, 0.02,
                "ms"),
        _approx("finish_full_anchor", m["finish_ms_full"], 3.07, 0.05, "ms"),
        _holds("finish_decreases",
               m["finish_ms_low"] > 100.0 * m["finish_ms_full"],
               f"{m['finish_ms_low']:.0f} ms (empty) vs "
               f"{m['finish_ms_full']:.2f} ms (full)"),
    )


register_experiment(Experiment(
    name="obs10_reset_finish_occupancy", obs=10,
    title="Reset/finish cost depends on zone occupancy",
    claim="Reset cost grows with occupancy (finished zones are 26.58% "
          "cheaper); finish is the most expensive command, hundreds of ms "
          "for nearly-empty zones.",
    figure="Fig. 5a/5b",
    points=(
        SweepPoint("reset_sweep", WorkloadSpec().reset_sweep(
            _OCC, n_per_level=10, pause_us=1e4)),
        SweepPoint("finished_reset", WorkloadSpec().reset_sweep(
            (0.5,), n_per_level=10, pause_us=1e4, finish_first=True)),
        SweepPoint("finish_sweep", WorkloadSpec().finish_sweep(
            (0.001, 0.5, 1.0), n_per_level=10, pause_us=1e4)),
    ),
    extract=_x10, check=_c10,
    knobs=("LatencyParams.reset_us_table",
           "LatencyParams.reset_finished_discount",
           "LatencyParams.finish_floor_us", "LatencyParams.finish_span_us"),
    tests=("tests/test_paper_claims.py::test_obs10_reset_finish_occupancy",),
))


# ---------------------------------------------------------------------------
# Obs 11 — stability under write pressure (ZNS vs conventional GC)
# ---------------------------------------------------------------------------
def _zns_pressure_wl(rate_mibs: float = 750.0, duration_s: float = 4.0,
                     threads: int = 4, size: int = 128 * KiB
                     ) -> WorkloadSpec:
    per = rate_mibs * MiB / threads
    n = int(per * duration_s / size)
    wl = WorkloadSpec()
    for t in range(threads):
        wl = wl.stream(_W, n=n, size=size, qd=8, zone=t * 50, nzones=8,
                       thread=t, rate_bytes_per_s=per)
    return wl


def _x11(ctx) -> Dict[str, float]:
    res = ctx["zns_writes"]
    _, mibs = res.throughput_timeseries(bin_s=1.0)
    steady = mibs[:-1] if len(mibs) > 1 else mibs  # drop partial last bin
    cv = float(np.std(steady) / np.mean(steady))
    conv = ConvDevice().run_write_pressure(rate_mibs=C.PEAK_WRITE_BW_MIBS,
                                           duration_s=60)
    zns = ctx.device.run_write_pressure(rate_mibs=C.PEAK_WRITE_BW_MIBS,
                                        duration_s=60)
    idle = ctx.device.run_write_pressure(rate_mibs=0.0, duration_s=60)
    return {"zns_write_cv": cv,
            "conv_write_cv": float(conv.write_cv),
            "conv_read_p95_ms": conv.read_lat_p95_us / 1e3,
            "zns_read_p95_ms": zns.read_lat_p95_us / 1e3,
            "idle_read_p95_us": idle.read_lat_p95_us,
            "zns_read_advantage": (conv.read_lat_p95_us
                                   / zns.read_lat_p95_us)}


def _c11(m) -> Tuple[Check, ...]:
    return (
        _holds("zns_writes_flat", m["zns_write_cv"] < 0.05,
               f"ZNS write-throughput CV {m['zns_write_cv']:.4f}"),
        _holds("conv_writes_fluctuate", m["conv_write_cv"] > 0.3,
               f"conventional (FTL GC) CV {m['conv_write_cv']:.2f}"),
        _approx("zns_read_p95", m["zns_read_p95_ms"],
                C.ZNS_READ_P95_UNDER_WRITES_MS, 0.05, "ms"),
        _approx("read_advantage", m["zns_read_advantage"], 3.06, 0.10, "x"),
        _holds("pressure_vs_idle",
               m["zns_read_p95_ms"] * 1e3 > 100.0 * m["idle_read_p95_us"],
               f"pressured p95 {m['zns_read_p95_ms']:.1f} ms vs idle "
               f"{m['idle_read_p95_us']:.1f} us"),
    )


register_experiment(Experiment(
    name="obs11_write_pressure", obs=11,
    title="ZNS performance is stable under write pressure",
    claim="Without device-side GC, ZNS write throughput stays flat and "
          "read p95 is ~3x lower than a conventional SSD under full-rate "
          "writes.",
    figure="Fig. 6",
    points=(
        SweepPoint("zns_writes", _zns_pressure_wl()),
    ),
    extract=_x11, check=_c11,
    knobs=("calibration.ZNS_READ_P95_UNDER_WRITES_MS",
           "calibration.CONV_READ_P95_UNDER_WRITES_MS",
           "ConvDeviceSpec.gc_write_amp_knee"),
    tests=("tests/test_paper_claims.py::test_obs11_read_latency_under_pressure",),
))


# ---------------------------------------------------------------------------
# Obs 12 — resets do not disturb I/O
# ---------------------------------------------------------------------------
def _quiet_reads() -> WorkloadSpec:
    return WorkloadSpec().reads(n=2500, size=4 * KiB, qd=32, thread=0)


def _x12(ctx) -> Dict[str, float]:
    quiet = ctx["quiet"]
    loud = ctx["loud"]
    rmask = loud.trace.op == int(_R)
    shift = float(np.max(np.abs(loud.sim.complete[rmask]
                                - quiet.sim.complete)))
    return {"max_read_shift_us": shift,
            "reset_mean_ms": loud.latency_stats(OpType.RESET).mean_us / 1e3}


def _c12(m) -> Tuple[Check, ...]:
    return (
        _holds("io_unperturbed", m["max_read_shift_us"] <= 1e-6,
               f"max read-completion shift {m['max_read_shift_us']:.2g} us "
               f"with 20 full-zone resets in flight"),
        _holds("resets_realistic", m["reset_mean_ms"] >= 1.0,
               f"reset latency {m['reset_mean_ms']:.2f} ms (ms-scale, so "
               f"the non-interference is meaningful)"),
    )


register_experiment(Experiment(
    name="obs12_reset_io_isolation", obs=12,
    title="Resets do not disturb concurrent I/O",
    claim="Zone resets are handled by a dedicated metadata path and leave "
          "concurrent read/write completions untouched.",
    figure="Fig. 7",
    points=(
        SweepPoint("quiet", _quiet_reads(), seed=0),
        SweepPoint("loud",
                   WorkloadSpec()
                   .resets(n=20, occupancy=1.0, nzones=20, thread=1)
                   .reads(n=2500, size=4 * KiB, qd=32, thread=0),
                   seed=0),
    ),
    extract=_x12, check=_c12,
    knobs=("LatencyParams.reset_on_io_path",
           "ZNSDeviceSpec.reset_parallelism"),
    tests=("tests/test_paper_claims.py::test_obs12_resets_do_not_disturb_io",),
))


# ---------------------------------------------------------------------------
# Obs 13 — concurrent I/O inflates reset latency
# ---------------------------------------------------------------------------
def _resets(io_ctx=None) -> WorkloadSpec:
    return WorkloadSpec().resets(n=30, occupancy=1.0, nzones=30,
                                 io_ctx=io_ctx)


def _x13(ctx) -> Dict[str, float]:
    iso = ctx["isolated"].latency_stats(OpType.RESET).mean_us
    m = {"isolated_reset_ms": iso / 1e3}
    for tag in ("read", "write", "append"):
        mean = ctx[f"under_{tag}"].latency_stats(OpType.RESET).mean_us
        m[f"{tag}_inflation_pct"] = (mean / iso - 1.0) * 100.0
    return m


def _c13(m) -> Tuple[Check, ...]:
    return (
        _approx("write_inflation", m["write_inflation_pct"], 78.42, 0.05,
                "%"),
        _approx("read_inflation", m["read_inflation_pct"], 56.11, 0.05, "%"),
        _approx("append_inflation", m["append_inflation_pct"], 75.50, 0.05,
                "%"),
        _holds("all_classes_inflate",
               min(m["read_inflation_pct"], m["write_inflation_pct"],
                   m["append_inflation_pct"]) > 30.0,
               "every concurrent I/O class inflates reset latency"),
    )


register_experiment(Experiment(
    name="obs13_reset_inflation", obs=13,
    title="Concurrent I/O inflates reset latency",
    claim="Resets take up to 78.42% longer when I/O runs concurrently "
          "(write worst, then append, then read) — the inverse of Obs#12.",
    figure="Fig. 7",
    points=(
        SweepPoint("isolated", _resets()),
        SweepPoint("under_read", _resets(_R)),
        SweepPoint("under_write", _resets(_W)),
        SweepPoint("under_append", _resets(_A)),
    ),
    extract=_x13, check=_c13,
    knobs=("LatencyParams.reset_inflation", "calibration.RESET_INFLATION"),
    tests=("tests/test_paper_claims.py::test_obs13_io_inflates_reset_p95",),
))
