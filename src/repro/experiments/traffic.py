"""Open-loop traffic scenarios (obs14/obs15): the paper's interference
observations at service scale.

Obs#12/#13 are per-request facts — resets never perturb concurrent I/O
on the ZN540 (a dedicated metadata path), while concurrent I/O inflates
the resets themselves.  These two registry entries replay those facts
under *open-loop* tenant traffic (:mod:`repro.core.arrival`), where they
become tail-latency SLO statements:

* ``obs14_qos_noisy_neighbor`` — a victim tenant issues Poisson reads
  while a noisy neighbor fires zone resets at increasing rates.  On the
  calibrated ZN540 the victim's completions are bit-identical at every
  aggressor rate (Obs#12 at scale); on the NVMeVirt profile, whose
  erase executes on the data path, the victim's p99.9 and SLO-violation
  rate climb with the reset rate.  The aggressor still pays Obs#13
  inflation on the calibrated profile.  An event-engine oracle pass
  asserts the open-loop lowering is exact (<= 1e-9) on every point.
* ``obs15_diurnal_reclaim`` — a diurnal (on/off) read service plus a
  host :class:`repro.host.ReclaimScheduler` backlog.  Scheduling the
  reclaim resets into the load troughs (``reclaim_workload(windows=)``)
  hides them completely even on NVMeVirt; spreading the *same* reclaim
  work uniformly across the day drags the busy-phase tail through the
  erase latency.  The calibrated profile is immune either way.

Both experiments run on both backends and extract deterministic metrics
(runner default ``jitter=False``), like every entry in
:mod:`repro.experiments.observations`.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (
    KiB, DeterministicRate, LatencyModel, OpType, PoissonArrivals,
    WorkloadSpec, ZNSDeviceSpec, ZnsDevice,
)
from repro.core import calibration as C
from repro.core.emulator_models import nvmevirt_params
from repro.host import ReclaimScheduler

from .observations import _approx, _holds
from .registry import Check, Experiment, SweepPoint, register_experiment

_R = OpType.READ
_RESET = OpType.RESET

#: Single-channel read path: one in-flight read at a time, so a reset
#: executing on the data path (NVMeVirt) visibly stalls the tenant.
_SPEC = ZNSDeviceSpec(read_parallelism=1)
_NV = nvmevirt_params()

_SLO_US = 1_000.0                    # tenant SLO: 1 ms from submission


def _from_issue_lat(res, mask) -> np.ndarray:
    return np.asarray(res.sim.latency_from(res.trace.issue))[mask]


def _read_mask(res) -> np.ndarray:
    return res.trace.op == int(_R)


def _victim_p999(res) -> float:
    lat = _from_issue_lat(res, _read_mask(res))
    return float(np.percentile(lat, 99.9))


def _victim_slo_rate(res) -> float:
    lat = _from_issue_lat(res, _read_mask(res))
    return float(np.count_nonzero(lat > _SLO_US) / len(lat))


def _oracle_pass(ctx) -> Tuple[float, bool]:
    """Re-run every sweep point on the event engine and return the worst
    completion-time relative difference plus the vectorized engine's own
    exactness claim (the PR's open-loop differential gate)."""
    worst, exact = 0.0, True
    for pt in ctx.experiment.points:
        dev = ZnsDevice(pt.spec, lat=LatencyModel(pt.spec, pt.params))
        ref = dev.run(pt.workload, backend="event", jitter=False)
        got = ctx[pt.label]
        a = np.asarray(got.sim.complete)
        b = np.asarray(ref.sim.complete)
        if len(b):
            worst = max(worst, float(
                np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0))))
        claim = got.exact
        exact = exact and (claim is None or bool(claim))
    return worst, exact


# ---------------------------------------------------------------------------
# Obs 14 — multi-tenant QoS under a reset-happy neighbor (Obs#12/#13 at scale)
# ---------------------------------------------------------------------------
_VICTIM_N = 5000
_VICTIM_RATE = 10_000.0              # ~500 ms of Poisson reads


def _victim() -> WorkloadSpec:
    return WorkloadSpec().reads(
        n=_VICTIM_N, size=4 * KiB, qd=0, thread=0,
        arrival=PoissonArrivals(rate_per_s=_VICTIM_RATE, seed=14))


def _aggressor(wl: WorkloadSpec, rate_per_s: float, n: int, *,
               io_ctx: Optional[OpType] = _R) -> WorkloadSpec:
    """Noisy neighbor: open-loop full-zone resets at ``rate_per_s``."""
    return wl.resets(
        n=n, occupancy=1.0, nzones=n, thread=1, qd=0, io_ctx=io_ctx,
        arrival=PoissonArrivals(rate_per_s=rate_per_s, seed=41))


def _x14(ctx) -> Dict[str, float]:
    m: Dict[str, float] = {}
    for label, key in (("quiet", "quiet"), ("aggr_10", "aggr10"),
                       ("aggr_40", "aggr40"), ("nv_quiet", "nv_quiet"),
                       ("nv_aggr_10", "nv_aggr10"),
                       ("nv_aggr_40", "nv_aggr40")):
        res = ctx[label]
        m[f"victim_p999_{key}_us"] = _victim_p999(res)
        m[f"slo_rate_{key}"] = _victim_slo_rate(res)
    quiet = ctx["quiet"]
    shift = 0.0
    for label in ("aggr_10", "aggr_40"):
        loud = ctx[label]
        shift = max(shift, float(np.max(np.abs(
            loud.sim.complete[_read_mask(loud)]
            - quiet.sim.complete[_read_mask(quiet)]))))
    m["max_read_shift_us"] = shift
    m["nv_tail_ratio_40"] = (m["victim_p999_nv_aggr40_us"]
                             / m["victim_p999_nv_quiet_us"])
    # Obs#13 rides along: the aggressor's resets inflate under the
    # victim's reads on the calibrated profile.
    alone = ctx["aggr_alone"]
    under = ctx["aggr_40"]
    iso = float(np.mean(
        alone.sim.in_device_latency[alone.trace.op == int(_RESET)]))
    ctx_mean = float(np.mean(
        under.sim.in_device_latency[under.trace.op == int(_RESET)]))
    m["read_ctx_inflation_pct"] = (ctx_mean / iso - 1.0) * 100.0
    m["oracle_max_rel_diff"], ok = _oracle_pass(ctx)
    m["oracle_all_exact"] = float(ok)
    return m


def _c14(m) -> Tuple[Check, ...]:
    anchor = (C.RESET_INFLATION[_R] - 1.0) * 100.0
    return (
        _holds("victim_immune_calibrated",
               m["max_read_shift_us"] <= 1e-6,
               f"max victim completion shift {m['max_read_shift_us']:.2g} us "
               f"across aggressor rates (Obs#12 at scale)"),
        _holds("nv_neighbor_hurts",
               m["nv_tail_ratio_40"] > 2.0
               and m["slo_rate_nv_aggr40"] > m["slo_rate_nv_quiet"],
               f"NVMeVirt victim p99.9 inflates "
               f"{m['nv_tail_ratio_40']:.1f}x at 40 resets/s "
               f"(SLO violations {m['slo_rate_nv_quiet']:.3f} -> "
               f"{m['slo_rate_nv_aggr40']:.3f})"),
        _holds("nv_tail_monotonic",
               m["victim_p999_nv_quiet_us"]
               <= m["victim_p999_nv_aggr10_us"]
               <= m["victim_p999_nv_aggr40_us"],
               f"p99.9 {m['victim_p999_nv_quiet_us']:.0f} <= "
               f"{m['victim_p999_nv_aggr10_us']:.0f} <= "
               f"{m['victim_p999_nv_aggr40_us']:.0f} us with reset rate"),
        _approx("aggressor_pays_obs13", m["read_ctx_inflation_pct"],
                anchor, 0.05, "%"),
        _holds("open_loop_oracle_exact",
               m["oracle_max_rel_diff"] <= 1e-9
               and m["oracle_all_exact"] >= 1.0,
               f"event-oracle rel diff {m['oracle_max_rel_diff']:.2g} "
               f"over all sweep points, exactness claimed"),
    )


register_experiment(Experiment(
    name="obs14_qos_noisy_neighbor", obs=14,
    title="Reset-happy neighbors only break tenant SLOs on the data path",
    claim="Under open-loop Poisson reads, a neighbor firing zone resets "
          "leaves the victim's completions bit-identical on the ZN540 "
          "(Obs#12), while the NVMeVirt profile — erase on the data path "
          "— inflates the victim's p99.9 and SLO-violation rate with the "
          "reset rate; the aggressor itself pays Obs#13 inflation.",
    figure="Fig. 7 (scenario extension)",
    points=(
        SweepPoint("quiet", _victim(), spec=_SPEC),
        SweepPoint("aggr_10", _aggressor(_victim(), 10.0, 5), spec=_SPEC),
        SweepPoint("aggr_40", _aggressor(_victim(), 40.0, 20), spec=_SPEC),
        SweepPoint("aggr_alone",
                   _aggressor(WorkloadSpec(), 40.0, 20, io_ctx=None),
                   spec=_SPEC),
        SweepPoint("nv_quiet", _victim(), spec=_SPEC, params=_NV),
        SweepPoint("nv_aggr_10", _aggressor(_victim(), 10.0, 5),
                   spec=_SPEC, params=_NV),
        SweepPoint("nv_aggr_40", _aggressor(_victim(), 40.0, 20),
                   spec=_SPEC, params=_NV),
    ),
    extract=_x14, check=_c14,
    knobs=("LatencyParams.reset_on_io_path", "LatencyParams.reset_inflation",
           "ZNSDeviceSpec.reset_parallelism", "StreamSpec.arrival"),
    tests=("tests/test_arrival.py::test_obs14_noisy_neighbor_registry_checks",),
))


# ---------------------------------------------------------------------------
# Obs 15 — diurnal load: schedule reclaim into the troughs
# ---------------------------------------------------------------------------
_DAY_PHASES = (0.0, 60_000.0)        # two 30 ms busy phases
_PHASE_N = 300                       # one read / 100 us
_TROUGHS = ((30_000.0, 60_000.0), (90_000.0, 120_000.0))
_WHOLE_DAY = ((0.0, 120_000.0),)
_BACKLOG_ZONES = 8


def _diurnal_reads() -> WorkloadSpec:
    wl = WorkloadSpec()
    for start in _DAY_PHASES:
        wl = wl.reads(n=_PHASE_N, size=4 * KiB, qd=0, start_us=start,
                      arrival=DeterministicRate(every_us=100.0))
    return wl


def _with_reclaim(windows) -> WorkloadSpec:
    """Foreground reads + the scheduler's backlog compiled open-loop
    into ``windows`` (the tentpole's trough-scheduling path)."""
    sched = ReclaimScheduler(ZnsDevice(_SPEC), io_ctx=_R)
    sched.schedule(range(_BACKLOG_ZONES))
    return sched.reclaim_workload(base=_diurnal_reads(), thread=5,
                                  windows=windows)


def _x15(ctx) -> Dict[str, float]:
    m: Dict[str, float] = {}
    for label in ("nv_no_reclaim", "nv_uniform", "nv_trough"):
        res = ctx[label]
        key = label[3:]
        m[f"p999_{key}_us"] = _victim_p999(res)
        m[f"slo_rate_{key}"] = _victim_slo_rate(res)
    for label in ("nv_uniform", "nv_trough"):
        res = ctx[label]
        rmask = res.trace.op == int(_RESET)
        m[f"reset_total_{label[3:]}_us"] = float(
            np.sum(res.sim.in_device_latency[rmask]))
        m[f"resets_{label[3:]}"] = float(np.count_nonzero(rmask))
    quiet = ctx["nv_no_reclaim"]
    trough = ctx["nv_trough"]
    m["trough_read_shift_us"] = float(np.max(np.abs(
        trough.sim.complete[_read_mask(trough)]
        - quiet.sim.complete[_read_mask(quiet)])))
    zq, zu = ctx["zn540_no_reclaim"], ctx["zn540_uniform"]
    m["zn540_read_shift_us"] = float(np.max(np.abs(
        zu.sim.complete[_read_mask(zu)]
        - zq.sim.complete[_read_mask(zq)])))
    return m


def _c15(m) -> Tuple[Check, ...]:
    return (
        _holds("trough_hides_reclaim",
               m["trough_read_shift_us"] <= 1e-6,
               f"trough-scheduled reclaim shifts busy-phase reads by "
               f"{m['trough_read_shift_us']:.2g} us (vs no reclaim)"),
        _holds("uniform_drags_tail",
               m["p999_uniform_us"] > 5.0 * m["p999_trough_us"]
               and m["slo_rate_uniform"] > m["slo_rate_trough"],
               f"uniform reclaim p99.9 {m['p999_uniform_us']:.0f} us vs "
               f"trough {m['p999_trough_us']:.0f} us (SLO violations "
               f"{m['slo_rate_uniform']:.3f} vs "
               f"{m['slo_rate_trough']:.3f})"),
        _holds("same_reclaim_work",
               m["resets_uniform"] == m["resets_trough"]
               and abs(m["reset_total_uniform_us"]
                       - m["reset_total_trough_us"])
               <= 1e-6 * m["reset_total_uniform_us"],
               f"both schedules reset {m['resets_uniform']:.0f} zones, "
               f"{m['reset_total_uniform_us'] / 1e3:.1f} ms of erase work"),
        _holds("zn540_immune_either_way",
               m["zn540_read_shift_us"] <= 1e-6,
               f"calibrated ZN540 read shift {m['zn540_read_shift_us']:.2g} "
               f"us even under uniform reclaim (Obs#12)"),
    )


register_experiment(Experiment(
    name="obs15_diurnal_reclaim", obs=15,
    title="Trough-scheduled reclaim hides erase latency from the tenant",
    claim="With diurnal open-loop load, scheduling the host reclaim "
          "backlog into load troughs leaves the busy-phase tail "
          "untouched even when erases run on the data path (NVMeVirt); "
          "spreading the same reclaim work uniformly drags the tenant "
          "p99.9 through the erase latency.  The calibrated ZN540 is "
          "immune either way.",
    figure="Fig. 7 (scenario extension)",
    points=(
        SweepPoint("nv_no_reclaim", _diurnal_reads(),
                   spec=_SPEC, params=_NV),
        SweepPoint("nv_uniform", _with_reclaim(_WHOLE_DAY),
                   spec=_SPEC, params=_NV),
        SweepPoint("nv_trough", _with_reclaim(_TROUGHS),
                   spec=_SPEC, params=_NV),
        SweepPoint("zn540_no_reclaim", _diurnal_reads(), spec=_SPEC),
        SweepPoint("zn540_uniform", _with_reclaim(_WHOLE_DAY), spec=_SPEC),
    ),
    extract=_x15, check=_c15,
    knobs=("LatencyParams.reset_on_io_path", "StreamSpec.arrival",
           "ReclaimScheduler.reclaim_workload"),
    tests=("tests/test_arrival.py::test_obs15_diurnal_reclaim_registry_checks",),
))
