"""CLI for the observation registry + host-scenario + cluster sweeps.

    python -m repro.experiments run --all [--backend vectorized]
    python -m repro.experiments run --only obs4,obs10 --out results/exp
    python -m repro.experiments list
    python -m repro.experiments host [--scenarios lsm,cache]
                                     [--policies greedy-open,striped]
    python -m repro.experiments cluster [--stripe-widths 2,4]
                                        [--schemes ec4+2,rep2-k2]
                                        [--policies round-robin,hashed]

``run`` executes the selected experiments as one fleet-batched sweep,
writes per-experiment JSON + a markdown report (cross-linking
docs/observations.md), prints a summary table, and exits non-zero if any
check fails or any fixpoint did not converge.  ``host`` runs the
application-scenario x placement-policy matrix (`repro.host`) the same
way — every combination is one member of a single
:class:`repro.core.DeviceFleet` call — and prints the per-scenario
policy ranking (see docs/host.md).  ``cluster`` compiles a (redundancy
scheme x placement policy) x users-ladder x (normal | degraded) rack
sweep to one fleet-level :class:`repro.core.ChainProgram`, solves it in
a single call, and ranks configurations by the user count served inside
the p99 latency SLO (see docs/cluster.md); ``--rates`` swaps the ladder
for open-loop Poisson offered load and ranks by arrival-rate-at-SLO.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .registry import all_experiments
from .runner import DEFAULT_OUT_DIR, ExperimentRunner

#: Artifact directory of the ``host`` subcommand.
HOST_OUT_DIR = os.path.join("results", "host")


def _cmd_list() -> int:
    for exp in all_experiments():
        print(f"obs{exp.obs:02d}  {exp.name:32s} {exp.figure:10s} "
              f"{len(exp.points)} points  — {exp.title}")
    return 0


def _cmd_run(args) -> int:
    keys = None if args.all else [k for k in args.only.split(",") if k]
    if keys is not None and not keys:
        print("run: pass --all or --only obs4,obs10,...", file=sys.stderr)
        return 2
    try:
        runner = ExperimentRunner(keys, backend=args.backend,
                                  jitter=args.jitter, seed=args.seed)
    except KeyError as e:
        print(f"run: {e.args[0]}", file=sys.stderr)
        return 2
    results = runner.run()
    paths = runner.write_artifacts(results, out_dir=args.out)
    width = max((len(r.name) for r in results), default=4)
    for r in results:
        ok = sum(c.ok for c in r.checks)
        status = "pass" if r.passed else "FAIL"
        print(f"obs{r.obs:02d}  {r.name:{width}s}  {ok}/{len(r.checks)} "
              f"checks  {status}")
        if not r.passed or args.verbose:
            for c in r.checks:
                print(f"        {c}")
    n_pass = sum(r.passed for r in results)
    stale = [r.name for r in results if not r.converged]
    print(f"\n{n_pass}/{len(results)} experiments passed "
          f"(backend={args.backend}); report: {paths['report']}")
    if stale:
        print(f"WARNING: fixpoint did not converge for "
              f"{', '.join(stale)} — metrics are not steady-state",
              file=sys.stderr)
    return 0 if n_pass == len(results) and not stale else 1


def _cmd_host(args) -> int:
    from repro.host import (
        available_placement_policies, available_scenarios, compare_policies,
        rank_policies,
    )

    scenarios = [s for s in args.scenarios.split(",") if s] or None
    policies = [p for p in args.policies.split(",") if p] or None
    if args.list:
        for s in available_scenarios():
            print(f"scenario  {s}")
        for p in available_placement_policies():
            print(f"policy    {p}")
        return 0
    try:
        rows = compare_policies(scenarios, policies, backend=args.backend,
                                seed=args.seed, scale=args.scale)
    except KeyError as e:
        print(f"host: {e.args[0]}", file=sys.stderr)
        return 2
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "host_policies.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
    width = max(len(r["policy"]) for r in rows)
    for r in rows:
        print(f"{r['scenario']:14s} {r['policy']:{width}s} "
              f"makespan={r['makespan_s'] * 1e3:9.2f}ms "
              f"WA={r['write_amplification']:.3f} "
              f"reclaim={r['reclaim_mibs']:8.1f}MiB/s "
              f"({r['n_requests']} reqs)")
    print()
    for scen, order in rank_policies(rows).items():
        print(f"{scen:14s} best-first: {' > '.join(order)}")
    print(f"\n{len(rows)} combinations in one fleet run "
          f"(backend={args.backend}); results: {out_path}")
    return 0


#: Artifact directory of the ``cluster`` subcommand.
CLUSTER_OUT_DIR = os.path.join("results", "cluster")


def _cluster_configs(args):
    from repro.cluster import (ClusterConfig, available_placements, erasure,
                               parse_scheme, replication)
    if args.schemes:
        schemes = [parse_scheme(s) for s in args.schemes.split(",") if s]
    else:
        widths = [int(w) for w in args.stripe_widths.split(",") if w]
        schemes = []
        for k in widths:
            schemes.append(erasure(k, args.parity))
            schemes.append(replication(k, copies=args.parity + 1))
    policies = ([p for p in args.policies.split(",") if p]
                or available_placements())
    return [ClusterConfig(scheme=s, placement=p)
            for s in schemes for p in policies]


def _cmd_cluster(args) -> int:
    from repro.cluster import (ClusterSpec, ClusterWorkload,
                               available_placements, plan_capacity)

    if args.list:
        for p in available_placements():
            print(f"placement  {p}")
        print("schemes    ec<k>+<m> (erasure) or rep<copies>-k<k> "
              "(replication), e.g. ec4+2, rep2-k2")
        return 0
    try:
        configs = _cluster_configs(args)
        base_spec = ClusterSpec(n_gateways=args.gateways,
                                n_servers=args.servers,
                                durability=args.durability)
        for cfg in configs:
            if cfg.scheme.n_shards > args.servers:
                print(f"cluster: {cfg.scheme.name} needs "
                      f"{cfg.scheme.n_shards} servers, have {args.servers}",
                      file=sys.stderr)
                return 2
    except (KeyError, ValueError) as e:
        print(f"cluster: {e.args[0]}", file=sys.stderr)
        return 2
    ladder = [int(u) for u in args.users.split(",") if u]
    rate_ladder = [float(r) for r in args.rates.split(",") if r] or None
    workload = ClusterWorkload(
        ops_per_user=args.objects_per_user,
        object_bytes=int(args.object_mib * (1 << 20)),
        get_fraction=args.get_fraction, seed=args.seed,
        n_users=ladder[-1] if rate_ladder and ladder else 8)
    report = plan_capacity(
        configs, ladder, base_spec=base_spec, workload=workload,
        slo_us=args.slo_ms * 1e3, rate_ladder=rate_ladder,
        degraded=not args.no_degraded,
        sweeps=args.sweeps, max_refine=args.max_refine,
        warm_ladder=args.warm_ladder)

    os.makedirs(args.out, exist_ok=True)
    json_path = os.path.join(args.out, "capacity.json")
    with open(json_path, "w") as f:
        json.dump(report.to_json(), f, indent=1, sort_keys=True)
    csv_path = os.path.join(args.out, "capacity_curves.csv")
    with open(csv_path, "w") as f:
        f.write("config,degraded,users,objects_per_sec,p50_us,p99_us,"
                "p999_us,slo_violation_rate,offered_rate\n")
        for c in report.curves:
            for p in c.points:
                rate = "" if p.offered_rate is None \
                    else f"{p.offered_rate:.3f}"
                f.write(f"{c.config.name},{int(c.degraded)},{p.users},"
                        f"{p.objects_per_sec:.3f},{p.lat.p50_us:.3f},"
                        f"{p.lat.p99_us:.3f},{p.lat.p999_us:.3f},"
                        f"{p.slo_violation_rate:.6f},{rate}\n")

    width = max(len(c.config.name) for c in report.curves)
    fom = "rate@SLO" if rate_ladder else "users@SLO"
    print(f"{'config':{width}s} {'mode':8s} {fom:>9s} "
          f"{'p99(us) by rung':>24s}")
    for c in report.ranking():
        rungs = " ".join(f"{p.lat.p99_us:7.1f}" for p in c.points)
        print(f"{c.config.name:{width}s} {'normal':8s} "
              f"{c.load_at_slo:9.2f} {rungs:>24s}")
        d = report.degraded_curve(c.config)
        if d is not None:
            rungs = " ".join(f"{p.lat.p99_us:7.1f}" for p in d.points)
            print(f"{'':{width}s} {'degraded':8s} "
                  f"{d.load_at_slo:9.2f} {rungs:>24s}")
    print(f"\n{report.n_programs} programs ({report.n_events} events) in "
          f"one fleet-level solve ({report.sweeps_used} sweeps, SLO "
          f"p99 <= {report.slo_us / 1e3:g}ms); results: {json_path}")
    if args.warm_ladder:
        print(f"warm ladder: {report.warm_hits}/{report.warm_attempts} "
              f"rung seeds verified tight (misses fall back cold; curves "
              f"are identical either way)")
    if report.order_unstable:
        print("WARNING: pop-order refinement budget exhausted for "
              f"{', '.join(report.order_unstable)} — their curves are "
              "approximate (raise --max-refine)", file=sys.stderr)
    if not report.converged:
        print("WARNING: fixpoint did not converge — capacity numbers are "
              "not steady-state", file=sys.stderr)
    return 0 if report.converged else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list registered experiments")
    host = sub.add_parser(
        "host", help="host-scenario x placement-policy sweep (repro.host)")
    host.add_argument("--scenarios", default="",
                      help="comma-separated scenario names (default: all)")
    host.add_argument("--policies", default="",
                      help="comma-separated placement policies (default: all)")
    host.add_argument("--backend", default="vectorized",
                      choices=("event", "vectorized", "auto"))
    host.add_argument("--scale", type=float, default=1.0,
                      help="scenario size multiplier")
    host.add_argument("--seed", type=int, default=0)
    host.add_argument("--out", default=HOST_OUT_DIR,
                      help=f"artifact directory (default {HOST_OUT_DIR})")
    host.add_argument("--list", action="store_true",
                      help="list scenarios/policies instead of running")
    clu = sub.add_parser(
        "cluster",
        help="rack capacity sweep: scheme x placement -> users at p99 SLO")
    clu.add_argument("--stripe-widths", default="2,4",
                     help="comma-separated stripe widths k; each yields an "
                          "ec(k,parity) and a rep(k,parity+1 copies) scheme")
    clu.add_argument("--parity", type=int, default=1,
                     help="redundancy degree m paired with --stripe-widths")
    clu.add_argument("--schemes", default="",
                     help="explicit scheme list (ec4+2,rep2-k2,...); "
                          "overrides --stripe-widths/--parity")
    clu.add_argument("--policies", default="",
                     help="comma-separated placement policies (default: all)")
    clu.add_argument("--gateways", type=int, default=2)
    clu.add_argument("--servers", type=int, default=8)
    clu.add_argument("--users", default="2,4,8",
                     help="comma-separated users-per-rack ladder")
    clu.add_argument("--rates", default="",
                     help="comma-separated open-loop offered-load ladder "
                          "(objects/s, Poisson arrivals); switches the "
                          "figure of merit to arrival-rate-at-SLO and "
                          "fixes the population at the last --users rung")
    clu.add_argument("--slo-ms", type=float, default=10.0,
                     help="p99 latency SLO in milliseconds")
    clu.add_argument("--objects-per-user", type=int, default=6)
    clu.add_argument("--object-mib", type=float, default=2.0)
    clu.add_argument("--get-fraction", type=float, default=0.5)
    clu.add_argument("--durability", default="writeback",
                     choices=("writeback", "write-through"))
    clu.add_argument("--no-degraded", action="store_true",
                     help="skip the one-server-down rows")
    clu.add_argument("--sweeps", type=int, default=512)
    clu.add_argument("--warm-ladder", action="store_true",
                     help="thread each rung's completions into the next "
                          "rung's fixpoint seed (per-op content-digest "
                          "slot mapping; bit-identical curves, pays on "
                          "--rates ladders)")
    clu.add_argument("--max-refine", type=int, default=None,
                     help="pop-order refinement budget per config "
                          "(default: compiler MAX_REFINE)")
    clu.add_argument("--seed", type=int, default=0)
    clu.add_argument("--out", default=CLUSTER_OUT_DIR,
                     help=f"artifact directory (default {CLUSTER_OUT_DIR})")
    clu.add_argument("--list", action="store_true",
                     help="list placement policies / scheme syntax")
    run = sub.add_parser("run", help="run experiments (one batched sweep)")
    run.add_argument("--all", action="store_true",
                     help="run every registered experiment")
    run.add_argument("--only", default="",
                     help="comma-separated names/numbers (obs4,obs10,...)")
    run.add_argument("--backend", default="vectorized",
                     choices=("event", "vectorized", "auto"))
    run.add_argument("--out", default=DEFAULT_OUT_DIR,
                     help=f"artifact directory (default {DEFAULT_OUT_DIR})")
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--jitter", action="store_true",
                     help="enable stochastic service-time jitter "
                          "(checks are calibrated for jitter off)")
    run.add_argument("--verbose", action="store_true",
                     help="print every check, not just failures")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    if args.cmd == "host":
        return _cmd_host(args)
    if args.cmd == "cluster":
        return _cmd_cluster(args)
    return _cmd_run(args)


if __name__ == "__main__":
    raise SystemExit(main())
