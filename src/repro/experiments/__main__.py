"""CLI for the observation registry.

    python -m repro.experiments run --all [--backend vectorized]
    python -m repro.experiments run --only obs4,obs10 --out results/exp
    python -m repro.experiments list

``run`` executes the selected experiments as one fleet-batched sweep,
writes per-experiment JSON + a markdown report (cross-linking
docs/observations.md), prints a summary table, and exits non-zero if any
check fails.
"""
from __future__ import annotations

import argparse
import sys

from .registry import all_experiments
from .runner import DEFAULT_OUT_DIR, ExperimentRunner


def _cmd_list() -> int:
    for exp in all_experiments():
        print(f"obs{exp.obs:02d}  {exp.name:32s} {exp.figure:10s} "
              f"{len(exp.points)} points  — {exp.title}")
    return 0


def _cmd_run(args) -> int:
    keys = None if args.all else [k for k in args.only.split(",") if k]
    if keys is not None and not keys:
        print("run: pass --all or --only obs4,obs10,...", file=sys.stderr)
        return 2
    try:
        runner = ExperimentRunner(keys, backend=args.backend,
                                  jitter=args.jitter, seed=args.seed)
    except KeyError as e:
        print(f"run: {e.args[0]}", file=sys.stderr)
        return 2
    results = runner.run()
    paths = runner.write_artifacts(results, out_dir=args.out)
    width = max((len(r.name) for r in results), default=4)
    for r in results:
        ok = sum(c.ok for c in r.checks)
        status = "pass" if r.passed else "FAIL"
        print(f"obs{r.obs:02d}  {r.name:{width}s}  {ok}/{len(r.checks)} "
              f"checks  {status}")
        if not r.passed or args.verbose:
            for c in r.checks:
                print(f"        {c}")
    n_pass = sum(r.passed for r in results)
    print(f"\n{n_pass}/{len(results)} experiments passed "
          f"(backend={args.backend}); report: {paths['report']}")
    return 0 if n_pass == len(results) else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list registered experiments")
    run = sub.add_parser("run", help="run experiments (one batched sweep)")
    run.add_argument("--all", action="store_true",
                     help="run every registered experiment")
    run.add_argument("--only", default="",
                     help="comma-separated names/numbers (obs4,obs10,...)")
    run.add_argument("--backend", default="vectorized",
                     choices=("event", "vectorized", "auto"))
    run.add_argument("--out", default=DEFAULT_OUT_DIR,
                     help=f"artifact directory (default {DEFAULT_OUT_DIR})")
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--jitter", action="store_true",
                     help="enable stochastic service-time jitter "
                          "(checks are calibrated for jitter off)")
    run.add_argument("--verbose", action="store_true",
                     help="print every check, not just failures")
    args = ap.parse_args(argv)
    return _cmd_list() if args.cmd == "list" else _cmd_run(args)


if __name__ == "__main__":
    raise SystemExit(main())
