"""CLI for the observation registry + host-scenario sweeps.

    python -m repro.experiments run --all [--backend vectorized]
    python -m repro.experiments run --only obs4,obs10 --out results/exp
    python -m repro.experiments list
    python -m repro.experiments host [--scenarios lsm,cache]
                                     [--policies greedy-open,striped]

``run`` executes the selected experiments as one fleet-batched sweep,
writes per-experiment JSON + a markdown report (cross-linking
docs/observations.md), prints a summary table, and exits non-zero if any
check fails.  ``host`` runs the application-scenario x placement-policy
matrix (`repro.host`) the same way — every combination is one member of
a single :class:`repro.core.DeviceFleet` call — and prints the
per-scenario policy ranking (see docs/host.md).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .registry import all_experiments
from .runner import DEFAULT_OUT_DIR, ExperimentRunner

#: Artifact directory of the ``host`` subcommand.
HOST_OUT_DIR = os.path.join("results", "host")


def _cmd_list() -> int:
    for exp in all_experiments():
        print(f"obs{exp.obs:02d}  {exp.name:32s} {exp.figure:10s} "
              f"{len(exp.points)} points  — {exp.title}")
    return 0


def _cmd_run(args) -> int:
    keys = None if args.all else [k for k in args.only.split(",") if k]
    if keys is not None and not keys:
        print("run: pass --all or --only obs4,obs10,...", file=sys.stderr)
        return 2
    try:
        runner = ExperimentRunner(keys, backend=args.backend,
                                  jitter=args.jitter, seed=args.seed)
    except KeyError as e:
        print(f"run: {e.args[0]}", file=sys.stderr)
        return 2
    results = runner.run()
    paths = runner.write_artifacts(results, out_dir=args.out)
    width = max((len(r.name) for r in results), default=4)
    for r in results:
        ok = sum(c.ok for c in r.checks)
        status = "pass" if r.passed else "FAIL"
        print(f"obs{r.obs:02d}  {r.name:{width}s}  {ok}/{len(r.checks)} "
              f"checks  {status}")
        if not r.passed or args.verbose:
            for c in r.checks:
                print(f"        {c}")
    n_pass = sum(r.passed for r in results)
    print(f"\n{n_pass}/{len(results)} experiments passed "
          f"(backend={args.backend}); report: {paths['report']}")
    return 0 if n_pass == len(results) else 1


def _cmd_host(args) -> int:
    from repro.host import (
        available_placement_policies, available_scenarios, compare_policies,
        rank_policies,
    )

    scenarios = [s for s in args.scenarios.split(",") if s] or None
    policies = [p for p in args.policies.split(",") if p] or None
    if args.list:
        for s in available_scenarios():
            print(f"scenario  {s}")
        for p in available_placement_policies():
            print(f"policy    {p}")
        return 0
    try:
        rows = compare_policies(scenarios, policies, backend=args.backend,
                                seed=args.seed, scale=args.scale)
    except KeyError as e:
        print(f"host: {e.args[0]}", file=sys.stderr)
        return 2
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "host_policies.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
    width = max(len(r["policy"]) for r in rows)
    for r in rows:
        print(f"{r['scenario']:14s} {r['policy']:{width}s} "
              f"makespan={r['makespan_s'] * 1e3:9.2f}ms "
              f"WA={r['write_amplification']:.3f} "
              f"reclaim={r['reclaim_mibs']:8.1f}MiB/s "
              f"({r['n_requests']} reqs)")
    print()
    for scen, order in rank_policies(rows).items():
        print(f"{scen:14s} best-first: {' > '.join(order)}")
    print(f"\n{len(rows)} combinations in one fleet run "
          f"(backend={args.backend}); results: {out_path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="list registered experiments")
    host = sub.add_parser(
        "host", help="host-scenario x placement-policy sweep (repro.host)")
    host.add_argument("--scenarios", default="",
                      help="comma-separated scenario names (default: all)")
    host.add_argument("--policies", default="",
                      help="comma-separated placement policies (default: all)")
    host.add_argument("--backend", default="vectorized",
                      choices=("event", "vectorized", "auto"))
    host.add_argument("--scale", type=float, default=1.0,
                      help="scenario size multiplier")
    host.add_argument("--seed", type=int, default=0)
    host.add_argument("--out", default=HOST_OUT_DIR,
                      help=f"artifact directory (default {HOST_OUT_DIR})")
    host.add_argument("--list", action="store_true",
                      help="list scenarios/policies instead of running")
    run = sub.add_parser("run", help="run experiments (one batched sweep)")
    run.add_argument("--all", action="store_true",
                     help="run every registered experiment")
    run.add_argument("--only", default="",
                     help="comma-separated names/numbers (obs4,obs10,...)")
    run.add_argument("--backend", default="vectorized",
                     choices=("event", "vectorized", "auto"))
    run.add_argument("--out", default=DEFAULT_OUT_DIR,
                     help=f"artifact directory (default {DEFAULT_OUT_DIR})")
    run.add_argument("--seed", type=int, default=0)
    run.add_argument("--jitter", action="store_true",
                     help="enable stochastic service-time jitter "
                          "(checks are calibrated for jitter off)")
    run.add_argument("--verbose", action="store_true",
                     help="print every check, not just failures")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    if args.cmd == "host":
        return _cmd_host(args)
    return _cmd_run(args)


if __name__ == "__main__":
    raise SystemExit(main())
