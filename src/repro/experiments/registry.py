"""Declarative observation registry.

Each of the paper's 13 key observations is encoded as one
:class:`Experiment`: a device configuration, a set of
:class:`SweepPoint` workloads (``WorkloadSpec`` + latency-parameter
profile + seed), a metric extractor, and an executable ``check`` that
asserts the observation's *qualitative* claim against the extracted
metrics.  The :class:`repro.experiments.ExperimentRunner` lowers every
registered experiment's sweep points onto a single batched
:class:`repro.core.DeviceFleet` call, so "run the whole characterization
matrix" is one device-axis-parallel computation.

Example::

    >>> from repro.experiments import all_experiments, get_experiment
    >>> len(all_experiments())
    15
    >>> get_experiment("obs4").title
    'Appends have higher latency than writes'
    >>> get_experiment(4) is get_experiment("obs04_append_vs_write")
    True
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core import LatencyParams, WorkloadSpec, ZNSDeviceSpec
from repro.core.registry import Registry


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (device, workload, seed) simulation of an experiment's sweep.

    ``params=None`` uses the calibrated ZN540 latency profile; emulator
    A/B points name a :data:`repro.core.emulator_models.EMULATOR_PROFILES`
    entry via ``params``.
    """

    label: str
    workload: WorkloadSpec
    spec: ZNSDeviceSpec = dataclasses.field(default_factory=ZNSDeviceSpec)
    params: Optional[LatencyParams] = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Check:
    """One verdict of an experiment's ``check``: a named sub-claim, a
    boolean outcome, and a human-readable detail string."""

    name: str
    ok: bool
    detail: str

    def __str__(self) -> str:
        return f"[{'PASS' if self.ok else 'FAIL'}] {self.name}: {self.detail}"


#: ``extract(ctx) -> {metric: value}`` where ``ctx`` is the runner's
#: :class:`repro.experiments.runner.ExperimentContext`.
ExtractFn = Callable[[object], Dict[str, float]]
#: ``check(metrics) -> (Check, ...)`` — pure over the metric dict.
CheckFn = Callable[[Dict[str, float]], Tuple[Check, ...]]


@dataclasses.dataclass(frozen=True)
class Experiment:
    """One paper observation as an executable, fleet-batchable unit.

    ``knobs`` names the :class:`repro.core.LatencyParams` fields /
    :mod:`repro.core.calibration` anchors that govern the observation
    (the docs tree maps observation -> knob -> test via this field);
    ``tests`` points at the asserting test functions.
    """

    name: str                       # registry key, e.g. "obs04_append_vs_write"
    obs: int                        # 1..13 the paper's numbering; 14+ are
    #                                 scenario extensions built on the model
    title: str
    claim: str                      # the paper's qualitative claim
    figure: str                     # paper figure/section it reproduces
    points: Tuple[SweepPoint, ...]
    extract: ExtractFn
    check: CheckFn
    knobs: Tuple[str, ...] = ()
    tests: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.obs < 1:
            raise ValueError(f"obs must be >= 1, got {self.obs}")
        labels = [p.label for p in self.points]
        if len(set(labels)) != len(labels):
            raise ValueError(f"{self.name}: duplicate sweep-point labels "
                             f"{labels}")


_REGISTRY: Registry = Registry("experiment")


def register_experiment(exp: Experiment, *, replace: bool = False
                        ) -> Experiment:
    """Add an experiment to the registry (warns on name collisions via
    the shared :class:`repro.core.registry.Registry`, mirroring
    :func:`repro.core.register_backend`)."""
    return _REGISTRY.register(exp.name, exp, replace=replace)


def unregister_experiment(name: str) -> None:
    _REGISTRY.unregister(name)


def get_experiment(key) -> Experiment:
    """Look up by registry name (``"obs04_append_vs_write"``), observation
    number (``4`` or ``"obs4"``/``"obs04"``), or unique name substring."""
    if isinstance(key, Experiment):
        return key
    if isinstance(key, int) or (isinstance(key, str) and key.isdigit()):
        num = int(key)
        for exp in _REGISTRY.values():
            if exp.obs == num:
                return exp
        raise KeyError(f"no experiment registered for observation {num}")
    key = str(key)
    if key in _REGISTRY:
        return _REGISTRY[key]
    if key.lower().startswith("obs"):
        tail = key[3:].lstrip("0_")
        if tail.isdigit():
            return get_experiment(int(tail))
    matches = [e for n, e in _REGISTRY.items() if key in n]
    if len(matches) == 1:
        return matches[0]
    raise KeyError(
        f"unknown experiment {key!r} "
        f"({'ambiguous' if matches else 'no match'}); registered: "
        f"{sorted(_REGISTRY)}")


def all_experiments() -> Tuple[Experiment, ...]:
    """Every registered experiment, ordered by observation number."""
    return tuple(sorted(_REGISTRY.values(), key=lambda e: (e.obs, e.name)))


def resolve_experiments(keys: Optional[Sequence] = None
                        ) -> Tuple[Experiment, ...]:
    """``None`` -> all; else each key through :func:`get_experiment`."""
    if keys is None:
        return all_experiments()
    return tuple(get_experiment(k) for k in keys)
