"""Observation registry + fleet-batched experiment runner.

The paper's contribution is 13 key observations about ZNS SSD behavior;
this package makes each one an executable :class:`Experiment` (device
spec + latency profile + workload sweep + metric extractors + a
``check`` asserting the qualitative claim) and runs any subset of them
as **one** batched :class:`repro.core.DeviceFleet` computation.  Two
scenario extensions (obs14/obs15, :mod:`repro.experiments.traffic`)
replay the interference observations under open-loop arrival processes.

    python -m repro.experiments run --all        # all 15, one fleet sweep
    python -m repro.experiments list             # what's registered

    >>> from repro.experiments import ExperimentRunner, get_experiment
    >>> res = ExperimentRunner(["obs13"]).run()[0]
    >>> res.passed, round(res.metrics["write_inflation_pct"], 2)
    (True, 78.42)

`docs/observations.md` maps every observation to its registry entry,
model knobs, and tests; ``benchmarks/fig2..fig8`` + ``table1`` are thin
shims over these entries.
"""
from .registry import (  # noqa: F401
    Check, Experiment, SweepPoint, all_experiments, get_experiment,
    register_experiment, resolve_experiments, unregister_experiment,
)
from .runner import (  # noqa: F401
    DEFAULT_OUT_DIR, ExperimentContext, ExperimentResult, ExperimentRunner,
    render_report,
)
from . import observations  # noqa: F401  (populates the registry)
from . import traffic  # noqa: F401  (obs14/obs15 open-loop scenarios)
