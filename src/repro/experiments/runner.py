"""Fleet-batched experiment runner + artifact emission.

The runner flattens every selected experiment's sweep points into one
list, builds a single heterogeneous :class:`repro.core.DeviceFleet`
(one member per point — specs and latency-parameter pytrees may differ
per point), and solves the whole characterization matrix with one
batched fleet call instead of N sequential device runs.  On the
``vectorized`` backend every sweep point lowers through the
trace-compilation layer into one fleet-level
:class:`repro.core.ChainProgram` solved by a single fused fixpoint
(compiled programs are cached, so re-running a selection skips
re-lowering); the ``event`` backend degrades to a per-point loop with
identical semantics.  Per-experiment results surface the fixpoint's
convergence diagnostics (``ExperimentResult.converged``).

    >>> from repro.experiments import ExperimentRunner
    >>> runner = ExperimentRunner(["obs4"], backend="event")
    >>> [r.passed for r in runner.run()]
    [True]
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import DeviceFleet, LatencyModel, RunResult, ZnsDevice

from .registry import Check, Experiment, resolve_experiments

#: Default artifact directory of the CLI (``python -m repro.experiments``).
DEFAULT_OUT_DIR = os.path.join("results", "experiments")


@dataclasses.dataclass
class ExperimentContext:
    """What an experiment's ``extract`` callback sees: the per-point
    simulation results plus a single-device session for closed-form
    metrics (``ctx.device.steady_state`` etc.)."""

    experiment: Experiment
    results: Dict[str, RunResult]    # sweep-point label -> result
    device: ZnsDevice                # session on the experiment's device
    backend: str

    def __getitem__(self, label: str) -> RunResult:
        if label not in self.results:
            raise KeyError(
                f"{self.experiment.name}: unknown sweep point {label!r}; "
                f"have {sorted(self.results)}")
        return self.results[label]


@dataclasses.dataclass
class ExperimentResult:
    """One experiment's extracted metrics + check verdicts."""

    experiment: Experiment
    backend: str
    metrics: Dict[str, float]
    checks: Tuple[Check, ...]
    n_requests: int
    #: False if any sweep point's fixpoint exhausted its budget (the
    #: chain-program backends surface convergence; the event engine is
    #: always converged).
    converged: bool = True

    @property
    def name(self) -> str:
        return self.experiment.name

    @property
    def obs(self) -> int:
        return self.experiment.obs

    @property
    def passed(self) -> bool:
        return all(c.ok for c in self.checks)

    def to_json(self) -> Dict:
        """JSON-ready dict (non-finite floats become ``None``)."""
        clean = {k: (float(v) if math.isfinite(v) else None)
                 for k, v in self.metrics.items()}
        exp = self.experiment
        return {
            "name": exp.name, "obs": exp.obs, "title": exp.title,
            "claim": exp.claim, "figure": exp.figure,
            "knobs": list(exp.knobs), "tests": list(exp.tests),
            "backend": self.backend, "n_requests": self.n_requests,
            "passed": bool(self.passed), "converged": bool(self.converged),
            "metrics": clean,
            "checks": [{"name": c.name, "ok": bool(c.ok), "detail": c.detail}
                       for c in self.checks],
        }


class ExperimentRunner:
    """Run a set of registry experiments as one batched fleet sweep.

    ``experiments=None`` selects the full registry (all 13 observations).
    ``jitter=False`` by default so extracted metrics are deterministic
    and ``check()`` verdicts are reproducible on both backends.
    """

    def __init__(self, experiments: Optional[Sequence] = None, *,
                 backend: str = "vectorized", jitter: bool = False,
                 seed: int = 0):
        self.experiments = resolve_experiments(experiments)
        self.backend = backend
        self.jitter = jitter
        self.seed = seed

    def run(self) -> List[ExperimentResult]:
        """One fleet-batched simulation of every sweep point, then
        per-experiment extraction and checks."""
        points = [(exp, pt) for exp in self.experiments
                  for pt in exp.points]
        if not points:
            return []
        fleet = DeviceFleet(
            [(pt.spec, pt.params) if pt.params is not None else pt.spec
             for _, pt in points])
        fres = fleet.run([pt.workload for _, pt in points],
                         backend=self.backend,
                         seeds=[self.seed + pt.seed for _, pt in points],
                         jitter=self.jitter)
        out: List[ExperimentResult] = []
        i = 0
        for exp in self.experiments:
            results = {pt.label: fres[i + j]
                       for j, pt in enumerate(exp.points)}
            i += len(exp.points)
            first = exp.points[0]
            dev = ZnsDevice(first.spec,
                            lat=LatencyModel(first.spec, first.params)
                            if first.params is not None else None)
            ctx = ExperimentContext(experiment=exp, results=results,
                                    device=dev, backend=fres.backend)
            metrics = exp.extract(ctx)
            checks = tuple(exp.check(metrics))
            out.append(ExperimentResult(
                experiment=exp, backend=fres.backend, metrics=metrics,
                checks=checks,
                n_requests=sum(len(r) for r in results.values()),
                converged=all(r.converged for r in results.values())))
        return out

    # -- artifacts -----------------------------------------------------------
    def write_artifacts(self, results: Sequence[ExperimentResult],
                        out_dir: str = DEFAULT_OUT_DIR) -> Dict[str, str]:
        """Emit per-experiment JSON + a rendered markdown report.

        Returns ``{artifact name: path}``; the report cross-links
        ``docs/observations.md`` (the observation -> code map).
        """
        os.makedirs(out_dir, exist_ok=True)
        paths: Dict[str, str] = {}
        for res in results:
            p = os.path.join(out_dir, f"{res.name}.json")
            with open(p, "w") as f:
                json.dump(res.to_json(), f, indent=1, sort_keys=True)
            paths[res.name] = p
        report = os.path.join(out_dir, "report.md")
        with open(report, "w") as f:
            f.write(render_report(results, out_dir=out_dir))
        paths["report"] = report
        return paths


def _docs_link(out_dir: str) -> str:
    """Relative link from the artifact dir to docs/observations.md (falls
    back to the repo-root-relative path when the docs tree isn't nearby)."""
    here = os.path.abspath(out_dir)
    probe = here
    for _ in range(6):
        cand = os.path.join(probe, "docs", "observations.md")
        if os.path.exists(cand):
            return os.path.relpath(cand, here)
        probe = os.path.dirname(probe)
    return "docs/observations.md"


def render_report(results: Sequence[ExperimentResult], *,
                  out_dir: str = DEFAULT_OUT_DIR) -> str:
    """Markdown report: one row per observation, check details below."""
    docs = _docs_link(out_dir)
    n_pass = sum(r.passed for r in results)
    backend = results[0].backend if results else "-"
    lines = [
        "# ZNS observation experiments — run report",
        "",
        f"Backend: `{backend}` · experiments: {len(results)} · "
        f"passed: {n_pass}/{len(results)}",
        "",
        f"Each experiment is one entry of the observation registry "
        f"(`repro.experiments`); see [{docs}]({docs}) for the full "
        f"observation → workload → model-knob map.",
        "",
        "| Obs | Experiment | Paper ref | Requests | Checks | Status |",
        "| --- | --- | --- | --- | --- | --- |",
    ]
    for r in results:
        ok = sum(c.ok for c in r.checks)
        status = "✅ pass" if r.passed else "❌ FAIL"
        lines.append(
            f"| #{r.obs} | [`{r.name}`]({r.name}.json) | {r.experiment.figure}"
            f" | {r.n_requests} | {ok}/{len(r.checks)} | {status} |")
    for r in results:
        lines += ["", f"## Obs#{r.obs} — {r.experiment.title}", "",
                  f"> {r.experiment.claim}", ""]
        for c in r.checks:
            mark = "✅" if c.ok else "❌"
            lines.append(f"- {mark} **{c.name}** — {c.detail}")
    stale = [r.name for r in results if not r.converged]
    if stale:
        lines += [
            "",
            f"> ⚠️ **Fixpoint did not converge** for: "
            f"{', '.join(f'`{n}`' for n in stale)} — metrics above are "
            f"lower bounds from an exhausted sweep budget, not steady-state "
            f"values. Re-run with a larger `sweeps` budget.",
        ]
    lines.append("")
    return "\n".join(lines)
