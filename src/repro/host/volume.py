"""Log-structured volume: host write streams -> device workloads.

:class:`LogStructuredVolume` is the facade that ties the host layer
together: applications write/read/delete *objects* on named streams; the
volume places bytes through a :class:`ZoneAllocator` (policy-driven),
tracks validity for the :class:`ReclaimScheduler`, and **compiles** the
accumulated host activity into a declarative
:class:`repro.core.WorkloadSpec` — so a whole application scenario runs
as one batched device simulation on either backend (and many scenarios
run as one :class:`repro.core.DeviceFleet` call).

    vol = LogStructuredVolume(spec, policy="lifetime-binned")
    vol.write("sst-1", 8 * MiB, stream=0, lifetime=0)
    vol.read("sst-1")
    vol.delete("sst-1")
    vol.collect()                       # host GC: relocate + reset
    res = vol.run(backend="vectorized") # compiled WorkloadSpec, one run
    res.write_amplification, res.result.latency_stats().p99_us
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    KiB, MiB, OpType, RunResult, WorkloadSpec, ZnsDevice, ZNSDeviceSpec,
    ZoneError,
)

from .allocator import Extent, ZoneAllocator
from .reclaim import ReclaimReport, ReclaimScheduler


@dataclasses.dataclass
class HostObject:
    key: str
    extents: List[Extent]
    nbytes: int
    stream: int
    lifetime: Optional[int]


@dataclasses.dataclass(frozen=True)
class _ReclaimEvent:
    """One collect(): captured at reclaim time for faithful compilation."""

    occupancies: tuple          # per victim zone, at reset time
    zone: int                   # representative victim (for the trace)
    relocated_bytes: int


@dataclasses.dataclass
class HostRunResult:
    """Device-simulation result + host-layer accounting of one volume."""

    result: RunResult
    user_bytes: int             # bytes applications asked to write
    device_bytes: int           # user + relocation bytes hitting flash
    reclaim: ReclaimReport      # cumulative reclaim totals
    policy: str

    @property
    def write_amplification(self) -> float:
        if self.user_bytes <= 0:
            return 1.0
        return self.device_bytes / self.user_bytes

    @property
    def makespan_s(self) -> float:
        c = self.result.sim.complete
        return float(c.max()) / 1e6 if len(c) else 0.0

    @property
    def user_bandwidth_mibs(self) -> float:
        span = self.makespan_s
        return self.user_bytes / span / MiB if span > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "policy_makespan_s": self.makespan_s,
            "user_bytes": float(self.user_bytes),
            "device_bytes": float(self.device_bytes),
            "write_amplification": self.write_amplification,
            "user_bandwidth_mibs": self.user_bandwidth_mibs,
            "zones_reset": float(self.reclaim.zones_reset),
            "reclaim_mibs": self.reclaim.reclaim_mibs,
            "reclaim_seconds": self.reclaim.seconds,
        }


class LogStructuredVolume:
    """Object store over one ZNS device, compiled to ``WorkloadSpec``\\ s.

    Host activity (writes per stream, reads, deletes, collects) is both
    *applied* — the zone state machine, allocator and reclaim scheduler
    advance immediately, so legality and limits are enforced live — and
    *recorded*, so :meth:`compile` can replay the whole history as a
    declarative workload for either simulation backend.
    """

    def __init__(self, spec: Optional[ZNSDeviceSpec] = None, *,
                 device: Optional[ZnsDevice] = None,
                 policy: str = "greedy-open",
                 stripe_bytes: int = 1 * MiB,
                 append_qd: int = 4,
                 read_qd: int = 8,
                 read_chunk: int = 32 * KiB,
                 io_ctx: Optional[OpType] = OpType.APPEND,
                 **alloc_kw):
        self.device = device if device is not None else ZnsDevice(spec)
        self.spec = self.device.spec
        self.allocator = ZoneAllocator(zones=self.device.zones, policy=policy,
                                       stripe_bytes=stripe_bytes, **alloc_kw)
        self.reclaim = ReclaimScheduler(self.device, allocator=self.allocator,
                                        io_ctx=io_ctx,
                                        relocation_stripe=stripe_bytes,
                                        relocation_qd=append_qd)
        self.policy = policy
        self.stripe_bytes = int(stripe_bytes)
        self.append_qd = int(append_qd)
        self.read_qd = int(read_qd)
        self.read_chunk = int(read_chunk)
        self.io_ctx = io_ctx
        self.objects: Dict[str, HostObject] = {}
        self.user_bytes = 0
        self._stream_bytes: Dict[int, int] = {}   # insertion-ordered
        self._read_bytes = 0
        self._read_zones: set = set()
        self._events: List[_ReclaimEvent] = []

    # -- host operations -----------------------------------------------------
    def write(self, key: str, nbytes: int, *, stream: int = 0,
              lifetime: Optional[int] = None) -> HostObject:
        """Append an object; placement is the active policy's call."""
        if key in self.objects:
            raise ZoneError(f"object {key!r} already exists (log-structured: "
                            f"delete then rewrite)")
        extents = self.allocator.allocate(int(nbytes), stream=stream,
                                          lifetime=lifetime)
        self.reclaim.account(extents)
        obj = HostObject(key=key, extents=extents, nbytes=int(nbytes),
                         stream=stream, lifetime=lifetime)
        self.objects[key] = obj
        self.user_bytes += int(nbytes)
        self._stream_bytes[stream] = \
            self._stream_bytes.get(stream, 0) + int(nbytes)
        return obj

    def read(self, key: str) -> HostObject:
        obj = self.objects[key]
        for e in obj.extents:
            self.device.zones.read(e.zone, e.offset, e.nbytes)
            self._read_zones.add(e.zone)
        self._read_bytes += obj.nbytes
        return obj

    def delete(self, key: str) -> None:
        obj = self.objects.pop(key)
        self.reclaim.invalidate(obj.extents)

    def collect(self, n: int = 1, *, max_valid_frac: float = 1.0,
                concurrent_io: bool = True) -> ReclaimReport:
        """Host GC: pick ``n`` least-valid victims, relocate their live
        objects, reset them (live state mutation), and record the event
        for compilation."""
        victims = self.reclaim.pick_victims(n, max_valid_frac=max_valid_frac)
        if not victims:
            return ReclaimReport()
        vset = set(victims)
        cap = self.spec.zone_cap_bytes
        occs = tuple(
            float(np.clip(self.device.zones.write_pointer(z) / cap, 0.0, 1.0))
            for z in victims)
        # Relocate surviving objects out of the victims before the reset;
        # their extents repoint at the new copies so later reads/deletes
        # stay consistent.  Victim zones are frozen out of placement.
        # The new copy is allocated *before* the old one is invalidated:
        # if the device is too full to relocate, the collect aborts with
        # every object and the validity accounting intact (already-moved
        # objects keep their new copies) and the victims thawed.
        for obj in self.objects.values():
            dead = [e for e in obj.extents if e.zone in vset]
            if not dead:
                continue
            keep = [e for e in obj.extents if e.zone not in vset]
            moved = sum(e.nbytes for e in dead)
            try:
                fresh = self.allocator.allocate(moved, stream=obj.stream,
                                                lifetime=obj.lifetime)
            except ZoneError:
                self.reclaim.unschedule(victims)
                raise
            self.reclaim.invalidate(dead)
            self.reclaim.account(fresh)
            self.reclaim.charge_relocation(moved)
            obj.extents = keep + fresh
        rep = self.reclaim.drain(concurrent_io=concurrent_io)
        self._events.append(_ReclaimEvent(occupancies=occs, zone=victims[0],
                                          relocated_bytes=rep.relocated_bytes))
        return rep

    def free_capacity_frac(self) -> float:
        zm = self.device.zones
        used = sum(zm.write_pointer(z) for z in range(self.spec.num_zones))
        return 1.0 - used / self.spec.capacity_bytes

    # -- compilation ---------------------------------------------------------
    def compile_program(self, *, include_reclaim: bool = True):
        """Lower the recorded host history all the way down the compile
        pipeline: host history → :class:`repro.core.WorkloadSpec` →
        ``Trace`` → :class:`repro.core.ChainProgram` bound to this
        volume's device.  The program is content-cached, so repeated
        :meth:`run`/policy-comparison calls on an unchanged history skip
        re-lowering; its ``exact`` flag states whether the fused
        fixpoint reproduces the event engine to float tolerance for
        this history (single-service-class pools, stable pop order).
        """
        from repro.core import compile_program as _compile
        wl = self.compile(include_reclaim=include_reclaim)
        return _compile(wl.build(), self.device.spec, self.device.lat)

    def compile(self, *, include_reclaim: bool = True) -> WorkloadSpec:
        """Replay the recorded host history as a declarative workload.

        Per write stream: one closed-loop append stream (``append_qd``)
        of stripe-sized requests.  Reads become one random-read stream
        over the touched zones.  Reclaim compiles to one reset sweep at
        every ``collect``'s captured occupancies (``io_ctx`` charges
        Obs#13) plus one relocation-append stream.  Every stream gets
        its own thread, matching the paper's multi-threaded host
        layouts; every stream is single-service-class, so the compiled
        trace stays inside the chain-program compiler's exactness
        envelope and the ``event`` and ``vectorized`` backends agree to
        float tolerance even when the append pool saturates (see
        :meth:`compile_program`).
        """
        wl = WorkloadSpec()
        relocated = sum(ev.relocated_bytes for ev in self._events) \
            if include_reclaim else 0
        append_bytes = self.user_bytes + relocated
        if append_bytes > 0:
            # One closed-loop append stream for all append traffic (user
            # streams + relocation): a single saturated stream is the
            # D/D/c case both backends solve identically; per-stream
            # byte attribution stays in the host accounting.
            n = max(int(np.ceil(append_bytes / self.stripe_bytes)), 1)
            wl = wl.appends(n=n, size=self.stripe_bytes, qd=self.append_qd,
                            zone=0, nzones=max(self.allocator.zones_opened, 1))
        if self._read_bytes > 0:
            n = max(int(np.ceil(self._read_bytes / self.read_chunk)), 1)
            wl = wl.reads(n=n, size=self.read_chunk, qd=self.read_qd,
                          zone=min(self._read_zones, default=0),
                          nzones=max(len(self._read_zones), 1))
        if include_reclaim and self._events:
            ctx = -1 if self.io_ctx is None else int(self.io_ctx)
            occs = tuple(o for ev in self._events for o in ev.occupancies)
            wl = wl.stream(OpType.RESET, n=1, occupancies=occs,
                           n_per_level=1, zone=self._events[0].zone,
                           io_ctx=ctx)
        return wl

    def run(self, *, backend: str = "auto", seed: int = 0,
            jitter: bool = False, include_reclaim: bool = True
            ) -> HostRunResult:
        """Compile and simulate on this volume's device."""
        wl = self.compile(include_reclaim=include_reclaim)
        res = self.device.run(wl, backend=backend, seed=seed, jitter=jitter)
        return self._wrap(res)

    def _wrap(self, res: RunResult) -> HostRunResult:
        return HostRunResult(
            result=res, user_bytes=self.user_bytes,
            device_bytes=self.user_bytes + self.reclaim.total.relocated_bytes,
            reclaim=self.reclaim.total, policy=self.policy)

    def __repr__(self) -> str:
        return (f"LogStructuredVolume(policy={self.policy!r}, "
                f"objects={len(self.objects)}, "
                f"user_bytes={self.user_bytes})")
