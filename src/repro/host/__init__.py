"""Host storage-stack layer: zone allocation, reclaim scheduling, and
log-structured volumes over the calibrated ZNS device model.

The paper closes with guidelines for ZNS *application* developers; this
package is where those guidelines become executable host policy:

* :class:`ZoneAllocator` — pluggable placement policies
  (``greedy-open`` / ``striped`` / ``lifetime-binned``,
  :func:`register_placement_policy`) bounded by the device's
  max-open/max-active limits, following fill-don't-finish (R3).
* :class:`ReclaimScheduler` — host GC as reset traffic concurrent with
  foreground I/O: occupancy-dependent reset costs (Obs#10), Obs#13
  inflation charged to reclaim throughput (never the write path,
  Obs#12), write-amplification accounting for relocation.
* :class:`LogStructuredVolume` — object writes/reads/deletes/GC on one
  device, compiled to :class:`repro.core.WorkloadSpec`\\ s so whole app
  scenarios simulate batched on either backend.
* scenarios — ``lsm`` / ``circular-log`` / ``cache`` generators
  (:func:`register_scenario`) + :func:`compare_policies`, which runs
  every (scenario, policy) combination as one
  :class:`repro.core.DeviceFleet` call.
* :mod:`repro.host.conformance` — replay/differential validation of zone
  op sequences (imperative manager vs vectorized table semantics).

    from repro.host import LogStructuredVolume, compare_policies
    rows = compare_policies(["lsm"], backend="vectorized")
"""
from .allocator import (  # noqa: F401
    Extent, StreamHint, ZoneAllocator, available_placement_policies,
    register_placement_policy, unregister_placement_policy,
)
from .reclaim import ReclaimReport, ReclaimScheduler  # noqa: F401
from .volume import HostObject, HostRunResult, LogStructuredVolume  # noqa: F401
from .scenarios import (  # noqa: F401
    HOST_SCENARIO_SPEC, ScenarioBuild, available_scenarios, build_scenario,
    compare_policies, rank_policies, register_scenario, unregister_scenario,
)
from . import conformance  # noqa: F401
