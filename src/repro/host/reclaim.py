"""Zone reclaim (host-side GC) scheduling and costing.

ZNS devices do no background GC (Obs#11/#12): reclaiming space is the
*host's* job — relocate whatever is still valid out of a victim zone,
then ``reset`` it.  The :class:`ReclaimScheduler` models that traffic
against the calibrated ZN540 model:

* reset cost is occupancy-dependent (Obs#10, linear) and — when resets
  run concurrently with foreground I/O — inflated by the paper's
  measured +78% p95 factor (Obs#13, ``LatencyParams.reset_inflation``);
  the inflation is charged to *reclaim throughput*, never to the
  foreground write path (Obs#12 holds structurally in the engines).
* relocation traffic (valid bytes moved before the reset) is charged at
  the device's append bandwidth and surfaces as write amplification.

The scheduler tracks valid bytes per zone (`account` / `invalidate`),
selects victims greedily by least-valid-data, and can either cost a
backlog drain in closed form (:meth:`drain`) or compile the reclaim
traffic into a :class:`repro.core.WorkloadSpec` stream
(:meth:`reclaim_workload`) so it simulates *concurrently with* a
foreground workload on either backend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (MiB, OpType, TraceReplay, WorkloadSpec, ZnsDevice,
                        ZoneError, spread_into_windows)

from .allocator import Extent, ZoneAllocator


@dataclasses.dataclass
class ReclaimReport:
    """Outcome of one backlog drain."""

    zones_reset: int = 0
    reclaimed_bytes: int = 0      # zone capacity returned to the free pool
    relocated_bytes: int = 0      # valid data rewritten before resets
    seconds: float = 0.0          # modeled reclaim wall time

    @property
    def write_amplification(self) -> float:
        """Device bytes per reclaimed byte beyond the user's own write
        (1.0 = pure resets, no relocation)."""
        if self.reclaimed_bytes <= 0:
            return 1.0
        return 1.0 + self.relocated_bytes / self.reclaimed_bytes

    @property
    def reclaim_mibs(self) -> float:
        """Reclaim throughput: capacity returned per modeled second."""
        if self.seconds <= 0:
            return float("inf") if self.reclaimed_bytes else 0.0
        return self.reclaimed_bytes / self.seconds / MiB


class ReclaimScheduler:
    """Backlog of reclaimable zones + calibrated costing of draining it.

    ``io_ctx`` names the foreground op type running concurrently with
    reclaim (charging Obs#13 inflation); ``None`` models isolated resets.
    """

    def __init__(self, device: ZnsDevice, *,
                 allocator: Optional[ZoneAllocator] = None,
                 io_ctx: Optional[OpType] = OpType.APPEND,
                 relocation_stripe: int = 1 * MiB,
                 relocation_qd: int = 4):
        self.device = device
        self.spec = device.spec
        self.zm = device.zones
        self.allocator = allocator
        self.io_ctx = io_ctx
        self.relocation_stripe = int(relocation_stripe)
        self.relocation_qd = int(relocation_qd)
        self.backlog: List[int] = []
        self._valid: Dict[int, int] = {}      # zone -> valid bytes
        self._pending_relocation = 0          # host-attributed moves to cost
        self.total = ReclaimReport()

    # -- validity accounting -------------------------------------------------
    def account(self, extents: List[Extent]) -> None:
        """Record freshly written extents as valid data."""
        for e in extents:
            self._valid[e.zone] = self._valid.get(e.zone, 0) + e.nbytes

    def invalidate(self, extents: List[Extent]) -> None:
        """Mark extents dead (deleted/overwritten/evicted objects)."""
        for e in extents:
            v = self._valid.get(e.zone, 0) - e.nbytes
            self._valid[e.zone] = max(v, 0)

    def valid_bytes(self, zone: int) -> int:
        return self._valid.get(zone, 0)

    # -- victim selection ----------------------------------------------------
    def schedule(self, zones) -> None:
        """Queue explicit zones for reclaim (deduplicated, order kept).
        Queued zones are frozen out of placement until their reset."""
        for z in zones:
            if z not in self.backlog:
                self.backlog.append(z)
                if self.allocator is not None:
                    self.allocator.frozen.add(z)

    def unschedule(self, zones) -> None:
        """Abort a pending reclaim of ``zones``: drop them from the
        backlog and thaw them for placement (used when a caller cannot
        complete the relocation step, e.g. the device is too full)."""
        for z in zones:
            if z in self.backlog:
                self.backlog.remove(z)
            if self.allocator is not None:
                self.allocator.frozen.discard(z)

    def charge_relocation(self, nbytes: int) -> None:
        """Record host-side relocation traffic (an object owner already
        re-placed the bytes through the allocator); the next ``drain``
        folds its cost and byte count into the report."""
        self._pending_relocation += int(nbytes)

    def pick_victims(self, n: int = 1, *, max_valid_frac: float = 1.0
                     ) -> List[int]:
        """Greedy least-valid-data victims among non-empty zones, queued
        onto the backlog.  ``max_valid_frac`` bounds how much relocation
        a victim may require (1.0 = any)."""
        cap = self.spec.zone_cap_bytes
        cands: List[Tuple[int, int]] = []
        for z in range(self.spec.num_zones):
            if z in self.backlog:
                continue
            if self.zm.write_pointer(z) == 0:
                continue
            valid = self.valid_bytes(z)
            if valid <= max_valid_frac * cap:
                cands.append((valid, z))
        cands.sort()
        picked = [z for _, z in cands[:n]]
        self.schedule(picked)
        return picked

    # -- costing -------------------------------------------------------------
    def _reset_cost_us(self, occupancy: float, was_finished: bool,
                       concurrent_io: bool) -> float:
        us = float(self.device.lat.reset_us(occupancy, was_finished))
        if concurrent_io and self.io_ctx is not None:
            us *= float(self.device.lat.reset_inflation([self.io_ctx]))
        return us

    def _relocation_cost_s(self, nbytes: int) -> float:
        if nbytes <= 0:
            return 0.0
        bw = self.device.steady_state(
            OpType.APPEND, self.relocation_stripe,
            qd=self.relocation_qd).bandwidth_bytes
        return nbytes / bw

    def drain(self, *, concurrent_io: bool = True) -> ReclaimReport:
        """Reclaim every backlog zone: relocate valid bytes, reset, and
        return the costed :class:`ReclaimReport`.  Mutates zone state
        (resets happen) and re-places relocated bytes through the
        allocator when one is attached."""
        rep = ReclaimReport()
        pend, self._pending_relocation = self._pending_relocation, 0
        if pend > 0:
            rep.relocated_bytes += pend
            rep.seconds += self._relocation_cost_s(pend)
        backlog, self.backlog = self.backlog, []
        for z in backlog:
            valid = self.valid_bytes(z)
            if valid > 0:
                if self.allocator is not None:
                    # Relocation is a host write: it must land somewhere.
                    moved = self.allocator.allocate(valid, stream=-1)
                    self.account(moved)
                rep.relocated_bytes += valid
                rep.seconds += self._relocation_cost_s(valid)
            try:
                occ, finished = self.zm.reset(z)
            except ZoneError:
                if self.allocator is not None:
                    self.allocator.frozen.discard(z)
                continue                      # zone vanished; skip costing
            if self.allocator is not None:
                self.allocator.frozen.discard(z)
            rep.zones_reset += 1
            rep.reclaimed_bytes += int(round(occ * self.spec.zone_cap_bytes))
            rep.seconds += self._reset_cost_us(occ, finished,
                                               concurrent_io) / 1e6
            self._valid[z] = 0
            if self.allocator is not None:
                self.allocator.forget_zone(z)
        self.total.zones_reset += rep.zones_reset
        self.total.reclaimed_bytes += rep.reclaimed_bytes
        self.total.relocated_bytes += rep.relocated_bytes
        self.total.seconds += rep.seconds
        return rep

    # -- workload compilation ------------------------------------------------
    def reclaim_workload(self, *, base: Optional[WorkloadSpec] = None,
                         thread: Optional[int] = None,
                         windows: Optional[Sequence[Tuple[float, float]]]
                         = None) -> WorkloadSpec:
        """Compile the backlog into reset (+ relocation append) streams on
        ``base`` **without draining it** — running the returned spec on a
        device models reclaim concurrent with whatever else is in
        ``base``.  Occupancies are read from live zone state.

        ``windows`` schedules the resets *open-loop into load troughs*:
        issue times are spread over the given ``(start_us, end_us)``
        windows proportionally to window length (diurnal scheduling —
        reclaim runs when foreground traffic is quiet) instead of
        back-to-back from time zero.  Omitting it keeps the legacy
        closed-loop drain."""
        wl = base if base is not None else WorkloadSpec()
        if not self.backlog:
            return wl
        cap = self.spec.zone_cap_bytes
        occs = tuple(
            float(np.clip(self.zm.write_pointer(z) / cap, 0.0, 1.0))
            for z in self.backlog)
        relocate = sum(self.valid_bytes(z) for z in self.backlog)
        ctx = -1 if self.io_ctx is None else int(self.io_ctx)
        kw = {} if thread is None else {"thread": thread}
        if windows is not None:
            times = spread_into_windows(len(occs), windows)
            kw.update(qd=0,
                      arrival=TraceReplay(times_us=tuple(map(float, times))))
        wl = wl.stream(OpType.RESET, n=1, occupancies=occs, n_per_level=1,
                       zone=self.backlog[0], io_ctx=ctx, **kw)
        if relocate > 0:
            n = max(int(np.ceil(relocate / self.relocation_stripe)), 1)
            wl = wl.appends(n=n, size=self.relocation_stripe,
                            qd=self.relocation_qd, zone=self.backlog[0])
        return wl
