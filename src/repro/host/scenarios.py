"""Application scenarios over the host layer + policy comparison.

Three registry-visible scenario generators (the application classes the
paper's guidelines target, and where follow-on ZNS work lives):

* ``"lsm"``          — LSM-tree flush + compaction: short-lived L0
  flushes, long-lived compacted runs, deletes of compaction inputs, host
  GC of the freed zones (RocksDB-on-ZNS shape).
* ``"circular-log"`` — a bounded circular log: append at the head, trim
  whole zones at the tail.  Data dies strictly in write order, so
  reclaim is pure resets (write amplification ≈ 1) — the ZNS best case.
* ``"cache"``        — cache admission/eviction: admissions append,
  hits read, random evictions punch holes, so victims carry live data
  that must be relocated (write amplification > 1) — the flash-cache
  shape of arXiv:2410.11260.

Each scenario *drives* a :class:`LogStructuredVolume` deterministically
(seeded) and returns the compiled :class:`repro.core.WorkloadSpec` plus
the host-layer accounting; :func:`compare_policies` builds every
(scenario, placement-policy) combination and simulates them all with
**one** batched :class:`repro.core.DeviceFleet` call on either backend.

    >>> from repro.host import available_scenarios, build_scenario
    >>> available_scenarios()
    ('cache', 'circular-log', 'lsm')
    >>> b = build_scenario("circular-log", policy="greedy-open")
    >>> b.stats["write_amplification"]
    1.0
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import (
    DeviceFleet, MiB, WorkloadSpec, ZNSDeviceSpec,
)

from repro.core.registry import Registry
from .allocator import available_placement_policies
from .volume import LogStructuredVolume

#: Scaled-down geometry scenarios default to: ZN540 ratios (cap < size,
#: 14 open/active) at 1/32 zone scale so the event backend stays cheap.
HOST_SCENARIO_SPEC = ZNSDeviceSpec(
    name="ZN540-host-1/32",
    zone_size_bytes=64 * MiB, zone_cap_bytes=48 * MiB, num_zones=64,
    max_open_zones=14, max_active_zones=14)

_SCENARIOS = Registry("host scenario")

#: ``fn(volume, rng, scale, **cfg) -> None`` — drive the volume's host
#: operations; everything observable must derive from ``rng``/``cfg``.
ScenarioFn = Callable[..., None]


def register_scenario(name: str, fn: Optional[ScenarioFn] = None, *,
                      replace: bool = False):
    """Register a scenario driver (decorator-friendly, warn-on-collision,
    mirroring :func:`repro.core.register_backend`)."""
    return _SCENARIOS.register(name, fn, replace=replace)


def unregister_scenario(name: str) -> None:
    _SCENARIOS.unregister(name)


def available_scenarios() -> tuple:
    return _SCENARIOS.available()


@dataclasses.dataclass
class ScenarioBuild:
    """One driven scenario: final host state + compiled device workload."""

    name: str
    policy: str
    seed: int
    volume: LogStructuredVolume
    workload: WorkloadSpec
    stats: Dict[str, float]


def build_scenario(name: str, *, spec: Optional[ZNSDeviceSpec] = None,
                   policy: str = "greedy-open", seed: int = 0,
                   scale: float = 1.0, **cfg) -> ScenarioBuild:
    """Drive scenario ``name`` on a fresh volume; deterministic in
    ``(name, spec, policy, seed, scale, cfg)``."""
    fn = _SCENARIOS.get(name)
    spec = spec if spec is not None else HOST_SCENARIO_SPEC
    vol = LogStructuredVolume(spec, policy=policy)
    rng = np.random.default_rng(seed)
    fn(vol, rng, scale, **cfg)
    wl = vol.compile()
    stats = {
        "user_bytes": float(vol.user_bytes),
        "device_bytes": float(vol.user_bytes
                              + vol.reclaim.total.relocated_bytes),
        "write_amplification":
            (vol.user_bytes + vol.reclaim.total.relocated_bytes)
            / vol.user_bytes if vol.user_bytes else 1.0,
        "zones_reset": float(vol.reclaim.total.zones_reset),
        "zones_opened": float(vol.allocator.zones_opened),
        "reclaim_seconds": vol.reclaim.total.seconds,
        "reclaim_mibs": vol.reclaim.total.reclaim_mibs,
    }
    return ScenarioBuild(name=name, policy=policy, seed=seed, volume=vol,
                         workload=wl, stats=stats)


# ---------------------------------------------------------------------------
# Built-in scenarios
# ---------------------------------------------------------------------------
@register_scenario("lsm")
def _lsm(vol: LogStructuredVolume, rng, scale: float = 1.0, *,
         memtable_bytes: int = 8 * MiB, fanout: int = 4,
         flushes: int = 24) -> None:
    """Flush L0 memtables (short-lived); every ``fanout`` flushes,
    compact them into one long-lived run, delete the inputs, and GC."""
    n_flushes = max(int(flushes * scale), fanout)
    level0: List[str] = []
    runs = 0
    for i in range(n_flushes):
        key = f"mem-{i}"
        vol.write(key, memtable_bytes, stream=0, lifetime=0)
        level0.append(key)
        if len(level0) >= fanout:
            for k in level0:
                vol.read(k)                       # compaction reads inputs
            merged = int(memtable_bytes * fanout * 0.9)  # dedup shrinks
            vol.write(f"run-{runs}", merged, stream=1, lifetime=1)
            runs += 1
            for k in level0:
                vol.delete(k)
            level0 = []
            vol.collect(2, max_valid_frac=0.75)


@register_scenario("circular-log")
def _circular_log(vol: LogStructuredVolume, rng, scale: float = 1.0, *,
                  record_bytes: int = 2 * MiB, window: int = 24,
                  records: int = 96) -> None:
    """Bounded log: append at the head, trim at the tail; trimmed zones
    are fully dead, so reclaim never relocates (WA stays 1.0)."""
    n = max(int(records * scale), window + 1)
    for i in range(n):
        vol.write(f"rec-{i}", record_bytes, stream=0, lifetime=0)
        if i >= window:
            vol.delete(f"rec-{i - window}")
        # Trim reclaim: only fully-dead zones qualify (WA == 1).
        if i % 8 == 7:
            vol.collect(2, max_valid_frac=0.0)


@register_scenario("cache")
def _cache(vol: LogStructuredVolume, rng, scale: float = 1.0, *,
           object_bytes: int = 1 * MiB, capacity_objects: int = 48,
           admissions: int = 96, reads_per_admit: int = 2) -> None:
    """Cache admission/eviction: random evictions leave victims with
    live neighbours, so reclaim relocates (WA > 1)."""
    n = max(int(admissions * scale), 1)
    resident: List[str] = []
    for i in range(n):
        key = f"obj-{i}"
        size = int(object_bytes * (0.5 + rng.random()))
        vol.write(key, size, stream=0, lifetime=int(rng.integers(0, 4)))
        resident.append(key)
        for _ in range(reads_per_admit):
            if resident:
                vol.read(resident[int(rng.integers(len(resident)))])
        while len(resident) > capacity_objects:
            victim = resident.pop(int(rng.integers(len(resident))))
            vol.delete(victim)
        if i % 12 == 11:
            vol.collect(1, max_valid_frac=0.5)


# ---------------------------------------------------------------------------
# Fleet-batched policy comparison
# ---------------------------------------------------------------------------
def compare_policies(scenarios: Optional[Sequence[str]] = None,
                     policies: Optional[Sequence[str]] = None, *,
                     spec: Optional[ZNSDeviceSpec] = None,
                     backend: str = "vectorized", seed: int = 0,
                     scale: float = 1.0, jitter: bool = False
                     ) -> List[Dict]:
    """Every (scenario, policy) combination, simulated as **one**
    :class:`DeviceFleet` run; returns one metrics dict per combination
    (host accounting + device timing)."""
    scenarios = tuple(scenarios) if scenarios else available_scenarios()
    policies = tuple(policies) if policies else available_placement_policies()
    spec = spec if spec is not None else HOST_SCENARIO_SPEC
    builds = [build_scenario(s, spec=spec, policy=p, seed=seed, scale=scale)
              for s in scenarios for p in policies]
    fleet = DeviceFleet.homogeneous(len(builds), spec=spec)
    fres = fleet.run([b.workload for b in builds], backend=backend,
                     seeds=[seed] * len(builds), jitter=jitter)
    rows: List[Dict] = []
    for b, res in zip(builds, fres):
        host = b.volume._wrap(res)
        row = {"scenario": b.name, "policy": b.policy,
               "backend": fres.backend, "n_requests": len(res)}
        row.update(b.stats)
        row["makespan_s"] = host.makespan_s
        row["user_bandwidth_mibs"] = host.user_bandwidth_mibs
        rows.append(row)
    return rows


def rank_policies(rows: Sequence[Dict]) -> Dict[str, List[str]]:
    """Per-scenario policy ranking, best first (lowest makespan; write
    amplification breaks ties)."""
    out: Dict[str, List[str]] = {}
    for scen in sorted({r["scenario"] for r in rows}):
        scoped = [r for r in rows if r["scenario"] == scen]
        scoped.sort(key=lambda r: (r["makespan_s"],
                                   r["write_amplification"]))
        out[scen] = [r["policy"] for r in scoped]
    return out
