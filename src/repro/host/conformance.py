"""Conformance & differential validation of zone-op sequences.

The spirit of the NVMe-ZNS conformance suites (write at a non-WP offset,
append past zone capacity, exceed the open limit, reset/finish from
every state, read across a zone boundary) applied to this repo's model:
an op sequence — a :class:`repro.core.Trace` or
:class:`repro.core.WorkloadSpec` — is replayed through

* the **imperative** :class:`repro.core.ZoneManager` (authoritative:
  state legality *plus* write pointers, capacity, and open/active
  limits), collecting the :class:`repro.core.ZoneError` taxonomy, and
* the **table-driven** vectorized transition semantics
  (``repro.core.state_machine.TRANSITION_TABLE`` /
  :func:`transition_array`), which knows states but not pointers.

Differential invariant: every op the table rejects the manager rejects
too; anything the manager additionally rejects must be a pointer /
capacity / limit violation.  ``tests/test_zns_conformance.py`` asserts
this for the conformance scenarios on both simulation backends.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple, Union

import numpy as np

from repro.core import (
    OpType, Trace, WorkloadSpec, ZoneError, ZoneManager, ZoneState,
    ZNSDeviceSpec,
)
from repro.core.state_machine import TRANSITION_TABLE


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rejected op: trace index, op, zone, and the ZoneError text."""

    index: int
    op: OpType
    zone: int
    error: str

    def __str__(self) -> str:
        return f"[{self.index}] {self.op.name} zone={self.zone}: {self.error}"


def _as_trace(workload: Union[Trace, WorkloadSpec]) -> Trace:
    return workload.build() if isinstance(workload, WorkloadSpec) \
        else workload


def replay_trace(workload: Union[Trace, WorkloadSpec],
                 spec: ZNSDeviceSpec = ZNSDeviceSpec(), *,
                 default_io_bytes: int = 4096
                 ) -> Tuple[np.ndarray, List[Violation]]:
    """Replay ops in issue order through a fresh :class:`ZoneManager`.

    Returns ``(ok, violations)``: ``ok[i]`` is False when op ``i`` raised
    a :class:`ZoneError` (the op is skipped, replay continues — matching
    how a device fails one command without wedging the queue).
    RESET/FINISH occupancies are taken from live pointer state, not the
    trace's modelling hint.
    """
    trace = _as_trace(workload)
    zm = ZoneManager(spec)
    n = len(trace)
    ok = np.ones(n, dtype=bool)
    violations: List[Violation] = []
    order = np.argsort(trace.issue, kind="stable")
    for i in order:
        i = int(i)
        op = OpType(int(trace.op[i]))
        z = int(trace.zone[i])
        size = int(trace.size[i])
        try:
            if op == OpType.READ:
                # reads model a probe; a size-0 read in a trace means
                # "unspecified", not an illegal zero-length command
                zm.read(z, 0, size or default_io_bytes)
            elif op in (OpType.WRITE, OpType.APPEND):
                # size flows through untouched: a zero-size write-like
                # op must be rejected here exactly as table_ok rejects
                # it, keeping the differential invariant two-sided
                zm.write(z, size, append=op == OpType.APPEND)
            elif op == OpType.RESET:
                zm.reset(z)
            elif op == OpType.FINISH:
                zm.finish(z)
            elif op == OpType.OPEN:
                zm.open(z)
            elif op == OpType.CLOSE:
                zm.close(z)
        except ZoneError as e:
            ok[i] = False
            violations.append(Violation(index=i, op=op, zone=z,
                                        error=str(e)))
    return ok, violations


_FULL = int(ZoneState.FULL)
_WRITE_LIKE = (int(OpType.WRITE), int(OpType.APPEND))


def table_ok(workload: Union[Trace, WorkloadSpec],
             spec: ZNSDeviceSpec = ZNSDeviceSpec(), *,
             track_capacity: bool = True) -> np.ndarray:
    """State-table legality of the same replay (vectorized semantics:
    :data:`TRANSITION_TABLE` lookups over a state vector, mirroring
    :func:`repro.core.transition_array`'s ``where(ok, nxt, states)``).

    With ``track_capacity`` (default) a write-pointer vector rides
    along: write-like ops reject on overflow and drive the fill-to-cap /
    ``FINISH`` / ``RESET`` pointer updates, so the only legality the
    table layer *cannot* see is what needs global host state — the
    open/active limits and non-WP write offsets.
    """
    trace = _as_trace(workload)
    n = len(trace)
    states = np.zeros(spec.num_zones, dtype=np.int32)
    wp = np.zeros(spec.num_zones, dtype=np.int64)
    cap = spec.zone_cap_bytes
    ok = np.ones(n, dtype=bool)
    order = np.argsort(trace.issue, kind="stable")
    for i in order:
        i = int(i)
        z = int(trace.zone[i])
        op = int(trace.op[i])
        nxt = TRANSITION_TABLE[states[z], op]
        if nxt < 0:
            ok[i] = False
            continue
        if track_capacity and op in _WRITE_LIKE:
            size = int(trace.size[i])
            if size <= 0 or wp[z] + size > cap:
                ok[i] = False
                continue
            wp[z] += size
            if wp[z] >= cap:
                nxt = _FULL
        if track_capacity:
            if op == int(OpType.FINISH):
                wp[z] = cap
            elif op == int(OpType.RESET):
                wp[z] = 0
        states[z] = nxt
    return ok


def differential_check(workload: Union[Trace, WorkloadSpec],
                       spec: ZNSDeviceSpec = ZNSDeviceSpec()) -> dict:
    """Cross-check imperative vs table semantics on one op sequence.

    Returns a report dict; ``report["consistent"]`` is True iff the
    table's rejections are a subset of the manager's and every extra
    manager rejection mentions a pointer/capacity/limit concern.
    """
    ok_zm, violations = replay_trace(workload, spec)
    ok_tab = table_ok(workload, spec)
    table_only = np.flatnonzero(ok_zm & ~ok_tab)
    extra = [v for v in violations if ok_tab[v.index]]
    resourceful = ("limit", "overflow", "write pointer", "boundary",
                   "invalid write", "<= 0 bytes")
    unexplained = [v for v in extra
                   if not any(s in v.error for s in resourceful)]
    return {
        "ok_manager": ok_zm,
        "ok_table": ok_tab,
        "violations": violations,
        "table_only_rejections": table_only,
        "unexplained_manager_rejections": unexplained,
        "consistent": len(table_only) == 0 and len(unexplained) == 0,
    }
