"""Zone allocation with pluggable placement policies.

The :class:`ZoneAllocator` owns the host-side placement decision — which
zone receives the next extent of a write stream — on top of the strict
:class:`repro.core.ZoneManager` state machine.  Policies are registered
functions (``register_placement_policy``); three ship built in:

* ``"greedy-open"``   — fill the lowest-numbered already-open zone first
  (the paper's R3 guidance: *fill* zones to capacity, never ``finish``
  them), opening a new zone only when every open zone is full.
* ``"striped"``       — rotate extents over up to ``stripe_width`` open
  zones in ``stripe_bytes`` chunks (inter-zone write parallelism,
  Obs#5: writes scale with open zones up to the limit).
* ``"lifetime-binned"`` — one active zone per data-lifetime bin so data
  that dies together is reclaimed together (the flash-cache / LSM
  guidance: zone-sized groups of equal lifetime reset with WA ≈ 1).

Every policy is bounded by the device's ``max_open_zones`` /
``max_active_zones`` limits: the allocator tracks shadow state during
planning and never proposes a placement the :class:`ZoneManager` would
reject for a limit violation.

    alloc = ZoneAllocator(spec, policy="striped", stripe_width=4)
    extents = alloc.allocate(64 * MiB, stream=1)   # plan + commit
    sum(e.nbytes for e in extents) == 64 * MiB
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import MiB, ZNSDeviceSpec, ZoneError, ZoneManager, ZoneState
from repro.core.spec import ACTIVE_STATES, OPEN_STATES

from repro.core.registry import Registry


@dataclasses.dataclass(frozen=True)
class Extent:
    """One contiguous placement: ``nbytes`` at byte ``offset`` of ``zone``."""

    zone: int
    offset: int
    nbytes: int

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


@dataclasses.dataclass(frozen=True)
class StreamHint:
    """Placement hints accompanying an allocation request."""

    stream: int = 0
    lifetime: Optional[int] = None   # smaller = shorter-lived; None = unknown


class _PlanView:
    """Shadow of zone states during one ``plan()`` — placement decisions
    must not mutate the device before ``commit``."""

    def __init__(self, alloc: "ZoneAllocator"):
        self.alloc = alloc
        self.spec = alloc.spec
        self._wp: Dict[int, int] = {}
        self._opened: set = set()     # zones this plan newly opens

    def wp(self, z: int) -> int:
        return self._wp.get(z, self.alloc.zm.write_pointer(z))

    def state(self, z: int) -> ZoneState:
        st = self.alloc.zm.state(z)
        if z in self._opened:
            # this plan writes into an EMPTY or CLOSED zone: both count
            # against the open limit the moment the write lands
            st = ZoneState.IMPLICIT_OPEN
        if self.wp(z) >= self.spec.zone_cap_bytes:
            st = ZoneState.FULL
        return st

    def remaining(self, z: int) -> int:
        return self.spec.zone_cap_bytes - self.wp(z)

    @property
    def open_count(self) -> int:
        return sum(1 for z in range(self.spec.num_zones)
                   if self.state(z) in OPEN_STATES)

    @property
    def active_count(self) -> int:
        return sum(1 for z in range(self.spec.num_zones)
                   if self.state(z) in ACTIVE_STATES)

    def can_open_new(self) -> bool:
        return (self.open_count < self.spec.max_open_zones
                and self.active_count < self.spec.max_active_zones)

    def open_zones(self) -> List[int]:
        """Writable non-reserved zones this plan may target without a
        limit violation: open zones with capacity always qualify; CLOSED
        zones re-open on write, so they qualify only while the open
        count has headroom."""
        skip = self.alloc.reserved | self.alloc.frozen
        out = []
        open_headroom = self.open_count < self.spec.max_open_zones
        for z in range(self.spec.num_zones):
            if z in skip or self.remaining(z) <= 0:
                continue
            st = self.state(z)
            if st in OPEN_STATES or (st == ZoneState.CLOSED
                                     and open_headroom):
                out.append(z)
        return out

    def empty_zones(self) -> List[int]:
        skip = self.alloc.reserved | self.alloc.frozen
        return [z for z in range(self.spec.num_zones)
                if z not in skip and self.state(z) == ZoneState.EMPTY]

    def place(self, z: int, nbytes: int) -> Extent:
        if self.state(z) not in OPEN_STATES:
            # EMPTY or CLOSED: the write (implicitly) opens the zone
            self._opened.add(z)
        wp = self.wp(z)
        if nbytes > self.remaining(z):
            raise ZoneError(f"plan overflow: zone {z} has "
                            f"{self.remaining(z)} bytes, asked {nbytes}")
        self._wp[z] = wp + nbytes
        return Extent(zone=z, offset=wp, nbytes=nbytes)


#: A placement policy maps (view, hint, remaining bytes) to the next
#: ``(zone, take_bytes)`` placement.  It must only return zones the view
#: reports writable, and may open a new (EMPTY) zone only when
#: ``view.can_open_new()`` holds.
PolicyFn = Callable[["ZoneAllocator", _PlanView, StreamHint, int],
                    Tuple[int, int]]

_POLICIES = Registry("placement policy")


def register_placement_policy(name: str, fn: Optional[PolicyFn] = None, *,
                              replace: bool = False):
    """Register a placement policy (usable as a decorator); collisions
    warn unless ``replace=True``, mirroring ``register_backend``."""
    return _POLICIES.register(name, fn, replace=replace)


def unregister_placement_policy(name: str) -> None:
    _POLICIES.unregister(name)


def available_placement_policies() -> tuple:
    return _POLICIES.available()


def _next_zone_or_raise(view: _PlanView, prefer_open: bool = True
                        ) -> Optional[int]:
    """Lowest open zone with space, else lowest empty zone if a new one
    may be opened; None when neither exists (caller decides)."""
    opens = view.open_zones()
    if prefer_open and opens:
        return opens[0]
    if view.can_open_new():
        empties = view.empty_zones()
        if empties:
            return empties[0]
    if opens:
        return opens[0]
    return None


@register_placement_policy("greedy-open")
def _greedy_open(alloc: "ZoneAllocator", view: _PlanView, hint: StreamHint,
                 remaining: int) -> Tuple[int, int]:
    z = _next_zone_or_raise(view)
    if z is None:
        raise ZoneError("device full: no writable zones (reclaim first)")
    return z, min(remaining, view.remaining(z))


@register_placement_policy("striped")
def _striped(alloc: "ZoneAllocator", view: _PlanView, hint: StreamHint,
             remaining: int) -> Tuple[int, int]:
    # Keep up to stripe_width zones in rotation; chunks of stripe_bytes.
    width = max(1, min(alloc.stripe_width, alloc.spec.max_open_zones))
    opens = view.open_zones()
    while len(opens) < width and view.can_open_new():
        empties = view.empty_zones()
        if not empties:
            break
        # Touch the empty zone so it joins the rotation set.
        view._opened.add(empties[0])
        opens = view.open_zones()
    if not opens:
        z = _next_zone_or_raise(view)
        if z is None:
            raise ZoneError("device full: no writable zones (reclaim first)")
        opens = [z]
    ring = opens[:width]
    z = ring[alloc._rr % len(ring)]
    alloc._rr += 1
    return z, min(remaining, alloc.stripe_bytes, view.remaining(z))


@register_placement_policy("lifetime-binned")
def _lifetime_binned(alloc: "ZoneAllocator", view: _PlanView,
                     hint: StreamHint, remaining: int) -> Tuple[int, int]:
    key = hint.lifetime if hint.lifetime is not None else hint.stream
    b = int(key) % max(alloc.lifetime_bins, 1)
    z = alloc._bin_zone.get(b)
    if z is not None and z not in view.open_zones():
        z = None                  # bin zone full/frozen/limit-bound: rebind
    if z is None:
        # A fresh zone for the bin when limits allow; otherwise fall back
        # to sharing the greedy zone (bounded by max-open/max-active).
        taken = {v for k, v in alloc._bin_zone.items() if k != b}
        if view.can_open_new():
            empties = [e for e in view.empty_zones() if e not in taken]
            if empties:
                z = empties[0]
        if z is None:
            unclaimed = [o for o in view.open_zones() if o not in taken]
            opens = unclaimed or view.open_zones()
            if not opens:
                raise ZoneError("device full: no writable zones "
                                "(reclaim first)")
            z = opens[0]
        alloc._bin_zone[b] = z
    return z, min(remaining, view.remaining(z))


class ZoneAllocator:
    """Policy-driven zone placement over a :class:`ZoneManager`.

    ``plan(nbytes)`` produces :class:`Extent`\\ s without touching device
    state (a shadow tracks in-plan write pointers and newly opened
    zones); ``commit(extents)`` applies them through the state machine,
    which re-checks every transition.  ``allocate`` = plan + commit.
    """

    def __init__(self, spec: Optional[ZNSDeviceSpec] = None, *,
                 zones: Optional[ZoneManager] = None,
                 policy: str = "greedy-open",
                 reserved: Tuple[int, ...] = (),
                 stripe_bytes: int = 1 * MiB,
                 stripe_width: int = 4,
                 lifetime_bins: int = 4):
        if zones is not None:
            self.zm = zones
            self.spec = zones.spec
        else:
            self.spec = spec if spec is not None else ZNSDeviceSpec()
            self.zm = ZoneManager(self.spec)
        self.policy = policy
        self._policy_fn = _POLICIES.get(policy)
        self.reserved = frozenset(reserved)
        self.stripe_bytes = int(stripe_bytes)
        self.stripe_width = int(stripe_width)
        self.lifetime_bins = int(lifetime_bins)
        self._rr = 0                       # striped rotation counter
        self._bin_zone: Dict[int, int] = {}  # lifetime bin -> active zone
        #: Zones queued for reclaim (set by the ReclaimScheduler): never
        #: placement candidates until their reset lands.
        self.frozen: set = set()
        # counters
        self.bytes_placed = 0
        self.zones_opened = 0

    # -- planning ------------------------------------------------------------
    def plan(self, nbytes: int, *, stream: int = 0,
             lifetime: Optional[int] = None) -> List[Extent]:
        """Bin-pack ``nbytes`` into zones per the policy; pure w.r.t.
        device state.  Raises :class:`ZoneError` when the device cannot
        take the payload."""
        if nbytes <= 0:
            raise ZoneError(f"allocation of {nbytes} bytes")
        hint = StreamHint(stream=stream, lifetime=lifetime)
        view = _PlanView(self)
        out: List[Extent] = []
        remaining = int(nbytes)
        while remaining > 0:
            z, take = self._policy_fn(self, view, hint, remaining)
            take = min(take, remaining, view.remaining(z))
            if take <= 0:
                raise ZoneError(
                    f"placement policy {self.policy!r} returned a full "
                    f"zone {z}")
            out.append(view.place(z, take))
            remaining -= take
        return out

    def commit(self, extents: List[Extent], *, append: bool = True) -> None:
        """Apply planned extents through the zone state machine (which
        enforces legality and the open/active limits a second time)."""
        for e in extents:
            if self.zm.write_pointer(e.zone) != e.offset:
                raise ZoneError(
                    f"stale plan: zone {e.zone} wp="
                    f"{self.zm.write_pointer(e.zone)} != extent offset "
                    f"{e.offset}")
            was_empty = self.zm.state(e.zone) == ZoneState.EMPTY
            self.zm.write(e.zone, e.nbytes, append=append,
                          at=None if append else e.offset)
            if was_empty:
                self.zones_opened += 1
            self.bytes_placed += e.nbytes

    def allocate(self, nbytes: int, *, stream: int = 0,
                 lifetime: Optional[int] = None,
                 append: bool = True) -> List[Extent]:
        extents = self.plan(nbytes, stream=stream, lifetime=lifetime)
        self.commit(extents, append=append)
        return extents

    # -- bookkeeping hooks ---------------------------------------------------
    def forget_zone(self, z: int) -> None:
        """Drop any policy affinity for a reclaimed zone (called by the
        reclaim scheduler after a reset)."""
        for b, zz in list(self._bin_zone.items()):
            if zz == z:
                del self._bin_zone[b]

    @property
    def open_count(self) -> int:
        return self.zm.open_count

    @property
    def active_count(self) -> int:
        return self.zm.active_count

    def occupancy(self, z: int) -> float:
        return self.zm.occupancy(z)

    def __repr__(self) -> str:
        return (f"ZoneAllocator(policy={self.policy!r}, "
                f"open={self.open_count}/{self.spec.max_open_zones}, "
                f"active={self.active_count}/{self.spec.max_active_zones})")
