from . import collectives, ctx, flash_decode, moe_parallel, pipeline, sharding  # noqa: F401
from .sharding import DEFAULT_RULES, make_rules, tree_shardings_for, tree_specs  # noqa: F401
