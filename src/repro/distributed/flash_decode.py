"""Flash-decoding over a sequence-sharded KV cache (shard_map).

The decode-cell baseline lets GSPMD partition the softmax over the
cache_seq axis; this module is the *explicit* schedule: each model-shard
computes a partial (m, l, o) over its cache slice and a single small
psum combines them — O(B·H·Dh) wire bytes per layer instead of any
logits gather.  Used by the decode hillclimb and as a correctness
reference for what GSPMD should produce.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _partial_softmax_attend(q, k, v, valid):
    """q: (B,K,rep,Dh); k/v: (B,K,S_loc,Dh); valid: (B,S_loc) bool.
    Returns partial (o, m, l) for cross-shard combination."""
    logits = jnp.einsum("bkrd,bksd->bkrs", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(q.shape[-1])
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)            # (B,K,rep,1)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkrs,bksd->bkrd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def flash_decode(mesh: Mesh, q, cache_k, cache_v, pos, *,
                 seq_axis: str = "model", batch_axes=("data",)):
    """Distributed decode attention.

    q: (B, K, rep, Dh) float; cache_{k,v}: (B, K, S, Dh) sharded
    (batch_axes, None, seq_axis, None); pos: scalar int32 (current
    length-1 index insertion is assumed done by the caller).
    Returns (B, K, rep, Dh) attention output, replicated over seq_axis.
    """
    ba = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)
    b_spec = ba[0] if len(ba) == 1 else ba

    def body(q_l, k_l, v_l, pos_l):
        s_loc = k_l.shape[2]
        shard = jax.lax.axis_index(seq_axis)
        kpos = shard * s_loc + jnp.arange(s_loc)           # global positions
        valid = (kpos <= pos_l)[None, :]
        valid = jnp.broadcast_to(valid, (k_l.shape[0], s_loc))
        o, m, l = _partial_softmax_attend(q_l, k_l, v_l, valid)
        # combine across seq shards: global max, rescale, sum
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        o = jax.lax.psum(o * corr, seq_axis)
        l = jax.lax.psum(l * corr, seq_axis)
        return (o / jnp.maximum(l, 1e-30)).astype(q_l.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(PS(b_spec, None, None, None),
                  PS(b_spec, None, seq_axis, None),
                  PS(b_spec, None, seq_axis, None),
                  PS()),
        out_specs=PS(b_spec, None, None, None),
        check_rep=False,
    )(q, cache_k, cache_v, pos)
