"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Stages are layer-blocks sharded over a ``pipe`` mesh axis (on the
production mesh this is typically the ``pod`` axis: one pod per stage).
Microbatches stream through the classic (M + n_stages - 1)-tick
schedule; activations hop stages with ``ppermute``.  Because
``ppermute`` is differentiable (its transpose is the reverse permute),
``jax.grad`` through :func:`gpipe` yields the backward pipeline
schedule automatically — GPipe semantics without hand-written bwd.

This is the optional PP layer: enable by resharding a model's stacked
layer params over the pipe axis and wrapping the stack body.  Dry-run
and tests exercise a 4-stage configuration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map


def gpipe(mesh: Mesh, stage_fn, stage_params, x_microbatches, *,
          axis: str = "pipe"):
    """Run ``stage_fn`` as a pipeline over ``axis``.

    stage_fn(params_slice, x) -> y, where params_slice is one stage's
    params (leading stage dim stripped).
    stage_params: pytree with leading dim n_stages on every leaf.
    x_microbatches: (M, mb, ...) — microbatched inputs (replicated).
    Returns (M, mb, ...) outputs of the final stage, replicated.
    """
    n = mesh.shape[axis]
    m = x_microbatches.shape[0]
    ticks = m + n - 1

    def body(params_local, x_mb):
        sid = jax.lax.axis_index(axis)
        params_one = jax.tree.map(lambda a: a[0], params_local)
        zero = jnp.zeros_like(x_mb[0])
        recv = zero
        outs = []
        perm = [(i, (i + 1) % n) for i in range(n)]
        for t in range(ticks):
            feed = x_mb[t] if t < m else zero
            inp = jnp.where(sid == 0, feed, recv)
            out = stage_fn(params_one, inp)
            if t >= n - 1:
                # last stage emits microbatch t-(n-1)
                outs.append(jnp.where(sid == n - 1, out, jnp.zeros_like(out)))
            recv = jax.lax.ppermute(out, axis, perm)
        stacked = jnp.stack(outs)                      # (M, mb, ...)
        # broadcast the last stage's result to every shard
        return jax.lax.psum(stacked, axis)

    in_specs = (jax.tree.map(lambda _: PS(axis), stage_params), PS())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=PS(),
                     check_rep=False)(stage_params, x_microbatches)


def stages_from_stack(layers, n_stages: int):
    """Reshape a (L, ...)-stacked layer pytree into (n_stages, L/n, ...)."""
    def split(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(split, layers)


def stack_stage_fn(layer_fn):
    """Lift a per-layer fn into a per-stage fn (scan over the stage's
    layer slice)."""
    def stage(params_stage, x):
        def body(c, lp):
            return layer_fn(lp, c), None
        y, _ = jax.lax.scan(body, x, params_stage)
        return y
    return stage
