"""Ambient sharding context: lets model code express *logical* activation
shardings without threading a mesh through every call.

launch code enters ``axis_rules(mesh, rules)``; model layers call
``constrain(x, (..logical axes..))`` which resolves through the rules and
applies ``with_sharding_constraint``.  Outside any context (unit tests,
single device) it is a no-op, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax

from . import sharding as sh

_CTX = contextvars.ContextVar("repro_sharding_ctx", default=None)


@contextlib.contextmanager
def axis_rules(mesh, rules: sh.Rules = sh.DEFAULT_RULES):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current():
    return _CTX.get()


def constrain(x, axes: Sequence[Optional[str]]):
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    Mesh axes that don't divide the corresponding dim are dropped
    (sanitize), so the same annotation works across shapes.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = sh.spec_from_axes(tuple(axes), rules, mesh)
    spec = sh.sanitize([x], [spec], mesh)[0]
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
