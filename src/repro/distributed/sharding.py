"""Logical-axis sharding rules -> PartitionSpecs / NamedShardings.

Model code annotates parameters and activations with *logical* axis names
(see models.common spec trees); this module maps them onto physical mesh
axes.  Rules are ordered; the first matching rule whose mesh axes are all
still unused in the current PartitionSpec wins (a mesh axis may appear at
most once per spec — the classic MaxText/t5x resolution scheme).

Default placement:
  TP  over "model":  vocab, q-heads, mlp hidden, experts, ssm/rnn inner
  FSDP over "data":  the embed (d_model) dim of weight matrices
  DP  over ("pod", "data"): batch
  decode KV cache:   cache_seq over "model" (flash-decode style)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


Rules = tuple[tuple[str, tuple[str, ...]], ...]


def make_rules(*, fsdp: bool = True, seq_shard_cache: bool = True,
               expert_parallel: bool = True,
               data_axes: tuple[str, ...] = ("pod", "data"),
               fsdp_axes: Optional[tuple[str, ...]] = None,
               model_axis: str = "model") -> Rules:
    m = (model_axis,)
    # FSDP shards weights over every batch axis (pod included) — ZeRO-3
    # across the full fleet, so optimizer state scales 1/chips.
    fsdp_axes = fsdp_axes if fsdp_axes is not None else data_axes
    rules = [
        ("batch", data_axes),
        ("vocab", m),
        ("heads", m),
        ("mlp", m),
        ("ssm_inner", m),
        ("rnn", m),
        ("experts", m if expert_parallel else ()),
        ("expert_mlp", () if expert_parallel else m),
        ("experts_r", m if not expert_parallel else ()),
        ("cache_seq", m if seq_shard_cache else ()),
        ("embed", fsdp_axes if fsdp else ()),
        ("act_embed", ()),
        ("layers", ()),
        ("layer_groups", ()),
        ("kv_heads", ()),
        ("head_dim", ()),
        ("seq", ()),
        ("seq_sp", m),
        ("conv", ()),
        ("ssm_heads", ()),
        ("ssm_state", ()),
        ("rnn_blocks", ()),
        ("rnn_in", ()),
        ("rnn_out", ()),
        ("embed_in", ()),
        ("codebooks", ()),
    ]
    return tuple((k, tuple(v)) for k, v in rules)


DEFAULT_RULES = make_rules()


def spec_from_axes(axes: Optional[Sequence[Optional[str]]],
                   rules: Rules = DEFAULT_RULES,
                   mesh: Optional[Mesh] = None) -> PS:
    """Resolve one logical-axes tuple to a PartitionSpec.

    Mesh axes already used by an earlier dim are skipped (replicate), as
    are rules whose mesh axes don't exist in ``mesh`` (e.g. no "pod" axis
    on the single-pod mesh).
    """
    if axes is None:
        return PS()
    rule_map = dict(rules)
    used: set[str] = set()
    out = []
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        if ax not in rule_map:
            raise KeyError(f"no sharding rule for logical axis {ax!r}")
        cand = [a for a in rule_map[ax]
                if a not in used and (mesh_axes is None or a in mesh_axes)]
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            used.add(cand[0])
            out.append(cand[0])
        else:
            used.update(cand)
            out.append(tuple(cand))
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return PS(*out)


def tree_specs(axes_tree, rules: Rules = DEFAULT_RULES,
               mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: spec_from_axes(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, (tuple, type(None)))
        and (x is None or all(isinstance(e, (str, type(None))) for e in x)),
    )


def tree_shardings(axes_tree, mesh: Mesh, rules: Rules = DEFAULT_RULES):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        tree_specs(axes_tree, rules, mesh))


def shardable(dim: int, mesh: Mesh, axes) -> bool:
    """True if ``dim`` divides by the mesh extent of ``axes``."""
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else axes
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % n == 0


def validate_specs(shape_tree, spec_tree, mesh: Mesh):
    """Raise if any spec doesn't divide its array shape on ``mesh``."""
    def check(shape, spec):
        shape = getattr(shape, "shape", shape)
        for i, axes in enumerate(spec):
            if axes is None:
                continue
            if not shardable(shape[i], mesh, axes):
                raise ValueError(
                    f"dim {i} of shape {tuple(shape)} not divisible by mesh "
                    f"axes {axes} ({mesh.shape})")
    jax.tree.map(check, shape_tree, spec_tree,
                 is_leaf=lambda x: isinstance(x, PS))


# ---------------------------------------------------------------------------
# Sanitization: drop mesh axes that don't divide the dim (e.g. kv_heads=8 on
# model=16, batch=1 on data=16).  Keeps the dry-run honest: the spec is the
# *intent*, sanitize resolves per-(arch, shape) feasibility.
# ---------------------------------------------------------------------------
def sanitize(shape_tree, spec_tree, mesh: Mesh):
    def fix(shape, spec):
        shape = getattr(shape, "shape", shape)
        out = []
        for i, axes in enumerate(spec):
            if i >= len(shape):
                break
            if axes is None:
                out.append(None)
                continue
            tup = (axes,) if isinstance(axes, str) else tuple(axes)
            # greedily keep the largest prefix of axes that divides
            keep = []
            rem = shape[i]
            for a in tup:
                ext = mesh.shape[a]
                if rem % ext == 0:
                    keep.append(a)
                    rem //= ext
            if not keep:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(tuple(keep))
        while out and out[-1] is None:
            out.pop()
        return PS(*out)

    return jax.tree.map(fix, shape_tree, spec_tree)


def tree_shardings_for(shape_tree, axes_tree, mesh: Mesh,
                       rules: Rules = DEFAULT_RULES):
    """specs resolved from rules, then sanitized against actual shapes."""
    specs = tree_specs(axes_tree, rules, mesh)
    specs = sanitize(shape_tree, specs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PS))
