"""Expert-parallel MoE dispatch via explicit all_to_all (shard_map).

The baseline MoE (models/moe.py) builds a global (E, capacity, D) buffer
and lets GSPMD shard it — correct, but the token scatter/gather makes
GSPMD materialize token-major intermediates (the arctic-480b prefill
cell measured ~289 GiB/dev).  This module is the classic EP schedule:

  tokens stay sharded over the data axes; each shard routes its *local*
  tokens into a (E, local_cap, D) buffer, a single all_to_all over the
  expert axis re-bins it to (E/m, m*local_cap, D) so each model-shard
  holds only its experts' tokens, the expert FFN runs locally, and the
  reverse all_to_all returns outputs to their source shard.

Wire bytes per layer = 2 x tokens_exchanged x D — independent of E.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

from repro.models.config import ModelConfig


def _local_dispatch(cfg: ModelConfig, router_logits, xf, cap):
    """Route local tokens -> (E_padded, cap, D) buffer + combine metadata."""
    t, d = xf.shape
    k, e = cfg.moe_top_k, cfg.moe_num_experts
    et = e + cfg.moe_expert_pad
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    flat_e = expert_idx.reshape(-1)
    flat_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    e_s, tok_s, gate_s = flat_e[order], flat_tok[order], flat_gate[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[e_s]
    valid = rank < cap
    slot = jnp.where(valid, e_s * cap + rank, et * cap)
    buf = jnp.zeros((et * cap + 1, d), xf.dtype).at[slot].set(xf[tok_s])
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32),
                  axis=0)
    aux = e * jnp.sum(me * fe)
    return buf[:-1].reshape(et, cap, d), (slot, tok_s, gate_s, valid), aux


def moe_ffn_ep(cfg: ModelConfig, mesh: Mesh, p, x, *,
               model_axis: str = "model", data_axes=("data",)):
    """Expert-parallel MoE FFN.  x: (B, S, D) sharded over data_axes.

    Experts (p['w_*'] leading dim) are sharded over ``model_axis``.
    Returns (y, aux) like models.moe.moe_ffn.
    """
    b, s, d = x.shape
    m = mesh.shape[model_axis]
    e = cfg.moe_num_experts
    et = e + cfg.moe_expert_pad
    assert et % m == 0, (
        f"experts {e} + pad {cfg.moe_expert_pad} must divide EP degree {m}"
        " — set moe_expert_pad")
    ba = tuple(a for a in data_axes if a in mesh.axis_names)
    n_data = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    t_local = b * s // n_data
    cap_local = max(int(np.ceil(t_local * cfg.moe_top_k / e
                                * cfg.moe_capacity_factor)), 8)
    b_spec = ba[0] if len(ba) == 1 else (ba if ba else None)

    def body(x_l, router_l, wg_l, wu_l, wd_l):
        bl, sl, dl = x_l.shape
        xf = x_l.reshape(bl * sl, dl)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_l)
        buf, (slot, tok_s, gate_s, valid), aux = _local_dispatch(
            cfg, logits, xf, cap_local)
        # (E, cap, D) -> exchange expert dim over model shards:
        # each shard keeps E/m experts, gains m x cap tokens for them.
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", buf, wg_l.astype(buf.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu_l.astype(buf.dtype))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                         wd_l.astype(buf.dtype))
        out = jax.lax.all_to_all(out, model_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
        out_flat = out.reshape(et * cap_local, dl)
        gathered = jnp.where(
            valid[:, None],
            out_flat[jnp.minimum(slot, et * cap_local - 1)], 0.0)
        contrib = gathered * gate_s[:, None].astype(out_flat.dtype)
        y = jnp.zeros((bl * sl, dl), x_l.dtype).at[tok_s].add(contrib)
        # aux is a mean over shards
        aux = jax.lax.pmean(aux, ba) if ba else aux
        return y.reshape(bl, sl, dl), aux

    return shard_map(
        body, mesh=mesh,
        in_specs=(PS(b_spec), PS(),
                  PS(model_axis), PS(model_axis), PS(model_axis)),
        out_specs=(PS(b_spec), PS()),
        check_rep=False,
    )(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_up"],
      p["w_down"])
