"""Ring attention: sequence-parallel exact attention via shard_map.

The §Perf B/SP iteration showed that *constraint-based* sequence
parallelism is refuted under GSPMD (it inserts gathers around every
constraint).  This is the hand-written schedule: Q, K, V are sharded
over the sequence dim on the model axis; K/V blocks rotate around the
ring with ``ppermute`` while each shard maintains an online-softmax
accumulator for its local queries.  Per layer the wire cost is
K+V once around the ring — 2·S·D_kv bytes — versus the TP all-reduce's
2·S·D_model, a (D_model / D_kv)-fold reduction for GQA models (16× for
llama3-405b's 128-vs-8 head ratio), and activation memory drops by the
ring degree.

Causality: shard i's queries attend to kv shards j <= i fully-unmasked
for j < i and causally for j == i; blocks with j > i are skipped
arithmetically (zero contribution) rather than by control flow, keeping
the schedule static.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _block_attend(q, k, v, mask, scale):
    """q: (B,H,Sq,D); k/v: (B,H,Sk,D); mask: (Sq,Sk) bool.
    Returns partial (o, m, l) in f32."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    # guard fully-masked rows
    m_safe = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m_safe)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m_safe, l


def ring_attention(mesh: Mesh, q, k, v, *, causal: bool = True,
                   scale=None, seq_axis: str = "model",
                   batch_axes=("data",)):
    """q: (B, Hq, S, D), k/v: (B, Hkv, S, D), all sharded on S over
    ``seq_axis``.  Returns (B, Hq, S, D) with the same sharding.

    GQA is handled by repeating KV heads locally (keeps ring payload at
    the *unrepeated* K/V size).
    """
    n = mesh.shape[seq_axis]
    b, hq, s_tot, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / float(np.sqrt(d))
    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_spec = ba[0] if len(ba) == 1 else (ba if ba else None)

    def body(q_l, k_l, v_l):
        bl, hl, s_loc, dl = q_l.shape      # local (batch-sharded) shapes
        sid = jax.lax.axis_index(seq_axis)
        qpos = sid * s_loc + jnp.arange(s_loc)
        q32 = q_l.astype(jnp.float32)

        acc = jnp.zeros((bl, hl, s_loc, dl), jnp.float32)
        m_run = jnp.full((bl, hl, s_loc, 1), NEG_INF, jnp.float32)
        l_run = jnp.zeros((bl, hl, s_loc, 1), jnp.float32)
        perm = [(i, (i - 1) % n) for i in range(n)]   # kv moves to rank-1

        k_cur, v_cur = k_l, v_l
        for step in range(n):
            src = (sid + step) % n                    # kv shard id held now
            kpos = src * s_loc + jnp.arange(s_loc)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            else:
                mask = jnp.ones((s_loc, s_loc), bool)
            k_rep = jnp.repeat(k_cur, rep, axis=1) if rep > 1 else k_cur
            v_rep = jnp.repeat(v_cur, rep, axis=1) if rep > 1 else v_cur
            o, m, l = _block_attend(q32, k_rep.astype(jnp.float32),
                                    v_rep, mask, scale)
            m_new = jnp.maximum(m_run, m)
            c_old = jnp.exp(m_run - m_new)
            c_blk = jnp.exp(m - m_new)
            acc = acc * c_old + o * c_blk
            l_run = l_run * c_old + l * c_blk
            m_run = m_new
            if step != n - 1:
                k_cur = jax.lax.ppermute(k_cur, seq_axis, perm)
                v_cur = jax.lax.ppermute(v_cur, seq_axis, perm)
        out = acc / jnp.maximum(l_run, 1e-30)
        return out.astype(q_l.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(PS(b_spec, None, seq_axis, None),
                  PS(b_spec, None, seq_axis, None),
                  PS(b_spec, None, seq_axis, None)),
        out_specs=PS(b_spec, None, seq_axis, None),
        check_rep=False,
    )(q, k, v)
