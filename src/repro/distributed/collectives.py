"""Compressed cross-pod gradient collectives with error feedback.

Cross-pod ICI/DCN links are the scarcest bandwidth at multi-pod scale.
``ef_compressed_psum`` halves (bf16) or quarters (int8, with a shared
pmax scale) the wire bytes of the pod-axis gradient all-reduce; the
quantization residual is carried in an error-feedback buffer so the
*accumulated* gradient stays unbiased (EF-SGD/EF21-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _compress_psum_leaf(g, e, axis, method):
    """One leaf: returns (psum-ed g_hat, new error)."""
    x = g.astype(jnp.float32) + e
    if method == "bf16":
        q = x.astype(jnp.bfloat16)
        err = x - q.astype(jnp.float32)
        out = jax.lax.psum(q, axis).astype(jnp.float32)
        return out, err
    if method == "int8":
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        err = x - q.astype(jnp.float32) * scale
        out = jax.lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32)
        return out * scale, err
    raise ValueError(method)


def ef_compressed_psum(mesh: Mesh, grads, error_state, *, axis: str = "pod",
                       method: str = "bf16", mean: bool = True):
    """All-reduce ``grads`` over ``axis`` with compression + error feedback.

    grads/error_state leaves carry a leading pod dimension of extent
    ``mesh.shape[axis]`` (each pod's partial gradient / residual).
    Returns (reduced_grads without the pod dim, per-pod new_error_state).
    """
    n = mesh.shape[axis]
    leaves, treedef = jax.tree.flatten(grads)
    eleaves = jax.tree.leaves(error_state)

    def body(*args):
        k = len(args) // 2
        gs, es = args[:k], args[k:]
        outs, errs = [], []
        for g, e in zip(gs, es):
            o, ne = _compress_psum_leaf(g[0], e[0], axis, method)
            if mean:
                o = o / n
            outs.append(o)
            errs.append(ne[None])
        return tuple(outs) + tuple(errs)

    # reduced outputs are identical on every shard (replicated out_specs);
    # error states stay PER-SHARD (PS(axis)) — each pod carries its own
    # quantization residual for the next step.
    res = shard_map(
        body, mesh=mesh,
        in_specs=tuple(PS(axis) for _ in range(2 * len(leaves))),
        out_specs=tuple(PS() for _ in range(len(leaves)))
        + tuple(PS(axis) for _ in range(len(leaves))),
        check_rep=False,
    )(*leaves, *eleaves)
    outs = jax.tree.unflatten(treedef, res[:len(leaves)])
    errs = jax.tree.unflatten(treedef, res[len(leaves):])
    return outs, errs


def compressed_psum_reference(grads_per_pod, method: str = "bf16"):
    """Single-process oracle: what the compressed all-reduce computes for a
    list of per-pod gradients (used by unit tests)."""
    n = len(grads_per_pod)
    if method == "bf16":
        q = [g.astype(jnp.bfloat16).astype(jnp.float32)
             for g in grads_per_pod]
        return sum(q) / n
    if method == "int8":
        scale = max(float(jnp.max(jnp.abs(g))) for g in grads_per_pod) / 127.0
        scale = max(scale, 1e-12)
        q = [jnp.round(jnp.clip(g / scale, -127, 127)) * scale
             for g in grads_per_pod]
        return sum(q) / n
    raise ValueError(method)
