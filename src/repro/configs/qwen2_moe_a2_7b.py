"""Qwen1.5-MoE-A2.7B — fine-grained MoE [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16 heads (kv=16, MHA), 60 routed experts top-4
(expert d_ff=1408) + shared expert (d_ff=5632), vocab=151936.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=151936,
    moe_num_experts=60, moe_top_k=4, moe_d_ff=1408,
    moe_shared_d_ff=5632,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, vocab_size=128,
        moe_num_experts=6, moe_top_k=2, moe_d_ff=48, moe_shared_d_ff=96,
        kernel_impl="xla")
