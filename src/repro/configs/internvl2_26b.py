"""InternVL2-26B — VLM; InternLM2-20B LM backbone [arXiv:2404.16821].

Backbone: 48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384,
vocab=92553.  The InternViT vision tower is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings which are
projected and spliced over the leading image-placeholder positions.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=92553,
    frontend="vision_stub", num_patches=256, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128, num_patches=4,
        kernel_impl="xla")
