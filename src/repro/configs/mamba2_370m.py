"""Mamba2-370M — SSD state-space LM, attention-free [arXiv:2405.21060].

48L, d_model=1024, ssm_state=128, vocab=50280.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state=128, ssm_headdim=64, ssm_groups=1, ssm_expand=2,
    conv_width=4, ssm_chunk=128, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=128,
        ssm_state=16, ssm_headdim=16, ssm_chunk=32, kernel_impl="xla")
