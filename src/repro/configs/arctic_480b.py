"""Snowflake Arctic 480B — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads (GQA kv=8), dense-residual FFN d_ff=4864 in
parallel with a 128-expert top-2 MoE (expert d_ff=4864), vocab=32000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=4864, vocab_size=32000,
    moe_num_experts=128, moe_top_k=2, moe_d_ff=4864,
    moe_dense_parallel=True,
    # bf16 master weights: 477B params + f32 moments = 4.8 TB must spread
    # over the fleet's HBM.
    param_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=128,
        moe_num_experts=8, moe_top_k=2, moe_d_ff=96, kernel_impl="xla")
