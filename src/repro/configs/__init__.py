from .registry import ARCH_IDS, all_configs, get_config, get_smoke_config  # noqa: F401
from .presets import get_optimized_config, step_settings  # noqa: F401
