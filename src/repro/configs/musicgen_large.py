"""MusicGen-large — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

48L, d_model=2048, 32 heads (kv=32, MHA), d_ff=8192, vocab=2048 per
codebook, 4 codebooks.  The EnCodec frontend is a STUB per the
assignment: inputs are codebook token ids (B, S, 4); embeddings are
summed and each position carries 4 output heads.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    frontend="audio_stub", num_codebooks=4,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=64, num_codebooks=2,
        kernel_impl="xla")
