"""RecurrentGemma-9B — Griffin-style hybrid [arXiv:2402.19427].

38 blocks in a (rec, rec, attn) 2:1 pattern; RG-LRU recurrence width
= d_model = 4096; local attention window 2048 with MQA (kv=1);
d_ff=12288; vocab=256000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256_000,
    block_pattern=("rec", "rec", "attn"), window=2048,
    logits_softcap=30.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128, window=32,
        block_pattern=("rec", "rec", "attn"), kernel_impl="xla")
