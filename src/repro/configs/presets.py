"""Best-known-config presets from the EXPERIMENTS.md §Perf hillclimbs.

``get_optimized_config(arch)`` layers the winning settings from the perf
loop onto the published architecture config: expert-parallel all_to_all
dispatch for the MoE archs (36.6x / 14x collective-wire reduction),
expert padding where E doesn't divide the TP degree, and the microbatch
setting that fits llama3-405b's activation carries.
"""
from __future__ import annotations

import dataclasses

from .registry import get_config

#: per-arch overrides validated in EXPERIMENTS.md §Perf
OPTIMIZED_OVERRIDES = {
    "arctic-480b": dict(moe_impl="ep"),                    # §Perf C
    "qwen2-moe-a2.7b": dict(moe_impl="ep", moe_expert_pad=4),  # §Perf A
}

#: step-level settings (consumed by launch drivers, not ModelConfig)
OPTIMIZED_STEP_SETTINGS = {
    "llama3-405b": dict(microbatches=16),                  # §Perf B.6
}


def get_optimized_config(arch: str, **extra):
    over = dict(OPTIMIZED_OVERRIDES.get(arch, {}))
    over.update(extra)
    return get_config(arch, **over)


def step_settings(arch: str) -> dict:
    return dict(OPTIMIZED_STEP_SETTINGS.get(arch, {}))
