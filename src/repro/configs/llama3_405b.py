"""Llama-3 405B — frontier dense LM [arXiv:2407.21783].

126L, d_model=16384, 128 heads (GQA kv=8), d_ff=53248, vocab=128256.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    head_dim=128, d_ff=53248, vocab_size=128256, rope_theta=500_000.0,
    # bf16 master weights + f32 Adam moments (10 B/param): the only way
    # 405B params + optimizer state fit 512 x 16 GiB v5e HBM.
    param_dtype="bfloat16",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
        head_dim=16, d_ff=384, vocab_size=256, kernel_impl="xla")
