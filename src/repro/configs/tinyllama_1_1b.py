"""TinyLlama-1.1B — llama2-arch small dense LM [arXiv:2401.02385].

22L, d_model=2048, 32 heads (GQA kv=4), d_ff=5632, vocab=32000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=5632, vocab_size=32000, rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=128, kernel_impl="xla")
