"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module exposing ``CONFIG``
(the exact published configuration) and ``smoke_config()`` (a reduced
same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "tinyllama-1.1b",
    "qwen3-4b",
    "qwen3-8b",
    "llama3-405b",
    "arctic-480b",
    "qwen2-moe-a2.7b",
    "mamba2-370m",
    "internvl2-26b",
    "musicgen-large",
    "recurrentgemma-9b",
)

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch]}")
    cfg = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch]}")
    cfg = mod.smoke_config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
