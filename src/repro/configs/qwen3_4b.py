"""Qwen3-4B — dense LM with qk-norm and GQA [hf:Qwen/Qwen3-8B family].

36L, d_model=2560, 32 heads (GQA kv=8), d_ff=9728, vocab=151936.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=9728, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=128, kernel_impl="xla")
