"""Small pytree helpers shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(tree)))


def tree_count(tree) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def scan_or_loop(use_scan: bool, body, carry, xs, length: int):
    """lax.scan when ``use_scan`` else an unrolled python loop.

    The unrolled form exists for roofline extraction: XLA's cost analysis
    counts a while-loop body once, so per-layer costs are measured from
    small unrolled variants and extrapolated affinely in depth.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and all(y is not None for y in jax.tree.leaves(ys[0], is_leaf=lambda v: v is None)):
        stacked = jax.tree.map(lambda *vs: jnp.stack(vs), *ys) if ys else None
    else:
        stacked = None
    return carry, stacked
