"""Post-compile HLO analysis: collective-traffic accounting.

``compiled.as_text()`` (post-SPMD-partitioning, post-optimization) lists
every collective instruction with its result shape.  We sum result-shape
bytes per collective kind and derive a wire-bytes estimate with standard
ring-algorithm factors.  Conventions:

* all-gather:          result = fully gathered tensor  -> wire ~ result
* all-reduce:          result = operand                -> wire ~ 2 x result
* reduce-scatter:      result = operand / n            -> wire ~ n x result
* all-to-all:          result = operand                -> wire ~ result
* collective-permute:  result = operand                -> wire ~ result

``-start``/``-done`` async pairs are deduplicated by counting only the
start (or the sync form).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "u1": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shape like bf16[8,128]{1,0} or f32[] ; tuple results are (shape, shape, ...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_REPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WIRE_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    count: dict
    result_bytes: dict
    wire_bytes: dict

    @property
    def total_result_bytes(self) -> float:
        return float(sum(self.result_bytes.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def as_dict(self) -> dict:
        return {"count": self.count, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes,
                "total_result_bytes": self.total_result_bytes,
                "total_wire_bytes": self.total_wire_bytes}


def collective_stats(hlo_text: str) -> CollectiveStats:
    count = {k: 0 for k in _COLLECTIVES}
    rbytes = {k: 0.0 for k in _COLLECTIVES}
    wbytes = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_text, kind, _ = m.groups()
        nbytes = _shape_bytes(result_text)
        # group size for reduce-scatter wire estimate
        g = _REPL_RE.search(line)
        gsize = (len(g.group(1).split(",")) if g else 1) or 1
        count[kind] += 1
        rbytes[kind] += nbytes
        if kind == "reduce-scatter":
            wbytes[kind] += nbytes * gsize
        else:
            wbytes[kind] += nbytes * _WIRE_FACTOR[kind]
    return CollectiveStats(count, rbytes, wbytes)
