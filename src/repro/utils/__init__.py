from .hlo import CollectiveStats, collective_stats  # noqa: F401
from .tree import scan_or_loop, tree_bytes, tree_count  # noqa: F401
