"""Shared warn-on-collision registry.

One implementation of the register/unregister/available semantics every
repo registry promises (see docs/api.md): registering an existing name
with a *different* value warns (``replace=True`` silences), same-value
re-registration is silent, and lookups fail with the available names.
Used by the experiment registry and the host-layer registries; the
simulation/pressure-backend registries in :mod:`repro.core.device`
predate it and keep their bare-dict form (tests mutate those dicts
directly), with identical observable semantics.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, Optional


class Registry:
    """Name -> value map with collision warnings and decorator support."""

    def __init__(self, what: str):
        self.what = what
        self._entries: Dict[str, object] = {}

    def register(self, name: str, fn: Optional[object] = None, *,
                 replace: bool = False):
        def _do(f):
            if not replace and name in self._entries \
                    and self._entries[name] is not f:
                warnings.warn(
                    f"{self.what} {name!r} is already registered; replacing "
                    f"it. Pass replace=True to silence this warning.",
                    RuntimeWarning, stacklevel=3)
            self._entries[name] = f
            return f
        return _do(fn) if fn is not None else _do

    def unregister(self, name: str) -> None:
        self._entries.pop(name, None)

    def get(self, name: str):
        if name not in self._entries:
            raise KeyError(f"unknown {self.what} {name!r}; available: "
                           f"{self.available()}")
        return self._entries[name]

    def available(self) -> tuple:
        return tuple(sorted(self._entries))

    # -- mapping protocol (read-only) ----------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __getitem__(self, name: str):
        return self._entries[name]

    def __iter__(self):
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def values(self):
        return self._entries.values()

    def items(self):
        return self._entries.items()
