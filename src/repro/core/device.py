"""Unified device-session API: ``ZnsDevice`` / ``ConvDevice`` /
``DeviceFleet`` facades.

The paper's artifact is a calibrated ZN540 performance model; this module
is its single entry point.  A :class:`ZnsDevice` owns the device spec, the
calibrated :class:`LatencyModel` (a thin binding of the
:class:`LatencyParams` parameter pytree), the :class:`ZoneManager`, and
the closed-form :class:`ThroughputModel`, and runs declarative
:class:`WorkloadSpec` workloads through pluggable simulation backends:

* ``"event"``      — the per-request discrete-event engine (exact pools,
  greedy server assignment); reference semantics.
* ``"vectorized"`` — the trace compiles (once, content-cached) into a
  :class:`repro.core.ChainProgram` solved by one fused max-plus
  fixpoint (the Pallas ``zns_fixpoint`` kernel on TPU, the batched
  float64 doubling scan elsewhere); order-of-magnitude faster on large
  traces and, on jitter-free runs, exact even on saturated
  single-service-class pools.
* ``"auto"``       — vectorized for large traces, event otherwise
  (threshold per session: ``ZnsDevice(auto_threshold=...)``).

Third parties can add backends with :func:`register_backend`.

    dev = ZnsDevice()                       # ZN540 by default
    wl = WorkloadSpec().writes(n=100_000, size=4 * KiB, qd=4)
    res = dev.run(wl, backend="auto")
    res.latency_stats().p99_us, res.iops, res.bandwidth_bytes

:class:`DeviceFleet` scales the same session API to N heterogeneous
devices: specs + latency-parameter pytrees stack along a leading device
axis and one batched run replaces the per-device Python loop
(`repro.core.fleet`).  :class:`ConvDevice` exposes the conventional-SSD
(SN640) baseline through the same facade, with its write-pressure path
registered on the shared pressure-backend registry
(:func:`register_pressure_backend`) returning the same
:class:`PressureResult` type.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .chain_program import CompileStats, SolveStats, last_compile_stats, \
    last_solve_stats
from .conventional import ConventionalSSD, PressureResult, \
    zns_write_pressure_series
from .engine import (
    SimResult, SteadyStateResult, ThroughputModel, Trace, simulate,
    simulate_vectorized, zone_sequential_completions,
)
from .fleet import batched_sequential_completions, simulate_fleet_vectorized
from .latency import LatencyModel, LatencyParams, stack_latency_params
from .metrics import LatencyStats, bandwidth_bytes, extract_metrics, iops, \
    throughput_timeseries
from .spec import (
    ConvDeviceSpec, LBAFormat, MiB, OpType, Stack, ZNSDeviceSpec,
)
from .state_machine import ZoneManager
from .workload import WorkloadSpec

#: Default trace length above which ``backend="auto"`` picks the
#: vectorized engine.  Per-session override: ``ZnsDevice(auto_threshold=…)``
#: / ``DeviceFleet(…, auto_threshold=…)``.
AUTO_VECTORIZED_MIN = 8192

#: Workload→trace memo entries kept per device session.
_TRACE_MEMO_MAX = 16


# ---------------------------------------------------------------------------
# Run results
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RunResult:
    """Per-request simulation output + figure-ready reductions.

    Example::

        >>> from repro.core import KiB, WorkloadSpec, ZnsDevice
        >>> dev = ZnsDevice()
        >>> res = dev.run(WorkloadSpec().writes(n=100, size=4 * KiB),
        ...               backend="event", jitter=False)
        >>> len(res), res.backend
        (100, 'event')
        >>> round(res.latency_stats().mean_us, 2)   # QD1 -> service time
        11.36
    """

    trace: Trace
    sim: SimResult
    backend: str
    #: Lowering/compile-cache stats of the chain-program backend
    #: (:func:`repro.core.last_compile_stats` snapshot; ``None`` for the
    #: event engine, which has no compile step).  Attribute wall-clock
    #: to compile vs solve with ``compile_stats.lowering_ms`` and the
    #: cache ``hits``/``misses``.
    compile_stats: Optional["CompileStats"] = None
    #: Solver telemetry of the fixpoint that produced this result
    #: (:func:`repro.core.last_solve_stats` snapshot; ``None`` for the
    #: event engine).  ``solve_stats.sweeps`` is the sweep count,
    #: ``active_blocks``/``residuals`` trace the active-set driver's
    #: per-sweep work and convergence trajectory.
    solve_stats: Optional["SolveStats"] = None
    _stats_cache: Dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)

    def latency_stats(self, op: Optional[OpType] = None, *,
                      from_issue: bool = False) -> LatencyStats:
        """mean/p50/p95/p99 latency (us); in-device (start -> complete) by
        default, submission-to-completion with ``from_issue=True``.
        Memoized per ``(op, from_issue)`` — percentile reductions over
        large traces are not recomputed on repeated access."""
        key = (None if op is None else int(op), bool(from_issue))
        cached = self._stats_cache.get(key)
        if cached is not None:
            return cached
        lat = self.sim.latency_from(self.trace.issue) if from_issue \
            else self.sim.in_device_latency
        if op is not None:
            lat = lat[self.trace.op == int(op)]
            if len(lat) == 0:
                raise ValueError(
                    f"no {OpType(op).name} requests in this trace; present: "
                    f"{[OpType(o).name for o in np.unique(self.trace.op)]}")
        stats = LatencyStats.from_samples(lat)
        self._stats_cache[key] = stats
        return stats

    def per_op_stats(self, *, from_issue: bool = False
                     ) -> Dict[OpType, LatencyStats]:
        return {OpType(o): self.latency_stats(OpType(o),
                                              from_issue=from_issue)
                for o in np.unique(self.trace.op)}

    @property
    def iops(self) -> float:
        return iops(self.sim.complete)

    @property
    def bandwidth_bytes(self) -> float:
        return bandwidth_bytes(self.sim.complete, self.trace.size)

    def throughput_timeseries(self, *, bin_s: float = 1.0):
        return throughput_timeseries(self.sim.complete, self.trace.size,
                                     bin_s=bin_s)

    # -- convergence diagnostics (chain-program fixpoint backends) ----------
    @property
    def sweeps_used(self) -> int:
        """Gauss–Seidel sweeps the fixpoint solver spent (0 = event
        engine, which is exact by construction)."""
        return self.sim.sweeps_used

    @property
    def converged(self) -> bool:
        """False when the sweep budget was exhausted while constraints
        were still moving — completions are then a lower bound (a
        RuntimeWarning was emitted at solve time; re-run with a larger
        ``sweeps=``)."""
        return self.sim.converged

    @property
    def exact(self) -> Optional[bool]:
        """Whether this run carries the compiler's exactness claim:
        the program's pool chains replay the event engine's greedy
        schedule for the solved service vector (jitter seed included).
        ``True`` for the event engine itself; ``False`` when refinement
        was disabled (``refine=0``) or the claim was voided by solving
        a service vector the program was not compiled for."""
        return self.sim.exact

    @property
    def order_stable(self) -> Optional[bool]:
        """Whether every pool's pop order froze during compile-time
        refinement (see :attr:`exact`; ``False`` names the culprits in
        :attr:`unstable_pools`)."""
        return self.sim.order_stable

    @property
    def unstable_pools(self) -> Tuple[str, ...]:
        """``dev{i}:{pool}`` labels whose chains kept the issue-ordered
        bootstrap approximation (empty when :attr:`order_stable`)."""
        return tuple(self.sim.unstable_pools)

    def summary(self, metrics: Optional[Sequence[str]] = None
                ) -> Dict[str, float]:
        """Named-metric snapshot via the extractor registry
        (:func:`repro.core.metrics.register_metric`); the experiment
        runner's JSON artifacts are built from these.

        Example::

            >>> from repro.core import KiB, WorkloadSpec, ZnsDevice
            >>> res = ZnsDevice().run(WorkloadSpec().writes(n=10, size=4*KiB),
            ...                       backend="event", jitter=False)
            >>> res.summary(["n_requests"])
            {'n_requests': 10.0}
        """
        return extract_metrics(self, metrics)

    def __len__(self) -> int:
        return len(self.trace)


# ---------------------------------------------------------------------------
# Backend registries (trace simulation + write-pressure scenarios)
# ---------------------------------------------------------------------------
BackendFn = Callable[..., SimResult]
_BACKENDS: Dict[str, BackendFn] = {}

PressureBackendFn = Callable[..., PressureResult]
_PRESSURE_BACKENDS: Dict[str, PressureBackendFn] = {}


def _register_into(registry: Dict, what: str, name: str, fn, replace: bool):
    def _register(f, stacklevel: int):
        if not replace and name in registry and registry[name] is not f:
            warnings.warn(
                f"{what} {name!r} is already registered; replacing it. "
                f"Pass replace=True to silence this warning.",
                RuntimeWarning, stacklevel=stacklevel)
        registry[name] = f
        return f
    if fn is not None:
        # user -> register_*() -> _register_into -> _register -> warn
        return _register(fn, 4)
    # decorator form: the user's frame invokes the returned closure
    return lambda f: _register(f, 3)


def register_backend(name: str, fn: Optional[BackendFn] = None, *,
                     replace: bool = False):
    """Register a simulation backend ``fn(trace, spec, lat, *, seed,
    jitter, **opts) -> SimResult``; usable as a decorator.  Registering an
    existing name warns (``replace=True`` silences).

    Example::

        >>> from repro.core import (available_backends, register_backend,
        ...                         unregister_backend)
        >>> @register_backend("null-engine")
        ... def _null(trace, spec, lat, *, seed=0, jitter=True, **opts):
        ...     raise NotImplementedError
        >>> "null-engine" in available_backends()
        True
        >>> unregister_backend("null-engine")
        >>> "null-engine" in available_backends()
        False
    """
    return _register_into(_BACKENDS, "backend", name, fn, replace)


def unregister_backend(name: str) -> None:
    """Remove a backend; ``"auto"`` degrades gracefully (see
    :func:`_resolve_backend`)."""
    _BACKENDS.pop(name, None)


def register_pressure_backend(name: str,
                              fn: Optional[PressureBackendFn] = None, *,
                              replace: bool = False):
    """Register a write-pressure scenario backend ``fn(device, *,
    rate_mibs, duration_s, bin_s, ...) -> PressureResult``."""
    return _register_into(_PRESSURE_BACKENDS, "pressure backend", name, fn,
                          replace)


def available_backends() -> tuple:
    return tuple(sorted(_BACKENDS))


def available_pressure_backends() -> tuple:
    return tuple(sorted(_PRESSURE_BACKENDS))


@register_backend("event")
def _event_backend(trace, spec, lat, *, seed=0, jitter=True, **_):
    return simulate(trace, spec, lat, seed=seed, jitter=jitter)


@register_backend("vectorized")
def _vectorized_backend(trace, spec, lat, *, seed=0, jitter=True, **opts):
    return simulate_vectorized(trace, spec, lat, seed=seed, jitter=jitter,
                               **opts)


def _resolve_auto(n_requests: int,
                  threshold: int = AUTO_VECTORIZED_MIN) -> str:
    # Tolerate a mutated registry (third parties may unregister or
    # replace the built-ins mid-session): fall back from the preferred
    # engine to its sibling, then to any registered backend.
    want = "vectorized" if n_requests >= threshold else "event"
    alt = "event" if want == "vectorized" else "vectorized"
    for cand in (want, alt, *available_backends()):
        if cand in _BACKENDS:
            return cand
    raise KeyError("backend='auto' but no simulation backends are "
                   "registered (registry was emptied mid-session)")


def _resolve_backend(name: str, trace: Trace, *,
                     threshold: int = AUTO_VECTORIZED_MIN) -> str:
    if name == "auto":
        return _resolve_auto(len(trace), threshold)
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; available: "
                       f"{available_backends()} (or 'auto')")
    return name


# ---------------------------------------------------------------------------
# ZNS facade
# ---------------------------------------------------------------------------
class ZnsDevice:
    """One ZNS device session: spec + latency + zones + throughput model.

    This is the facade the rest of the repo binds to — benchmarks, the
    checkpoint store, and examples all speak ``ZnsDevice`` instead of
    wiring ``ThroughputModel``/``simulate()``/``Trace`` by hand.

    Example::

        >>> from repro.core import KiB, OpType, ZnsDevice
        >>> dev = ZnsDevice()                      # ZN540 by default
        >>> round(float(dev.io_latency_us(OpType.WRITE, 4 * KiB)), 2)
        11.36
        >>> round(dev.steady_state(OpType.APPEND, 4 * KiB, qd=4).iops / 1e3)
        132
    """

    def __init__(self, spec: Optional[ZNSDeviceSpec] = None, *,
                 lat: Optional[LatencyModel] = None,
                 throughput: Optional[ThroughputModel] = None,
                 auto_threshold: Optional[int] = None):
        """``auto_threshold``: trace length at which ``backend="auto"``
        switches from the event engine to the vectorized chain-program
        engine (default :data:`AUTO_VECTORIZED_MIN`).  Lower it for
        sessions dominated by repeated mid-size workloads (the compiled
        program is cached, so the vectorized engine amortizes sooner);
        raise it to pin small-but-subtle traces to reference semantics.
        """
        self.spec = spec if spec is not None else ZNSDeviceSpec()
        self.lat = lat or LatencyModel(self.spec)
        self.zones = ZoneManager(self.spec)
        self.throughput = throughput or ThroughputModel(self.spec, self.lat)
        self.auto_threshold = AUTO_VECTORIZED_MIN if auto_threshold is None \
            else int(auto_threshold)
        self._trace_memo: Dict = {}

    @property
    def params(self) -> LatencyParams:
        """The device's latency-parameter pytree."""
        return self.lat.params

    # -- workload session ----------------------------------------------------
    def workload(self, **kw) -> WorkloadSpec:
        """A fresh :class:`WorkloadSpec` (convenience entry point)."""
        return WorkloadSpec(**kw)

    def run(self, workload: Union[WorkloadSpec, Trace], *,
            backend: str = "auto", seed: int = 0, jitter: bool = True,
            **backend_opts) -> RunResult:
        """Simulate a workload; returns a :class:`RunResult`.

        ``workload`` may be a :class:`WorkloadSpec` (lowered via
        ``build()``; the built trace is memoized per device session, and
        the vectorized backend's compiled :class:`repro.core.ChainProgram`
        is cached by content — repeated runs of the same workload skip
        both lowering steps) or an already-built :class:`Trace`.
        """
        if isinstance(workload, WorkloadSpec):
            trace = self._trace_memo.get(workload)
            if trace is None:
                trace = workload.build()
                if len(self._trace_memo) >= _TRACE_MEMO_MAX:
                    self._trace_memo.pop(next(iter(self._trace_memo)))
                self._trace_memo[workload] = trace
        else:
            trace = workload
        name = _resolve_backend(backend, trace,
                                threshold=self.auto_threshold)
        sim = _BACKENDS[name](trace, self.spec, self.lat, seed=seed,
                              jitter=jitter, **backend_opts)
        stats = last_compile_stats() if name == "vectorized" else None
        sstats = last_solve_stats() if name == "vectorized" else None
        return RunResult(trace=trace, sim=sim, backend=name,
                         compile_stats=stats, solve_stats=sstats)

    # -- closed-form model (Figs. 3/4/8) ------------------------------------
    def steady_state(self, op: OpType, size_bytes: int, *, qd: int = 1,
                     zones: int = 1, stack: Stack = Stack.SPDK,
                     fmt: LBAFormat = LBAFormat.LBA_4K) -> SteadyStateResult:
        return self.throughput.steady_state(op, size_bytes, qd=qd,
                                            zones=zones, stack=stack, fmt=fmt)

    # -- calibrated latency points (Figs. 2/5) -------------------------------
    def io_latency_us(self, op: OpType, size_bytes, *,
                      stack: Stack = Stack.SPDK,
                      fmt: LBAFormat = LBAFormat.LBA_4K):
        return self.lat.io_service_us(op, size_bytes, stack, fmt)

    def reset_latency_us(self, occupancy, *, was_finished=False):
        return self.lat.reset_us(occupancy, was_finished)

    def finish_latency_us(self, occupancy):
        return self.lat.finish_us(occupancy)

    # -- interference closures (§III-F/G) ------------------------------------
    def read_latency_under_write_pressure_us(self, write_utilization: float,
                                             qd: int = 1):
        return self.throughput.read_latency_under_write_pressure_us(
            write_utilization, qd)

    def run_write_pressure(self, *, rate_mibs: float, duration_s: float = 60.0,
                           bin_s: float = 1.0, seed: int = 0,
                           backend: str = "zns", **opts) -> PressureResult:
        """Fig. 6 scenario through the shared pressure-backend registry."""
        if backend not in _PRESSURE_BACKENDS:
            raise KeyError(f"unknown pressure backend {backend!r}; "
                           f"available: {available_pressure_backends()}")
        return _PRESSURE_BACKENDS[backend](self, rate_mibs=rate_mibs,
                                           duration_s=duration_s, bin_s=bin_s,
                                           seed=seed, **opts)

    # -- kernels -------------------------------------------------------------
    def sequential_completions(self, issue, svc, segment_starts, *,
                               backend: str = "auto"):
        """Per-zone serialized completion times (max-plus scan)."""
        return zone_sequential_completions(issue, svc, segment_starts,
                                           backend=backend)

    def __repr__(self) -> str:
        return f"ZnsDevice({self.spec.name}, zones={self.spec.num_zones})"


@register_pressure_backend("zns")
def _zns_pressure_backend(dev: "ZnsDevice", *, rate_mibs: float,
                          duration_s: float = 60.0, bin_s: float = 1.0,
                          seed: int = 0) -> PressureResult:
    """ZNS side of the Fig. 6 scenario: flat writes, stable reads."""
    if not isinstance(dev, ZnsDevice):
        raise TypeError(f"pressure backend 'zns' needs a ZnsDevice, got "
                        f"{type(dev).__name__}")
    t, w = zns_write_pressure_series(rate_mibs=rate_mibs,
                                     duration_s=duration_s, bin_s=bin_s,
                                     seed=seed)
    u = rate_mibs / (dev.spec.peak_write_bw_bytes / MiB)
    mean, p95 = dev.read_latency_under_write_pressure_us(u)
    return PressureResult(t_s=t, write_mibs=w, read_lat_mean_us=mean,
                          read_lat_p95_us=p95)


# ---------------------------------------------------------------------------
# Conventional-SSD facade (§III-F baseline)
# ---------------------------------------------------------------------------
class ConvDevice:
    """Conventional (non-zoned) SSD session sharing the ZnsDevice shape."""

    def __init__(self, spec: Optional[ConvDeviceSpec] = None, *,
                 seed: int = 0):
        self.spec = spec if spec is not None else ConvDeviceSpec()
        self.model = ConventionalSSD(self.spec, seed=seed)
        self.lat = self.model.lat

    def write_amplification(self, utilization: float) -> float:
        return self.model.write_amplification(utilization)

    def run_write_pressure(self, *, rate_mibs: float, duration_s: float = 60.0,
                           bin_s: float = 1.0, backend: str = "conventional",
                           **opts) -> PressureResult:
        if backend not in _PRESSURE_BACKENDS:
            raise KeyError(f"unknown pressure backend {backend!r}; "
                           f"available: {available_pressure_backends()}")
        return _PRESSURE_BACKENDS[backend](self, rate_mibs=rate_mibs,
                                           duration_s=duration_s, bin_s=bin_s,
                                           **opts)

    def __repr__(self) -> str:
        return f"ConvDevice({self.spec.name})"


@register_pressure_backend("conventional")
def _conv_pressure_backend(dev: "ConvDevice", *, rate_mibs: float,
                           duration_s: float = 60.0, utilization: float = 0.85,
                           read_qd: int = 32, bin_s: float = 1.0,
                           seed: int = 0) -> PressureResult:
    """FTL-GC baseline (Fig. 6a sawtooth + Obs#11 read inflation)."""
    if not isinstance(dev, ConvDevice):
        raise TypeError(f"pressure backend 'conventional' needs a "
                        f"ConvDevice, got {type(dev).__name__}")
    return dev.model.simulate_write_pressure(
        rate_mibs=rate_mibs, duration_s=duration_s, utilization=utilization,
        read_qd=read_qd, bin_s=bin_s)


# ---------------------------------------------------------------------------
# Fleet facade: N heterogeneous devices, one batched computation
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FleetRunResult:
    """Per-device :class:`RunResult`\\ s of one batched fleet run."""

    results: tuple
    backend: str
    #: Compile-cache stats of the fleet's one chain-program lowering
    #: (``None`` on non-vectorized backends); see
    #: :attr:`RunResult.compile_stats`.
    compile_stats: Optional["CompileStats"] = None
    #: Solver telemetry of the fleet's one fused fixpoint solve
    #: (``None`` on non-vectorized backends); see
    #: :attr:`RunResult.solve_stats`.
    solve_stats: Optional["SolveStats"] = None

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i: int) -> RunResult:
        return self.results[i]

    def __iter__(self):
        return iter(self.results)

    @property
    def completion_us(self) -> np.ndarray:
        """Per-device makespan (max completion time, us; 0 if idle)."""
        return np.array([float(r.sim.complete.max()) if len(r) else 0.0
                         for r in self.results])

    @property
    def total_iops(self) -> float:
        return float(sum(r.iops for r in self.results if len(r)))

    @property
    def total_bandwidth_bytes(self) -> float:
        return float(sum(r.bandwidth_bytes for r in self.results if len(r)))

    @property
    def converged(self) -> bool:
        """True unless any device's fixpoint exhausted its sweep budget
        (see :attr:`RunResult.converged`)."""
        return all(r.converged for r in self.results)

    @property
    def exact(self) -> bool:
        """True when every device carries the compiler's exactness
        claim (see :attr:`RunResult.exact`)."""
        return all(bool(r.exact) for r in self.results)

    @property
    def order_stable(self) -> bool:
        """True when every device's pool pop orders froze during
        refinement (see :attr:`RunResult.order_stable`)."""
        return all(bool(r.order_stable) for r in self.results)

    @property
    def unstable_pools(self) -> Tuple[str, ...]:
        """Sorted union of every device's ``dev{i}:{pool}`` labels that
        kept the bootstrap approximation (empty when exact)."""
        return tuple(sorted({p for r in self.results
                             for p in r.unstable_pools}))

    def latency_stats(self, op: Optional[OpType] = None, *,
                      from_issue: bool = False) -> LatencyStats:
        """Fleet-pooled latency percentiles across all devices."""
        samples = []
        for r in self.results:
            if not len(r):
                continue
            lat = r.sim.latency_from(r.trace.issue) if from_issue \
                else r.sim.in_device_latency
            if op is not None:
                lat = lat[r.trace.op == int(op)]
            samples.append(lat)
        pool = np.concatenate(samples) if samples else np.zeros(0)
        if len(pool) == 0:
            raise ValueError("no matching requests in this fleet run")
        return LatencyStats.from_samples(pool)

    def summary(self, metrics: Optional[Sequence[str]] = None) -> Dict:
        """Fleet aggregates + one metric snapshot per device (the
        per-device dicts come from :meth:`RunResult.summary`)."""
        return {
            "n_devices": len(self.results),
            "backend": self.backend,
            "total_iops": self.total_iops,
            "total_bandwidth_bytes": self.total_bandwidth_bytes,
            "devices": [r.summary(metrics) for r in self.results],
        }


class DeviceFleet:
    """N device sessions stacked along a leading device axis.

    Members may be heterogeneous in both geometry (``ZNSDeviceSpec``) and
    latency model (``LatencyParams`` profile — e.g. the §IV emulator
    profiles).  ``run`` shards a workload across the members and solves
    all devices' serialized chains with batched max-plus scans
    (`repro.core.fleet`): a 32-device sweep is one device-axis-parallel
    computation, not 32 sequential simulations.

    Accepted member forms: ``ZnsDevice``, ``ZNSDeviceSpec``,
    ``LatencyParams``, ``(spec, params)``, or an emulator-profile name.

    Example::

        >>> from repro.core import DeviceFleet, KiB, WorkloadSpec
        >>> fleet = DeviceFleet.homogeneous(2)
        >>> wl = WorkloadSpec().writes(n=64, size=4 * KiB)
        >>> res = fleet.run(wl, policy="replicate", backend="vectorized",
        ...                 jitter=False)
        >>> len(res), [len(r) for r in res]
        (2, [64, 64])
    """

    def __init__(self, members: Sequence, *,
                 auto_threshold: Optional[int] = None):
        devices = []
        for m in members:
            devices.append(self._as_device(m))
        if not devices:
            raise ValueError("DeviceFleet needs at least one member")
        self.devices: tuple = tuple(devices)
        self.auto_threshold = AUTO_VECTORIZED_MIN if auto_threshold is None \
            else int(auto_threshold)

    @staticmethod
    def _as_device(m) -> ZnsDevice:
        if isinstance(m, ZnsDevice):
            return m
        if isinstance(m, ZNSDeviceSpec):
            return ZnsDevice(m)
        if isinstance(m, LatencyParams):
            spec = ZNSDeviceSpec()
            return ZnsDevice(spec, lat=LatencyModel(spec, m))
        if isinstance(m, str):
            from .emulator_models import EMULATOR_PROFILES
            spec = ZNSDeviceSpec()
            return ZnsDevice(spec, lat=LatencyModel(spec,
                                                    EMULATOR_PROFILES[m]))
        if isinstance(m, tuple) and len(m) == 2:
            spec, params = m
            return ZnsDevice(spec, lat=LatencyModel(spec, params))
        raise TypeError(f"cannot build a fleet member from {type(m)}")

    @classmethod
    def homogeneous(cls, n: int, spec: Optional[ZNSDeviceSpec] = None,
                    params: Optional[LatencyParams] = None) -> "DeviceFleet":
        spec = spec if spec is not None else ZNSDeviceSpec()
        return cls([(spec, params) if params is not None else spec
                    for _ in range(n)])

    @classmethod
    def from_profiles(cls, names: Sequence[str],
                      spec: Optional[ZNSDeviceSpec] = None) -> "DeviceFleet":
        """A fleet of emulator-profile devices (femu/nvmevirt/ours)."""
        from .emulator_models import EMULATOR_PROFILES
        spec = spec if spec is not None else ZNSDeviceSpec()
        return cls([(spec, EMULATOR_PROFILES[n]) for n in names])

    # -- shape ---------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.devices)

    def __len__(self) -> int:
        return self.n

    def __getitem__(self, i: int) -> ZnsDevice:
        return self.devices[i]

    @property
    def specs(self) -> tuple:
        return tuple(d.spec for d in self.devices)

    def stacked_params(self) -> LatencyParams:
        """All members' latency pytrees stacked on a leading device axis."""
        return stack_latency_params([d.params for d in self.devices])

    # -- simulation ----------------------------------------------------------
    def _lower(self, workload, policy: str) -> List[Trace]:
        if isinstance(workload, WorkloadSpec):
            shards = workload.shard(self.n, policy=policy)
        elif isinstance(workload, Trace):
            shards = [workload] * self.n          # replicate a built trace
        else:
            shards = list(workload)
            if len(shards) != self.n:
                raise ValueError(f"got {len(shards)} workloads for "
                                 f"{self.n} devices")
        return [w.build(allow_empty=True) if isinstance(w, WorkloadSpec)
                else w for w in shards]

    def run(self, workload, *, backend: str = "auto", seed: int = 0,
            seeds: Optional[Sequence[int]] = None, jitter: bool = True,
            policy: str = "round_robin", **backend_opts) -> FleetRunResult:
        """Simulate one workload per device; returns :class:`FleetRunResult`.

        ``workload``: a single :class:`WorkloadSpec` (lowered per device
        via ``shard(n, policy=...)``), a single :class:`Trace`
        (replicated), or a sequence of per-device specs/traces.  Device
        ``i`` uses ``seed + i``, so results match a Python loop of
        single-device ``ZnsDevice.run(..., seed=seed + i)`` calls.
        ``seeds`` overrides that with an explicit per-device list (the
        experiment runner stacks sweep points from unrelated experiments
        into one fleet call and pins each point's seed).
        """
        traces = self._lower(workload, policy)
        if seeds is None:
            seeds = [seed + i for i in range(self.n)]
        elif len(seeds) != self.n:
            raise ValueError(f"got {len(seeds)} seeds for {self.n} devices")
        total = sum(len(t) for t in traces)
        name = _resolve_auto(total, self.auto_threshold) \
            if backend == "auto" else backend
        if name not in _BACKENDS:
            raise KeyError(f"unknown backend {name!r}; available: "
                           f"{available_backends()} (or 'auto')")
        # The device-axis-batched engine implements the built-in
        # "vectorized" backend; a third-party replacement of that name is
        # honored by falling back to the per-device loop.
        stats = sstats = None
        if name == "vectorized" and _BACKENDS[name] is _vectorized_backend:
            sims = simulate_fleet_vectorized(
                traces, self.specs, [d.lat for d in self.devices],
                seeds=list(seeds), jitter=jitter, **backend_opts)
            stats = last_compile_stats()
            sstats = last_solve_stats()
        else:
            # The per-device loop would emit one sweep-budget
            # RuntimeWarning per device with no budget context; collapse
            # them into a single fleet-level warning naming the
            # offending entries (other warnings pass through untouched).
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                sims = [
                    _BACKENDS[name](traces[i], self.devices[i].spec,
                                    self.devices[i].lat, seed=seeds[i],
                                    jitter=jitter, **backend_opts)
                    for i in range(self.n)
                ]
            budget_hit = False
            for w in caught:
                if issubclass(w.category, RuntimeWarning) \
                        and "sweep budget" in str(w.message):
                    budget_hit = True
                    continue
                warnings.warn_explicit(w.message, w.category, w.filename,
                                       w.lineno)
            if budget_hit:
                bad = [i for i in range(self.n) if not sims[i].converged]
                used = [sims[i].sweeps_used for i in bad]
                budget = backend_opts.get("sweeps", "the default")
                warnings.warn(
                    f"fleet sweep budget exhausted on {len(bad)} of "
                    f"{self.n} devices (indices {bad}; sweeps_used="
                    f"{used}, budget={budget}); those completions are "
                    f"a lower bound. Raise sweeps= or inspect "
                    f"FleetRunResult.converged.",
                    RuntimeWarning, stacklevel=2)
        results = tuple(RunResult(trace=traces[i], sim=sims[i], backend=name,
                                  compile_stats=stats, solve_stats=sstats)
                        for i in range(self.n))
        return FleetRunResult(results=results, backend=name,
                              compile_stats=stats, solve_stats=sstats)

    def sequential_completions(self, issues, svcs, segment_starts, *,
                               backend: str = "auto") -> List[np.ndarray]:
        """Batched per-device max-plus scans (ragged inputs allowed):
        the fleet counterpart of :meth:`ZnsDevice.sequential_completions`,
        one (B, L) kernel invocation instead of B sequential scans."""
        return batched_sequential_completions(issues, svcs, segment_starts,
                                              backend=backend)

    def __repr__(self) -> str:
        names = {d.spec.name for d in self.devices}
        return f"DeviceFleet(n={self.n}, specs={sorted(names)})"
