"""Unified device-session API: ``ZnsDevice`` / ``ConvDevice`` facades.

The paper's artifact is a calibrated ZN540 performance model; this module
is its single entry point.  A :class:`ZnsDevice` owns the device spec, the
calibrated :class:`LatencyModel`, the :class:`ZoneManager`, and the
closed-form :class:`ThroughputModel`, and runs declarative
:class:`WorkloadSpec` workloads through pluggable simulation backends:

* ``"event"``      — the per-request discrete-event engine (exact pools,
  greedy server assignment); reference semantics.
* ``"vectorized"`` — chain-decomposed max-plus scans batched through
  ``zone_sequential_completions`` (the Pallas kernel on TPU, a numpy
  doubling scan elsewhere); order-of-magnitude faster on large traces.
* ``"auto"``       — vectorized for large traces, event otherwise.

Third parties can add backends with :func:`register_backend`.

    dev = ZnsDevice()                       # ZN540 by default
    wl = WorkloadSpec().writes(n=100_000, size=4 * KiB, qd=4)
    res = dev.run(wl, backend="auto")
    res.latency_stats().p99_us, res.iops, res.bandwidth_bytes

:class:`ConvDevice` exposes the conventional-SSD (SN640) baseline through
the same facade so ZNS-vs-conventional scenarios share one interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Union

import numpy as np

from .conventional import ConventionalSSD, ConvSimResult, \
    zns_write_pressure_series
from .engine import (
    SimResult, SteadyStateResult, ThroughputModel, Trace, simulate,
    simulate_vectorized, zone_sequential_completions,
)
from .latency import LatencyModel
from .metrics import LatencyStats, bandwidth_bytes, iops, \
    throughput_timeseries
from .spec import (
    ConvDeviceSpec, LBAFormat, MiB, OpType, Stack, ZNSDeviceSpec,
)
from .state_machine import ZoneManager
from .workload import WorkloadSpec

#: Trace length above which ``backend="auto"`` picks the vectorized engine.
AUTO_VECTORIZED_MIN = 8192


# ---------------------------------------------------------------------------
# Run results
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RunResult:
    """Per-request simulation output + figure-ready reductions."""

    trace: Trace
    sim: SimResult
    backend: str

    def latency_stats(self, op: Optional[OpType] = None, *,
                      from_issue: bool = False) -> LatencyStats:
        """mean/p50/p95/p99 latency (us); in-device (start -> complete) by
        default, submission-to-completion with ``from_issue=True``."""
        lat = self.sim.latency_from(self.trace.issue) if from_issue \
            else self.sim.in_device_latency
        if op is not None:
            lat = lat[self.trace.op == int(op)]
            if len(lat) == 0:
                raise ValueError(
                    f"no {OpType(op).name} requests in this trace; present: "
                    f"{[OpType(o).name for o in np.unique(self.trace.op)]}")
        return LatencyStats.from_samples(lat)

    def per_op_stats(self, *, from_issue: bool = False
                     ) -> Dict[OpType, LatencyStats]:
        return {OpType(o): self.latency_stats(OpType(o),
                                              from_issue=from_issue)
                for o in np.unique(self.trace.op)}

    @property
    def iops(self) -> float:
        return iops(self.sim.complete)

    @property
    def bandwidth_bytes(self) -> float:
        return bandwidth_bytes(self.sim.complete, self.trace.size)

    def throughput_timeseries(self, *, bin_s: float = 1.0):
        return throughput_timeseries(self.sim.complete, self.trace.size,
                                     bin_s=bin_s)

    def __len__(self) -> int:
        return len(self.trace)


@dataclasses.dataclass(frozen=True)
class PressureResult:
    """Write-pressure scenario output, shared by ZNS and conventional
    devices (Fig. 6 layout: rate-limited writes + 4 KiB random reads)."""

    t_s: np.ndarray
    write_mibs: np.ndarray
    read_lat_mean_us: float
    read_lat_p95_us: float
    read_mibs: Optional[np.ndarray] = None
    write_amplification: float = 1.0

    @property
    def write_cv(self) -> float:
        m = float(np.mean(self.write_mibs))
        return float(np.std(self.write_mibs)) / m if m > 0 else 0.0


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------
BackendFn = Callable[..., SimResult]
_BACKENDS: Dict[str, BackendFn] = {}


def register_backend(name: str, fn: Optional[BackendFn] = None):
    """Register a simulation backend ``fn(trace, spec, lat, *, seed,
    jitter, **opts) -> SimResult``; usable as a decorator."""
    def _register(f: BackendFn) -> BackendFn:
        _BACKENDS[name] = f
        return f
    return _register(fn) if fn is not None else _register


def available_backends() -> tuple:
    return tuple(sorted(_BACKENDS))


@register_backend("event")
def _event_backend(trace, spec, lat, *, seed=0, jitter=True, **_):
    return simulate(trace, spec, lat, seed=seed, jitter=jitter)


@register_backend("vectorized")
def _vectorized_backend(trace, spec, lat, *, seed=0, jitter=True, **opts):
    return simulate_vectorized(trace, spec, lat, seed=seed, jitter=jitter,
                               **opts)


def _resolve_backend(name: str, trace: Trace) -> str:
    if name == "auto":
        return "vectorized" if len(trace) >= AUTO_VECTORIZED_MIN else "event"
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; available: "
                       f"{available_backends()} (or 'auto')")
    return name


# ---------------------------------------------------------------------------
# ZNS facade
# ---------------------------------------------------------------------------
class ZnsDevice:
    """One ZNS device session: spec + latency + zones + throughput model.

    This is the facade the rest of the repo binds to — benchmarks, the
    checkpoint store, and examples all speak ``ZnsDevice`` instead of
    wiring ``ThroughputModel``/``simulate()``/``Trace`` by hand.
    """

    def __init__(self, spec: Optional[ZNSDeviceSpec] = None, *,
                 lat: Optional[LatencyModel] = None,
                 throughput: Optional[ThroughputModel] = None):
        self.spec = spec if spec is not None else ZNSDeviceSpec()
        self.lat = lat or LatencyModel(self.spec)
        self.zones = ZoneManager(self.spec)
        self.throughput = throughput or ThroughputModel(self.spec, self.lat)

    # -- workload session ----------------------------------------------------
    def workload(self, **kw) -> WorkloadSpec:
        """A fresh :class:`WorkloadSpec` (convenience entry point)."""
        return WorkloadSpec(**kw)

    def run(self, workload: Union[WorkloadSpec, Trace], *,
            backend: str = "auto", seed: int = 0, jitter: bool = True,
            **backend_opts) -> RunResult:
        """Simulate a workload; returns a :class:`RunResult`.

        ``workload`` may be a :class:`WorkloadSpec` (lowered via
        ``build()``) or an already-built :class:`Trace`.
        """
        trace = workload.build() if isinstance(workload, WorkloadSpec) \
            else workload
        name = _resolve_backend(backend, trace)
        sim = _BACKENDS[name](trace, self.spec, self.lat, seed=seed,
                              jitter=jitter, **backend_opts)
        return RunResult(trace=trace, sim=sim, backend=name)

    # -- closed-form model (Figs. 3/4/8) ------------------------------------
    def steady_state(self, op: OpType, size_bytes: int, *, qd: int = 1,
                     zones: int = 1, stack: Stack = Stack.SPDK,
                     fmt: LBAFormat = LBAFormat.LBA_4K) -> SteadyStateResult:
        return self.throughput.steady_state(op, size_bytes, qd=qd,
                                            zones=zones, stack=stack, fmt=fmt)

    # -- calibrated latency points (Figs. 2/5) -------------------------------
    def io_latency_us(self, op: OpType, size_bytes, *,
                      stack: Stack = Stack.SPDK,
                      fmt: LBAFormat = LBAFormat.LBA_4K):
        return self.lat.io_service_us(op, size_bytes, stack, fmt)

    def reset_latency_us(self, occupancy, *, was_finished=False):
        return self.lat.reset_us(occupancy, was_finished)

    def finish_latency_us(self, occupancy):
        return self.lat.finish_us(occupancy)

    # -- interference closures (§III-F/G) ------------------------------------
    def read_latency_under_write_pressure_us(self, write_utilization: float,
                                             qd: int = 1):
        return self.throughput.read_latency_under_write_pressure_us(
            write_utilization, qd)

    def run_write_pressure(self, *, rate_mibs: float, duration_s: float = 60.0,
                           bin_s: float = 1.0, seed: int = 0
                           ) -> PressureResult:
        """ZNS side of the Fig. 6 scenario: flat writes, stable reads."""
        t, w = zns_write_pressure_series(rate_mibs=rate_mibs,
                                         duration_s=duration_s, bin_s=bin_s,
                                         seed=seed)
        u = rate_mibs / (self.spec.peak_write_bw_bytes / MiB)
        mean, p95 = self.read_latency_under_write_pressure_us(u)
        return PressureResult(t_s=t, write_mibs=w, read_lat_mean_us=mean,
                              read_lat_p95_us=p95)

    # -- kernels -------------------------------------------------------------
    def sequential_completions(self, issue, svc, segment_starts, *,
                               backend: str = "auto"):
        """Per-zone serialized completion times (max-plus scan)."""
        return zone_sequential_completions(issue, svc, segment_starts,
                                           backend=backend)

    def __repr__(self) -> str:
        return f"ZnsDevice({self.spec.name}, zones={self.spec.num_zones})"


# ---------------------------------------------------------------------------
# Conventional-SSD facade (§III-F baseline)
# ---------------------------------------------------------------------------
class ConvDevice:
    """Conventional (non-zoned) SSD session sharing the ZnsDevice shape."""

    def __init__(self, spec: Optional[ConvDeviceSpec] = None, *,
                 seed: int = 0):
        self.spec = spec if spec is not None else ConvDeviceSpec()
        self.model = ConventionalSSD(self.spec, seed=seed)
        self.lat = self.model.lat

    def write_amplification(self, utilization: float) -> float:
        return self.model.write_amplification(utilization)

    def run_write_pressure(self, *, rate_mibs: float, duration_s: float = 60.0,
                           utilization: float = 0.85, read_qd: int = 32,
                           bin_s: float = 1.0) -> PressureResult:
        r: ConvSimResult = self.model.simulate_write_pressure(
            rate_mibs=rate_mibs, duration_s=duration_s,
            utilization=utilization, read_qd=read_qd, bin_s=bin_s)
        return PressureResult(t_s=r.t_s, write_mibs=r.write_mibs,
                              read_lat_mean_us=r.read_lat_mean_us,
                              read_lat_p95_us=r.read_lat_p95_us,
                              read_mibs=r.read_mibs,
                              write_amplification=r.write_amplification)

    def __repr__(self) -> str:
        return f"ConvDevice({self.spec.name})"
