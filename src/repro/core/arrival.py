"""Open-loop arrival processes for the workload layer.

A closed-loop stream (the default everywhere else in
:mod:`repro.core.workload`) issues its next request only when a queue
slot frees up — the device sets the pace.  An *open-loop* stream issues
on its own clock regardless of completions, which is how the paper's
interference effects (Obs#12/#13) bite at scale: bursts pile onto the
device no matter how slowly it drains.  An :class:`ArrivalProcess` is a
seeded, deterministic recipe for such a clock: it lowers to an explicit
per-request issue-time vector, which both simulation backends (the event
oracle and the chain-program fixpoint) already consume — so the
exactness contract between them carries over to open-loop traffic
unchanged.

Attach one to a stream via ``WorkloadSpec.stream(..., arrival=...)``;
combine with ``qd=0`` ("unbounded in-flight") for a purely open-loop
stream whose closed-loop gate never binds:

    >>> from repro.core import KiB, WorkloadSpec
    >>> from repro.core.arrival import PoissonArrivals
    >>> wl = WorkloadSpec().reads(
    ...     n=100, size=4 * KiB, qd=0,
    ...     arrival=PoissonArrivals(rate_per_s=50_000, seed=1))
    >>> tr = wl.build()
    >>> bool((tr.issue[1:] >= tr.issue[:-1]).all())
    True

Variants (all frozen, hashable, deterministic in their ``seed``):

* :class:`DeterministicRate` — fixed spacing; subsumes the legacy
  ``every_us`` / ``rate_bytes_per_s`` stream knobs.
* :class:`PoissonArrivals` — exponential inter-arrival gaps.
* :class:`MarkovModulated` — a two-state (on/off) Markov-modulated
  Poisson process: bursty traffic with exponential dwell times.
* :class:`TraceReplay` — explicit issue times, inline or from a file.

:func:`spread_into_windows` is the scheduling helper behind
``ReclaimScheduler.reclaim_workload(windows=...)``: it places ``n``
events into trough windows proportionally to window length.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base class: a deterministic recipe for per-request issue times.

    Subclasses implement :meth:`issue_times`; randomized processes carry
    their own ``seed`` field so ``WorkloadSpec.build()`` stays a pure
    function of the spec.
    """

    def issue_times(self, n: int, *, start_us: float = 0.0,
                    size: int = 0) -> np.ndarray:
        """``n`` nondecreasing issue times (us), offset by ``start_us``.

        ``size`` is the stream's request size in bytes — only
        byte-rate-paced processes consume it.
        """
        raise NotImplementedError

    def _check_n(self, n: int) -> int:
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        return int(n)


@dataclasses.dataclass(frozen=True)
class DeterministicRate(ArrivalProcess):
    """Fixed inter-arrival spacing, specified exactly one of three ways:
    ``every_us`` (direct spacing), ``rate_per_s`` (requests per second),
    or ``rate_bytes_per_s`` (byte rate; spacing is ``size / rate``, so
    the stream's request size must be nonzero).

    Subsumes the legacy ``StreamSpec.every_us`` / ``rate_bytes_per_s``
    knobs — those now lower through this class.

    >>> DeterministicRate(every_us=10.0).issue_times(3, start_us=5.0)
    array([ 5., 15., 25.])
    >>> DeterministicRate(rate_per_s=1e6).interval_us()
    1.0
    """

    every_us: Optional[float] = None
    rate_per_s: Optional[float] = None
    rate_bytes_per_s: Optional[float] = None

    def __post_init__(self):
        set_ = [k for k in ("every_us", "rate_per_s", "rate_bytes_per_s")
                if getattr(self, k) is not None]
        if len(set_) != 1:
            raise ValueError(
                f"DeterministicRate needs exactly one of every_us | "
                f"rate_per_s | rate_bytes_per_s, got {set_ or 'none'}")
        val = float(getattr(self, set_[0]))
        if not val > 0.0 or not np.isfinite(val):
            raise ValueError(f"{set_[0]} must be finite and > 0, got {val}")

    def interval_us(self, size: int = 0) -> float:
        if self.every_us is not None:
            return float(self.every_us)
        if self.rate_per_s is not None:
            return 1e6 / float(self.rate_per_s)
        if size <= 0:
            raise ValueError(
                "rate_bytes_per_s pacing needs a request size > 0 "
                "(a zero-size stream would silently degrade to "
                "closed-loop); set size= on the stream or use "
                "rate_per_s / every_us")
        return float(size) / float(self.rate_bytes_per_s) * 1e6

    def issue_times(self, n: int, *, start_us: float = 0.0,
                    size: int = 0) -> np.ndarray:
        n = self._check_n(n)
        pace = self.interval_us(size)
        return start_us + np.arange(n, dtype=np.float64) * pace


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Poisson process at ``rate_per_s``: i.i.d. exponential gaps,
    deterministic in ``seed``.

    >>> a = PoissonArrivals(rate_per_s=1000.0, seed=7)
    >>> t = a.issue_times(4)
    >>> bool((np.diff(t) > 0).all()), len(t)
    (True, 4)
    >>> bool((t == a.issue_times(4)).all())       # same seed, same draw
    True
    """

    rate_per_s: float = 1000.0
    seed: int = 0

    def __post_init__(self):
        if not self.rate_per_s > 0.0 or not np.isfinite(self.rate_per_s):
            raise ValueError(
                f"rate_per_s must be finite and > 0, got {self.rate_per_s}")

    def issue_times(self, n: int, *, start_us: float = 0.0,
                    size: int = 0) -> np.ndarray:
        n = self._check_n(n)
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1e6 / float(self.rate_per_s), n)
        return start_us + np.cumsum(gaps)


@dataclasses.dataclass(frozen=True)
class MarkovModulated(ArrivalProcess):
    """Two-state Markov-modulated Poisson process (on/off bursts).

    The source alternates between an *on* state (Poisson arrivals at
    ``rate_on_per_s``) and an *off* state (``rate_off_per_s``, typically
    0) with exponentially distributed dwell times of means
    ``mean_on_us`` / ``mean_off_us``.  Deterministic in ``seed``.

    >>> a = MarkovModulated(rate_on_per_s=1e5, mean_on_us=500.0,
    ...                     mean_off_us=2000.0, seed=3)
    >>> t = a.issue_times(50)
    >>> bool((np.diff(t) >= 0).all()), len(t)
    (True, 50)
    """

    rate_on_per_s: float = 10_000.0
    rate_off_per_s: float = 0.0
    mean_on_us: float = 10_000.0
    mean_off_us: float = 10_000.0
    seed: int = 0
    start_on: bool = True

    def __post_init__(self):
        if not self.rate_on_per_s > 0.0:
            raise ValueError(
                f"rate_on_per_s must be > 0, got {self.rate_on_per_s}")
        if self.rate_off_per_s < 0.0:
            raise ValueError(
                f"rate_off_per_s must be >= 0, got {self.rate_off_per_s}")
        if not (self.mean_on_us > 0.0 and self.mean_off_us > 0.0):
            raise ValueError("dwell-time means must be > 0")

    def issue_times(self, n: int, *, start_us: float = 0.0,
                    size: int = 0) -> np.ndarray:
        n = self._check_n(n)
        rng = np.random.default_rng(self.seed)
        out = np.empty(n, dtype=np.float64)
        t = 0.0
        on = bool(self.start_on)
        dwell = self.mean_on_us if on else self.mean_off_us
        state_end = float(rng.exponential(dwell))
        i = 0
        while i < n:
            rate = self.rate_on_per_s if on else self.rate_off_per_s
            # Memorylessness makes discarding the partial gap at a state
            # switch and redrawing in the new state statistically exact.
            gap = (float(rng.exponential(1e6 / rate)) if rate > 0.0
                   else float("inf"))
            if t + gap >= state_end:
                t = state_end
                on = not on
                dwell = self.mean_on_us if on else self.mean_off_us
                state_end = t + float(rng.exponential(dwell))
                continue
            t += gap
            out[i] = t
            i += 1
        return start_us + out


@dataclasses.dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replay explicit issue times — inline (``times_us``) or from a
    text file (``path``: whitespace-separated microsecond floats;
    ``#``-prefixed comment lines are skipped).  Times are sorted at
    lowering; the trace must hold at least as many times as the stream
    has requests.

    >>> TraceReplay(times_us=(30.0, 10.0, 20.0)).issue_times(2)
    array([10., 20.])
    """

    times_us: Tuple[float, ...] = ()
    path: Optional[str] = None

    def __post_init__(self):
        if bool(self.times_us) == (self.path is not None):
            raise ValueError(
                "TraceReplay needs exactly one of times_us | path")

    def _load(self) -> np.ndarray:
        if self.path is not None:
            vals = []
            with open(self.path) as f:
                for line in f:
                    line = line.strip()
                    if not line or line.startswith("#"):
                        continue
                    vals.extend(float(tok) for tok in line.split())
            times = np.asarray(vals, dtype=np.float64)
        else:
            times = np.asarray(self.times_us, dtype=np.float64)
        if not np.isfinite(times).all():
            raise ValueError("TraceReplay times must be finite")
        return np.sort(times)

    def issue_times(self, n: int, *, start_us: float = 0.0,
                    size: int = 0) -> np.ndarray:
        n = self._check_n(n)
        times = self._load()
        if len(times) < n:
            raise ValueError(
                f"TraceReplay holds {len(times)} issue times but the "
                f"stream needs {n}")
        return start_us + times[:n]


def spread_into_windows(n: int, windows: Sequence[Tuple[float, float]]
                        ) -> np.ndarray:
    """``n`` issue times (us) spread over ``[(start_us, end_us), ...]``
    windows: each window receives a share proportional to its length,
    placed evenly inside it (half-step inset from the edges).  The
    trough-scheduling primitive behind
    ``ReclaimScheduler.reclaim_workload(windows=...)``.

    >>> t = spread_into_windows(4, [(0.0, 100.0), (300.0, 400.0)])
    >>> [round(float(x), 1) for x in t]
    [25.0, 75.0, 325.0, 375.0]
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    wins = [(float(lo), float(hi)) for lo, hi in windows]
    if not wins or any(hi <= lo for lo, hi in wins):
        raise ValueError(f"windows must be nonempty (start < end): {wins}")
    lengths = np.asarray([hi - lo for lo, hi in wins])
    # Largest-remainder apportionment of n slots over the windows.
    quota = n * lengths / lengths.sum()
    counts = np.floor(quota).astype(int)
    rem = n - int(counts.sum())
    if rem > 0:
        order = np.argsort(-(quota - counts), kind="stable")
        counts[order[:rem]] += 1
    out = []
    for (lo, hi), k in zip(wins, counts):
        if k == 0:
            continue
        step = (hi - lo) / k
        out.append(lo + step * (np.arange(k) + 0.5))
    return np.sort(np.concatenate(out)) if out \
        else np.zeros(0, dtype=np.float64)
