"""Every calibration constant, traceable to a paper anchor.

The paper reports exact numbers for a subset of configurations (those are
used verbatim as anchors) and trends for the rest (those are interpolated,
with the chosen interpolation documented next to each table).  Benchmarks in
``benchmarks/`` re-derive the paper's figures from the model built on these
constants; ``tests/test_paper_claims.py`` asserts the anchors round-trip.

All latencies are in **microseconds** unless suffixed ``_ms``.
"""
from __future__ import annotations

from .spec import KiB, MiB, LBAFormat, OpType, Stack

US_PER_S = 1e6

# ---------------------------------------------------------------------------
# §III-C  (Fig. 2, Fig. 3): QD=1 service latencies, SPDK, 4 KiB LBA format.
#
# Anchors:
#   write  4 KiB SPDK            = 11.36 us   (Obs#2/#4)
#   append 8 KiB SPDK            = 14.02 us   (Obs#4; 23.42% over write)
#   write  85 KIOPS @ 4&8 KiB    -> 11.76 us  (Obs#3; QD1 => svc = 1/IOPS)
#   append 66 KIOPS @ 4 KiB      -> 15.15 us  (Obs#3)
#   append 69 KIOPS @ 8 KiB      -> 14.49 us  (Obs#3; Fig2b reports 14.02)
#   bytes-throughput saturates for >=32 KiB requests (Obs#3/#8, ~1155 MiB/s)
#
# Between anchors we interpolate linearly in request size; beyond the table
# service time grows proportionally to size (bandwidth-limited regime).
# ---------------------------------------------------------------------------

# size_bytes -> service us  (SPDK, LBA_4K)
WRITE_SVC_TABLE_US = {
    4 * KiB: 11.36,
    8 * KiB: 11.76,     # still ~85 KIOPS (Obs#3)
    16 * KiB: 14.20,    # IOPS starts to fall; ~70 KIOPS
    32 * KiB: 27.10,    # 32 KiB / 27.1us = 1.15 GiB/s ~ device limit (Obs#8)
    64 * KiB: 54.20,
    128 * KiB: 108.40,
}
APPEND_SVC_TABLE_US = {
    4 * KiB: 15.15,     # 66 KIOPS (Obs#3)
    8 * KiB: 14.02,     # lowest append latency (Obs#4)
    16 * KiB: 16.80,
    32 * KiB: 29.70,    # converges to bandwidth-limited regime (Obs#8)
    64 * KiB: 56.80,
    128 * KiB: 111.00,
}
# Flash read: paper gives read-only p95 = 81.41 us (Obs#11) and 424 KIOPS at
# QD128 (Obs#7).  Mean flash read svc ~= 70 us with ~30 parallel dies gives
# 30/70us = 428 KIOPS saturation and a QD1 latency consistent with p95.
READ_SVC_TABLE_US = {
    4 * KiB: 70.0,
    8 * KiB: 72.0,
    16 * KiB: 76.0,
    32 * KiB: 84.0,
    64 * KiB: 100.0,
    128 * KiB: 132.0,
}

# Stack overheads added on top of SPDK service time (Obs#2).
STACK_OVERHEAD_US = {
    Stack.SPDK: 0.0,
    Stack.KERNEL_NONE: 1.26,          # 12.62 - 11.36
    Stack.KERNEL_MQ_DEADLINE: 3.11,   # 14.47 - 11.36 (1.85us scheduler + io_uring)
}

# LBA-format penalty multipliers (Obs#1: "sometimes by as much as a factor
# of two").  4 KiB format is the baseline; the 512 B format penalizes small
# requests most (firmware not optimized for small I/O).
LBA512_PENALTY = {
    OpType.WRITE: 1.95,
    OpType.APPEND: 1.60,
    OpType.READ: 1.35,
}

# ---------------------------------------------------------------------------
# §III-D (Fig. 4): concurrency scaling saturation caps (KIOPS for 4 KiB).
#
#   read   424 KIOPS @ QD128 intra-zone (Obs#7)
#   write  293 KIOPS @ QD32 intra-zone with mq-deadline merging (Obs#7)
#   write  186 KIOPS inter-zone via SPDK (no merging; Obs#7)
#   append 132 KIOPS at concurrency 4, intra == inter (Obs#6)
#   4 KiB inter-zone writes peak at 726.74 MiB/s (Obs#8)
# ---------------------------------------------------------------------------
READ_IOPS_CAP = 424_000.0
WRITE_INTRA_MERGED_IOPS_CAP = 293_000.0
WRITE_INTER_IOPS_CAP = 186_000.0
APPEND_IOPS_CAP = 132_000.0

# mq-deadline merging (Obs#7): sequential same-zone writes are merged into
# larger requests; 92.35% of ops merged at QD16.  We model the merge factor
# (requests per merged super-request) as min(max(qd // 2, 1), MERGE_MAX).
MERGE_MAX = 8                      # 8 x 4 KiB = 32 KiB super-writes
MERGE_FRACTION_AT_QD16 = 0.9235    # validation anchor

# ---------------------------------------------------------------------------
# §III-E (Fig. 5): zone-management operation costs.
# ---------------------------------------------------------------------------
OPEN_LAT_US = 9.56        # Obs#9
CLOSE_LAT_US = 11.01      # Obs#9
IMPLICIT_OPEN_FIRST_WRITE_PENALTY_US = 2.02    # Obs#9
IMPLICIT_OPEN_FIRST_APPEND_PENALTY_US = 2.83   # Obs#9

# reset latency vs occupancy (Fig. 5a) — piecewise-linear anchors
# (occupancy fraction -> ms).  0%/50%/100% anchors are from the text;
# intermediate points follow the figure's monotone trend.
RESET_LAT_MS_TABLE = {
    0.0: 0.40,
    0.0005: 0.52,   # "1 page"
    0.0625: 2.10,
    0.125: 3.70,
    0.25: 6.60,
    0.50: 11.60,    # Obs#10 anchor
    1.00: 16.19,    # Obs#10 anchor
}
# Resetting a finished zone is cheaper: 26.58% less at 50% occupancy
# (Obs#10).  Applied as a multiplicative discount.
RESET_FINISHED_DISCOUNT = 1.0 - 0.2658

# finish latency vs occupancy (Fig. 5b).  Physical model: finishing
# programs the *remaining* capacity (or equivalent mapping work), linear in
# (1 - occupancy) — consistent with the reported linearity <0.1%..25% — plus
# a metadata floor.  Anchors: 907.51 ms @ <0.1%, 3.07 ms @ 100% (Obs#10).
FINISH_LAT_FLOOR_MS = 3.07
FINISH_LAT_SPAN_MS = 907.51 - 3.07     # cost of programming a ~empty zone

# ---------------------------------------------------------------------------
# §III-F (Fig. 6): interference & the conventional-SSD GC baseline.
# ---------------------------------------------------------------------------
PEAK_WRITE_BW_MIBS = 1155.0           # measured peak (both devices)
ZNS_READ_P95_UNDER_WRITES_MS = 98.04  # Obs#11 anchor
CONV_READ_P95_UNDER_WRITES_MS = 299.89
READONLY_READ_P95_US = 81.41

# Conventional GC model: above the dirty-block knee, the FTL steals write
# bandwidth in bursts, producing Fig. 6a's sawtooth between ~0 and peak.
CONV_GC_PERIOD_S = 18.0       # sawtooth period at full-rate writes
CONV_GC_DUTY = 0.45           # fraction of the period spent in deep GC
CONV_GC_FLOOR_MIBS = 40.0     # throughput floor during GC stalls

# ---------------------------------------------------------------------------
# §III-G (Fig. 7): reset-interference coupling.
#
# p95 reset latency of full zones: 17.94 ms isolated; inflated by concurrent
# I/O (Obs#13), while resets leave I/O unaffected (Obs#12).
# ---------------------------------------------------------------------------
RESET_P95_ISOLATED_MS = 17.94
RESET_INFLATION = {
    OpType.READ: 1.5611,     # -> 28.00 ms
    OpType.WRITE: 1.7842,    # -> 32.00 ms
    OpType.APPEND: 1.7550,   # -> 31.48 ms
}

# Lognormal-ish tail shape used to turn mean latencies into distributions;
# sigma chosen so mean->p95 matches the reset anchors (16.19 mean, 17.94 p95).
RESET_TAIL_SIGMA = 0.0623


def interp_table(table: dict, x: float) -> float:
    """Piecewise-linear interpolation with proportional extrapolation."""
    keys = sorted(table)
    if x <= keys[0]:
        return table[keys[0]]
    if x >= keys[-1]:
        # bandwidth-limited regime: scale the last point proportionally
        return table[keys[-1]] * (x / keys[-1])
    for lo, hi in zip(keys, keys[1:]):
        if lo <= x <= hi:
            f = (x - lo) / (hi - lo)
            return table[lo] * (1 - f) + table[hi] * f
    raise AssertionError


def interp_table_clamped(table: dict, x: float) -> float:
    """Piecewise-linear interpolation, clamped at both ends (no extrapolation)."""
    keys = sorted(table)
    if x <= keys[0]:
        return table[keys[0]]
    if x >= keys[-1]:
        return table[keys[-1]]
    return interp_table(table, x)
