"""Core: the paper's contribution — a calibrated ZNS device performance
model (zone state machine + latency model + event engine) and the
conventional-SSD GC baseline it is compared against."""
from .spec import (  # noqa: F401
    KiB, MiB, GiB,
    ConvDeviceSpec, LBAFormat, OpType, Stack, ZNSDeviceSpec, ZoneState,
    SN640, ZN540,
)
from .state_machine import ZoneError, ZoneManager, transition_array  # noqa: F401
from .latency import (  # noqa: F401
    DEFAULT_LATENCY_MODEL, DEFAULT_LATENCY_PARAMS, LatencyModel,
    LatencyParams, stack_latency_params, unstack_latency_params,
    zn540_params,
)
from .engine import (  # noqa: F401
    SimResult, SteadyStateResult, ThroughputModel, Trace,
    compute_service_times, simulate, simulate_vectorized,
    zone_sequential_completions, zone_sequential_completions_batched,
)
from .chain_program import (  # noqa: F401
    ChainProgram, CompileStats, SolveStats, block_adjacency, build_program,
    clear_program_cache, compile_fleet_program, compile_program,
    concat_programs, extend_program, force_layout, last_compile_stats,
    last_solve_stats, program_cache_dir, program_cache_info,
    program_chains, set_program_cache_dir, solve_program,
    unjustified_slots, verify_fixpoint,
)
from .shard import (  # noqa: F401
    Shard, ShardedProgram, Window, WindowedProgram, clear_shard_plans,
    shard_program, solve_program_sharded, solve_program_windowed,
    window_program,
)
from .conventional import ConventionalSSD, zns_write_pressure_series  # noqa: F401
from .metrics import (  # noqa: F401
    LatencyStats, available_metrics, bandwidth_bytes, extract_metrics, iops,
    register_metric, slo_violations, throughput_timeseries,
    unregister_metric, violation_rate,
)
from .arrival import (  # noqa: F401
    ArrivalProcess, DeterministicRate, MarkovModulated, PoissonArrivals,
    TraceReplay, spread_into_windows,
)
from .workload import StreamSpec, WorkloadSpec  # noqa: F401
from .fleet import batched_sequential_completions, simulate_fleet_vectorized  # noqa: F401
from .device import (  # noqa: F401
    ConvDevice, DeviceFleet, FleetRunResult, PressureResult, RunResult,
    ZnsDevice, available_backends, available_pressure_backends,
    register_backend, register_pressure_backend, unregister_backend,
)
from . import calibration, emulator_models, workloads  # noqa: F401
