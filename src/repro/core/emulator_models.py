"""Executable form of the paper's emulator-fidelity analysis (§IV).

The paper examines FEMU and NVMeVirt and identifies which of the 13
observations each can reproduce, given its latency-model design.  This
module encodes each emulator's *model* (not the emulators themselves) so
the benchmark harness can compare them against ours on identical
workloads, and so tests can assert the fidelity matrix from §IV.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .latency import LatencyModel
from .spec import KiB, LBAFormat, OpType, Stack

#: Which paper observations each emulator reproduces (paper §IV text).
#: Observations 1, 2, 11 are excluded by the paper as not-ZNS-essential.
FIDELITY_MATRIX = {
    # obs:      3      4      5      6      7      8      9      10     12     13
    "femu":     dict.fromkeys([3, 4, 5, 6, 7, 8, 9, 10, 12, 13], False),
    "nvmevirt": {3: True, 4: False, 5: False, 6: False, 7: True, 8: True,
                 9: False, 10: False, 12: False, 13: False},
    "ours":     dict.fromkeys([3, 4, 5, 6, 7, 8, 9, 10, 12, 13], True),
}


class EmulatorModel:
    """Common interface: per-op service latency in microseconds."""

    name = "abstract"

    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        raise NotImplementedError

    def reset_us(self, occupancy, was_finished=False):
        raise NotImplementedError

    def finish_us(self, occupancy):
        raise NotImplementedError


class FEMUModel(EmulatorModel):
    """FEMU 'makes no attempt at emulating ZNS SSD request latency';
    requests complete as fast as host DRAM permits (§IV)."""

    name = "femu"
    DRAM_LAT_US = 1.5          # DRAM-backed completion
    DRAM_BW = 12e9             # bytes/s host memcpy

    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        size = np.asarray(size_bytes, dtype=np.float64)
        return self.DRAM_LAT_US + size / self.DRAM_BW * 1e6

    def reset_us(self, occupancy, was_finished=False):
        return np.zeros_like(np.asarray(occupancy, dtype=np.float64)) + self.DRAM_LAT_US

    def finish_us(self, occupancy):
        # "finish operations will become unrealistically fast" (§IV)
        return np.zeros_like(np.asarray(occupancy, dtype=np.float64)) + self.DRAM_LAT_US


class NVMeVirtModel(EmulatorModel):
    """NVMeVirt: explicit channel/NAND timing, accurate for read/write, but
    (a) append == write latency, (b) reset is a static NAND-erase constant,
    (c) no finish/open/close timing (§IV)."""

    name = "nvmevirt"
    NAND_ERASE_US = 3500.0     # "multiple milliseconds", static

    def __init__(self):
        self._lat = LatencyModel()

    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        op = np.asarray(op)
        # append modeled with the *write* latency model — the §IV critique.
        op_as_write = np.where(op == OpType.APPEND, int(OpType.WRITE), op)
        return self._lat.io_service_us(op_as_write, size_bytes, stack, fmt)

    def reset_us(self, occupancy, was_finished=False):
        occ = np.asarray(occupancy, dtype=np.float64)
        return np.full_like(occ, self.NAND_ERASE_US)

    def finish_us(self, occupancy):
        occ = np.asarray(occupancy, dtype=np.float64)
        return np.zeros_like(occ)   # not modeled at all


class OurModel(EmulatorModel):
    """The model this repo proposes (and the paper prescribes): distinct
    append/write latencies, occupancy-linear reset/finish, transition
    timing, interference coupling — see latency.py / engine.py."""

    name = "ours"

    def __init__(self):
        self._lat = LatencyModel()

    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        return self._lat.io_service_us(op, size_bytes, stack, fmt)

    def reset_us(self, occupancy, was_finished=False):
        return self._lat.reset_us(occupancy, was_finished)

    def finish_us(self, occupancy):
        return self._lat.finish_us(occupancy)


ALL_MODELS = {m.name: m for m in (FEMUModel(), NVMeVirtModel(), OurModel())}


def fidelity_report() -> list[tuple[str, int, bool]]:
    rows = []
    for name, obs in FIDELITY_MATRIX.items():
        for k in sorted(obs):
            rows.append((name, k, obs[k]))
    return rows
