"""Executable form of the paper's emulator-fidelity analysis (§IV).

The paper examines FEMU and NVMeVirt and identifies which of the 13
observations each can reproduce, given its latency-model design.  Each
emulator's *model* (not the emulator itself) is encoded as a named
:class:`repro.core.latency.LatencyParams` profile — the same parameter
pytree the calibrated ZN540 model uses — so all three run through the
identical simulation engines (single device or batched
:class:`repro.core.DeviceFleet`), benchmarks compare them on identical
workloads, and :func:`simulated_fidelity` *derives* the §IV matrix from
simulated outputs instead of trusting the hardcoded table.

The old ``EmulatorModel`` class hierarchy remains as thin shims over the
profiles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import calibration as C
from .latency import (
    DEFAULT_LATENCY_PARAMS, LatencyModel, LatencyParams, finish_us,
    io_service_us, reset_us,
)
from .spec import KiB, LBAFormat, MiB, OpType, Stack

#: Which paper observations each emulator reproduces (paper §IV text).
#: Observations 1, 2, 11 are excluded by the paper as not-ZNS-essential.
FIDELITY_MATRIX = {
    # obs:      3      4      5      6      7      8      9      10     12     13
    "femu":     dict.fromkeys([3, 4, 5, 6, 7, 8, 9, 10, 12, 13], False),
    "nvmevirt": {3: True, 4: False, 5: False, 6: False, 7: True, 8: True,
                 9: False, 10: False, 12: False, 13: False},
    "ours":     dict.fromkeys([3, 4, 5, 6, 7, 8, 9, 10, 12, 13], True),
}


# ---------------------------------------------------------------------------
# Profiles: one LatencyParams per emulator, on the ZN540 anchor grids so
# heterogeneous fleets can stack them along a device axis.
# ---------------------------------------------------------------------------
_FEMU_DRAM_LAT_US = 1.5    # DRAM-backed completion
_FEMU_DRAM_BW = 12e9       # bytes/s host memcpy
_NVMEVIRT_NAND_ERASE_US = 3500.0   # "multiple milliseconds", static


def femu_params() -> LatencyParams:
    """FEMU 'makes no attempt at emulating ZNS SSD request latency';
    requests complete as fast as host DRAM permits (§IV)."""
    d = DEFAULT_LATENCY_PARAMS
    sizes = d.size_anchors
    dram = _FEMU_DRAM_LAT_US + sizes / _FEMU_DRAM_BW * 1e6
    return dataclasses.replace(
        d,
        io_svc_us=np.stack([dram, dram, dram]),       # read==write==append
        stack_overhead_us=np.zeros(3),                # no host-stack model
        lba512_penalty=np.ones(3),
        reset_us_table=np.full_like(d.reset_occ, _FEMU_DRAM_LAT_US),
        reset_finished_discount=np.float64(1.0),
        # "finish operations will become unrealistically fast" (§IV)
        finish_floor_us=np.float64(_FEMU_DRAM_LAT_US),
        finish_span_us=np.float64(0.0),
        open_cost_us=np.float64(0.0),
        close_cost_us=np.float64(0.0),
        implicit_open_us=np.zeros(3),
        reset_inflation=np.ones(3),                   # no Obs#13 coupling
        reset_on_io_path=np.float64(0.0),
        reset_tail_sigma=np.float64(0.0),
        io_jitter_sigma=np.zeros(3),
    )


def nvmevirt_params() -> LatencyParams:
    """NVMeVirt: explicit channel/NAND timing, accurate for read/write, but
    (a) append == write latency, (b) reset is a static NAND-erase constant
    executed on the data path, (c) no finish/open/close timing (§IV)."""
    d = DEFAULT_LATENCY_PARAMS
    # append modeled with the *write* latency row — the §IV critique.
    io_rows = np.stack([d.io_svc_us[int(OpType.READ)],
                        d.io_svc_us[int(OpType.WRITE)],
                        d.io_svc_us[int(OpType.WRITE)]])
    return dataclasses.replace(
        d,
        io_svc_us=io_rows,
        stack_overhead_us=np.zeros(3),                # device emulator only
        reset_us_table=np.full_like(d.reset_occ, _NVMEVIRT_NAND_ERASE_US),
        reset_finished_discount=np.float64(1.0),
        finish_floor_us=np.float64(0.0),              # not modeled at all
        finish_span_us=np.float64(0.0),
        open_cost_us=np.float64(0.0),
        close_cost_us=np.float64(0.0),
        implicit_open_us=np.zeros(3),
        reset_inflation=np.ones(3),
        reset_on_io_path=np.float64(1.0),             # erase blocks the channel
        reset_tail_sigma=np.float64(0.0),
    )


EMULATOR_PROFILES: dict[str, LatencyParams] = {
    "femu": femu_params(),
    "nvmevirt": nvmevirt_params(),
    "ours": DEFAULT_LATENCY_PARAMS,
}


# ---------------------------------------------------------------------------
# Simulated fidelity: derive the §IV matrix from model outputs.
# ---------------------------------------------------------------------------
def _within(x: float, anchor: float, rel: float) -> bool:
    return abs(x - anchor) <= rel * anchor


def simulated_fidelity(profile, *, backend: str = "event") -> dict:
    """Which observations a latency profile reproduces, **by simulation**.

    Every entry is decided from the profile's actual outputs — pure
    latency-function evaluations for the per-request observations, full
    engine runs (through the standard device session) for the concurrency
    and interference ones — never from :data:`FIDELITY_MATRIX` itself.
    Tests assert the derived dict equals the paper's table.
    """
    from .device import ZnsDevice          # local import: device -> us
    from .workload import WorkloadSpec

    params = EMULATOR_PROFILES[profile] if isinstance(profile, str) \
        else profile
    dev = ZnsDevice(lat=LatencyModel(params=params))
    obs = {}

    def run(wl):
        return dev.run(wl, backend=backend, jitter=False)

    # Obs#3 — request-size dependence matching the measured curve.
    w4 = float(io_service_us(params, OpType.WRITE, 4 * KiB))
    w32 = float(io_service_us(params, OpType.WRITE, 32 * KiB))
    obs[3] = _within(w4, 11.36, 0.25) and _within(w32, 27.10, 0.25)
    # Obs#4 — append and write have distinct service latencies.
    a8 = float(io_service_us(params, OpType.APPEND, 8 * KiB))
    w8 = float(io_service_us(params, OpType.WRITE, 8 * KiB))
    obs[4] = a8 >= 1.10 * w8
    # Obs#5 — scheduler-dependent write path (mq-deadline adds measurable
    # overhead over SPDK; prerequisite for modeling merged intra-zone
    # writes at QD>1).
    mq = float(io_service_us(params, OpType.WRITE, 4 * KiB,
                             Stack.KERNEL_MQ_DEADLINE))
    obs[5] = _within(mq - w4, 3.11, 0.25)
    # Obs#6 — append concurrency saturates at the measured 132 KIOPS.
    r = run(WorkloadSpec().appends(n=3000, size=4 * KiB, qd=4))
    obs[6] = _within(r.iops, C.APPEND_IOPS_CAP, 0.20)
    # Obs#7 — intra-zone read scaling reaches the measured 424 KIOPS.
    r = run(WorkloadSpec().reads(n=6000, size=4 * KiB, qd=128))
    obs[7] = _within(r.iops, C.READ_IOPS_CAP, 0.20)
    # Obs#8 — >=32 KiB writes saturate device bandwidth (~1155 MiB/s).
    r = run(WorkloadSpec().writes(n=2000, size=32 * KiB, qd=1))
    obs[8] = _within(r.bandwidth_bytes / MiB, C.PEAK_WRITE_BW_MIBS, 0.15)
    # Obs#9 — explicit open/close transition costs.
    obs[9] = _within(float(params.open_cost_us), C.OPEN_LAT_US, 0.25) and \
        _within(float(params.close_cost_us), C.CLOSE_LAT_US, 0.25)
    # Obs#10 — occupancy-dependent reset and finish costs.
    r_lo = float(reset_us(params, 0.25))
    r_hi = float(reset_us(params, 1.0))
    f_lo = float(finish_us(params, 0.001))
    f_hi = float(finish_us(params, 1.0))
    obs[10] = r_hi >= 1.3 * r_lo and f_lo >= 10.0 * max(f_hi, 1e-9)
    # Obs#12 — resets never delay I/O.  Requires (a) simulated I/O
    # completions unchanged by concurrent resets under pool saturation and
    # (b) a reset latency in the realistic ms range, otherwise the paper's
    # interference experiment cannot even be reproduced.
    quiet = WorkloadSpec().reads(n=2000, size=4 * KiB, qd=32, thread=0)
    loud = (WorkloadSpec()
            .resets(n=20, occupancy=1.0, nzones=20, thread=1)
            .reads(n=2000, size=4 * KiB, qd=32, thread=0))
    a = run(quiet)
    b = run(loud)
    rmask = b.trace.op == int(OpType.READ)
    shifted = bool(np.any(np.abs(b.sim.complete[rmask] - a.sim.complete)
                          > 1e-6))
    obs[12] = (not shifted) and r_hi >= 1e3
    # Obs#13 — concurrent I/O inflates reset latency.
    iso = run(WorkloadSpec().resets(n=30, occupancy=1.0, nzones=30))
    infl = run(WorkloadSpec().resets(n=30, occupancy=1.0, nzones=30,
                                     io_ctx=OpType.WRITE))
    ratio = (infl.latency_stats(OpType.RESET).mean_us
             / max(iso.latency_stats(OpType.RESET).mean_us, 1e-9))
    obs[13] = ratio >= 1.3
    return obs


# ---------------------------------------------------------------------------
# Legacy class shims (delegate to the profiles)
# ---------------------------------------------------------------------------
class EmulatorModel:
    """Common interface: per-op service latency in microseconds.

    .. deprecated:: prefer the :data:`EMULATOR_PROFILES` parameter pytrees;
       these shims only delegate to them.
    """

    name = "abstract"

    @property
    def params(self) -> LatencyParams:
        return EMULATOR_PROFILES[self.name]

    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        return io_service_us(self.params, op, size_bytes, stack, fmt)

    def reset_us(self, occupancy, was_finished=False):
        return reset_us(self.params, occupancy, was_finished)

    def finish_us(self, occupancy):
        return finish_us(self.params, occupancy)


class FEMUModel(EmulatorModel):
    """FEMU 'makes no attempt at emulating ZNS SSD request latency' (§IV)."""

    name = "femu"
    DRAM_LAT_US = _FEMU_DRAM_LAT_US
    DRAM_BW = _FEMU_DRAM_BW


class NVMeVirtModel(EmulatorModel):
    """NVMeVirt: append == write, static reset, no finish timing (§IV)."""

    name = "nvmevirt"
    NAND_ERASE_US = _NVMEVIRT_NAND_ERASE_US


class OurModel(EmulatorModel):
    """The model this repo proposes (and the paper prescribes): distinct
    append/write latencies, occupancy-linear reset/finish, transition
    timing, interference coupling — see latency.py / engine.py."""

    name = "ours"


ALL_MODELS = {m.name: m for m in (FEMUModel(), NVMeVirtModel(), OurModel())}


def fidelity_report() -> list[tuple[str, int, bool]]:
    rows = []
    for name, obs in FIDELITY_MATRIX.items():
        for k in sorted(obs):
            rows.append((name, k, obs[k]))
    return rows
