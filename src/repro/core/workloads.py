"""fio/SPDK-style workload generators producing :class:`Trace` objects.

Each generator mirrors one of the paper's experimental setups (§III-A..G):
closed-loop threads at a queue depth, optional rate limiting, intra- vs
inter-zone layouts, fill/reset/finish sequences for the state-machine
costs, and the two-thread reset-interference layout of §III-G.

The sweep/interference generators are now thin wrappers over the
declarative :class:`repro.core.WorkloadSpec` builder (they lower to the
identical traces); prefer composing a ``WorkloadSpec`` directly for new
workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .engine import Trace
from .latency import LatencyModel
from .spec import KiB, MiB, LBAFormat, OpType, Stack, ZNSDeviceSpec
from .workload import WorkloadSpec


def _closed_loop_issue(n: int, pace_us: float) -> np.ndarray:
    """Nominal issue times; the engine's per-thread rings enforce QD."""
    return np.arange(n, dtype=np.float64) * pace_us


def io_stream(op: OpType, *, size: int, n: int, qd: int = 1, zone: int = 0,
              thread: int = 0, stack: Stack = Stack.SPDK,
              fmt: LBAFormat = LBAFormat.LBA_4K,
              rate_bytes_per_s: Optional[float] = None,
              start_us: float = 0.0, nzones: int = 1) -> Trace:
    """A single closed-loop thread issuing ``n`` ops of one type.

    ``nzones > 1`` round-robins requests over zones [zone, zone+nzones)
    (the paper's inter-zone layout uses 1 thread/zone; round-robin from
    one thread is equivalent for device-side concurrency accounting).
    """
    zones = zone + (np.arange(n) % nzones)
    if rate_bytes_per_s is not None:
        pace = size / rate_bytes_per_s * 1e6
    else:
        pace = 0.0   # purely closed-loop: QD gates everything
    issue = start_us + _closed_loop_issue(n, pace)
    return Trace.build(
        op=np.full(n, int(op)), zone=zones, size=np.full(n, size),
        issue=issue, thread=np.full(n, thread), qd=np.full(n, qd),
        stack=stack, fmt=fmt)


def merge_intra_zone_writes(trace: Trace, merge_factor: int) -> Trace:
    """Model mq-deadline merging: coalesce groups of ``merge_factor``
    sequential same-zone writes into single device requests (Obs#7)."""
    if merge_factor <= 1:
        return trace
    n = len(trace)
    keep = np.arange(0, n, merge_factor)
    sizes = np.add.reduceat(trace.size, keep)
    return Trace.build(
        op=trace.op[keep], zone=trace.zone[keep], size=sizes,
        issue=trace.issue[keep], thread=trace.thread[keep],
        qd=np.maximum(trace.qd[keep] // merge_factor, 1),
        stack=trace.stack, fmt=trace.fmt)


def concat(*traces: Trace) -> Trace:
    ts = [t for t in traces if len(t)]
    if len({(t.stack, t.fmt) for t in ts}) != 1:
        raise ValueError("cannot concat traces with mixed stack/format")
    cat = lambda f: np.concatenate([getattr(t, f) for t in ts])
    return Trace(op=cat("op"), zone=cat("zone"), size=cat("size"),
                 issue=cat("issue"), thread=cat("thread"), qd=cat("qd"),
                 occupancy=cat("occupancy"), was_finished=cat("was_finished"),
                 io_ctx=cat("io_ctx"), stack=ts[0].stack, fmt=ts[0].fmt)


# ---------------------------------------------------------------------------
# §III-E: state-machine cost workloads
# ---------------------------------------------------------------------------
def reset_sweep(occupancies, *, finished_first: bool, n_per_level: int = 100,
                pause_us: float = 1e6, spec: ZNSDeviceSpec = ZNSDeviceSpec()
                ) -> Trace:
    """Reset (optionally finish-then-reset) zones at given occupancy levels.

    Mirrors the Fig. 5 methodology: fill to the level, pause 1 s for the
    device to stabilize, then reset (or finish+reset).
    """
    return (WorkloadSpec()
            .reset_sweep(occupancies, n_per_level=n_per_level,
                         pause_us=pause_us, finish_first=finished_first)
            .build())


def finish_sweep(occupancies, *, n_per_level: int = 100,
                 pause_us: float = 1e6) -> Trace:
    return (WorkloadSpec()
            .finish_sweep(occupancies, n_per_level=n_per_level,
                          pause_us=pause_us)
            .build())


# ---------------------------------------------------------------------------
# §III-G: reset interference (two threads)
# ---------------------------------------------------------------------------
def reset_interference(io_op: Optional[OpType], *, n_resets: int = 400,
                       io_size: int = 4 * KiB,
                       spec: ZNSDeviceSpec = ZNSDeviceSpec()) -> Trace:
    """Thread 0 resets full zones back-to-back; thread 1 issues I/O.

    ``io_op = None`` reproduces the isolated-reset baseline.
    """
    wl = WorkloadSpec().resets(n=n_resets, occupancy=1.0,
                               nzones=spec.num_zones // 2, io_ctx=io_op)
    if io_op is None:
        return wl.build()
    # Enough I/O to overlap every reset (resets take ~16-32 ms each).
    est_span_us = n_resets * 35e3
    svc = float(LatencyModel(spec).io_service_us(io_op, io_size))
    n_io = min(int(est_span_us / svc) + 1, 150_000)
    return wl.stream(io_op, n=n_io, size=io_size, qd=1,
                     zone=spec.num_zones // 2,
                     nzones=spec.num_zones // 2).build()


# ---------------------------------------------------------------------------
# §III-F: GC / write-pressure interference
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class WritePressureConfig:
    rate_mibs: float                # rate limit for the write side
    duration_s: float = 60.0
    write_size: int = 128 * KiB
    write_threads: int = 4
    write_qd: int = 8
    read_size: int = 4 * KiB
    read_qd: int = 32


def write_pressure_workload(cfg: WritePressureConfig, *, use_append: bool,
                            spec: ZNSDeviceSpec = ZNSDeviceSpec()) -> Trace:
    """4 writer threads (rate-limited) + 1 random-read thread (§III-F)."""
    per_thread_rate = cfg.rate_mibs * MiB / cfg.write_threads
    n_w = int(per_thread_rate * cfg.duration_s / cfg.write_size)
    op = OpType.APPEND if use_append else OpType.WRITE
    wl = WorkloadSpec()
    for t in range(cfg.write_threads):
        wl = wl.stream(op, n=max(n_w, 1), size=cfg.write_size,
                       qd=cfg.write_qd, zone=t * 50, nzones=8, thread=t,
                       rate_bytes_per_s=per_thread_rate)
    est_read_rate = 2_000.0  # reads crawl under pressure; engine decides
    wl = wl.reads(n=int(est_read_rate * cfg.duration_s), size=cfg.read_size,
                  qd=cfg.read_qd, zone=500, nzones=200,
                  thread=cfg.write_threads)
    return wl.build()
