"""Declarative workload builder for the :class:`repro.core.ZnsDevice` API.

A :class:`WorkloadSpec` is an immutable, chainable description of a
benchmark workload as a set of *streams* — mirroring how the paper
drives fio/SPDK (§III-A): each stream is one thread issuing one
operation type at a queue depth, with optional rate limiting, intra- vs
inter-zone layouts, occupancy sweeps for zone-management ops, and phases
(time offsets).  Streams are closed-loop by default; an
:class:`repro.core.arrival.ArrivalProcess` (``arrival=``, with ``qd=0``
for unbounded in-flight) paces them open-loop instead.  ``build()``
lowers the spec to the struct-of-arrays :class:`repro.core.Trace`
consumed by the simulation backends.

    wl = (WorkloadSpec()
          .writes(n=10_000, size=4 * KiB, qd=4, zone=0)
          .reads(n=10_000, size=4 * KiB, qd=8, zone=100, nzones=64)
          .resets(n=50, occupancy=1.0, io_ctx=OpType.WRITE))
    result = ZnsDevice().run(wl, backend="vectorized")

Streams get distinct thread ids unless pinned, so closed-loop gating is
per stream exactly as in the paper's multi-thread setups.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from .arrival import ArrivalProcess, DeterministicRate
from .engine import Trace
from .spec import KiB, LBAFormat, OpType, Stack

_IO_OPS = (OpType.READ, OpType.WRITE, OpType.APPEND)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One stream of a single operation type.

    Closed-loop by default (``qd`` gates issue on completions).  An
    :class:`repro.core.arrival.ArrivalProcess` (``arrival=``) paces the
    stream open-loop instead; ``qd=0`` means "unbounded in-flight" —
    the closed-loop gate never binds and the stream runs purely on its
    arrival clock.  The legacy ``every_us`` / ``rate_bytes_per_s`` knobs
    lower through :class:`repro.core.arrival.DeterministicRate`.
    """

    op: OpType
    n: int
    size: int = 0
    qd: int = 1                     # 0 = open loop (unbounded in-flight)
    zone: int = 0
    nzones: int = 1                 # round-robin over [zone, zone + nzones)
    thread: Optional[int] = None    # auto-assigned at build() when None
    rate_bytes_per_s: Optional[float] = None
    every_us: Optional[float] = None  # fixed inter-issue spacing
    arrival: Optional[ArrivalProcess] = None
    start_us: float = 0.0
    # zone-management parameters
    occupancy: float = 0.0
    occupancies: Optional[Tuple[float, ...]] = None  # sweep levels
    n_per_level: int = 1
    pause_us: float = 0.0           # settle time before each mgmt op
    finish_first: bool = False      # FINISH each zone before RESET
    was_finished: bool = False
    io_ctx: int = -1                # OpType running concurrently (Obs#13)

    def __post_init__(self):
        if self.qd < 0:
            raise ValueError(f"qd must be >= 0 (0 = open loop), "
                             f"got {self.qd}")
        if self.rate_bytes_per_s is not None:
            if not self.rate_bytes_per_s > 0.0:
                raise ValueError(
                    f"rate_bytes_per_s must be > 0, got "
                    f"{self.rate_bytes_per_s}; drop it for a purely "
                    f"closed-loop stream")
            if self.op in _IO_OPS and self.size <= 0:
                raise ValueError(
                    "rate_bytes_per_s pacing needs size > 0 — a "
                    "zero-size stream would silently degrade to "
                    "closed-loop (pace 0)")
        if self.every_us is not None and self.every_us < 0.0:
            raise ValueError(f"every_us must be >= 0, got {self.every_us}")
        if self.arrival is not None and (
                self.every_us is not None
                or self.rate_bytes_per_s is not None):
            raise ValueError(
                "arrival= conflicts with the legacy every_us / "
                "rate_bytes_per_s pacing knobs; use one or the other "
                "(DeterministicRate subsumes both)")
        if self.occupancies is not None and self.n != self.n_per_level:
            raise ValueError(
                f"occupancies= sizes the stream by n_per_level "
                f"(={self.n_per_level}), so n={self.n} conflicts; pass "
                f"n=n_per_level or use reset_sweep()/finish_sweep()")

    def lower(self, thread: int) -> Trace:
        if self.op in _IO_OPS:
            return self._lower_io(thread)
        return self._lower_mgmt(thread)

    def _resolved_arrival(self) -> Optional[ArrivalProcess]:
        """The stream's arrival process, with legacy pacing knobs lowered
        through :class:`DeterministicRate` (None = purely closed-loop)."""
        if self.arrival is not None:
            return self.arrival
        if self.every_us is not None:
            return DeterministicRate(every_us=float(self.every_us)) \
                if self.every_us > 0.0 else None
        if self.rate_bytes_per_s is not None:
            return DeterministicRate(
                rate_bytes_per_s=float(self.rate_bytes_per_s))
        return None

    def _lowered_qd(self, n: int) -> int:
        # qd=0 (open loop): lower with qd >= n so the closed-loop gate
        # (request p waits on completion p-qd) can never bind.
        return self.qd if self.qd > 0 else max(n, 1)

    # -- I/O streams --------------------------------------------------------
    def _lower_io(self, thread: int) -> Trace:
        n = self.n
        zones = self.zone + (np.arange(n) % max(self.nzones, 1))
        arrival = self._resolved_arrival()
        if arrival is not None:
            issue = arrival.issue_times(n, start_us=self.start_us,
                                        size=self.size)
        else:
            issue = np.full(n, self.start_us, dtype=np.float64)
        return Trace.build(
            op=np.full(n, int(self.op)), zone=zones,
            size=np.full(n, self.size), issue=issue,
            thread=np.full(n, thread), qd=np.full(n, self._lowered_qd(n)))

    # -- zone-management streams -------------------------------------------
    def _lower_mgmt(self, thread: int) -> Trace:
        ops, occs, fin, issue, ctx = [], [], [], [], []
        levels = self.occupancies if self.occupancies is not None \
            else (self.occupancy,)
        per = self.n_per_level if self.occupancies is not None else self.n
        arrival = self.arrival
        base = arrival.issue_times(len(levels) * per,
                                   start_us=self.start_us) \
            if arrival is not None else None
        t = self.start_us
        slot = 0
        for occ in levels:
            for _ in range(per):
                if base is not None:
                    t = float(base[slot]) + self.pause_us
                else:
                    t += self.pause_us
                slot += 1
                if self.op == OpType.RESET and self.finish_first \
                        and 0.0 < occ < 1.0:
                    ops.append(int(OpType.FINISH)); occs.append(occ)
                    fin.append(False); issue.append(t); ctx.append(self.io_ctx)
                    t += 1.0
                    ops.append(int(OpType.RESET)); occs.append(occ)
                    fin.append(True); issue.append(t); ctx.append(self.io_ctx)
                else:
                    ops.append(int(self.op)); occs.append(occ)
                    fin.append(self.was_finished); issue.append(t)
                    ctx.append(self.io_ctx)
                if base is None and self.every_us is not None:
                    t += self.every_us
        n = len(ops)
        zones = self.zone + (np.arange(n) % max(self.nzones, 1))
        return Trace.build(
            op=ops, zone=zones, size=None, issue=issue,
            thread=np.full(n, thread), qd=np.full(n, self._lowered_qd(n)),
            occupancy=occs, was_finished=fin, io_ctx=ctx)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Composable, declarative multi-stream workload.

    Every builder method returns a *new* spec (chainable, immutable).
    ``stack``/``fmt`` apply to the whole workload (a :class:`Trace` is
    homogeneous in both, matching the paper's per-experiment setup).

    Example::

        >>> from repro.core import KiB, WorkloadSpec
        >>> wl = (WorkloadSpec()
        ...       .writes(n=4, size=4 * KiB, qd=2)
        ...       .resets(n=1, occupancy=1.0))
        >>> len(wl.streams), len(wl.build())
        (2, 5)
    """

    streams: Tuple[StreamSpec, ...] = ()
    stack: Stack = Stack.SPDK
    fmt: LBAFormat = LBAFormat.LBA_4K
    phase_us: float = 0.0
    # Set on shards returned by :meth:`shard`: a remainder shard with no
    # streams (more devices than streams/requests) lowers to an empty
    # trace instead of raising at ``build()``.
    empty_ok: bool = False

    # -- configuration ------------------------------------------------------
    def on_stack(self, stack: Stack) -> "WorkloadSpec":
        return dataclasses.replace(self, stack=Stack(stack))

    def with_format(self, fmt: LBAFormat) -> "WorkloadSpec":
        return dataclasses.replace(self, fmt=LBAFormat(fmt))

    def phase(self, *, at_us: Optional[float] = None,
              after_us: float = 0.0) -> "WorkloadSpec":
        """Shift the start time of subsequently added streams."""
        new = at_us if at_us is not None else self.phase_us + after_us
        return dataclasses.replace(self, phase_us=float(new))

    # -- stream builders ----------------------------------------------------
    def stream(self, op: OpType, **kw) -> "WorkloadSpec":
        kw.setdefault("start_us", self.phase_us)
        s = StreamSpec(op=OpType(op), **kw)
        return dataclasses.replace(self, streams=self.streams + (s,))

    def reads(self, n: int, *, size: int = 4 * KiB, **kw) -> "WorkloadSpec":
        return self.stream(OpType.READ, n=n, size=size, **kw)

    def writes(self, n: int, *, size: int = 4 * KiB, **kw) -> "WorkloadSpec":
        return self.stream(OpType.WRITE, n=n, size=size, **kw)

    def appends(self, n: int, *, size: int = 8 * KiB, **kw) -> "WorkloadSpec":
        return self.stream(OpType.APPEND, n=n, size=size, **kw)

    def resets(self, n: int = 1, *, occupancy: float = 1.0,
               io_ctx: Union[OpType, int, None] = None,
               **kw) -> "WorkloadSpec":
        ctx = -1 if io_ctx is None else int(io_ctx)
        return self.stream(OpType.RESET, n=n, occupancy=occupancy,
                           io_ctx=ctx, **kw)

    def finishes(self, n: int = 1, *, occupancy: float = 0.0,
                 **kw) -> "WorkloadSpec":
        return self.stream(OpType.FINISH, n=n, occupancy=occupancy, **kw)

    def opens(self, n: int = 1, **kw) -> "WorkloadSpec":
        return self.stream(OpType.OPEN, n=n, **kw)

    def closes(self, n: int = 1, **kw) -> "WorkloadSpec":
        return self.stream(OpType.CLOSE, n=n, **kw)

    # -- sweeps (Fig. 5 methodology) ----------------------------------------
    def reset_sweep(self, occupancies: Sequence[float], *,
                    n_per_level: int = 100, pause_us: float = 1e6,
                    finish_first: bool = False, **kw) -> "WorkloadSpec":
        """Reset (optionally finish-then-reset) at each occupancy level,
        pausing ``pause_us`` before each op for the device to settle."""
        return self.stream(OpType.RESET, n=n_per_level,
                           occupancies=tuple(float(o) for o in occupancies),
                           n_per_level=n_per_level, pause_us=pause_us,
                           finish_first=finish_first, **kw)

    def finish_sweep(self, occupancies: Sequence[float], *,
                     n_per_level: int = 100, pause_us: float = 1e6,
                     **kw) -> "WorkloadSpec":
        return self.stream(OpType.FINISH, n=n_per_level,
                           occupancies=tuple(float(o) for o in occupancies),
                           n_per_level=n_per_level, pause_us=pause_us, **kw)

    # -- fleet lowering ------------------------------------------------------
    def shard(self, n_devices: int, *, policy: str = "round_robin"
              ) -> Tuple["WorkloadSpec", ...]:
        """Lower this workload onto ``n_devices`` fleet members.

        Policies:

        * ``"round_robin"`` — stream ``i`` goes to device ``i %
          n_devices`` whole (the paper's layout: one closed-loop stream
          per device); devices beyond the stream count sit idle.
        * ``"replicate"`` — every device runs the full workload (emulator
          A/B sweeps: same workload, different device/latency profiles).
        * ``"split"`` — every stream's request count is divided evenly
          across devices (bulk sweeps where a stream is a request budget,
          not a thread identity); remainders go to the lowest devices.
        """
        if n_devices <= 0:
            raise ValueError(f"n_devices must be positive, got {n_devices}")
        if policy == "replicate":
            return tuple(self for _ in range(n_devices))
        if policy == "round_robin":
            per: list = [() for _ in range(n_devices)]
            for i, s in enumerate(self.streams):
                per[i % n_devices] += (s,)
            return tuple(dataclasses.replace(self, streams=st, empty_ok=True)
                         for st in per)
        if policy == "split":
            shards = []
            for d in range(n_devices):
                st = []
                for s in self.streams:
                    # occupancy-sweep streams are sized by n_per_level (one
                    # count per level), plain streams by n — split whichever
                    # actually determines the request count.
                    total = s.n_per_level if s.occupancies is not None else s.n
                    n = total // n_devices + (1 if d < total % n_devices
                                              else 0)
                    if n == 0:
                        continue
                    if s.occupancies is not None:
                        # n mirrors n_per_level on sweep streams (the
                        # conflicting combination is rejected at
                        # construction), so shard both together.
                        st.append(dataclasses.replace(s, n=n, n_per_level=n))
                    else:
                        st.append(dataclasses.replace(s, n=n))
                shards.append(dataclasses.replace(self, streams=tuple(st),
                                                  empty_ok=True))
            return tuple(shards)
        raise ValueError(f"unknown shard policy {policy!r}; expected "
                         f"round_robin | replicate | split")

    # -- lowering ------------------------------------------------------------
    def build(self, *, allow_empty: bool = False) -> Trace:
        """Lower to a :class:`Trace` (struct-of-arrays request list).

        An empty spec raises unless ``allow_empty=True`` or the spec is a
        fleet shard (:meth:`shard` may hand idle devices zero streams or
        zero requests when ``n_devices`` exceeds the stream/request
        count — those shards lower to empty traces).

        Example::

            >>> from repro.core import KiB, WorkloadSpec
            >>> shards = WorkloadSpec().writes(n=3, size=4*KiB).shard(
            ...     5, policy="split")
            >>> [len(s.build()) for s in shards]    # devices 3-4 idle
            [1, 1, 1, 0, 0]
        """
        allow_empty = allow_empty or self.empty_ok
        if not self.streams:
            if allow_empty:
                return _empty_trace(self.stack, self.fmt)
            raise ValueError("empty WorkloadSpec: add at least one stream")
        used = {s.thread for s in self.streams if s.thread is not None}
        auto = (t for t in range(len(self.streams) + len(used))
                if t not in used)
        traces = []
        for s in self.streams:
            thread = s.thread if s.thread is not None else next(auto)
            tr = s.lower(thread)
            traces.append(tr)
        return _concat(traces, self.stack, self.fmt,
                       allow_empty=allow_empty)

    def __len__(self) -> int:
        return len(self.streams)


def _empty_trace(stack: Stack, fmt: LBAFormat) -> Trace:
    return Trace.build(op=np.zeros(0, dtype=np.int32), zone=None, size=None,
                       issue=np.zeros(0), stack=stack, fmt=fmt)


def _concat(traces, stack: Stack, fmt: LBAFormat, *,
            allow_empty: bool = False) -> Trace:
    ts = [t for t in traces if len(t)]
    if not ts:
        if allow_empty:
            return _empty_trace(stack, fmt)
        raise ValueError("WorkloadSpec lowered to an empty trace")
    cat = lambda f: np.concatenate([getattr(t, f) for t in ts])
    return Trace(op=cat("op"), zone=cat("zone"), size=cat("size"),
                 issue=cat("issue"), thread=cat("thread"), qd=cat("qd"),
                 occupancy=cat("occupancy"), was_finished=cat("was_finished"),
                 io_ctx=cat("io_ctx"), stack=stack, fmt=fmt)
