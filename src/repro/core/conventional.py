"""Conventional (non-zoned) NVMe SSD baseline with an FTL GC model (§III-F).

The paper compares the ZN540 against a same-hardware conventional SSD
(SN640) and shows that firmware-triggered garbage collection makes write
and read throughput fluctuate (Fig. 6a/6b) and inflates read tail latency
to ~300 ms (vs ~98 ms on ZNS).  This module provides that baseline:

* a write-amplification model (dirty-block pressure vs overprovisioning),
* a GC sawtooth throughput model calibrated to Fig. 6a,
* read-latency inflation under write+GC pressure calibrated to Obs#11.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import calibration as C
from .latency import LatencyModel
from .spec import KiB, MiB, ConvDeviceSpec, OpType


@dataclasses.dataclass(frozen=True)
class PressureResult:
    """Write-pressure scenario output, shared by ZNS and conventional
    devices (Fig. 6 layout: rate-limited writes + 4 KiB random reads).

    Every registered pressure backend (``repro.core.device.
    register_pressure_backend``) returns this type, so ZNS-vs-conventional
    comparisons are one code path.
    """

    t_s: np.ndarray
    write_mibs: np.ndarray
    read_lat_mean_us: float
    read_lat_p95_us: float
    read_mibs: Optional[np.ndarray] = None
    write_amplification: float = 1.0

    @property
    def write_cv(self) -> float:
        m = float(np.mean(self.write_mibs))
        return float(np.std(self.write_mibs)) / m if m > 0 else 0.0


#: .. deprecated:: the conventional path now returns the shared
#:    :class:`PressureResult` directly.
ConvSimResult = PressureResult


class ConventionalSSD:
    """Steady-state + time-series model of a conventional SSD under load."""

    def __init__(self, spec: ConvDeviceSpec = ConvDeviceSpec(),
                 seed: int = 0):
        self.spec = spec
        self.lat = LatencyModel()
        self.rng = np.random.default_rng(seed)

    # -- GC model -----------------------------------------------------------
    def write_amplification(self, utilization: float) -> float:
        """Greedy-GC write amplification vs device utilization.

        Classic closed form: WA ~= 1 / (1 - u_eff) in the worst case; we
        use the standard smoothed model with overprovisioning.
        """
        op = self.spec.overprovision_frac
        u = min(utilization, 0.999) * (1.0 - op)
        if u <= self.spec.gc_write_amp_knee:
            return 1.0
        return float(1.0 + (u - self.spec.gc_write_amp_knee) / max(1.0 - u, 1e-3))

    def simulate_write_pressure(self, *, rate_mibs: float,
                                duration_s: float = 60.0,
                                utilization: float = 0.85,
                                read_qd: int = 32,
                                bin_s: float = 1.0) -> PressureResult:
        """Reproduce Fig. 6: rate-limited random writes + random 4 KiB reads.

        The ZNS device sustains the target rate flat; the conventional SSD
        oscillates between near-zero (deep GC) and peak (Fig. 6a shows a
        few MiB/s up to ~1,200 MiB/s at full-rate writes).
        """
        wa = self.write_amplification(utilization)
        peak = self.spec.peak_write_bw_bytes / MiB
        target = min(rate_mibs, peak)
        pressure = target / peak      # fraction of peak the host demands
        n = int(duration_s / bin_s)
        t = np.arange(n) * bin_s
        if wa <= 1.0 or pressure < 0.2:
            w = np.full(n, target)
        else:
            # GC sawtooth: the FTL periodically stalls host writes to free
            # blocks.  Duty/period calibrated to Fig. 6a at full pressure.
            duty = C.CONV_GC_DUTY * pressure
            period = C.CONV_GC_PERIOD_S
            phase = (t % period) / period
            in_gc = phase < duty
            burst = peak * (1.0 + 0.05 * self.rng.standard_normal(n))
            floor = C.CONV_GC_FLOOR_MIBS * (1.0 + 0.3 * np.abs(self.rng.standard_normal(n)))
            w = np.where(in_gc, floor, np.minimum(burst, target / max(1 - duty, 1e-3)))
            # conserve host-visible average at the target rate when feasible
            scale = target / max(w.mean(), 1e-9)
            w = np.minimum(w * min(scale, 1.5), peak * 1.05)
        # Reads: starved during GC bursts (Fig. 6b: up to ~3 MiB/s only).
        read_peak_mibs = 3.0 * pressure + (1 - pressure) * (
            self.spec.peak_read_bw_bytes / MiB)
        r = np.where(w > target * 0.5, read_peak_mibs * 0.6, read_peak_mibs)
        r = r * (1.0 + 0.25 * np.abs(self.rng.standard_normal(n)))
        r = np.minimum(r, self.spec.peak_read_bw_bytes / MiB)
        # Read latency under pressure (Obs#11 anchors).
        idle_mean = float(self.lat.io_service_us(OpType.READ, 4 * KiB))
        sigma = 0.54
        pressured_mean = C.CONV_READ_P95_UNDER_WRITES_MS * 1e3 / np.exp(1.645 * sigma)
        mean = idle_mean + (pressure ** 3) * pressured_mean
        p95 = mean * (np.exp(1.645 * sigma) if pressure > 0.05
                      else C.READONLY_READ_P95_US / idle_mean)
        return PressureResult(t_s=t, write_mibs=w, read_mibs=r,
                              read_lat_mean_us=float(mean),
                              read_lat_p95_us=float(p95),
                              write_amplification=wa)


def zns_write_pressure_series(*, rate_mibs: float, duration_s: float = 60.0,
                              bin_s: float = 1.0, seed: int = 0):
    """ZNS side of Fig. 6: flat at the target rate (Obs#11), host-driven GC
    (resets) costs ~1% of fill cost and runs on the metadata engine."""
    rng = np.random.default_rng(seed)
    n = int(duration_s / bin_s)
    t = np.arange(n) * bin_s
    w = np.full(n, rate_mibs) * (1.0 + 0.01 * rng.standard_normal(n))
    return t, w
