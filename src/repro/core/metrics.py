"""Throughput/latency reducers used by the benchmark harness (§III-B),
plus the named metric-extractor registry behind
:meth:`repro.core.RunResult.summary` and the experiment runner
(:mod:`repro.experiments`).

Example (registering a custom extractor)::

    >>> from repro.core.metrics import (available_metrics, register_metric,
    ...                                 unregister_metric)
    >>> @register_metric("span_s", replace=True)
    ... def _span_s(result):
    ...     c = result.sim.complete
    ...     return float(c.max() - c.min()) / 1e6 if len(c) else 0.0
    >>> "span_s" in available_metrics()
    True
    >>> unregister_metric("span_s")
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """mean/percentile latency summary (microseconds) of one sample set.

    Includes the p99.9 tail (``p999_us``) the cluster capacity planner's
    SLO curves are drawn from.  Empty sample sets are safe: every field
    is 0.0 with ``n == 0`` (no numpy warnings, no NaNs in JSON).

    Example::

        >>> from repro.core import LatencyStats
        >>> LatencyStats.from_samples([10.0, 20.0, 30.0]).mean_us
        20.0
        >>> LatencyStats.from_samples([]).p999_us
        0.0
    """

    mean_us: float
    p50_us: float
    p95_us: float
    p99_us: float
    p999_us: float
    n: int

    @staticmethod
    def from_samples(lat_us) -> "LatencyStats":
        lat = np.asarray(lat_us, dtype=np.float64)
        if len(lat) == 0:
            return LatencyStats(mean_us=0.0, p50_us=0.0, p95_us=0.0,
                                p99_us=0.0, p999_us=0.0, n=0)
        return LatencyStats(
            mean_us=float(lat.mean()), p50_us=float(np.percentile(lat, 50)),
            p95_us=float(np.percentile(lat, 95)),
            p99_us=float(np.percentile(lat, 99)),
            p999_us=float(np.percentile(lat, 99.9)), n=len(lat))


def iops(complete_us, n: int = None) -> float:
    """Operations per second over the busy interval (0.0 for empty runs)."""
    c = np.asarray(complete_us, dtype=np.float64)
    if len(c) == 0:
        return 0.0
    n = n if n is not None else len(c)
    span = c.max() - c.min()
    if span <= 0:
        return float("inf")
    return (n - 1) / span * 1e6


def bandwidth_bytes(complete_us, sizes) -> float:
    """Bytes per second over the busy interval (0.0 for empty runs)."""
    c = np.asarray(complete_us, dtype=np.float64)
    if len(c) == 0:
        return 0.0
    span = (c.max() - c.min()) / 1e6
    if span <= 0:
        return float("inf")
    return float(np.sum(sizes)) / span


def throughput_timeseries(complete_us, sizes, *, bin_s: float = 1.0):
    """(t_seconds, MiB/s) series for Fig. 6-style plots."""
    c = np.asarray(complete_us, dtype=np.float64) / 1e6
    sizes = np.asarray(sizes, dtype=np.float64)
    t0, t1 = c.min(), c.max()
    nbins = max(int((t1 - t0) / bin_s) + 1, 1)
    idx = np.clip(((c - t0) / bin_s).astype(int), 0, nbins - 1)
    acc = np.zeros(nbins)
    np.add.at(acc, idx, sizes)
    return t0 + np.arange(nbins) * bin_s, acc / bin_s / (1024 ** 2)


# ---------------------------------------------------------------------------
# Metric-extractor registry
# ---------------------------------------------------------------------------
#: An extractor maps a finished run (anything shaped like
#: :class:`repro.core.RunResult`: ``.trace``, ``.sim``, ``.latency_stats()``)
#: to one scalar.  Registered extractors drive ``RunResult.summary()`` and
#: the per-experiment JSON artifacts of :mod:`repro.experiments`.
MetricFn = Callable[[object], float]
_METRICS: Dict[str, MetricFn] = {}


def register_metric(name: str, fn: Optional[MetricFn] = None, *,
                    replace: bool = False):
    """Register a named metric extractor; usable as a decorator.

    Registering an existing name warns unless ``replace=True`` (mirrors
    :func:`repro.core.register_backend` semantics).
    """
    def _register(f):
        if not replace and name in _METRICS and _METRICS[name] is not f:
            warnings.warn(
                f"metric {name!r} is already registered; replacing it. "
                f"Pass replace=True to silence this warning.",
                RuntimeWarning, stacklevel=3)
        _METRICS[name] = f
        return f
    return _register(fn) if fn is not None else _register


def unregister_metric(name: str) -> None:
    _METRICS.pop(name, None)


def available_metrics() -> tuple:
    return tuple(sorted(_METRICS))


def extract_metrics(result, names: Optional[Sequence[str]] = None
                    ) -> Dict[str, float]:
    """Evaluate registered extractors on a run result -> ``{name: value}``.

    ``names=None`` evaluates every registered extractor; unknown names
    raise ``KeyError``.
    """
    if names is None:
        names = available_metrics()
    out = {}
    for name in names:
        if name not in _METRICS:
            raise KeyError(f"unknown metric {name!r}; available: "
                           f"{available_metrics()}")
        out[name] = float(_METRICS[name](result))
    return out


@register_metric("n_requests")
def _m_n(result) -> float:
    return float(len(result.trace))


@register_metric("iops")
def _m_iops(result) -> float:
    return iops(result.sim.complete)


@register_metric("bandwidth_mibs")
def _m_bw(result) -> float:
    return bandwidth_bytes(result.sim.complete, result.trace.size) / (1024 ** 2)


@register_metric("makespan_us")
def _m_makespan(result) -> float:
    c = result.sim.complete
    return float(c.max()) if len(c) else 0.0


def _lat_metric(field):
    def fn(result) -> float:
        if not len(result.trace):
            return 0.0
        return getattr(result.latency_stats(), field)
    return fn


for _f in ("mean_us", "p50_us", "p95_us", "p99_us", "p999_us"):
    register_metric(f"lat_{_f}", _lat_metric(_f))
del _f


def _qlat_metric(field):
    """Submission-to-completion ("queueing-inclusive") latency reducer:
    measured from the trace's issue times, so open-loop streams charge
    the time a burst spends waiting to be served — the quantity the
    tail-latency SLO scenarios gate on."""
    def fn(result) -> float:
        if not len(result.trace):
            return 0.0
        lat = result.sim.latency_from(result.trace.issue)
        return getattr(LatencyStats.from_samples(lat), field)
    return fn


for _f in ("p50_us", "p99_us", "p999_us"):
    register_metric(f"qlat_{_f}", _qlat_metric(_f))
del _f


#: Threshold of the default registered SLO-violation extractor
#: (``slo_violations_10ms`` in every ``RunResult.summary()``).
DEFAULT_SLO_US = 10_000.0


def violation_rate(lat_us, threshold_us: float) -> float:
    """Fraction of latency samples strictly above ``threshold_us``
    (0.0 for empty sample sets)."""
    lat = np.asarray(lat_us, dtype=np.float64)
    if len(lat) == 0:
        return 0.0
    return float(np.count_nonzero(lat > float(threshold_us)) / len(lat))


def slo_violations(threshold_us: float) -> MetricFn:
    """Extractor factory: fraction of requests whose
    submission-to-completion latency exceeds ``threshold_us``.

    Returns a :data:`MetricFn` suitable for :func:`register_metric`; the
    cluster capacity planner evaluates these per sweep point to find the
    user count a rack can serve inside a p99 SLO.  Empty runs report
    0.0 violations.

    Example::

        >>> from repro.core.metrics import slo_violations, register_metric
        >>> from repro.core import KiB, WorkloadSpec, ZnsDevice
        >>> fn = register_metric("slo_violations_1us", slo_violations(1.0))
        >>> res = ZnsDevice().run(WorkloadSpec().writes(n=10, size=4 * KiB),
        ...                       backend="event", jitter=False)
        >>> res.summary(["slo_violations_1us"])   # every write > 1 us
        {'slo_violations_1us': 1.0}
        >>> from repro.core.metrics import unregister_metric
        >>> unregister_metric("slo_violations_1us")
    """
    thresh = float(threshold_us)

    def fn(result) -> float:
        if not len(result.trace):
            return 0.0
        return violation_rate(result.sim.latency_from(result.trace.issue),
                              thresh)
    fn.__name__ = f"slo_violations_{thresh:g}us"
    return fn


register_metric("slo_violations_10ms", slo_violations(DEFAULT_SLO_US))
