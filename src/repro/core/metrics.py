"""Throughput/latency reducers used by the benchmark harness (§III-B)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    mean_us: float
    p50_us: float
    p95_us: float
    p99_us: float
    n: int

    @staticmethod
    def from_samples(lat_us) -> "LatencyStats":
        lat = np.asarray(lat_us, dtype=np.float64)
        return LatencyStats(
            mean_us=float(lat.mean()), p50_us=float(np.percentile(lat, 50)),
            p95_us=float(np.percentile(lat, 95)),
            p99_us=float(np.percentile(lat, 99)), n=len(lat))


def iops(complete_us, n: int = None) -> float:
    """Operations per second over the busy interval."""
    c = np.asarray(complete_us, dtype=np.float64)
    n = n if n is not None else len(c)
    span = c.max() - c.min()
    if span <= 0:
        return float("inf")
    return (n - 1) / span * 1e6


def bandwidth_bytes(complete_us, sizes) -> float:
    c = np.asarray(complete_us, dtype=np.float64)
    span = (c.max() - c.min()) / 1e6
    if span <= 0:
        return float("inf")
    return float(np.sum(sizes)) / span


def throughput_timeseries(complete_us, sizes, *, bin_s: float = 1.0):
    """(t_seconds, MiB/s) series for Fig. 6-style plots."""
    c = np.asarray(complete_us, dtype=np.float64) / 1e6
    sizes = np.asarray(sizes, dtype=np.float64)
    t0, t1 = c.min(), c.max()
    nbins = max(int((t1 - t0) / bin_s) + 1, 1)
    idx = np.clip(((c - t0) / bin_s).astype(int), 0, nbins - 1)
    acc = np.zeros(nbins)
    np.add.at(acc, idx, sizes)
    return t0 + np.arange(nbins) * bin_s, acc / bin_s / (1024 ** 2)
