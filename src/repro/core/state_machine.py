"""The ZNS zone state machine (paper Fig. 1).

Two implementations share one transition table:

* :class:`ZoneManager` — the host-side, imperative API used by the
  checkpoint store and the discrete-event engine.  Raises
  :class:`ZoneError` on illegal transitions, enforces the max-open /
  max-active limits, and tracks write pointers.
* :func:`transition_array` — a vectorized, pure-JAX transition function
  over arrays of zone states, used by property tests and the vectorized
  simulator.  Illegal transitions are reported via an ``ok`` mask instead
  of exceptions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .spec import (
    ACTIVE_STATES,
    OPEN_STATES,
    OpType,
    ZNSDeviceSpec,
    ZoneState,
)


class ZoneError(RuntimeError):
    pass


# (state, op) -> new state, for ops that are unconditionally legal from that
# state.  WRITE/APPEND additionally require wp + nbytes <= cap; they map
# EMPTY -> IMPLICIT_OPEN (implicit transition) and *_OPEN -> FULL when the
# write fills the zone.
_TRANSITIONS = {
    (ZoneState.EMPTY, OpType.OPEN): ZoneState.EXPLICIT_OPEN,
    (ZoneState.EMPTY, OpType.WRITE): ZoneState.IMPLICIT_OPEN,
    (ZoneState.EMPTY, OpType.APPEND): ZoneState.IMPLICIT_OPEN,
    (ZoneState.EMPTY, OpType.FINISH): None,   # spec forbids finish on empty
    (ZoneState.EMPTY, OpType.RESET): ZoneState.EMPTY,
    (ZoneState.IMPLICIT_OPEN, OpType.WRITE): ZoneState.IMPLICIT_OPEN,
    (ZoneState.IMPLICIT_OPEN, OpType.APPEND): ZoneState.IMPLICIT_OPEN,
    (ZoneState.IMPLICIT_OPEN, OpType.OPEN): ZoneState.EXPLICIT_OPEN,
    (ZoneState.IMPLICIT_OPEN, OpType.CLOSE): ZoneState.CLOSED,
    (ZoneState.IMPLICIT_OPEN, OpType.FINISH): ZoneState.FULL,
    (ZoneState.IMPLICIT_OPEN, OpType.RESET): ZoneState.EMPTY,
    (ZoneState.EXPLICIT_OPEN, OpType.WRITE): ZoneState.EXPLICIT_OPEN,
    (ZoneState.EXPLICIT_OPEN, OpType.APPEND): ZoneState.EXPLICIT_OPEN,
    (ZoneState.EXPLICIT_OPEN, OpType.CLOSE): ZoneState.CLOSED,
    (ZoneState.EXPLICIT_OPEN, OpType.FINISH): ZoneState.FULL,
    (ZoneState.EXPLICIT_OPEN, OpType.RESET): ZoneState.EMPTY,
    (ZoneState.CLOSED, OpType.WRITE): ZoneState.IMPLICIT_OPEN,
    (ZoneState.CLOSED, OpType.APPEND): ZoneState.IMPLICIT_OPEN,
    (ZoneState.CLOSED, OpType.OPEN): ZoneState.EXPLICIT_OPEN,
    (ZoneState.CLOSED, OpType.FINISH): ZoneState.FULL,
    (ZoneState.CLOSED, OpType.RESET): ZoneState.EMPTY,
    (ZoneState.FULL, OpType.RESET): ZoneState.EMPTY,
    # READs are legal from any non-offline state and change nothing.
}


@dataclasses.dataclass
class ZoneInfo:
    state: ZoneState
    write_pointer: int      # bytes written (relative to zone start)
    was_finished: bool      # finish() seen since last reset (discounts reset)


class ZoneManager:
    """Host-side zone bookkeeping with strict legality enforcement."""

    def __init__(self, spec: ZNSDeviceSpec):
        self.spec = spec
        self.zones = [
            ZoneInfo(ZoneState.EMPTY, 0, False) for _ in range(spec.num_zones)
        ]

    # -- queries ------------------------------------------------------------
    def state(self, z: int) -> ZoneState:
        return self.zones[z].state

    def write_pointer(self, z: int) -> int:
        return self.zones[z].write_pointer

    def occupancy(self, z: int) -> float:
        return self.zones[z].write_pointer / self.spec.zone_cap_bytes

    @property
    def open_count(self) -> int:
        return sum(1 for zi in self.zones if zi.state in OPEN_STATES)

    @property
    def active_count(self) -> int:
        return sum(1 for zi in self.zones if zi.state in ACTIVE_STATES)

    def find_empty(self) -> Optional[int]:
        for z, zi in enumerate(self.zones):
            if zi.state == ZoneState.EMPTY:
                return z
        return None

    # -- transitions ----------------------------------------------------------
    def _check_limits(self, z: int) -> None:
        zi = self.zones[z]
        opening = zi.state not in OPEN_STATES
        activating = zi.state not in ACTIVE_STATES
        if opening and self.open_count >= self.spec.max_open_zones:
            raise ZoneError(
                f"max open zone limit ({self.spec.max_open_zones}) reached"
            )
        if activating and self.active_count >= self.spec.max_active_zones:
            raise ZoneError(
                f"max active zone limit ({self.spec.max_active_zones}) reached"
            )

    def open(self, z: int) -> None:
        self._apply(z, OpType.OPEN)

    def close(self, z: int) -> None:
        zi = self.zones[z]
        if zi.state not in OPEN_STATES:
            raise ZoneError(f"close on zone {z} in state {zi.state.name}")
        zi.state = ZoneState.CLOSED

    def finish(self, z: int) -> float:
        """Finish a zone; returns the occupancy at finish time (for costing)."""
        zi = self.zones[z]
        if zi.state == ZoneState.EMPTY:
            raise ZoneError("finish on EMPTY zone is not permitted (§III-E)")
        if zi.state == ZoneState.FULL:
            raise ZoneError("finish on FULL zone is not permitted (§III-E)")
        occ = self.occupancy(z)
        zi.state = ZoneState.FULL
        zi.was_finished = True
        zi.write_pointer = self.spec.zone_cap_bytes
        return occ

    def reset(self, z: int) -> tuple[float, bool]:
        """Reset a zone; returns (occupancy, was_finished) for costing."""
        zi = self.zones[z]
        if zi.state in (ZoneState.READ_ONLY, ZoneState.OFFLINE):
            raise ZoneError(f"reset on zone {z} in state {zi.state.name}")
        occ = self.occupancy(z)
        finished = zi.was_finished
        zi.state = ZoneState.EMPTY
        zi.write_pointer = 0
        zi.was_finished = False
        return occ, finished

    def write(self, z: int, nbytes: int, *, append: bool = False,
              at: Optional[int] = None) -> int:
        """Advance the write pointer; returns the LBA (bytes) written at.

        For ``append`` the returned LBA is what the device reports on
        completion (§II-B); for ``write`` the host must already know it —
        passing ``at`` (a byte offset within the zone) asserts that
        knowledge: a regular write whose offset is not the current write
        pointer is rejected (NVMe "Zone Invalid Write"), exactly as the
        ZNS conformance suites probe it.  ``at`` on an append is ignored
        (the device chooses the location).
        """
        zi = self.zones[z]
        op = OpType.APPEND if append else OpType.WRITE
        if (zi.state, op) not in _TRANSITIONS:
            raise ZoneError(f"{op.name} on zone {z} in state {zi.state.name}")
        if nbytes <= 0:
            raise ZoneError("write of <= 0 bytes")
        if not append and at is not None and at != zi.write_pointer:
            raise ZoneError(
                f"zone {z} invalid write: offset {at} != write pointer "
                f"{zi.write_pointer}"
            )
        if zi.write_pointer + nbytes > self.spec.zone_cap_bytes:
            raise ZoneError(
                f"zone {z} overflow: wp={zi.write_pointer} + {nbytes} "
                f"> cap={self.spec.zone_cap_bytes}"
            )
        self._check_limits(z)
        lba = self.spec.zone_start(z) + zi.write_pointer
        zi.state = _TRANSITIONS[(zi.state, op)]
        zi.write_pointer += nbytes
        if zi.write_pointer == self.spec.zone_cap_bytes:
            zi.state = ZoneState.FULL
        return lba

    def read(self, z: int, offset: int = 0, nbytes: int = 1) -> None:
        """Legality check for a read of ``nbytes`` at byte ``offset``.

        Reads are legal from every non-OFFLINE state but must not cross
        the zone's LBA boundary (the ZN540 does not report the
        cross-zone-read capability bit; conformance suites assert the
        boundary error)."""
        zi = self.zones[z]
        if zi.state == ZoneState.OFFLINE:
            raise ZoneError(f"read on OFFLINE zone {z}")
        if nbytes <= 0:
            raise ZoneError("read of <= 0 bytes")
        if offset < 0 or offset + nbytes > self.spec.zone_size_bytes:
            raise ZoneError(
                f"zone {z} boundary error: read [{offset}, "
                f"{offset + nbytes}) crosses zone size "
                f"{self.spec.zone_size_bytes}"
            )

    def read_ok(self, z: int) -> bool:
        return self.zones[z].state != ZoneState.OFFLINE

    def _apply(self, z: int, op: OpType) -> None:
        zi = self.zones[z]
        key = (zi.state, op)
        if key not in _TRANSITIONS or _TRANSITIONS[key] is None:
            raise ZoneError(f"{op.name} on zone {z} in state {zi.state.name}")
        if op == OpType.OPEN:
            self._check_limits(z)
        zi.state = _TRANSITIONS[key]


# ---------------------------------------------------------------------------
# Vectorized (pure-function) form, usable under jit and by hypothesis tests.
# ---------------------------------------------------------------------------
N_STATES = len(ZoneState)
N_OPS = len(OpType)

# transition_table[state, op] = next_state, or -1 if illegal.
TRANSITION_TABLE = np.full((N_STATES, N_OPS), -1, dtype=np.int32)
for (s, o), ns in _TRANSITIONS.items():
    if ns is not None:
        TRANSITION_TABLE[int(s), int(o)] = int(ns)
for s in ZoneState:
    if s != ZoneState.OFFLINE:
        TRANSITION_TABLE[int(s), int(OpType.READ)] = int(s)


def transition_array(states, ops):
    """Vectorized transition: (states[i], ops[i]) -> (new_states[i], ok[i]).

    Works with numpy or jax.numpy arrays (table lookups only).
    """
    import jax.numpy as jnp

    table = jnp.asarray(TRANSITION_TABLE)
    nxt = table[states, ops]
    ok = nxt >= 0
    return jnp.where(ok, nxt, states), ok
