"""Device geometry and operation vocabulary for the ZNS device model.

Mirrors the benchmarking environment of the paper (Tab. II): a Western
Digital Ultrastar DC ZN540 1TB large-zone ZNS SSD, plus the conventional
Ultrastar DC SN640 used as the §III-F comparison baseline.
"""
from __future__ import annotations

import dataclasses
import enum

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB


class OpType(enum.IntEnum):
    """I/O and zone-management operations (§II-B)."""

    READ = 0
    WRITE = 1
    APPEND = 2
    RESET = 3
    FINISH = 4
    OPEN = 5
    CLOSE = 6


#: Operations that move a zone's write pointer.
WRITE_LIKE = (OpType.WRITE, OpType.APPEND)
#: Zone-management operations (no data transfer).
MGMT_OPS = (OpType.RESET, OpType.FINISH, OpType.OPEN, OpType.CLOSE)


class Stack(enum.IntEnum):
    """Host storage stacks benchmarked in the paper (§III-A)."""

    SPDK = 0
    KERNEL_NONE = 1          # io_uring, scheduler = none
    KERNEL_MQ_DEADLINE = 2   # io_uring, scheduler = mq-deadline


class LBAFormat(enum.IntEnum):
    """NVMe namespace LBA formats evaluated in Fig. 2a."""

    LBA_512 = 0
    LBA_4K = 1

    @property
    def block_bytes(self) -> int:
        return 512 if self is LBAFormat.LBA_512 else 4 * KiB


class ZoneState(enum.IntEnum):
    """Zone state machine states (Fig. 1)."""

    EMPTY = 0
    IMPLICIT_OPEN = 1
    EXPLICIT_OPEN = 2
    CLOSED = 3
    FULL = 4
    READ_ONLY = 5
    OFFLINE = 6


OPEN_STATES = (ZoneState.IMPLICIT_OPEN, ZoneState.EXPLICIT_OPEN)
ACTIVE_STATES = OPEN_STATES + (ZoneState.CLOSED,)


@dataclasses.dataclass(frozen=True)
class ZNSDeviceSpec:
    """Geometry + structural limits of a ZNS device.

    Defaults are the ZN540 exactly as reported in Tab. II.
    """

    name: str = "WD-Ultrastar-DC-ZN540"
    zone_size_bytes: int = 2048 * MiB       # LBA-address span of a zone
    zone_cap_bytes: int = 1077 * MiB        # writable capacity of a zone
    num_zones: int = 904
    max_open_zones: int = 14
    max_active_zones: int = 14
    lba_format: LBAFormat = LBAFormat.LBA_4K
    # Device-level limits observed in §III-C/D.
    peak_write_bw_bytes: float = 1155 * MiB          # Fig. 4c plateau
    peak_read_bw_bytes: float = 1740 * MiB           # 424 KIOPS x 4 KiB
    # Internal parallel units ("channels") implied by the scaling curves.
    append_parallelism: int = 2    # Obs#6: append saturates at 132 KIOPS (2 x 66)
    write_parallelism: int = 14    # inter-zone writes scale to ~max open zones
    read_parallelism: int = 30     # 424 KIOPS @ ~70 us/req flash read latency
    reset_parallelism: int = 1     # resets are serialized metadata updates

    @property
    def capacity_bytes(self) -> int:
        return self.zone_cap_bytes * self.num_zones

    def zone_of(self, lba_bytes: int) -> int:
        return lba_bytes // self.zone_size_bytes

    def zone_start(self, zone: int) -> int:
        return zone * self.zone_size_bytes


@dataclasses.dataclass(frozen=True)
class ConvDeviceSpec:
    """Conventional (non-zoned) NVMe SSD — the §III-F baseline (SN640)."""

    name: str = "WD-Ultrastar-DC-SN640"
    capacity_bytes: int = 960 * 10**9
    peak_write_bw_bytes: float = 1155 * MiB   # paper matches peaks for both
    peak_read_bw_bytes: float = 1740 * MiB
    overprovision_frac: float = 0.07
    gc_write_amp_knee: float = 0.60           # utilization where GC starts biting
    read_parallelism: int = 30
    write_parallelism: int = 14


ZN540 = ZNSDeviceSpec()
SN640 = ConvDeviceSpec()
