"""Entry-axis sharding of compiled chain programs.

A fleet-wide :class:`~repro.core.chain_program.ChainProgram` is
block-diagonal over its entries: chains never cross devices (the fleet
compiler) or cluster entries (``concat_programs``), so the fused
Gauss-Seidel fixpoint decomposes into independent sub-fixpoints.  This
module exploits that two ways:

* **host executor** — partition the entries into *signature groups*
  (entries with identical chain structure: replicas, or one
  heterogeneity tier of a mixed fleet) and solve each group with the
  float64 numpy driver under its own convergence budget.  A single
  whole-fleet solve pays ``max_s sweeps(s)`` sweeps of fleet-wide
  gathers and edge checks; the grouped solve pays
  ``sum_s sweeps(s) * |group_s|`` — on fleets mixing easy
  (read-dominated, ~2 sweeps) and hard (saturated qd-2 write pools,
  ``threads + 1`` sweeps) devices that is a multiple-x win on one chip,
  before any parallel hardware enters the picture.
* **mesh executor** — balance the entries across every local jax
  device with a 1-D :class:`jax.sharding.Mesh` + ``shard_map``
  (``repro.kernels.zns_fixpoint.zns_fixpoint_sharded``): stacked,
  padded per-shard block tensors, one early-exiting float64
  ``while_loop`` per shard, completion buffers donated across sweeps.

Partitioning is safe by construction: entries are the connected
components of the chain/device incidence graph (a union-find pass), so
a family added by ``extend_program`` that couples two devices simply
fuses them into one shard.  ``solve_program(fixpoint="auto")`` routes
here only on multi-chip accelerator hosts; on CPU the single-chip numpy
driver stays the default and a 1-shard plan falls back to it
bit-identically.  Force an executor with ``REPRO_SHARD_EXECUTOR=mesh``
/ ``host`` / ``off`` (tests and the mega-fleet benchmark use this).
"""
from __future__ import annotations

import dataclasses
import os
import sys
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import hashlib

from .chain_program import (ChainProgram, SolveStats, _blocks_from_chains,
                            _solve_numpy, block_adjacency, program_chains)

#: Environment override for the sharded executor: ``mesh`` | ``host``
#: force one, ``off`` disables auto-sharding in ``solve_program``.
EXECUTOR_ENV = "REPRO_SHARD_EXECUTOR"

#: The host executor merges the smallest signature groups until at most
#: this many shards remain — each shard is one numpy sub-solve, and
#: Python dispatch per sweep makes many tiny solves slower than one
#: fused solve.
HOST_MAX_SHARDS = 16


@dataclasses.dataclass(frozen=True)
class Shard:
    """One independent sub-fixpoint of a sharded program.

    ``devices`` are base-program device ids (ascending); ``perm`` maps
    the shard's flat event order back to base flat indices
    (``base_comp[perm] = shard_comp``); ``program`` is the extracted
    sub-program (device metadata collapsed to one flat pseudo-device —
    results are always scattered back through ``perm``, never unpacked
    from the sub-program).
    """

    devices: Tuple[int, ...]
    program: ChainProgram
    perm: np.ndarray

    @property
    def n_events(self) -> int:
        return self.program.n_flat


@dataclasses.dataclass
class ShardedProgram:
    """A partition of a chain program's entry axis into shards."""

    base: ChainProgram
    shards: Tuple[Shard, ...]
    #: per-device-count stacked mesh tensors, built lazily
    _mesh_cache: Dict[int, dict] = dataclasses.field(
        default_factory=dict, repr=False)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def __repr__(self) -> str:
        sizes = [s.n_events for s in self.shards]
        return (f"ShardedProgram(shards={len(sizes)}, "
                f"events={sizes})")


def _entry_components(program: ChainProgram):
    """Union-find connected components of the chain/device graph.

    Returns ``(bounds, comp_list, recs)``: per-device flat bounds,
    components as ascending device-id lists, and one record ``(label,
    chain, component_index)`` per chain.
    """
    D = program.n_devices
    bounds = np.append(np.asarray(program.offsets, dtype=np.int64),
                       program.n_flat)
    parent = list(range(D))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: int, b: int) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)

    raw = []                    # (label, chain, device)
    for label, chs in program_chains(program).items():
        for c in chs:
            cmin = int(c.min())
            d0 = int(np.searchsorted(bounds, cmin, side="right") - 1)
            if int(c.max()) >= bounds[d0 + 1]:
                # cross-entry chain (extend_program coupling): fuse
                # every touched device into one component
                ds = np.unique(np.searchsorted(bounds, c,
                                               side="right") - 1)
                for d in ds[1:]:
                    union(int(ds[0]), int(d))
                d0 = int(ds[0])
            raw.append((label, c, d0))
    comps: "OrderedDict[int, list]" = OrderedDict()
    for d in range(D):
        comps.setdefault(find(d), []).append(d)
    pos = {root: i for i, root in enumerate(comps)}
    recs = [(label, c, pos[find(d)]) for label, c, d in raw]
    return bounds, list(comps.values()), recs


def _signatures(n_comps: int, recs) -> List[tuple]:
    """Chain-structure signature per component: sorted ``(label,
    n_chains, total_len)`` triples.  Replicated entries and the members
    of one heterogeneity tier share a signature."""
    acc: List[dict] = [OrderedDict() for _ in range(n_comps)]
    for label, c, i in recs:
        st = acc[i].setdefault(label, [0, 0])
        st[0] += 1
        st[1] += len(c)
    return [tuple(sorted((lab, st[0], st[1]) for lab, st in a.items()))
            for a in acc]


def _lpt(weights: Sequence[int], k: int) -> List[List[int]]:
    """Longest-processing-time balanced partition into ``k`` bins."""
    k = max(min(k, len(weights)), 1)
    bins: List[List[int]] = [[] for _ in range(k)]
    loads = [0] * k
    for i in sorted(range(len(weights)), key=lambda i: -weights[i]):
        j = min(range(k), key=loads.__getitem__)
        bins[j].append(i)
        loads[j] += weights[i]
    return [sorted(b) for b in bins if b]


def shard_program(program: ChainProgram, *,
                  n_shards: Optional[int] = None) -> ShardedProgram:
    """Partition a program's entry axis into independent shards.

    With ``n_shards=None`` (host executor) entries group by chain
    *signature* — replicas and same-tier devices solve together, each
    group under its own convergence budget — merged down to at most
    :data:`HOST_MAX_SHARDS` groups.  With ``n_shards=k`` (mesh
    executor) entries are LPT-balanced into ``<= k`` event-weighted
    bins.  Entries are connected components of the chain/device graph,
    so cross-entry families from ``extend_program`` are never split —
    and neither are a refined pool's greedy-replay coupling chains,
    which always live inside one device's component.  Sub-programs
    inherit the parent's exactness contract verbatim (``exact``,
    ``order_stable``, ``unstable_pools``, ``svc_seeds``), so the
    sharded solve claims exactly what the single-chip solve would.
    """
    if program.n_devices == 0 or program.n_flat == 0:
        return ShardedProgram(base=program, shards=())
    bounds, comp_list, recs = _entry_components(program)
    weights = [int(sum(bounds[d + 1] - bounds[d] for d in devs))
               for devs in comp_list]
    if n_shards is None:
        by_sig: "OrderedDict[tuple, list]" = OrderedDict()
        for i, sig in enumerate(_signatures(len(comp_list), recs)):
            by_sig.setdefault(sig, []).append(i)
        groups = list(by_sig.values())
        while len(groups) > HOST_MAX_SHARDS:
            groups.sort(key=lambda g: sum(weights[i] for i in g))
            a, b = groups[0], groups[1]
            groups = [sorted(a + b)] + groups[2:]
    else:
        groups = _lpt(weights, int(n_shards))

    group_of = np.empty(len(comp_list), dtype=np.int64)
    for g, comps in enumerate(groups):
        for i in comps:
            group_of[i] = g

    # global -> shard-local index map (shards partition the flat axis)
    loc = np.empty(program.n_flat, dtype=np.int64)
    perms: List[np.ndarray] = []
    dev_lists: List[Tuple[int, ...]] = []
    for comps in groups:
        devs = sorted(d for i in comps for d in comp_list[i])
        perm = np.concatenate([np.arange(bounds[d], bounds[d + 1])
                               for d in devs]) if devs else \
            np.zeros(0, dtype=np.int64)
        loc[perm] = np.arange(len(perm))
        perms.append(perm)
        dev_lists.append(tuple(devs))

    chain_maps: List["OrderedDict[str, list]"] = \
        [OrderedDict() for _ in groups]
    for label, c, i in recs:
        chain_maps[group_of[i]].setdefault(label, []).append(loc[c])

    shards = []
    for g, perm in enumerate(perms):
        n = len(perm)
        order = np.arange(n, dtype=np.int64)
        sub = ChainProgram(
            n_flat=n, offsets=(0,), orders=(order,), invs=(order,),
            issue_flat=program.issue_flat[perm],
            svc0_flat=program.svc0_flat[perm],
            families=_blocks_from_chains(chain_maps[g], n),
            exact=program.exact,
            multiclass_pools=program.multiclass_pools,
            refine_used=program.refine_used,
            order_stable=program.order_stable,
            unstable_pools=program.unstable_pools,
            svc_seeds=program.svc_seeds)
        shards.append(Shard(devices=dev_lists[g], program=sub, perm=perm))
    return ShardedProgram(base=program, shards=tuple(shards))


# ---------------------------------------------------------------------------
# Plan cache: program object identity fast path + content-digest
# fallback (mirrors the lowering cache), so rebuilding an identical
# program — e.g. across capacity-ladder rungs — still hits.
# ---------------------------------------------------------------------------
_PLAN_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_PLAN_CACHE_MAX = 4


def _program_digest(program: ChainProgram) -> bytes:
    """Content digest of a compiled program's solve-relevant structure
    (flat size, entry offsets, family tensors), memoized on the program
    object — same trick as the trace digest memo."""
    cached = getattr(program, "_shard_digest_memo", None)
    if cached is not None:
        return cached
    h = hashlib.sha1()
    h.update(np.int64(program.n_flat).tobytes())
    h.update(np.asarray(program.offsets, dtype=np.int64).tobytes())
    for blk in program.families:
        h.update(blk.label.encode())
        h.update(blk.layout.encode())
        h.update(np.ascontiguousarray(blk.gidx).tobytes())
        h.update(np.ascontiguousarray(blk.heads).tobytes())
    d = h.digest()
    try:
        object.__setattr__(program, "_shard_digest_memo", d)
    except Exception:        # pragma: no cover - slotted subclass
        pass
    return d


def _plan(program: ChainProgram,
          n_shards: Optional[int]) -> ShardedProgram:
    ikey = ("id", id(program), n_shards)
    hit = _PLAN_CACHE.get(ikey)
    if hit is not None and hit[0] is program:
        _PLAN_CACHE.move_to_end(ikey)
        return hit[1]
    dkey = ("sha", _program_digest(program), n_shards)
    hit = _PLAN_CACHE.get(dkey)
    if hit is not None:
        sp = hit[1]
        _PLAN_CACHE.move_to_end(dkey)
    else:
        sp = shard_program(program, n_shards=n_shards)
        _PLAN_CACHE[dkey] = (None, sp)
    # (re)bind the identity fast path for this object; the digest entry
    # keeps serving identical rebuilds after this object dies.
    _PLAN_CACHE[ikey] = (program, sp)
    _PLAN_CACHE.move_to_end(ikey)
    while len(_PLAN_CACHE) > 2 * _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
    return sp


def clear_shard_plans() -> None:
    _PLAN_CACHE.clear()


def _pick_executor() -> str:
    forced = os.environ.get(EXECUTOR_ENV, "").lower()
    if forced in ("mesh", "host"):
        return forced
    if "jax" in sys.modules:
        try:
            import jax
            devs = jax.local_devices()
            if len(devs) > 1 and devs[0].platform != "cpu":
                return "mesh"
        except Exception:
            pass
    return "host"


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
def _solve_host(program: ChainProgram, svc: np.ndarray, *, sweeps: int,
                scan_backend: str, comp0: Optional[np.ndarray]
                ) -> Tuple[np.ndarray, int, bool]:
    plan = _plan(program, None)
    if len(plan.shards) <= 1:
        if program.n_flat >= WINDOW_AUTO_MIN:
            # homogeneous mega-entry: the entry axis gives no
            # parallelism, but the request axis still pipelines into
            # issue-time windows with bounded per-window memory
            return solve_program_windowed(
                program, svc, sweeps=sweeps, scan_backend=scan_backend,
                comp0=comp0, warn=False)
        # one signature group: the grouped solve IS the base solve
        return _solve_numpy(program, svc, sweeps=sweeps,
                            scan_backend=scan_backend, comp0=comp0)
    comp = np.empty(program.n_flat, dtype=np.float64)
    used, conv = 0, True
    for sh in plan.shards:
        c, u, k = _solve_numpy(
            sh.program, svc[sh.perm], sweeps=sweeps,
            scan_backend=scan_backend,
            comp0=None if comp0 is None else comp0[sh.perm])
        comp[sh.perm] = c
        used = max(used, u)
        conv = conv and k
    return comp, used, conv


def _mesh_static(plan: ShardedProgram, ndev: int) -> dict:
    """Stacked padded block tensors for the mesh kernel (cached per
    plan + device count).  Family slot ``f`` stacks every shard's
    ``f``-th block at that slot's max (R, L); shards with fewer
    families pad with all-dead blocks; the shard count pads up to a
    multiple of ``ndev`` with empty shards."""
    cached = plan._mesh_cache.get(ndev)
    if cached is not None:
        return cached
    shards = plan.shards
    S = -(-len(shards) // ndev) * ndev
    n_max = max(sh.program.n_flat for sh in shards)
    views = [[blk.rows_view() for blk in sh.program.families]
             for sh in shards]
    F = max(len(v) for v in views)
    blocks = []
    for f in range(F):
        shapes = [v[f][0].shape for v in views if f < len(v)]
        R = max(s[0] for s in shapes)
        L = max(s[1] for s in shapes)
        gidx = np.full((S, R, L), n_max, dtype=np.int32)
        heads = np.ones((S, R, L), dtype=bool)
        for s, v in enumerate(views):
            if f < len(v):
                g, h = v[f]
                g = np.where(g == shards[s].program.n_flat, n_max, g)
                gidx[s, :g.shape[0], :g.shape[1]] = g
                heads[s, :h.shape[0], :h.shape[1]] = h
        blocks.append((gidx, heads))
    # per-shard block adjacency for the in-kernel active-set mask,
    # padded to the stacked family-slot count (padding slots gather
    # only the dead index, so they are adjacent to nothing)
    adjS = np.zeros((S, F, F), dtype=bool)
    for s, sh in enumerate(shards):
        a = block_adjacency(sh.program)
        adjS[s, :a.shape[0], :a.shape[1]] = a
    cached = {"S": S, "n_max": n_max, "blocks": tuple(blocks),
              "adj": adjS}
    plan._mesh_cache[ndev] = cached
    return cached


def _solve_mesh(program: ChainProgram, svc: np.ndarray, *, sweeps: int,
                scan_backend: str, comp0: Optional[np.ndarray]
                ) -> Tuple[np.ndarray, int, bool]:
    import jax
    from jax.experimental import enable_x64

    from repro.kernels.zns_fixpoint import zns_fixpoint_sharded

    devices = tuple(jax.local_devices())
    plan = _plan(program, len(devices))
    if len(plan.shards) <= 1:
        return _solve_numpy(program, svc, sweeps=sweeps,
                            scan_backend=scan_backend, comp0=comp0)
    st = _mesh_static(plan, len(devices))
    S, n_max = st["S"], st["n_max"]
    init = np.full((S, n_max + 1), -np.inf, dtype=np.float64)
    svcS = np.zeros((S, n_max + 1), dtype=np.float64)
    for s, sh in enumerate(plan.shards):
        v = svc[sh.perm]
        c0 = program.issue_flat[sh.perm] + v
        if comp0 is not None:
            c0 = np.maximum(c0, comp0[sh.perm])
        init[s, :len(v)] = c0
        svcS[s, :len(v)] = v
    with enable_x64():
        comp_s, used_s, conv_s = zns_fixpoint_sharded(
            init, svcS, st["blocks"], sweeps=sweeps, devices=devices,
            adj=st["adj"])
        comp_s = np.asarray(comp_s, dtype=np.float64)
        used_s = np.asarray(used_s)
        conv_s = np.asarray(conv_s)
    comp = np.empty(program.n_flat, dtype=np.float64)
    for s, sh in enumerate(plan.shards):
        comp[sh.perm] = comp_s[s, :len(sh.perm)]
    n = len(plan.shards)
    return comp, int(used_s[:n].max()), bool(conv_s[:n].all())


def solve_program_sharded(program: ChainProgram, svc_flat, *,
                          sweeps: int = 8, scan_backend: str = "auto",
                          comp0: Optional[np.ndarray] = None,
                          executor: str = "auto", warn: bool = True
                          ) -> Tuple[np.ndarray, int, bool]:
    """Sharded drop-in for :func:`repro.core.solve_program`.

    Partitions the program's entry axis (plan cached per program
    object) and solves each shard independently — the fixpoint is
    block-diagonal over entries, so the result equals the single-chip
    solve to float64 fixpoint tolerance (~1e-12 relative; a 1-shard
    plan falls back to the numpy driver bit-identically).  ``executor``
    = ``"host"`` (signature-grouped numpy sub-solves), ``"mesh"``
    (``shard_map`` across local jax devices), or ``"auto"`` (mesh on
    multi-chip accelerator hosts, host otherwise;
    ``REPRO_SHARD_EXECUTOR`` overrides).
    """
    svc = np.asarray(svc_flat, dtype=np.float64)
    if program.n_flat == 0:
        return np.zeros(0, dtype=np.float64), 0, True
    if len(svc) != program.n_flat:
        raise ValueError(f"service vector has {len(svc)} entries for a "
                         f"{program.n_flat}-request program")
    if comp0 is not None and len(comp0) != program.n_flat:
        raise ValueError(f"comp0 has {len(comp0)} entries for a "
                         f"{program.n_flat}-request program")
    if executor not in ("auto", "host", "mesh"):
        raise ValueError(f"unknown shard executor {executor!r}; "
                         f"expected auto | host | mesh")
    if executor == "auto":
        executor = _pick_executor()
    if executor == "host" or program.n_devices <= 1:
        comp, used, conv = _solve_host(program, svc, sweeps=sweeps,
                                       scan_backend=scan_backend,
                                       comp0=comp0)
    else:
        comp, used, conv = _solve_mesh(program, svc, sweeps=sweeps,
                                       scan_backend=scan_backend,
                                       comp0=comp0)
    import repro.core.chain_program as _cp
    _cp._LAST_SOLVE_STATS = SolveStats(
        driver=f"sharded/{executor}", sweeps=used, converged=conv,
        n_blocks=len(program.families))
    if not conv and warn:
        warnings.warn(
            f"sharded chain-program fixpoint exhausted its sweep budget "
            f"({sweeps}) while still moving; completions are a lower "
            f"bound.", RuntimeWarning, stacklevel=2)
    return comp, used, conv


# ---------------------------------------------------------------------------
# Intra-entry time-window sharding
# ---------------------------------------------------------------------------
#: Default issue-time window size (events) when ``n_windows`` is not
#: given: large enough that per-window solver overhead vanishes, small
#: enough that the per-window float64 scratch stays ~tens of MB.
WINDOW_TARGET_EVENTS = 1 << 18

#: ``solve_program_sharded`` auto-windows a degenerate 1-shard plan
#: only above this event count — smaller programs keep the documented
#: bit-identical numpy fallback.
WINDOW_AUTO_MIN = 2_000_000


@dataclasses.dataclass(frozen=True)
class Window:
    """One issue-time window of a windowed program.

    ``perm`` maps the window's flat event order back to base flat
    indices; ``bnd_local``/``bnd_pred`` are the pipeline boundary: the
    window-local index of each chain-segment head whose predecessor
    completed in an earlier window, and that predecessor's base flat
    index.  The boundary condition ``comp0[head] >= comp[pred] +
    svc[head]`` re-creates the cut chain edge exactly (the fixpoint is
    monotone from below, so a lower bound installed at init holds
    permanently)."""

    program: ChainProgram
    perm: np.ndarray
    bnd_local: np.ndarray
    bnd_pred: np.ndarray


@dataclasses.dataclass
class WindowedProgram:
    """A partition of one program's request axis into issue-time
    windows, solved as a pipelined sequence (earlier windows feed later
    ones their completion frontier)."""

    base: ChainProgram
    windows: Tuple[Window, ...]

    @property
    def n_windows(self) -> int:
        return len(self.windows)

    def __repr__(self) -> str:
        return (f"WindowedProgram(windows={len(self.windows)}, "
                f"events={[len(w.perm) for w in self.windows]})")


def window_program(program: ChainProgram, *,
                   n_windows: Optional[int] = None,
                   window_events: Optional[int] = None
                   ) -> WindowedProgram:
    """Partition a program's request axis into issue-time windows.

    Events are bucketed by issue-time rank into ``n_windows`` (default
    ``ceil(n_flat / window_events)``) near-equal windows, then the
    window index is repaired to be non-decreasing along every chain of
    every family (a running max per chain, iterated across families to
    a fixpoint) — so every cross-window chain edge points forward and
    the pipelined solve is exact.  Each window becomes a sub-program
    over its own events plus a boundary list of (segment head,
    upstream predecessor) pairs.  Results are memoized on the program
    per window count.
    """
    n = program.n_flat
    if n_windows is None:
        we = int(window_events) if window_events else WINDOW_TARGET_EVENTS
        n_windows = -(-n // we) if n else 1
    k = max(min(int(n_windows), n if n else 1), 1)
    memo = getattr(program, "_window_memo", None)
    if memo is not None and k in memo:
        return memo[k]

    w = np.empty(n, dtype=np.int64)
    order = np.argsort(program.issue_flat, kind="stable")
    w[order] = (np.arange(n, dtype=np.int64) * k) // max(n, 1)
    chains_by_label = program_chains(program)
    all_chains = [c for chs in chains_by_label.values() for c in chs]
    # monotone repair: raising an event's window can break another
    # chain through that event, so iterate to a fixpoint (bounded by
    # k passes; in practice 1-2)
    changed = True
    while changed:
        changed = False
        for c in all_chains:
            wc = w[c]
            acc = np.maximum.accumulate(wc)
            if (acc != wc).any():
                w[c] = acc
                changed = True

    perms = [np.nonzero(w == j)[0] for j in range(k)]
    loc = np.empty(n, dtype=np.int64)
    for p in perms:
        loc[p] = np.arange(len(p))
    chain_maps: List["OrderedDict[str, list]"] = \
        [OrderedDict() for _ in range(k)]
    bnds: List[Tuple[list, list]] = [([], []) for _ in range(k)]
    for label, chs in chains_by_label.items():
        for c in chs:
            wc = w[c]
            cut = np.nonzero(np.diff(wc))[0] + 1
            starts = np.concatenate(([0], cut))
            ends = np.concatenate((cut, [len(c)]))
            for a, b in zip(starts, ends):
                j = int(wc[a])
                chain_maps[j].setdefault(label, []).append(loc[c[a:b]])
                if a > 0:
                    bnds[j][0].append(int(loc[c[a]]))
                    bnds[j][1].append(int(c[a - 1]))

    windows = []
    for j in range(k):
        p = perms[j]
        m = len(p)
        oj = np.arange(m, dtype=np.int64)
        sub = ChainProgram(
            n_flat=m, offsets=(0,), orders=(oj,), invs=(oj,),
            issue_flat=program.issue_flat[p],
            svc0_flat=program.svc0_flat[p],
            families=_blocks_from_chains(chain_maps[j], m),
            exact=program.exact,
            multiclass_pools=program.multiclass_pools,
            refine_used=program.refine_used,
            order_stable=program.order_stable,
            unstable_pools=program.unstable_pools,
            svc_seeds=program.svc_seeds)
        windows.append(Window(
            program=sub, perm=p,
            bnd_local=np.asarray(bnds[j][0], dtype=np.int64),
            bnd_pred=np.asarray(bnds[j][1], dtype=np.int64)))
    wp = WindowedProgram(base=program, windows=tuple(windows))
    if memo is None:
        memo = {}
        try:
            object.__setattr__(program, "_window_memo", memo)
        except Exception:    # pragma: no cover - slotted subclass
            pass
    memo[k] = wp
    return wp


def solve_program_windowed(program: ChainProgram, svc_flat, *,
                           sweeps: int = 8, scan_backend: str = "auto",
                           comp0: Optional[np.ndarray] = None,
                           n_windows: Optional[int] = None,
                           window_events: Optional[int] = None,
                           warn: bool = True
                           ) -> Tuple[np.ndarray, int, bool]:
    """Solve one program as a pipeline of issue-time windows.

    Window ``j+1`` starts from window ``j``'s completion frontier: each
    cut chain edge becomes a ``comp0`` lower bound ``comp[pred] +
    svc[head]`` on its downstream head, which the monotone fixpoint
    enforces permanently — so the pipelined result equals the full
    solve (and hence the event oracle, when ``program.exact``) to
    float64 fixpoint tolerance, while the solver's per-sweep scratch
    (gathers + the per-family float64 service matrices) is bounded by
    the largest window instead of the whole program.  ``sweeps`` is a
    per-window budget; ``sweeps_used`` reports the hungriest window.
    """
    svc = np.asarray(svc_flat, dtype=np.float64)
    if program.n_flat == 0:
        return np.zeros(0, dtype=np.float64), 0, True
    if len(svc) != program.n_flat:
        raise ValueError(f"service vector has {len(svc)} entries for a "
                         f"{program.n_flat}-request program")
    if comp0 is not None and len(comp0) != program.n_flat:
        raise ValueError(f"comp0 has {len(comp0)} entries for a "
                         f"{program.n_flat}-request program")
    wp = window_program(program, n_windows=n_windows,
                        window_events=window_events)
    if wp.n_windows <= 1:
        return _solve_numpy(program, svc, sweeps=sweeps,
                            scan_backend=scan_backend, comp0=comp0)
    comp = np.empty(program.n_flat, dtype=np.float64)
    used, conv = 0, True
    for win in wp.windows:
        p = win.perm
        if not len(p):
            continue
        svc_w = svc[p]
        lb = None
        if comp0 is not None:
            lb = np.asarray(comp0, dtype=np.float64)[p].copy()
        if len(win.bnd_local):
            if lb is None:
                lb = np.full(len(p), -np.inf)
            np.maximum.at(lb, win.bnd_local,
                          comp[win.bnd_pred] + svc_w[win.bnd_local])
        c, u, ok = _solve_numpy(win.program, svc_w, sweeps=sweeps,
                                scan_backend=scan_backend, comp0=lb)
        comp[p] = c
        used = max(used, u)
        conv = conv and ok
    import repro.core.chain_program as _cp
    _cp._LAST_SOLVE_STATS = SolveStats(
        driver="windowed", sweeps=used, converged=conv,
        n_blocks=len(program.families))
    if not conv and warn:
        warnings.warn(
            f"windowed chain-program fixpoint exhausted its per-window "
            f"sweep budget ({sweeps}) while still moving; completions "
            f"are a lower bound.", RuntimeWarning, stacklevel=2)
    return comp, used, conv
