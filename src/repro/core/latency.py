"""Calibrated service-time model for ZNS operations.

This is the latency model the paper prescribes for emulators (§IV):

* distinct ``append`` vs ``write`` service times (Obs#4),
* request-size dependence (Obs#3),
* LBA-format and storage-stack terms (Obs#1/#2),
* occupancy-dependent ``reset``/``finish`` costs (Obs#10, *linear* models),
* explicit/implicit open and close costs (Obs#9),
* interference coupling: I/O inflates ``reset`` (Obs#13) but not vice versa
  (Obs#12).

All functions are pure and operate on scalars or numpy arrays so the
discrete-event engine can vectorize over requests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import calibration as C
from .spec import KiB, LBAFormat, OpType, Stack, ZNSDeviceSpec


def _interp_vec(table: dict, x):
    """Vectorized piecewise-linear interp with proportional tail (sizes)."""
    keys = np.array(sorted(table), dtype=np.float64)
    vals = np.array([table[k] for k in sorted(table)], dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    core = np.interp(x, keys, vals)
    # bandwidth-limited proportional extrapolation beyond the last anchor
    tail = vals[-1] * (x / keys[-1])
    return np.where(x > keys[-1], tail, core)


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Service times in microseconds for a given device spec."""

    spec: ZNSDeviceSpec = ZNSDeviceSpec()

    # -- data-path ops -------------------------------------------------------
    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        """QD=1 service latency of READ/WRITE/APPEND (Obs#1–#4)."""
        op = np.asarray(op)
        size = np.asarray(size_bytes, dtype=np.float64)
        w = _interp_vec(C.WRITE_SVC_TABLE_US, size)
        a = _interp_vec(C.APPEND_SVC_TABLE_US, size)
        r = _interp_vec(C.READ_SVC_TABLE_US, size)
        base = np.where(op == OpType.READ, r, np.where(op == OpType.WRITE, w, a))
        # LBA-format penalty (Obs#1), strongest for small requests.
        pen = np.where(
            op == OpType.READ, C.LBA512_PENALTY[OpType.READ],
            np.where(op == OpType.WRITE, C.LBA512_PENALTY[OpType.WRITE],
                     C.LBA512_PENALTY[OpType.APPEND]))
        if fmt == LBAFormat.LBA_512:
            # penalty decays once transfers are large (firmware small-I/O path)
            decay = np.clip(32 * KiB / np.maximum(size, 4 * KiB), 0.25, 1.0)
            base = base * (1.0 + (pen - 1.0) * decay)
        # Host-stack overhead (Obs#2).
        base = base + C.STACK_OVERHEAD_US[Stack(stack)]
        return base

    # -- zone-management ops ---------------------------------------------------
    def open_us(self, explicit: bool = True) -> float:
        return C.OPEN_LAT_US if explicit else 0.0

    def close_us(self) -> float:
        return C.CLOSE_LAT_US

    def implicit_open_penalty_us(self, op: OpType) -> float:
        """First write/append to a not-yet-open zone (Obs#9)."""
        if op == OpType.WRITE:
            return C.IMPLICIT_OPEN_FIRST_WRITE_PENALTY_US
        if op == OpType.APPEND:
            return C.IMPLICIT_OPEN_FIRST_APPEND_PENALTY_US
        return 0.0

    def reset_us(self, occupancy, was_finished=False):
        """Occupancy-dependent reset cost (Obs#10, Fig. 5a)."""
        occ = np.clip(np.asarray(occupancy, dtype=np.float64), 0.0, 1.0)
        keys = np.array(sorted(C.RESET_LAT_MS_TABLE))
        vals = np.array([C.RESET_LAT_MS_TABLE[k] for k in sorted(C.RESET_LAT_MS_TABLE)])
        ms = np.interp(occ, keys, vals)
        ms = np.where(np.asarray(was_finished, dtype=bool),
                      ms * C.RESET_FINISHED_DISCOUNT, ms)
        return ms * 1e3

    def finish_us(self, occupancy):
        """Occupancy-dependent finish cost (Obs#10, Fig. 5b).

        Linear in remaining capacity + metadata floor: 907.51 ms at ~0%
        down to 3.07 ms at 100%.
        """
        occ = np.clip(np.asarray(occupancy, dtype=np.float64), 0.0, 1.0)
        ms = C.FINISH_LAT_FLOOR_MS + C.FINISH_LAT_SPAN_MS * (1.0 - occ)
        return ms * 1e3

    def reset_inflation(self, concurrent_ops) -> float:
        """Multiplier on reset latency under concurrent I/O (Obs#13).

        ``concurrent_ops``: iterable of OpType present concurrently.  The
        worst single-op inflation applies (contention is for the same
        internal resource, not additive in op count — Fig. 7 shows similar
        inflation for each op class alone).
        """
        mult = 1.0
        for op in concurrent_ops:
            mult = max(mult, C.RESET_INFLATION.get(OpType(op), 1.0))
        return mult

    # -- derived helpers -------------------------------------------------------
    def qd1_iops(self, op, size_bytes, stack=Stack.SPDK,
                 fmt=LBAFormat.LBA_4K) -> float:
        return 1e6 / float(self.io_service_us(op, size_bytes, stack, fmt))


DEFAULT_LATENCY_MODEL = LatencyModel()
