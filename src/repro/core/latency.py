"""Calibrated service-time model for ZNS operations.

This is the latency model the paper prescribes for emulators (§IV):

* distinct ``append`` vs ``write`` service times (Obs#4),
* request-size dependence (Obs#3),
* LBA-format and storage-stack terms (Obs#1/#2),
* occupancy-dependent ``reset``/``finish`` costs (Obs#10, *linear* models),
* explicit/implicit open and close costs (Obs#9),
* interference coupling: I/O inflates ``reset`` (Obs#13) but not vice versa
  (Obs#12).

The model is a **parameter pytree**: every calibrated coefficient lives in
the :class:`LatencyParams` dataclass-of-arrays, and the latency functions
are *pure* — ``io_service_us(params, op, size, stack, fmt)`` etc. operate
on scalars or numpy arrays, so the simulation engines vectorize over
requests and the :class:`repro.core.DeviceFleet` layer stacks parameters
along a leading device axis (:func:`stack_latency_params`).  Emulator
profiles (FEMU, NVMeVirt — see :mod:`repro.core.emulator_models`) are just
alternative :class:`LatencyParams` values run through the same functions.

:class:`LatencyModel` remains as the thin object-style wrapper the rest of
the repo binds to (``spec`` + ``params``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from . import calibration as C
from .spec import KiB, LBAFormat, OpType, Stack, ZNSDeviceSpec

#: Index order of the per-op parameter rows: OpType.READ/WRITE/APPEND
#: values are 0/1/2, so ``params.io_svc_us[int(op)]`` is the op's row.
N_IO_OPS = 3


@dataclasses.dataclass(frozen=True, eq=False)
class LatencyParams:
    """All calibrated latency coefficients as a dataclass-of-arrays.

    Fields are plain ``np.float64`` arrays so a batch of heterogeneous
    devices stacks along a leading axis (:func:`stack_latency_params`) and
    maps cleanly onto jax pytrees for the accelerated fleet path.
    Equality is element-wise (ndarray fields break the generated
    ``__eq__``/``__hash__``, so both are provided explicitly — a
    :class:`LatencyModel` stays comparable and dict-keyable).

    Example (pure functions over the calibrated ZN540 values)::

        >>> from repro.core import DEFAULT_LATENCY_PARAMS, KiB, OpType
        >>> from repro.core.latency import io_service_us, reset_us
        >>> round(float(io_service_us(DEFAULT_LATENCY_PARAMS,
        ...                           OpType.WRITE, 4 * KiB)), 2)
        11.36
        >>> round(float(reset_us(DEFAULT_LATENCY_PARAMS, 0.5)))
        11600
    """

    # -- data-path ops: service = interp(size) [+ format/stack terms] -------
    size_anchors: np.ndarray       # (K,) request-size anchors, bytes
    io_svc_us: np.ndarray          # (3, K) rows: READ, WRITE, APPEND
    stack_overhead_us: np.ndarray  # (3,) indexed by Stack value (Obs#2)
    lba512_penalty: np.ndarray     # (3,) per-op multiplier (Obs#1)
    # -- zone-management ops -------------------------------------------------
    reset_occ: np.ndarray          # (M,) occupancy anchors (Obs#10)
    reset_us_table: np.ndarray     # (M,) reset cost at each anchor, us
    reset_finished_discount: np.ndarray  # () multiplier for finished zones
    finish_floor_us: np.ndarray    # () metadata floor (Obs#10)
    finish_span_us: np.ndarray     # () cost of finishing an ~empty zone
    open_cost_us: np.ndarray       # () explicit open (Obs#9)
    close_cost_us: np.ndarray      # () close (Obs#9)
    implicit_open_us: np.ndarray   # (3,) per-op first-write penalty (Obs#9)
    # -- interference couplings (Obs#12/#13) ---------------------------------
    reset_inflation: np.ndarray    # (3,) multiplier per concurrent I/O op
    reset_on_io_path: np.ndarray   # () 1.0 -> resets contend with I/O
    #                                 (emulator behaviour violating Obs#12);
    #                                 0.0 -> dedicated metadata engine.
    # -- stochastic service-time shape ---------------------------------------
    reset_tail_sigma: np.ndarray   # () lognormal sigma for reset/finish
    io_jitter_sigma: np.ndarray    # (3,) lognormal sigma per I/O op

    def fields(self) -> Iterator[Tuple[str, np.ndarray]]:
        for f in dataclasses.fields(self):
            yield f.name, getattr(self, f.name)

    def __eq__(self, other):
        if not isinstance(other, LatencyParams):
            return NotImplemented
        return all(np.array_equal(v, getattr(other, name))
                   for name, v in self.fields())

    def __hash__(self):
        return hash(tuple(np.asarray(v, dtype=np.float64).tobytes()
                          for _, v in self.fields()))


def _arr(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def zn540_params() -> LatencyParams:
    """The paper's calibrated ZN540 parameters (anchors in calibration.py)."""
    keys = sorted(C.WRITE_SVC_TABLE_US)
    assert keys == sorted(C.APPEND_SVC_TABLE_US) == sorted(C.READ_SVC_TABLE_US)
    occ = sorted(C.RESET_LAT_MS_TABLE)
    return LatencyParams(
        size_anchors=_arr(keys),
        io_svc_us=_arr([[C.READ_SVC_TABLE_US[k] for k in keys],
                        [C.WRITE_SVC_TABLE_US[k] for k in keys],
                        [C.APPEND_SVC_TABLE_US[k] for k in keys]]),
        stack_overhead_us=_arr([C.STACK_OVERHEAD_US[Stack(s)]
                                for s in range(3)]),
        lba512_penalty=_arr([C.LBA512_PENALTY[OpType.READ],
                             C.LBA512_PENALTY[OpType.WRITE],
                             C.LBA512_PENALTY[OpType.APPEND]]),
        reset_occ=_arr(occ),
        reset_us_table=_arr([C.RESET_LAT_MS_TABLE[o] * 1e3 for o in occ]),
        reset_finished_discount=_arr(C.RESET_FINISHED_DISCOUNT),
        finish_floor_us=_arr(C.FINISH_LAT_FLOOR_MS * 1e3),
        finish_span_us=_arr(C.FINISH_LAT_SPAN_MS * 1e3),
        open_cost_us=_arr(C.OPEN_LAT_US),
        close_cost_us=_arr(C.CLOSE_LAT_US),
        implicit_open_us=_arr([0.0, C.IMPLICIT_OPEN_FIRST_WRITE_PENALTY_US,
                               C.IMPLICIT_OPEN_FIRST_APPEND_PENALTY_US]),
        reset_inflation=_arr([C.RESET_INFLATION[OpType.READ],
                              C.RESET_INFLATION[OpType.WRITE],
                              C.RESET_INFLATION[OpType.APPEND]]),
        reset_on_io_path=_arr(0.0),
        reset_tail_sigma=_arr(C.RESET_TAIL_SIGMA),
        io_jitter_sigma=_arr([0.15, 0.05, 0.05]),
    )


def stack_latency_params(params: Sequence[LatencyParams]) -> LatencyParams:
    """Stack N parameter pytrees along a new leading device axis.

    All members must share anchor-grid shapes (the built-in profiles do);
    mismatched shapes raise ``ValueError``.
    """
    if not params:
        raise ValueError("stack_latency_params: empty sequence")
    out = {}
    for name, first in params[0].fields():
        vals = [getattr(p, name) for p in params]
        if any(v.shape != first.shape for v in vals):
            raise ValueError(
                f"LatencyParams.{name} shapes differ across devices: "
                f"{[v.shape for v in vals]}; re-anchor the profiles on a "
                f"common grid before stacking")
        out[name] = np.stack(vals)
    return LatencyParams(**out)


def unstack_latency_params(params: LatencyParams, i: int) -> LatencyParams:
    """Member ``i`` of a stacked parameter pytree."""
    return LatencyParams(**{name: val[i] for name, val in params.fields()})


# ---------------------------------------------------------------------------
# Pure latency functions over a LatencyParams pytree
# ---------------------------------------------------------------------------
def io_service_us(params: LatencyParams, op, size_bytes, stack=Stack.SPDK,
                  fmt=LBAFormat.LBA_4K):
    """QD=1 service latency of READ/WRITE/APPEND (Obs#1–#4), vectorized
    over ``op``/``size_bytes`` (mutually broadcastable)."""
    opi = np.clip(np.asarray(op, dtype=np.int64), 0, N_IO_OPS - 1)
    size = np.asarray(size_bytes, dtype=np.float64)
    keys = params.size_anchors
    svc = params.io_svc_us
    # piecewise-linear interp against the per-op anchor row
    x = np.clip(size, keys[0], keys[-1])
    hi = np.clip(np.searchsorted(keys, x, side="left"), 1, len(keys) - 1)
    lo = hi - 1
    f = (x - keys[lo]) / (keys[hi] - keys[lo])
    core = svc[opi, lo] * (1.0 - f) + svc[opi, hi] * f
    # bandwidth-limited proportional extrapolation beyond the last anchor
    tail = svc[opi, -1] * (size / keys[-1])
    base = np.where(size > keys[-1], tail, core)
    if fmt == LBAFormat.LBA_512:
        # LBA-format penalty (Obs#1), strongest for small requests; decays
        # once transfers are large (firmware small-I/O path).
        pen = params.lba512_penalty[opi]
        decay = np.clip(32 * KiB / np.maximum(size, 4 * KiB), 0.25, 1.0)
        base = base * (1.0 + (pen - 1.0) * decay)
    # Host-stack overhead (Obs#2).
    return base + params.stack_overhead_us[int(Stack(stack))]


def reset_us(params: LatencyParams, occupancy, was_finished=False):
    """Occupancy-dependent reset cost (Obs#10, Fig. 5a)."""
    occ = np.clip(np.asarray(occupancy, dtype=np.float64), 0.0, 1.0)
    us = np.interp(occ, params.reset_occ, params.reset_us_table)
    return np.where(np.asarray(was_finished, dtype=bool),
                    us * params.reset_finished_discount, us)


def finish_us(params: LatencyParams, occupancy):
    """Occupancy-dependent finish cost (Obs#10, Fig. 5b): linear in
    remaining capacity + metadata floor."""
    occ = np.clip(np.asarray(occupancy, dtype=np.float64), 0.0, 1.0)
    return params.finish_floor_us + params.finish_span_us * (1.0 - occ)


def open_us(params: LatencyParams, explicit: bool = True) -> float:
    return float(params.open_cost_us) if explicit else 0.0


def close_us(params: LatencyParams) -> float:
    return float(params.close_cost_us)


def implicit_open_penalty_us(params: LatencyParams, op: OpType) -> float:
    """First write/append to a not-yet-open zone (Obs#9)."""
    op = int(op)
    if 0 <= op < N_IO_OPS:
        return float(params.implicit_open_us[op])
    return 0.0


def reset_inflation_factors(params: LatencyParams, io_ctx) -> np.ndarray:
    """Obs#13 multiplier on reset latency for each concurrent-I/O context
    (``io_ctx``: OpType value of I/O running concurrently, or -1)."""
    ctx = np.asarray(io_ctx, dtype=np.int64)
    valid = (ctx >= 0) & (ctx < N_IO_OPS)
    return np.where(valid, params.reset_inflation[np.clip(ctx, 0,
                                                          N_IO_OPS - 1)], 1.0)


#: The calibrated ZN540 parameters (module-level default).
DEFAULT_LATENCY_PARAMS = zn540_params()


# ---------------------------------------------------------------------------
# Object-style wrapper (stable facade; all state lives in .params)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Service times in microseconds for a given device spec.

    A thin binding of ``(spec, params)``; all behaviour delegates to the
    pure functions above, so a :class:`LatencyModel` and its ``params``
    produce identical results by construction.
    """

    spec: ZNSDeviceSpec = ZNSDeviceSpec()
    params: Optional[LatencyParams] = None

    def __post_init__(self):
        if self.params is None:
            object.__setattr__(self, "params", DEFAULT_LATENCY_PARAMS)

    # -- data-path ops -------------------------------------------------------
    def io_service_us(self, op, size_bytes, stack=Stack.SPDK,
                      fmt=LBAFormat.LBA_4K):
        """QD=1 service latency of READ/WRITE/APPEND (Obs#1–#4)."""
        return io_service_us(self.params, op, size_bytes, stack, fmt)

    # -- zone-management ops ---------------------------------------------------
    def open_us(self, explicit: bool = True) -> float:
        return open_us(self.params, explicit)

    def close_us(self) -> float:
        return close_us(self.params)

    def implicit_open_penalty_us(self, op: OpType) -> float:
        """First write/append to a not-yet-open zone (Obs#9)."""
        return implicit_open_penalty_us(self.params, op)

    def reset_us(self, occupancy, was_finished=False):
        """Occupancy-dependent reset cost (Obs#10, Fig. 5a)."""
        return reset_us(self.params, occupancy, was_finished)

    def finish_us(self, occupancy):
        """Occupancy-dependent finish cost (Obs#10, Fig. 5b)."""
        return finish_us(self.params, occupancy)

    def reset_inflation(self, concurrent_ops) -> float:
        """Multiplier on reset latency under concurrent I/O (Obs#13).

        ``concurrent_ops``: iterable of OpType present concurrently.  The
        worst single-op inflation applies (contention is for the same
        internal resource, not additive in op count — Fig. 7 shows similar
        inflation for each op class alone).
        """
        mult = 1.0
        for op in concurrent_ops:
            mult = max(mult, float(
                reset_inflation_factors(self.params, int(OpType(op)))))
        return mult

    # -- derived helpers -------------------------------------------------------
    def qd1_iops(self, op, size_bytes, stack=Stack.SPDK,
                 fmt=LBAFormat.LBA_4K) -> float:
        return 1e6 / float(self.io_service_us(op, size_bytes, stack, fmt))


DEFAULT_LATENCY_MODEL = LatencyModel()


def resolve_params(lat) -> LatencyParams:
    """Normalize ``LatencyModel | LatencyParams | None`` to params."""
    if lat is None:
        return DEFAULT_LATENCY_PARAMS
    if isinstance(lat, LatencyModel):
        return lat.params
    if isinstance(lat, LatencyParams):
        return lat
    raise TypeError(f"expected LatencyModel or LatencyParams, got {type(lat)}")
