"""Discrete-event + steady-state performance engines for the ZNS model.

These engines back the :class:`repro.core.ZnsDevice` session API (the
preferred entry point; ``simulate``/``ThroughputModel`` remain as stable
shims for existing callers).  Three complementary engines, all built on
:mod:`repro.core.latency`:

* :class:`ThroughputModel` — closed-form steady-state throughput/latency
  for a homogeneous workload configuration.  This is what reproduces the
  paper's scalability figures (Fig. 3, Fig. 4, Fig. 8) exactly at the
  calibration anchors: throughput = min(concurrency-limited rate,
  device-parallelism rate, calibrated IOPS cap, bandwidth cap).

* :func:`simulate` — a per-request discrete-event simulation over a
  :class:`Trace`.  Supports closed-loop (fio-style queue-depth) semantics,
  per-zone write serialization, mq-deadline merging, management operations
  with occupancy-dependent costs, and the paper's interference couplings:
  I/O inflates reset latency (Obs#13) while resets never delay I/O
  (Obs#12, enforced structurally via a dedicated metadata pool).

* :func:`simulate_vectorized` — the ``"vectorized"`` ZnsDevice backend:
  lowers the trace (once, content-cached) into a
  :class:`repro.core.ChainProgram` of serialized chains and solves it
  with one fused max-plus fixpoint (:mod:`repro.core.chain_program`),
  10-20x faster than the event loop on 100k+-request traces and exact
  on saturated single-service-class pools (multi-thread append pools).

The per-zone sequential-completion recurrence that dominates large traces
(``c_i = max(c_{i-1}, s_i) + v_i``) is a max-plus linear scan; the TPU
Pallas kernel ``repro.kernels.zns_event_scan`` implements it blocked, and
:func:`zone_sequential_completions` dispatches to it (with a vectorized
float64 numpy doubling scan as the CPU path).
"""
from __future__ import annotations

import dataclasses
import heapq
import sys
from typing import Optional, Tuple

import numpy as np

from . import calibration as C
from .latency import (
    LatencyModel, LatencyParams, close_us as _close_us, finish_us as _finish_us,
    io_service_us as _io_service_us, open_us as _open_us,
    reset_inflation_factors, reset_us as _reset_us, resolve_params,
)
from .spec import KiB, MiB, LBAFormat, OpType, Stack, ZNSDeviceSpec

US = 1.0
MS = 1e3
S = 1e6


# ---------------------------------------------------------------------------
# Steady-state model (Figs. 3, 4, 8)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SteadyStateResult:
    iops: float            # user-visible operations / second
    bandwidth_bytes: float  # bytes / second
    mean_latency_us: float  # per user-visible request (closed loop, Little)
    merge_factor: int      # mq-deadline merges (1 = none)


class ThroughputModel:
    def __init__(self, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
                 lat: Optional[LatencyModel] = None):
        self.spec = spec
        self.lat = lat or LatencyModel(spec)

    def _caps(self, op: OpType, intra_zone: bool, stack: Stack):
        sp = self.spec
        if op == OpType.READ:
            return sp.read_parallelism, C.READ_IOPS_CAP, sp.peak_read_bw_bytes
        if op == OpType.APPEND:
            # Obs#6: append cap agnostic to intra/inter zone.
            return sp.append_parallelism, C.APPEND_IOPS_CAP, sp.peak_write_bw_bytes
        # WRITE
        if intra_zone and stack == Stack.KERNEL_MQ_DEADLINE:
            return sp.write_parallelism, C.WRITE_INTRA_MERGED_IOPS_CAP, sp.peak_write_bw_bytes
        return sp.write_parallelism, C.WRITE_INTER_IOPS_CAP, sp.peak_write_bw_bytes

    def steady_state(self, op: OpType, size_bytes: int, *, qd: int = 1,
                     zones: int = 1, stack: Stack = Stack.SPDK,
                     fmt: LBAFormat = LBAFormat.LBA_4K) -> SteadyStateResult:
        """Throughput/latency of a homogeneous closed-loop workload.

        ``qd`` requests in flight per zone stream, ``zones`` concurrent
        zones.  Intra-zone scalability is (qd>1, zones=1); inter-zone is
        (qd=1, zones>1), exactly as in §III-D.
        """
        op = OpType(op)
        intra = zones == 1 and qd > 1
        if op == OpType.WRITE and qd > 1 and stack != Stack.KERNEL_MQ_DEADLINE:
            raise ValueError(
                "multiple in-flight writes per zone require an I/O scheduler "
                "(mq-deadline); SPDK is limited to one write per zone (§III-A)")
        merge = 1
        dev_size = size_bytes
        dev_qd = qd
        if op == OpType.WRITE and intra and stack == Stack.KERNEL_MQ_DEADLINE:
            # mq-deadline merges sequential same-zone writes (Obs#7).
            merge = int(np.clip(qd // 2, 1, C.MERGE_MAX))
            dev_size = size_bytes * merge
            dev_qd = max(qd // merge, 1)
        svc_sync = float(self.lat.io_service_us(op, dev_size, stack, fmt))
        # At concurrency > 1 the host dispatch overhead overlaps with device
        # service (pipelined submission), so saturation is device-limited;
        # QD=1 latency keeps the full host+device path (Obs#2).
        svc_dev = float(self.lat.io_service_us(op, dev_size, Stack.SPDK, fmt))
        svc = svc_sync if qd * zones == 1 else svc_dev
        concurrency = dev_qd * zones
        # Writes are serialized within a zone: each zone contributes at most
        # one in-flight device write (the scheduler pipelines the next).
        if op == OpType.WRITE:
            concurrency = min(concurrency, zones * max(dev_qd, 1)) if intra else zones
            if intra:
                concurrency = 1  # one (merged) write in flight in the zone
        parallelism, iops_cap, bw_cap = self._caps(op, intra, stack)
        conc_rate = concurrency * S / svc          # concurrency-limited
        par_rate = min(concurrency, parallelism) * S / svc
        dev_iops = min(conc_rate, par_rate, iops_cap / merge, bw_cap / dev_size)
        user_iops = dev_iops * merge
        user_iops = min(user_iops, iops_cap)
        bw = user_iops * size_bytes
        total_inflight = qd * zones
        mean_lat = total_inflight * S / user_iops
        return SteadyStateResult(user_iops, bw, mean_lat, merge)

    def peak_write_bandwidth(self) -> float:
        return self.spec.peak_write_bw_bytes

    # -- interference closure (§III-F) -------------------------------------
    def read_latency_under_write_pressure_us(self, write_utilization: float,
                                             qd: int = 1):
        """Mean + p95 of 4 KiB random-read latency under concurrent writes.

        Calibrated macro-model: at full-rate writes the ZN540's QD1 p95 read
        latency is 98.04 ms (Obs#11) vs 81.41 us idle.  Latency inflation
        scales steeply (cubically) with write-bandwidth utilization — the
        paper reports stability (not degradation) at 25%/75% rate limits.
        """
        u = float(np.clip(write_utilization, 0.0, 1.0))
        idle_mean = float(self.lat.io_service_us(OpType.READ, 4 * KiB))
        sigma = 0.54  # lognormal shape: mean->p95 ratio ~2.43 under pressure
        pressured_mean = 40.3 * MS  # => p95 98.04 ms (Obs#11 anchor)
        mean = idle_mean + (u ** 3) * pressured_mean
        p95_ratio_idle = C.READONLY_READ_P95_US / idle_mean
        p95 = mean * (p95_ratio_idle if u < 0.05 else float(np.exp(1.645 * sigma)))
        return mean * max(qd, 1) ** 0.0, p95  # QD adds throughput, not p95 shift


# ---------------------------------------------------------------------------
# Trace-level discrete-event engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Trace:
    """A request trace (struct-of-arrays).

    ``issue``: earliest issue time (us).  For closed-loop threads the
    effective issue time additionally waits for the completion of the
    request ``qd`` positions earlier on the same thread.

    ``io_ctx``: OpType value of I/O running concurrently with a RESET (used
    for Obs#13 inflation), or -1.  Set by the workload generator, which
    knows the experiment layout (mirrors §III-G's two-thread setup).
    """

    op: np.ndarray           # int32 [N]
    zone: np.ndarray         # int32 [N] (-1 for non-zone ops)
    size: np.ndarray         # int64 [N] bytes (0 for mgmt ops)
    issue: np.ndarray        # float64 [N] us
    thread: np.ndarray       # int32 [N]
    qd: np.ndarray           # int32 [N] per-request thread queue depth
    occupancy: np.ndarray    # float64 [N] zone occupancy for RESET/FINISH
    was_finished: np.ndarray  # bool [N] for RESET discount
    io_ctx: np.ndarray       # int32 [N]
    stack: Stack = Stack.SPDK
    fmt: LBAFormat = LBAFormat.LBA_4K

    def __len__(self) -> int:
        return len(self.op)

    @staticmethod
    def build(op, zone, size, issue, thread=None, qd=None, occupancy=None,
              was_finished=None, io_ctx=None, stack=Stack.SPDK,
              fmt=LBAFormat.LBA_4K) -> "Trace":
        n = len(op)
        z = lambda v, d, t: np.asarray(v, dtype=t) if v is not None else np.full(n, d, dtype=t)
        return Trace(
            op=np.asarray(op, dtype=np.int32),
            zone=z(zone, -1, np.int32),
            size=z(size, 0, np.int64),
            issue=np.asarray(issue, dtype=np.float64),
            thread=z(thread, 0, np.int32),
            qd=z(qd, 1, np.int32),
            occupancy=z(occupancy, 0.0, np.float64),
            was_finished=z(was_finished, False, bool),
            io_ctx=z(io_ctx, -1, np.int32),
            stack=stack, fmt=fmt)


@dataclasses.dataclass
class SimResult:
    start: np.ndarray      # service start (us)
    complete: np.ndarray   # completion (us)
    service: np.ndarray    # service time (us)
    #: Gauss–Seidel sweeps spent by the fixpoint solver (0 for the
    #: event engine, whose heap loop is exact by construction).
    sweeps_used: int = 0
    #: False when the sweep budget ran out while constraints were still
    #: moving — completions are then a documented lower bound (a
    #: RuntimeWarning is emitted at solve time).
    converged: bool = True
    #: Exactness claim of the backend that produced this result versus
    #: the event engine: ``True`` for the event engine itself, the
    #: compiled program's claim for the vectorized backends (``None``
    #: when the backend predates the flag).
    exact: Optional[bool] = None
    #: Whether pop-order refinement reached a fixpoint at compile time
    #: (``None`` when not applicable to the backend).
    order_stable: Optional[bool] = None
    #: ``"dev{i}:{kind}"`` labels of pools whose pop order was still
    #: changing when the compile-time refinement budget ran out.
    unstable_pools: Tuple[str, ...] = ()

    @property
    def in_device_latency(self) -> np.ndarray:
        """Queueing-free service latency (start -> complete)."""
        return self.complete - self.start

    def latency_from(self, issue: np.ndarray) -> np.ndarray:
        """Submission-to-completion latency (§III-B definition)."""
        return self.complete - np.asarray(issue, dtype=np.float64)


_POOL_OF_OP = {
    OpType.READ: 0, OpType.WRITE: 1, OpType.APPEND: 1,  # shared flash pool
    OpType.RESET: 2, OpType.FINISH: 2, OpType.OPEN: 3, OpType.CLOSE: 3,
}


def compute_service_times(trace: Trace, lat=None, *, seed: int = 0,
                          jitter: bool = True) -> np.ndarray:
    """Per-request service times (us) for a trace.

    ``lat`` may be a :class:`LatencyModel` or a bare :class:`LatencyParams`
    pytree.  Shared by every simulation backend so that the event and
    vectorized engines draw *identical* jitter for the same seed: the rng
    stream is consumed in a fixed order (resets, finishes, then I/O).
    Includes Obs#13 reset inflation from ``trace.io_ctx``.
    """
    params = resolve_params(lat)
    rng = np.random.default_rng(seed)
    n = len(trace)
    ops = trace.op
    svc = np.zeros(n, dtype=np.float64)
    io_mask = (ops == OpType.READ) | (ops == OpType.WRITE) | (ops == OpType.APPEND)
    if io_mask.any():
        svc[io_mask] = _io_service_us(
            params, ops[io_mask], trace.size[io_mask], trace.stack, trace.fmt)
    rmask = ops == OpType.RESET
    if rmask.any():
        base = _reset_us(params, trace.occupancy[rmask],
                         trace.was_finished[rmask])
        infl = reset_inflation_factors(params, trace.io_ctx[rmask])
        if jitter:
            sig = float(params.reset_tail_sigma)
            g = rng.standard_normal(rmask.sum())
            base = base * np.exp(sig * g - sig ** 2 / 2)
        svc[rmask] = base * infl
    fmask = ops == OpType.FINISH
    if fmask.any():
        base = _finish_us(params, trace.occupancy[fmask])
        if jitter:
            sig = float(params.reset_tail_sigma)
            g = rng.standard_normal(fmask.sum())
            base = base * np.exp(sig * g - sig ** 2 / 2)
        svc[fmask] = base
    svc[ops == OpType.OPEN] = _open_us(params)
    svc[ops == OpType.CLOSE] = _close_us(params)
    if jitter and io_mask.any():
        sig = params.io_jitter_sigma[
            np.clip(ops[io_mask].astype(np.int64), 0, 2)]
        g = rng.standard_normal(io_mask.sum())
        svc[io_mask] = svc[io_mask] * np.exp(sig * g - sig ** 2 / 2)
    return svc


def simulate(trace: Trace, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
             lat: Optional[LatencyModel] = None, *, seed: int = 0,
             jitter: bool = True) -> SimResult:
    """Simulate a trace; returns per-request start/complete times (us).

    .. deprecated:: prefer :meth:`repro.core.ZnsDevice.run` (the ``"event"``
       backend), which wraps this engine behind the session API.

    Pools: flash data path (reads+writes+appends share
    ``read_parallelism`` servers, with writes additionally respecting
    per-zone serialization and the append pool limit), a dedicated
    metadata pool for RESET/FINISH (structurally enforcing Obs#12), and a
    free pool for OPEN/CLOSE.
    """
    lat = lat or LatencyModel(spec)
    n = len(trace)
    ops = trace.op
    svc = compute_service_times(trace, lat, seed=seed, jitter=jitter)
    # Emulator profiles may route resets through the data path (violating
    # Obs#12 structurally, as NVMeVirt's static NAND erase does).
    meta_on_io_path = bool(resolve_params(lat).reset_on_io_path)

    # Pools.
    flash_free = np.zeros(spec.read_parallelism, dtype=np.float64)
    append_tokens = np.zeros(spec.append_parallelism, dtype=np.float64)
    meta_free = np.zeros(max(spec.reset_parallelism, 1), dtype=np.float64)
    mgmt_free = np.zeros(2, dtype=np.float64)
    zone_ready = np.zeros(spec.num_zones, dtype=np.float64)

    # Closed-loop gating: exact completion history per thread — request at
    # thread position ``pos`` waits for the completion of the request ``qd``
    # positions earlier on the same thread.  Requests are processed in
    # *ready-time* order (a discrete-event heap), so server-pool assignment
    # is causal even when many closed-loop streams share issue times.
    threads = int(trace.thread.max()) + 1 if n else 1
    hist: list[list] = [[] for _ in range(threads)]
    order = np.argsort(trace.issue, kind="stable")
    by_thread: list[list] = [[] for _ in range(threads)]
    for idx in order:
        by_thread[int(trace.thread[idx])].append(int(idx))
    ptr = [0] * threads

    start = np.zeros(n, dtype=np.float64)
    complete = np.zeros(n, dtype=np.float64)

    heap: list = []

    def _push_next(t: int) -> None:
        p = ptr[t]
        if p >= len(by_thread[t]):
            return
        idx = by_thread[t][p]
        q = max(int(trace.qd[idx]), 1)
        gate = hist[t][p - q] if p >= q else 0.0
        ready = max(float(trace.issue[idx]), gate)
        heapq.heappush(heap, (ready, float(trace.issue[idx]), idx, t))

    for t in range(threads):
        _push_next(t)

    while heap:
        ready, _, idx, t = heapq.heappop(heap)
        ptr[t] += 1
        op = OpType(int(ops[idx]))
        z = int(trace.zone[idx])
        if op == OpType.WRITE and z >= 0:
            ready = max(ready, zone_ready[z])   # single in-flight write/zone
        pool = _POOL_OF_OP[op]
        if pool == 2 and meta_on_io_path:
            pool = 0                            # contend with I/O (not Obs#12)
        if pool in (0, 1):  # READ / WRITE / APPEND share the flash pool
            s = int(np.argmin(flash_free))
            begin = max(ready, flash_free[s])
            if op == OpType.APPEND:  # Obs#6: append-specific parallelism
                a = int(np.argmin(append_tokens))
                begin = max(begin, append_tokens[a])
                append_tokens[a] = begin + svc[idx]
            flash_free[s] = begin + svc[idx]
        elif pool == 2:  # RESET / FINISH — dedicated metadata engine
            s = int(np.argmin(meta_free))
            begin = max(ready, meta_free[s])
            meta_free[s] = begin + svc[idx]
        else:            # OPEN / CLOSE
            s = int(np.argmin(mgmt_free))
            begin = max(ready, mgmt_free[s])
            mgmt_free[s] = begin + svc[idx]
        end = begin + svc[idx]
        if op == OpType.WRITE and z >= 0:
            zone_ready[z] = end
        start[idx] = begin
        complete[idx] = end
        hist[t].append(end)
        _push_next(t)

    return SimResult(start=start, complete=complete, service=svc,
                     exact=True, order_stable=True)


def _maxplus_scan_numpy(issue, svc, seg):
    """Segmented max-plus scan, vectorized: O(n log n) doubling passes.

    Same Hillis–Steele composition as the Pallas kernel
    (``repro.kernels.zns_event_scan``) but in float64 numpy: each element
    is the affine max-plus map ``c -> max(c + a, b)`` with ``a = svc``
    (``-inf`` at segment heads, dropping the carry) and ``b = issue + svc``;
    prefix-composition yields ``c_i`` directly since ``c_0 = -inf``.
    Passes stop at the longest head-to-head run — composition never
    crosses a segment head, so larger shifts are no-ops.
    """
    a = np.where(seg, -np.inf, svc)
    b = issue + svc
    n = len(a)
    heads = np.flatnonzero(seg)
    if len(heads):
        bounds = np.concatenate([[0], heads, [n]])
        max_run = int(np.diff(bounds).max())
    else:
        max_run = n
    k = 1
    while k < max_run:
        # compose earlier (shifted) map, then current: (a_s,b_s) . (a,b);
        # b must fold the *current* a before a accumulates the shift.
        np.maximum(b[:-k] + a[k:], b[k:], out=b[k:])
        np.add(a[k:], a[:-k], out=a[k:])
        k *= 2
    return b


def zone_sequential_completions(issue, svc, segment_starts, *, backend="auto"):
    """Per-zone sequential completion times: c_i = max(c_{i-1}, s_i) + v_i.

    ``segment_starts``: bool array marking the first request of each zone
    segment (requests must be grouped by zone).  Backends: ``"pallas"``
    forces the TPU kernel (float32), ``"numpy"`` the vectorized float64
    doubling scan, ``"python"`` the sequential oracle; ``"auto"`` uses the
    Pallas kernel on TPU and the numpy scan elsewhere.
    """
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        try:
            from repro.kernels import ops as kops
            import jax.numpy as jnp
            out = kops.zns_event_scan(
                jnp.asarray(issue, dtype=jnp.float32),
                jnp.asarray(svc, dtype=jnp.float32),
                jnp.asarray(segment_starts, dtype=bool))
            return np.asarray(out, dtype=np.float64)
        except Exception:
            if backend == "pallas":
                raise
    issue = np.asarray(issue, dtype=np.float64)
    svc = np.asarray(svc, dtype=np.float64)
    seg = np.asarray(segment_starts, dtype=bool)
    if backend != "python":
        return _maxplus_scan_numpy(issue, svc, seg)
    out = np.empty_like(issue)
    c = -np.inf
    for i in range(len(issue)):
        if seg[i]:
            c = -np.inf
        c = max(c, issue[i]) + svc[i]
        out[i] = c
    return out


def _maxplus_scan_numpy_batched(issue, svc, seg):
    """Batched segmented max-plus scan over (B, L) arrays.

    Same doubling composition as :func:`_maxplus_scan_numpy` with the
    shifts taken along the trailing axis, so the B rows advance in lock
    step and segments never cross rows (each column-0 element starts with
    an empty carry by construction of ``b``).
    """
    issue = np.asarray(issue, dtype=np.float64)
    svc = np.asarray(svc, dtype=np.float64)
    seg = np.asarray(seg, dtype=bool)
    a = np.where(seg, -np.inf, svc)
    b = issue + svc
    bsz, n = a.shape
    # longest head-to-head run, treating every row start as a head
    heads = seg.copy()
    if n:
        heads[:, 0] = True
    flat = np.flatnonzero(heads.ravel())
    if len(flat):
        bounds = np.concatenate([flat, [bsz * n]])
        max_run = int(np.diff(bounds).max()) if len(bounds) > 1 else bsz * n
        max_run = min(max_run, n)
    else:
        max_run = n
    k = 1
    while k < max_run:
        np.maximum(b[:, :-k] + a[:, k:], b[:, k:], out=b[:, k:])
        np.add(a[:, k:], a[:, :-k], out=a[:, k:])
        k *= 2
    return b


def zone_sequential_completions_batched(issue, svc, segment_starts, *,
                                        backend="auto"):
    """Batched :func:`zone_sequential_completions` over (B, L) arrays.

    Each row is an independent set of serialized segments (rows never
    share a carry).  Backends mirror the 1-D dispatch: ``"pallas"`` forces
    the TPU kernel's batch grid dimension, ``"numpy"`` the batched float64
    doubling scan, ``"python"`` the per-row sequential oracle; ``"auto"``
    uses Pallas on TPU (``jax.vmap``-style batch grid) and numpy elsewhere.
    """
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        try:
            from repro.kernels import ops as kops
            import jax.numpy as jnp
            out = kops.zns_event_scan_batched(
                jnp.asarray(issue, dtype=jnp.float32),
                jnp.asarray(svc, dtype=jnp.float32),
                jnp.asarray(segment_starts, dtype=bool))
            return np.asarray(out, dtype=np.float64)
        except Exception:
            if backend == "pallas":
                raise
    if backend != "python":
        return _maxplus_scan_numpy_batched(issue, svc, segment_starts)
    issue = np.asarray(issue, dtype=np.float64)
    svc = np.asarray(svc, dtype=np.float64)
    seg = np.asarray(segment_starts, dtype=bool)
    return np.stack([zone_sequential_completions(issue[i], svc[i], seg[i],
                                                 backend="python")
                     for i in range(issue.shape[0])])


_ON_TPU: Optional[bool] = None


def _on_tpu() -> bool:
    # Only consult jax once something else has imported it: dragging the
    # whole jax runtime in for a CPU-side numpy scan costs ~1 s.  The
    # answer is only cached after jax is available, so early CPU-path
    # calls don't pin the dispatch before jax initializes.
    global _ON_TPU
    if _ON_TPU is None:
        jax = sys.modules.get("jax")
        if jax is None:
            return False
        try:
            _ON_TPU = jax.default_backend() == "tpu"
        except Exception:
            return False
    return _ON_TPU


# ---------------------------------------------------------------------------
# Vectorized trace engine (the ZnsDevice "vectorized" backend)
# ---------------------------------------------------------------------------
def _cumcount(keys: np.ndarray) -> np.ndarray:
    """Occurrence rank of each element within its key group (stable)."""
    n = len(keys)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    starts = np.r_[True, sk[1:] != sk[:-1]] if n else np.zeros(0, bool)
    group_start = np.maximum.accumulate(np.where(starts, np.arange(n), 0))
    rank = np.arange(n) - group_start
    out = np.empty(n, dtype=np.int64)
    out[order] = rank
    return out


def _chain_perm(member: np.ndarray, chain_id: np.ndarray):
    """(perm, heads) for a chain family: members sorted by (chain, seq)."""
    idx = np.flatnonzero(member)
    if len(idx) == 0:
        return idx, np.zeros(0, dtype=bool)
    order = np.argsort(chain_id[idx], kind="stable")
    perm = idx[order]
    cid = chain_id[perm]
    heads = np.r_[True, cid[1:] != cid[:-1]]
    return perm, heads


#: Gauss–Seidel application order of the chain families; shared by the
#: single-device engine below and the batched DeviceFleet engine
#: (repro.core.fleet), which sweeps the same kinds in the same order so a
#: batched run converges through identical iterates per device.
FAMILY_ORDER = ("thread", "zone_write", "meta", "mgmt", "io_pool",
                "append_pool")


def trace_chain_families(ops, zone, thread, qd, spec: ZNSDeviceSpec, *,
                         meta_on_io_path: bool = False):
    """Chain families of a trace already sorted by issue time.

    Returns ``[(kind, perm, heads)]`` in :data:`FAMILY_ORDER`: ``perm``
    indexes the sorted trace grouping chain members, ``heads`` marks chain
    starts.  Exact chains: per-thread closed-loop lag-qd interleaves (qd
    constant per thread), per-zone write serialization, and the
    single-server metadata engine.  Server pools (flash/append/mgmt) are
    lag-capacity FIFO chains — only added when the workload can actually
    saturate them, and approximate unless the saturating ops have
    near-homogeneous service times.  ``meta_on_io_path`` routes
    RESET/FINISH through the flash pool instead of the metadata engine
    (emulator profiles violating Obs#12).
    """
    n = len(ops)
    io = (ops == OpType.READ) | (ops == OpType.WRITE) | (ops == OpType.APPEND)
    wr = (ops == OpType.WRITE) & (zone >= 0)
    ap = ops == OpType.APPEND
    meta = (ops == OpType.RESET) | (ops == OpType.FINISH)
    mgmt = (ops == OpType.OPEN) | (ops == OpType.CLOSE)
    if meta_on_io_path:
        io = io | meta
        meta = np.zeros(n, dtype=bool)

    def _conc_bound(member: np.ndarray) -> int:
        """Upper bound on concurrent in-flight ops from ``member`` rows:
        sum over threads of the thread's queue depth."""
        t, q = thread[member], qd[member]
        if t.size == 0:
            return 0
        per_thread = np.zeros(int(t.max()) + 1, dtype=np.int64)
        np.maximum.at(per_thread, t, q)
        return int(per_thread.sum())

    tpos = _cumcount(thread)
    families = [("thread", np.ones(n, dtype=bool),
                 thread * (int(qd.max()) + 1) + tpos % qd)]
    if wr.any():
        families.append(("zone_write", wr, zone))
    meta_lag = max(spec.reset_parallelism, 1)
    if meta.any() and (meta_lag == 1 or _conc_bound(meta) > meta_lag):
        families.append(("meta", meta,
                         _cumcount(np.where(meta, 0, -1)) % meta_lag))
    if mgmt.any() and _conc_bound(mgmt) > 2:
        families.append(("mgmt", mgmt, _cumcount(np.where(mgmt, 0, -1)) % 2))
    if io.any() and _conc_bound(io) > spec.read_parallelism:
        families.append(("io_pool", io, _cumcount(np.where(io, 0, -1))
                         % max(spec.read_parallelism, 1)))
    if ap.any() and _conc_bound(ap) > spec.append_parallelism:
        families.append(("append_pool", ap, _cumcount(np.where(ap, 0, -1))
                         % max(spec.append_parallelism, 1)))
    out = []
    for kind, member, chain_id in families:
        perm, heads = _chain_perm(member, chain_id)
        if len(perm):
            out.append((kind, perm, heads))
    return out


def simulate_vectorized(trace: Trace, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
                        lat: Optional[LatencyModel] = None, *, seed: int = 0,
                        jitter: bool = True, sweeps: int = 8,
                        scan_backend: str = "auto", fixpoint: str = "auto",
                        refine: Optional[int] = None,
                        program=None) -> SimResult:
    """Vectorized counterpart of :func:`simulate` for large traces.

    The trace is lowered once into a :class:`repro.core.ChainProgram`
    (cached by content, see :mod:`repro.core.chain_program`): the event
    engine's per-request constraints decompose into serialized *chains*
    — per-zone write chains, the metadata (RESET/FINISH) chain,
    per-thread closed-loop lag-``qd`` chains, and lag-``capacity``
    server-pool chains split per service class and ordered by the event
    heap's pop order.  The compiled program is then solved by one fused
    Gauss–Seidel fixpoint of batched segmented max-plus scans
    (:func:`repro.core.chain_program.solve_program`): the Pallas
    ``zns_fixpoint`` kernel on TPU, the batched float64 numpy doubling
    scan elsewhere.  ``sweeps`` bounds the iteration; exhaustion sets
    ``SimResult.converged = False`` and warns.

    Exact (to float tolerance) versus :func:`simulate` whenever the
    compiled program's pop-order refinement stabilized
    (``ChainProgram.exact`` / ``SimResult.exact`` report the claim) —
    single- and multi-service-class saturated pools alike, the latter
    via the compiler's greedy server-assignment replay.  ``jitter=True``
    compiles jitter-aware (refinement re-sorts and replays against the
    seeded jittered service draw), so jittered saturated pools are
    exact too; only a refinement budget that runs out before the order
    freezes leaves a lower-bound approximation (``order_stable=False``,
    offending pools in ``unstable_pools``).  The event engine is the
    test oracle the claim is verified against
    (``benchmarks/exactness_matrix.py``), never a runtime fallback.

    ``program`` short-circuits compilation with a pre-compiled program
    (must match the trace; the exactness claim only transfers when the
    program was compiled for this ``jitter``/``seed`` binding);
    ``refine`` overrides the pop-order refinement budget
    (:data:`repro.core.chain_program.DEFAULT_REFINE`).
    """
    from . import chain_program as cp
    lat = lat or LatencyModel(spec)
    n = len(trace)
    if n == 0:
        z = np.zeros(0, dtype=np.float64)
        return SimResult(start=z, complete=z.copy(), service=z.copy(),
                         exact=True, order_stable=True)
    if program is None:
        program = cp.compile_program(
            trace, spec, lat,
            refine=cp.DEFAULT_REFINE if refine is None else refine,
            jitter=jitter, seed=seed)
    if jitter:
        svc_orig = compute_service_times(trace, lat, seed=seed, jitter=True)
        svc_flat = svc_orig[program.orders[0]]
    else:
        # jitter-free service times are part of the lowering output
        svc_flat = program.svc0_flat
        svc_orig = svc_flat[program.invs[0]]
    comp, used, converged = cp.solve_program(
        program, svc_flat, sweeps=sweeps, scan_backend=scan_backend,
        fixpoint=fixpoint)
    res = cp.unpack_results(program, comp, svc_flat, [svc_orig])[0]
    # the compile-time exactness claim binds to the service vector the
    # refinement ran against; solving any other draw voids it
    seeds_bind = (int(seed),) if jitter else None
    claimed = bool(program.exact) and program.svc_seeds == seeds_bind
    return dataclasses.replace(res, sweeps_used=used, converged=converged,
                               exact=claimed,
                               order_stable=bool(program.order_stable),
                               unstable_pools=tuple(program.unstable_pools))


def _simulate_vectorized_unfused(trace: Trace,
                                 spec: ZNSDeviceSpec = ZNSDeviceSpec(),
                                 lat: Optional[LatencyModel] = None, *,
                                 seed: int = 0, jitter: bool = True,
                                 sweeps: int = 8,
                                 scan_backend: str = "auto") -> SimResult:
    """Pre-compiler reference: the per-chain Python sweep loop.

    Kept as the baseline of ``benchmarks/chain_program.py`` (the fused
    :class:`repro.core.ChainProgram` path must beat this) and as an
    issue-ordered regression oracle.  Pool chains are issue-ordered
    here, so saturated multi-thread pools are approximate — exactly the
    gap the compiler closes.
    """
    lat = lat or LatencyModel(spec)
    n = len(trace)
    svc_orig = compute_service_times(trace, lat, seed=seed, jitter=jitter)
    if n == 0:
        z = np.zeros(0, dtype=np.float64)
        return SimResult(start=z, complete=z.copy(), service=svc_orig)

    # Work in event-processing order (stable sort by issue time).
    order = np.argsort(trace.issue, kind="stable")
    inv = np.empty(n, dtype=np.int64)
    inv[order] = np.arange(n)
    ops = trace.op[order]
    zone = trace.zone[order].astype(np.int64)
    thread = trace.thread[order].astype(np.int64)
    qd = np.maximum(trace.qd[order].astype(np.int64), 1)
    issue = trace.issue[order]
    svc = svc_orig[order]

    # Chain families (see trace_chain_families): exact serialized chains +
    # issue-ordered lag-capacity FIFO pool chains.
    chains = [(perm, heads, svc[perm])
              for _, perm, heads in trace_chain_families(
                  ops, zone, thread, qd, spec,
                  meta_on_io_path=bool(resolve_params(lat).reset_on_io_path))]

    comp = issue + svc       # lower bound: no queueing at all
    used, converged = 0, True
    for s in range(max(sweeps, 1)):
        moved = False
        for perm, heads, svc_p in chains:
            # Current begin estimates fold the issue times and every gate
            # applied so far; the scan serializes the chain on top.
            cur = comp[perm]
            out = zone_sequential_completions(cur - svc_p, svc_p, heads,
                                              backend=scan_backend)
            # Anything beyond float noise counts as progress
            # (re-deriving begin = comp - svc costs ~1 ulp per sweep).
            if (out > cur * (1.0 + 1e-12) + 1e-9).any():
                moved = True
                comp[perm] = np.maximum(cur, out)
        used = s + 1
        if not moved:
            converged = True
            break
        converged = False

    start = comp - svc
    return SimResult(start=start[inv].copy(), complete=comp[inv].copy(),
                     service=svc_orig, sweeps_used=used, converged=converged)
