"""Discrete-event + steady-state performance engines for the ZNS model.

Two complementary engines, both built on :mod:`repro.core.latency`:

* :class:`ThroughputModel` — closed-form steady-state throughput/latency
  for a homogeneous workload configuration.  This is what reproduces the
  paper's scalability figures (Fig. 3, Fig. 4, Fig. 8) exactly at the
  calibration anchors: throughput = min(concurrency-limited rate,
  device-parallelism rate, calibrated IOPS cap, bandwidth cap).

* :func:`simulate` — a per-request discrete-event simulation over a
  :class:`Trace`.  Supports closed-loop (fio-style queue-depth) semantics,
  per-zone write serialization, mq-deadline merging, management operations
  with occupancy-dependent costs, and the paper's interference couplings:
  I/O inflates reset latency (Obs#13) while resets never delay I/O
  (Obs#12, enforced structurally via a dedicated metadata pool).

The per-zone sequential-completion recurrence that dominates large traces
(``c_i = max(c_{i-1}, s_i) + v_i``) is a max-plus linear scan; the TPU
Pallas kernel ``repro.kernels.zns_event_scan`` implements it blocked, and
:func:`zone_sequential_completions` dispatches to it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import calibration as C
from .latency import LatencyModel
from .spec import KiB, MiB, LBAFormat, OpType, Stack, ZNSDeviceSpec

US = 1.0
MS = 1e3
S = 1e6


# ---------------------------------------------------------------------------
# Steady-state model (Figs. 3, 4, 8)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SteadyStateResult:
    iops: float            # user-visible operations / second
    bandwidth_bytes: float  # bytes / second
    mean_latency_us: float  # per user-visible request (closed loop, Little)
    merge_factor: int      # mq-deadline merges (1 = none)


class ThroughputModel:
    def __init__(self, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
                 lat: Optional[LatencyModel] = None):
        self.spec = spec
        self.lat = lat or LatencyModel(spec)

    def _caps(self, op: OpType, intra_zone: bool, stack: Stack):
        sp = self.spec
        if op == OpType.READ:
            return sp.read_parallelism, C.READ_IOPS_CAP, sp.peak_read_bw_bytes
        if op == OpType.APPEND:
            # Obs#6: append cap agnostic to intra/inter zone.
            return sp.append_parallelism, C.APPEND_IOPS_CAP, sp.peak_write_bw_bytes
        # WRITE
        if intra_zone and stack == Stack.KERNEL_MQ_DEADLINE:
            return sp.write_parallelism, C.WRITE_INTRA_MERGED_IOPS_CAP, sp.peak_write_bw_bytes
        return sp.write_parallelism, C.WRITE_INTER_IOPS_CAP, sp.peak_write_bw_bytes

    def steady_state(self, op: OpType, size_bytes: int, *, qd: int = 1,
                     zones: int = 1, stack: Stack = Stack.SPDK,
                     fmt: LBAFormat = LBAFormat.LBA_4K) -> SteadyStateResult:
        """Throughput/latency of a homogeneous closed-loop workload.

        ``qd`` requests in flight per zone stream, ``zones`` concurrent
        zones.  Intra-zone scalability is (qd>1, zones=1); inter-zone is
        (qd=1, zones>1), exactly as in §III-D.
        """
        op = OpType(op)
        intra = zones == 1 and qd > 1
        if op == OpType.WRITE and qd > 1 and stack != Stack.KERNEL_MQ_DEADLINE:
            raise ValueError(
                "multiple in-flight writes per zone require an I/O scheduler "
                "(mq-deadline); SPDK is limited to one write per zone (§III-A)")
        merge = 1
        dev_size = size_bytes
        dev_qd = qd
        if op == OpType.WRITE and intra and stack == Stack.KERNEL_MQ_DEADLINE:
            # mq-deadline merges sequential same-zone writes (Obs#7).
            merge = int(np.clip(qd // 2, 1, C.MERGE_MAX))
            dev_size = size_bytes * merge
            dev_qd = max(qd // merge, 1)
        svc_sync = float(self.lat.io_service_us(op, dev_size, stack, fmt))
        # At concurrency > 1 the host dispatch overhead overlaps with device
        # service (pipelined submission), so saturation is device-limited;
        # QD=1 latency keeps the full host+device path (Obs#2).
        svc_dev = float(self.lat.io_service_us(op, dev_size, Stack.SPDK, fmt))
        svc = svc_sync if qd * zones == 1 else svc_dev
        concurrency = dev_qd * zones
        # Writes are serialized within a zone: each zone contributes at most
        # one in-flight device write (the scheduler pipelines the next).
        if op == OpType.WRITE:
            concurrency = min(concurrency, zones * max(dev_qd, 1)) if intra else zones
            if intra:
                concurrency = 1  # one (merged) write in flight in the zone
        parallelism, iops_cap, bw_cap = self._caps(op, intra, stack)
        conc_rate = concurrency * S / svc          # concurrency-limited
        par_rate = min(concurrency, parallelism) * S / svc
        dev_iops = min(conc_rate, par_rate, iops_cap / merge, bw_cap / dev_size)
        user_iops = dev_iops * merge
        user_iops = min(user_iops, iops_cap)
        bw = user_iops * size_bytes
        total_inflight = qd * zones
        mean_lat = total_inflight * S / user_iops
        return SteadyStateResult(user_iops, bw, mean_lat, merge)

    def peak_write_bandwidth(self) -> float:
        return self.spec.peak_write_bw_bytes

    # -- interference closure (§III-F) -------------------------------------
    def read_latency_under_write_pressure_us(self, write_utilization: float,
                                             qd: int = 1):
        """Mean + p95 of 4 KiB random-read latency under concurrent writes.

        Calibrated macro-model: at full-rate writes the ZN540's QD1 p95 read
        latency is 98.04 ms (Obs#11) vs 81.41 us idle.  Latency inflation
        scales steeply (cubically) with write-bandwidth utilization — the
        paper reports stability (not degradation) at 25%/75% rate limits.
        """
        u = float(np.clip(write_utilization, 0.0, 1.0))
        idle_mean = float(self.lat.io_service_us(OpType.READ, 4 * KiB))
        sigma = 0.54  # lognormal shape: mean->p95 ratio ~2.43 under pressure
        pressured_mean = 40.3 * MS  # => p95 98.04 ms (Obs#11 anchor)
        mean = idle_mean + (u ** 3) * pressured_mean
        p95_ratio_idle = C.READONLY_READ_P95_US / idle_mean
        p95 = mean * (p95_ratio_idle if u < 0.05 else float(np.exp(1.645 * sigma)))
        return mean * max(qd, 1) ** 0.0, p95  # QD adds throughput, not p95 shift


# ---------------------------------------------------------------------------
# Trace-level discrete-event engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Trace:
    """A request trace (struct-of-arrays).

    ``issue``: earliest issue time (us).  For closed-loop threads the
    effective issue time additionally waits for the completion of the
    request ``qd`` positions earlier on the same thread.

    ``io_ctx``: OpType value of I/O running concurrently with a RESET (used
    for Obs#13 inflation), or -1.  Set by the workload generator, which
    knows the experiment layout (mirrors §III-G's two-thread setup).
    """

    op: np.ndarray           # int32 [N]
    zone: np.ndarray         # int32 [N] (-1 for non-zone ops)
    size: np.ndarray         # int64 [N] bytes (0 for mgmt ops)
    issue: np.ndarray        # float64 [N] us
    thread: np.ndarray       # int32 [N]
    qd: np.ndarray           # int32 [N] per-request thread queue depth
    occupancy: np.ndarray    # float64 [N] zone occupancy for RESET/FINISH
    was_finished: np.ndarray  # bool [N] for RESET discount
    io_ctx: np.ndarray       # int32 [N]
    stack: Stack = Stack.SPDK
    fmt: LBAFormat = LBAFormat.LBA_4K

    def __len__(self) -> int:
        return len(self.op)

    @staticmethod
    def build(op, zone, size, issue, thread=None, qd=None, occupancy=None,
              was_finished=None, io_ctx=None, stack=Stack.SPDK,
              fmt=LBAFormat.LBA_4K) -> "Trace":
        n = len(op)
        z = lambda v, d, t: np.asarray(v, dtype=t) if v is not None else np.full(n, d, dtype=t)
        return Trace(
            op=np.asarray(op, dtype=np.int32),
            zone=z(zone, -1, np.int32),
            size=z(size, 0, np.int64),
            issue=np.asarray(issue, dtype=np.float64),
            thread=z(thread, 0, np.int32),
            qd=z(qd, 1, np.int32),
            occupancy=z(occupancy, 0.0, np.float64),
            was_finished=z(was_finished, False, bool),
            io_ctx=z(io_ctx, -1, np.int32),
            stack=stack, fmt=fmt)


@dataclasses.dataclass
class SimResult:
    start: np.ndarray      # service start (us)
    complete: np.ndarray   # completion (us)
    service: np.ndarray    # service time (us)

    @property
    def in_device_latency(self) -> np.ndarray:
        """Queueing-free service latency (start -> complete)."""
        return self.complete - self.start

    def latency_from(self, issue: np.ndarray) -> np.ndarray:
        """Submission-to-completion latency (§III-B definition)."""
        return self.complete - np.asarray(issue, dtype=np.float64)


_POOL_OF_OP = {
    OpType.READ: 0, OpType.WRITE: 1, OpType.APPEND: 1,  # shared flash pool
    OpType.RESET: 2, OpType.FINISH: 2, OpType.OPEN: 3, OpType.CLOSE: 3,
}


def simulate(trace: Trace, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
             lat: Optional[LatencyModel] = None, *, seed: int = 0,
             jitter: bool = True) -> SimResult:
    """Simulate a trace; returns per-request start/complete times (us).

    Pools: flash data path (reads+writes+appends share
    ``read_parallelism`` servers, with writes additionally respecting
    per-zone serialization and the append pool limit), a dedicated
    metadata pool for RESET/FINISH (structurally enforcing Obs#12), and a
    free pool for OPEN/CLOSE.
    """
    lat = lat or LatencyModel(spec)
    rng = np.random.default_rng(seed)
    n = len(trace)
    ops = trace.op
    # Precompute base service times.
    svc = np.zeros(n, dtype=np.float64)
    io_mask = (ops == OpType.READ) | (ops == OpType.WRITE) | (ops == OpType.APPEND)
    if io_mask.any():
        svc[io_mask] = lat.io_service_us(
            ops[io_mask], trace.size[io_mask], trace.stack, trace.fmt)
    rmask = ops == OpType.RESET
    if rmask.any():
        base = lat.reset_us(trace.occupancy[rmask], trace.was_finished[rmask])
        infl = np.ones(rmask.sum())
        for i, ctx in enumerate(trace.io_ctx[rmask]):
            if ctx >= 0:
                infl[i] = C.RESET_INFLATION.get(OpType(int(ctx)), 1.0)
        if jitter:
            g = rng.standard_normal(rmask.sum())
            base = base * np.exp(C.RESET_TAIL_SIGMA * g - C.RESET_TAIL_SIGMA ** 2 / 2)
        svc[rmask] = base * infl
    fmask = ops == OpType.FINISH
    if fmask.any():
        base = lat.finish_us(trace.occupancy[fmask])
        if jitter:
            g = rng.standard_normal(fmask.sum())
            base = base * np.exp(C.RESET_TAIL_SIGMA * g - C.RESET_TAIL_SIGMA ** 2 / 2)
        svc[fmask] = base
    svc[ops == OpType.OPEN] = lat.open_us()
    svc[ops == OpType.CLOSE] = lat.close_us()
    if jitter and io_mask.any():
        sig = np.where(ops[io_mask] == OpType.READ, 0.15, 0.05)
        g = rng.standard_normal(io_mask.sum())
        svc[io_mask] = svc[io_mask] * np.exp(sig * g - sig ** 2 / 2)

    # Pools.
    flash_free = np.zeros(spec.read_parallelism, dtype=np.float64)
    append_tokens = np.zeros(spec.append_parallelism, dtype=np.float64)
    meta_free = np.zeros(max(spec.reset_parallelism, 1), dtype=np.float64)
    mgmt_free = np.zeros(2, dtype=np.float64)
    zone_ready = np.zeros(spec.num_zones, dtype=np.float64)

    # Closed-loop rings: completion history per thread.
    threads = int(trace.thread.max()) + 1 if n else 1
    maxqd = int(trace.qd.max()) if n else 1
    ring = np.zeros((threads, max(maxqd, 1)), dtype=np.float64)
    ring_pos = np.zeros(threads, dtype=np.int64)

    start = np.zeros(n, dtype=np.float64)
    complete = np.zeros(n, dtype=np.float64)

    order = np.argsort(trace.issue, kind="stable")
    for idx in order:
        op = OpType(int(ops[idx]))
        t = int(trace.thread[idx])
        q = max(int(trace.qd[idx]), 1)
        pos = ring_pos[t]
        gate = ring[t, int(pos % q)] if pos >= q else 0.0
        ready = max(float(trace.issue[idx]), gate)
        z = int(trace.zone[idx])
        if op == OpType.WRITE and z >= 0:
            ready = max(ready, zone_ready[z])   # single in-flight write/zone
        pool = _POOL_OF_OP[op]
        if pool in (0, 1):  # READ / WRITE / APPEND share the flash pool
            s = int(np.argmin(flash_free))
            begin = max(ready, flash_free[s])
            if op == OpType.APPEND:  # Obs#6: append-specific parallelism
                a = int(np.argmin(append_tokens))
                begin = max(begin, append_tokens[a])
                append_tokens[a] = begin + svc[idx]
            flash_free[s] = begin + svc[idx]
        elif pool == 2:  # RESET / FINISH — dedicated metadata engine
            s = int(np.argmin(meta_free))
            begin = max(ready, meta_free[s])
            meta_free[s] = begin + svc[idx]
        else:            # OPEN / CLOSE
            s = int(np.argmin(mgmt_free))
            begin = max(ready, mgmt_free[s])
            mgmt_free[s] = begin + svc[idx]
        end = begin + svc[idx]
        if op == OpType.WRITE and z >= 0:
            zone_ready[z] = end
        start[idx] = begin
        complete[idx] = end
        ring[t, int(pos % ring.shape[1])] = end
        ring_pos[t] = pos + 1

    return SimResult(start=start, complete=complete, service=svc)


def zone_sequential_completions(issue, svc, segment_starts, *, backend="auto"):
    """Per-zone sequential completion times: c_i = max(c_{i-1}, s_i) + v_i.

    ``segment_starts``: bool array marking the first request of each zone
    segment (requests must be grouped by zone).  Dispatches to the Pallas
    max-plus scan kernel when available; falls back to the numpy oracle.
    """
    if backend in ("auto", "pallas"):
        try:
            from repro.kernels import ops as kops
            import jax.numpy as jnp
            out = kops.zns_event_scan(
                jnp.asarray(issue, dtype=jnp.float32),
                jnp.asarray(svc, dtype=jnp.float32),
                jnp.asarray(segment_starts, dtype=bool))
            return np.asarray(out, dtype=np.float64)
        except Exception:
            if backend == "pallas":
                raise
    issue = np.asarray(issue, dtype=np.float64)
    svc = np.asarray(svc, dtype=np.float64)
    seg = np.asarray(segment_starts, dtype=bool)
    out = np.empty_like(issue)
    c = -np.inf
    for i in range(len(issue)):
        if seg[i]:
            c = -np.inf
        c = max(c, issue[i]) + svc[i]
        out[i] = c
    return out
