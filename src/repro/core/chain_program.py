"""Trace-compilation layer: ``Trace + spec + params -> ChainProgram``.

The vectorized backend decomposes a trace into serialized *chain
families* (per-thread closed-loop lag-qd chains, per-zone write chains,
the metadata engine, lag-capacity server-pool chains) and solves the
coupled system by Gauss-Seidel sweeps of segmented max-plus scans.
Before this module, that decomposition was re-derived on every call and
the sweeps ran as a Python loop of per-chain scans; worse, server-pool
chains were ordered by *issue* time, which breaks down exactly on the
paper's key workloads -- saturated multi-thread append pools (Obs#5-#7)
interleave threads in *readiness* order, so the issue-ordered FIFO
approximation serialized whole threads back to back.

A :class:`ChainProgram` is the compiled artifact:

* **event-order transform** per device (stable sort by issue time) and
  the inverse permutation back to trace order;
* **family blocks**: padded, length-bucketed ``(R, L)`` gather-index +
  segment-head tensors addressing one flat fleet-wide completion
  vector, so every Gauss-Seidel step is one vectorized gather ->
  batched max-plus scan -> scatter-max per family (no per-device Python
  loops);
* **pop-order pool chains**: server-pool families are ordered by the
  event engine's *processing* order -- ``ready = max(issue, completion
  of the request qd earlier on the same thread)``, the key the event
  heap pops by (zone/pool constraints apply after the pop, so they
  never affect the order).  The order is found by *refinement*: solve
  the fixpoint with the pool families removed (optimistic readiness),
  sort, rebuild, re-solve from below, and freeze once the order stops
  changing.  Single-service-class pools keep the vectorized FIFO
  lag-``capacity`` chains (round-robin in pop order IS the greedy
  assignment when services are homogeneous).  Pools whose saturating
  traffic mixes service classes -- and every saturated pool of a
  jitter-aware compile (``jitter=True``: refinement re-sorts against
  the *sampled* service vector) -- instead replay the event engine's
  greedy heterogeneous server assignment per pop: one free-time heap
  per pool reproduces ``argmin(free)`` exactly (server choice depends
  only on the free-time *multiset*), emitting one exact per-server
  coupling chain per slot plus pop-ordered per-zone write chains.
  Both forms reproduce the event engine to float tolerance once the
  pop order stabilizes; only budget exhaustion
  (``order_stable=False``, with the offending pools listed in
  ``unstable_pools``) leaves a documented lower-bound approximation.

Programs are cached in a module-level LRU keyed by ``(trace digest,
spec, params, refine, jitter, seeds)`` so experiment sweeps and the
host layer's ``compare_policies()`` stop re-lowering identical traces.

:func:`solve_program` runs the fused fixpoint: the numpy driver
iterates family blocks with the batched float64 doubling scan
(:func:`repro.core.engine.zone_sequential_completions_batched`); the
``"xla"``/``"pallas"`` drivers hand the whole program to
``repro.kernels.zns_fixpoint`` -- a jitted ``lax.while_loop`` (or the
Pallas TPU kernel) iterating all sweeps x families in-kernel with an
early-exit ``moved`` reduction.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import os
import pickle
import sys
import tempfile
import time
import warnings
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import (
    Trace, compute_service_times, trace_chain_families,
    zone_sequential_completions_batched, _on_tpu,
)
from .fleet import length_buckets
from .latency import resolve_params
from .spec import OpType, ZNSDeviceSpec

#: Default pop-order refinement budget.  The greedy replay derives each
#: pool's pop order dynamically, so any budget >= 1 freezes after one
#: rebuild; ``refine=0`` disables refinement entirely (issue-ordered
#: base pool chains, a warned, documented lower bound).
DEFAULT_REFINE = 4

#: Server-pool family kinds whose chains are re-ordered by readiness
#: when refinement triggers (the event engine pops all of them from one
#: ready-time heap).
REORDERED_KINDS = ("meta", "mgmt", "io_pool", "append_pool")

#: Family kinds whose *presence* triggers refinement: the saturated
#: server pools where issue order visibly diverges from pop order.
#: meta/mgmt-only traces keep their issue-ordered chains (paced
#: management sweeps issue in pop order already).
REFINE_TRIGGER_KINDS = ("io_pool", "append_pool")


def _pool_capacity(kind: str, spec: ZNSDeviceSpec) -> int:
    if kind == "meta":
        return max(spec.reset_parallelism, 1)
    if kind == "mgmt":
        return 2
    if kind == "io_pool":
        return max(spec.read_parallelism, 1)
    if kind == "append_pool":
        return max(spec.append_parallelism, 1)
    raise KeyError(kind)


# ---------------------------------------------------------------------------
# Program representation
# ---------------------------------------------------------------------------
#: Chain buckets with at least this many chains use the transposed
#: ``"cols"`` layout (position loop, vectorized across chains); smaller
#: buckets fall back to the ``"rows"`` doubling-scan layout whose cost
#: does not scale with chain count.
POSLOOP_MIN_CHAINS = 8

#: Layout cost cutover: the position loop does O(n) work but pays a
#: per-position dispatch overhead, the doubling scan does O(n log L)
#: bandwidth-bound work.  ``cols`` wins when R * log2(L) clears this
#: (both sides divided by L): ~2.6 us dispatch / (16 B / ~5 GB/s).
POSLOOP_COST_CUTOVER = 512.0

#: Max/min chain-length ratio within one padded bucket (tighter than the
#: fleet row bucketing: padded cells cost position-loop iterations).
CHAIN_BUCKET_RATIO = 2.0


@dataclasses.dataclass(frozen=True)
class FamilyBlock:
    """One length bucket of one chain family, fleet-wide.

    One *chain* per lane.  ``layout="cols"`` stores ``(L, R)`` matrices
    — lane ``r`` is column ``r`` — solved by a position loop that is
    sequential along the chain but vectorized across all R chains (the
    exact event-engine recurrence, O(n) work, contiguous row
    operations).  ``layout="rows"`` stores ``(R, L)`` matrices solved
    by the batched doubling scan (O(n log n) but independent of R; used
    for skinny buckets where the position loop would be overhead-bound,
    and by the jax/Pallas fixpoint kernels).

    ``gidx`` indexes the flat event-order completion vector (padding
    points at the dead slot ``n_flat``); ``heads`` marks chain starts
    (position 0 of every lane, plus all padding).
    """

    label: str            # e.g. "io_pool", "append_pool/cls0", "meta"
    gidx: np.ndarray      # int64; (R, L) for rows, (L, R) for cols
    heads: np.ndarray     # bool, same shape
    layout: str = "rows"  # "rows" | "cols"

    @property
    def shape(self) -> Tuple[int, int]:
        return self.gidx.shape

    def rows_view(self) -> Tuple[np.ndarray, np.ndarray]:
        """(gidx, heads) in rows layout regardless of storage."""
        if self.layout == "rows":
            return self.gidx, self.heads
        return np.ascontiguousarray(self.gidx.T), \
            np.ascontiguousarray(self.heads.T)

    def nbytes(self) -> int:
        return self.gidx.nbytes + self.heads.nbytes


@dataclasses.dataclass(frozen=True)
class ChainProgram:
    """A compiled multi-device trace: one fused fixpoint per fleet call.

    Solve with :func:`solve_program` after binding per-request service
    times (event order, concatenated across devices).  ``exact`` is the
    compiler's exactness claim versus the event engine for the service
    vector the program was compiled against: jitter-free services by
    default, or the seeded jittered draw when compiled with
    ``jitter=True`` (``svc_seeds`` records which).  The claim holds for
    single- AND multi-service-class pools — heterogeneous pools replay
    the event engine's greedy ``argmin(free)`` server assignment into
    per-server coupling chains — so the event engine is a test oracle,
    never a fallback.  ``exact`` is ``False`` only when pop-order
    refinement exhausted its budget before stabilizing
    (``order_stable=False``; the offending pools are listed in
    ``unstable_pools``), in which case completions remain a convergent
    lower bound.  Solving an ``exact`` program against any *other*
    service vector (e.g. a jittered draw on a jitter-free compile)
    voids the claim: the frozen pop order no longer matches the event
    heap's.
    """

    n_flat: int
    offsets: Tuple[int, ...]            # per-device starts into flat arrays
    orders: Tuple[np.ndarray, ...]      # per-device trace->event order perm
    invs: Tuple[np.ndarray, ...]        # per-device event->trace order perm
    issue_flat: np.ndarray              # (n_flat,) event-order issue times
    #: Jitter-free service times (event order, flat) — part of the
    #: lowering output, so ``jitter=False`` solves bind it directly
    #: instead of recomputing service times per call.
    svc0_flat: np.ndarray
    families: Tuple[FamilyBlock, ...]   # application order
    exact: bool
    multiclass_pools: Tuple[str, ...]   # pool kinds mixing service classes
    refine_used: int                    # refinement solves spent
    order_stable: bool                  # pop orders reached a fixpoint
    #: ``"dev{i}:{kind}"`` labels of the pools whose pop order was still
    #: changing when the refinement budget ran out (empty when
    #: ``order_stable``).
    unstable_pools: Tuple[str, ...] = ()
    #: Per-device seeds of the jittered service draw the refinement ran
    #: against, or ``None`` for a jitter-free compile.  The exactness
    #: claim is relative to exactly this service vector.
    svc_seeds: Optional[Tuple[int, ...]] = None

    @property
    def n_devices(self) -> int:
        return len(self.orders)

    def device_slice(self, d: int) -> slice:
        return slice(self.offsets[d],
                     self.offsets[d] + len(self.orders[d]))

    def nbytes(self) -> int:
        own = self.issue_flat.nbytes + sum(o.nbytes for o in self.orders) \
            + sum(i.nbytes for i in self.invs)
        return own + sum(f.nbytes() for f in self.families)

    def __repr__(self) -> str:
        return (f"ChainProgram(devices={self.n_devices}, n={self.n_flat}, "
                f"families={len(self.families)}, exact={self.exact})")


# ---------------------------------------------------------------------------
# Compile cache
# ---------------------------------------------------------------------------
_PROGRAM_CACHE: "OrderedDict[tuple, ChainProgram]" = OrderedDict()
_PROGRAM_CACHE_MAX = 8
_CACHE_STATS = {"hits": 0, "misses": 0, "disk_hits": 0}

#: Identity fast path: recent ``(traces, specs, params, refine) ->
#: program`` bindings keyed by trace object identity, so hot loops that
#: re-run the *same* trace objects (experiment sweeps, benchmarks, the
#: host layer's compare_policies) skip even the content digest.  Strong
#: refs to the traces are kept so ids cannot be recycled.
_IDENTITY_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_IDENTITY_CACHE_MAX = 4


def _trace_digest(trace: Trace) -> bytes:
    """Content digest of a trace, computed once per trace *object*.

    The digest is memoized on the trace itself (traces are structurally
    immutable once built), so refinement rebuilds, repeated fleet
    compiles, and the on-disk program cache all hash each trace exactly
    once instead of once per lookup.
    """
    cached = getattr(trace, "_digest_memo", None)
    if cached is not None:
        return cached
    h = hashlib.sha1()
    for f in ("op", "zone", "size", "issue", "thread", "qd", "occupancy",
              "was_finished", "io_ctx"):
        a = np.ascontiguousarray(getattr(trace, f))
        h.update(a.tobytes())
    h.update(bytes([int(trace.stack), int(trace.fmt)]))
    d = h.digest()
    try:
        trace._digest_memo = d
    except Exception:        # frozen/slotted trace subclass: skip memo
        pass
    return d


@dataclasses.dataclass(frozen=True)
class CompileStats:
    """Cost attribution of the most recent fleet compile.

    ``hits``/``misses`` count in-memory program-cache lookups (LRU +
    identity fast path) since the cache was last cleared; ``disk_hits``
    counts programs loaded from the persistent on-disk cache;
    ``lowering_ms`` is the wall-clock the last
    :func:`compile_fleet_program` call spent lowering (0.0 on any cache
    hit).  ``n_devices``/``n_unique`` expose the replica dedup: only
    ``n_unique`` of the ``n_devices`` member traces were lowered.
    """

    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    lowering_ms: float = 0.0
    n_devices: int = 0
    n_unique: int = 0

    def to_json(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


_LAST_STATS = CompileStats()

#: Persistent program cache directory (``None`` disables).  Seeded from
#: the ``REPRO_PROGRAM_CACHE_DIR`` environment variable; override with
#: :func:`set_program_cache_dir`.
_DISK_CACHE_DIR: Optional[str] = os.environ.get(
    "REPRO_PROGRAM_CACHE_DIR") or None

#: Bump when the ChainProgram layout or lowering semantics change: the
#: on-disk key includes it, so stale pickles are never deserialized.
#: v2: exact multi-class/jitter-aware pool replay (``unstable_pools`` /
#: ``svc_seeds`` fields; key gained the jitter/seeds components).
_DISK_CACHE_VERSION = 3


def last_compile_stats() -> CompileStats:
    """Stats of the most recent :func:`compile_fleet_program` call."""
    return _LAST_STATS


@dataclasses.dataclass(frozen=True)
class SolveStats:
    """Telemetry of the most recent :func:`solve_program` call.

    ``active_blocks[s]`` counts the family blocks the active-set
    Gauss–Seidel driver actually gathered/scanned during sweep ``s``
    (converged blocks whose inputs did not change are dropped from the
    sweep entirely); ``residuals[s]`` is the largest completion-time
    increase any event saw during that sweep (``0.0`` on a pure
    verification sweep).  Kernel and sharded drivers report the sweep
    count and leave the per-sweep trajectories empty.
    """

    driver: str = "loop"
    sweeps: int = 0
    converged: bool = True
    n_blocks: int = 0
    active_blocks: Tuple[int, ...] = ()
    residuals: Tuple[float, ...] = ()

    def to_json(self) -> Dict[str, object]:
        return {"driver": self.driver, "sweeps": self.sweeps,
                "converged": self.converged, "n_blocks": self.n_blocks,
                "active_blocks": list(self.active_blocks),
                "residuals": list(self.residuals)}


_LAST_SOLVE_STATS = SolveStats()


def last_solve_stats() -> SolveStats:
    """Stats of the most recent :func:`solve_program` call."""
    return _LAST_SOLVE_STATS


def set_program_cache_dir(path: Optional[str]) -> Optional[str]:
    """Set (or with ``None`` disable) the persistent program cache.

    Compiled :class:`ChainProgram` artifacts are pickled under
    ``path`` keyed by (trace content digests, device specs, latency
    params, refine budget), so repeated experiment and capacity sweeps
    across *processes* skip lowering entirely.  Returns the previous
    directory.  The directory is created on first write.  Only point
    this at a directory you trust: loading uses ``pickle``.
    """
    global _DISK_CACHE_DIR
    prev = _DISK_CACHE_DIR
    _DISK_CACHE_DIR = str(path) if path else None
    return prev


def program_cache_dir() -> Optional[str]:
    return _DISK_CACHE_DIR


def _disk_cache_path(key) -> Optional[str]:
    if _DISK_CACHE_DIR is None:
        return None
    digests, specs, params, refine, skey = key
    h = hashlib.sha1()
    h.update(repr(_DISK_CACHE_VERSION).encode())
    for d in digests:
        h.update(d)
    h.update(repr(specs).encode())
    h.update(repr(params).encode())
    h.update(repr(int(refine)).encode())
    h.update(repr(skey).encode())
    return os.path.join(_DISK_CACHE_DIR, f"program-{h.hexdigest()}.pkl")


def _disk_cache_get(key) -> Optional[ChainProgram]:
    path = _disk_cache_path(key)
    if path is None:
        return None
    try:
        with open(path, "rb") as f:
            prog = pickle.load(f)
    except (OSError, pickle.PickleError, EOFError, AttributeError):
        return None
    if not isinstance(prog, ChainProgram):
        return None
    _CACHE_STATS["disk_hits"] += 1
    return prog


def _disk_cache_put(key, prog: ChainProgram) -> None:
    path = _disk_cache_path(key)
    if path is None:
        return
    try:
        os.makedirs(_DISK_CACHE_DIR, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=_DISK_CACHE_DIR, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(prog, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)
        except BaseException:
            os.unlink(tmp)
            raise
    except OSError:
        pass                    # cache writes are strictly best-effort


def program_cache_info() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_PROGRAM_CACHE),
                maxsize=_PROGRAM_CACHE_MAX)


def clear_program_cache() -> None:
    _PROGRAM_CACHE.clear()
    _IDENTITY_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0, disk_hits=0)


def _cache_get(key):
    prog = _PROGRAM_CACHE.get(key)
    if prog is not None:
        _PROGRAM_CACHE.move_to_end(key)
        _CACHE_STATS["hits"] += 1
    else:
        _CACHE_STATS["misses"] += 1
    return prog


def _cache_put(key, prog: ChainProgram) -> None:
    _PROGRAM_CACHE[key] = prog
    _PROGRAM_CACHE.move_to_end(key)
    while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
        _PROGRAM_CACHE.popitem(last=False)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DeviceLowering:
    """Mutable per-device scratch state during compilation."""

    n: int
    order: np.ndarray
    inv: np.ndarray
    issue: np.ndarray          # event order
    svc0: np.ndarray           # jitter-free service times, event order
    base: list                 # [(kind, perm, heads)] from trace_chain_families
    caps: dict                 # kind -> capacity for reordered kinds
    members: dict              # kind -> sorted member indices
    tperm: Optional[np.ndarray] = None
    theads: Optional[np.ndarray] = None
    reordered: Optional[list] = None    # [(label, perm, heads)] current
    needs_refine: bool = False
    multiclass: Tuple[str, ...] = ()
    #: Refinement service vector (event order): ``svc0`` by default, the
    #: seeded jittered draw under a jitter-aware compile.  Pop orders,
    #: class splits, and the greedy replay all use this vector.
    svcr: Optional[np.ndarray] = None
    thread: Optional[np.ndarray] = None   # event-order thread ids
    zone: Optional[np.ndarray] = None     # event-order zone ids
    wr: Optional[np.ndarray] = None       # event-order zoned-write mask
    #: True when any reordered pool mixes service classes under ``svcr``
    #: with more than one server — the exact greedy replay path.
    replay: bool = False
    #: Base family labels the replay re-emits in pop order (the base
    #: issue-ordered versions are dropped from the refined assembly).
    replaced: Tuple[str, ...] = ()
    #: Lag-qd same-thread predecessor per event (-1 at chain heads);
    #: the closed-loop gate the replay applies dynamically.
    pred: Optional[np.ndarray] = None


def _lower_device(trace: Trace, spec: ZNSDeviceSpec, params, *,
                  jitter: bool = False, seed: int = 0) -> _DeviceLowering:
    n = len(trace)
    if n == 0:
        e = np.zeros(0, dtype=np.int64)
        return _DeviceLowering(n=0, order=e, inv=e.copy(),
                               issue=np.zeros(0), svc0=np.zeros(0),
                               base=[], caps={}, members={})
    order = np.argsort(trace.issue, kind="stable")
    inv = np.empty(n, dtype=np.int64)
    inv[order] = np.arange(n)
    svc0 = compute_service_times(trace, params, seed=0, jitter=False)[order]
    base = trace_chain_families(
        trace.op[order], trace.zone[order].astype(np.int64),
        trace.thread[order].astype(np.int64),
        np.maximum(trace.qd[order].astype(np.int64), 1), spec,
        meta_on_io_path=bool(params.reset_on_io_path))
    dev = _DeviceLowering(n=n, order=order, inv=inv,
                          issue=trace.issue[order], svc0=svc0, base=base,
                          caps={}, members={})
    dev.thread = trace.thread[order].astype(np.int64)
    dev.zone = trace.zone[order].astype(np.int64)
    dev.wr = (trace.op[order] == OpType.WRITE) & (dev.zone >= 0)
    dev.svcr = compute_service_times(
        trace, params, seed=seed, jitter=True)[order] if jitter else svc0
    for kind, perm, heads in base:
        if kind == "thread":
            dev.tperm, dev.theads = perm, heads
        if kind in REORDERED_KINDS:
            dev.members[kind] = np.sort(perm)
            dev.caps[kind] = _pool_capacity(kind, spec)
    dev.needs_refine = any(kind in dev.members
                           for kind in REFINE_TRIGGER_KINDS)
    if dev.needs_refine:
        dev.multiclass = tuple(
            kind for kind in REORDERED_KINDS if kind in dev.members
            and dev.caps[kind] > 1
            and len(np.unique(dev.svc0[dev.members[kind]])) > 1)
        # every refined pool goes through the exact greedy replay: even
        # homogeneous pools need it, because the alternative (round-robin
        # chains re-sorted against the previous solve) can limit-cycle
        # and silently diverge from the event engine's greedy assignment
        dev.replay = True
        if bool(dev.wr.any()):
            dev.replaced = ("zone_write",)
        dev.pred = np.full(n, -1, dtype=np.int64)
        tail = ~dev.theads[1:]
        dev.pred[dev.tperm[1:][tail]] = dev.tperm[:-1][tail]
    return dev


def _chain_family(chain_lists) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate chains into one ``(perm, heads)`` family."""
    chs = [c for c in chain_lists if c]
    perm = np.asarray([e for c in chs for e in c], dtype=np.int64)
    heads = np.zeros(len(perm), dtype=bool)
    pos = 0
    for c in chs:
        heads[pos] = True
        pos += len(c)
    return perm, heads


def _replay_pools(dev: _DeviceLowering) -> list:
    """Exact greedy pool replay for every refined pool.

    Walks the event-heap pop order once, keeping one ``(free, slot)``
    heap per server pool, exactly as the event engine keeps free-time
    arrays: each pop starts at ``max(closed-loop thread gate, zone
    gate, min(free) of every touched pool)`` — appends touch the flash
    *and* append pools jointly — and pushes its end back.  Greedy
    ``argmin(free)`` depends only on the free-time *multiset*, so the
    replay reproduces the event engine's begins exactly, event by
    event; the per-slot event sequences become one coupling chain per
    server.  Per-zone write chains are re-emitted in pop order too
    (``dev.replaced`` drops the issue-ordered base family), since the
    zone gate binds in pop order.

    The pop order is derived *dynamically* along the walk, exactly as
    the event heap builds it: each thread keeps one in-flight request
    (the next is pushed with ``ready = max(issue, end of the lag-qd
    predecessor — already popped)`` only after its predecessor pops),
    and the walk always pops the smallest ``(ready, issue, index)``
    key.  The rebuild is therefore deterministic — independent of any
    solve-side readiness estimate — so refinement freezes after one
    round trip instead of iterating order -> solve -> order to a
    fixed point, which can limit-cycle even for homogeneous pools
    (and wander for tens of round trips on heterogeneous ones).
    """
    kinds = [k for k in REORDERED_KINDS if k in dev.members]
    in_kind = {}
    for k in kinds:
        m = np.zeros(dev.n, dtype=bool)
        m[dev.members[k]] = True
        in_kind[k] = m
    heaps = {k: [(0.0, j) for j in range(dev.caps[k])] for k in kinds}
    chains: Dict[str, list] = {k: [[] for _ in range(dev.caps[k])]
                               for k in kinds}
    zchains: Dict[int, list] = {}
    zready: Dict[int, float] = {}
    end = [0.0] * dev.n
    issue_l = dev.issue.tolist()
    svc_l = dev.svcr.tolist()
    wr_l = dev.wr.tolist()
    zone_l = dev.zone.tolist()
    pred_l = dev.pred.tolist()
    kind_l = {k: in_kind[k].tolist() for k in kinds}
    # per-thread event queues in event order (the push discipline)
    by_t = np.argsort(dev.thread, kind="stable")
    tsort = dev.thread[by_t]
    starts = np.flatnonzero(np.r_[True, tsort[1:] != tsort[:-1]])
    queues = [q.tolist() for q in np.split(by_t, starts[1:])]
    ptr = [0] * len(queues)
    heap: list = []
    for t, q in enumerate(queues):
        e = q[0]
        heapq.heappush(heap, (issue_l[e], issue_l[e], e, t))
    while heap:
        r, _, e, t = heapq.heappop(heap)
        begin = r
        if wr_l[e]:
            begin = max(begin, zready.get(zone_l[e], 0.0))
        touched = [k for k in kinds if kind_l[k][e]]
        for k in touched:
            begin = max(begin, heaps[k][0][0])
        end[e] = begin + svc_l[e]
        for k in touched:
            _, j = heaps[k][0]
            heapq.heapreplace(heaps[k], (end[e], j))
            chains[k][j].append(e)
        if wr_l[e]:
            zready[zone_l[e]] = end[e]
            zchains.setdefault(zone_l[e], []).append(e)
        ptr[t] += 1
        if ptr[t] < len(queues[t]):
            x = queues[t][ptr[t]]
            p = pred_l[x]
            rx = issue_l[x] if p < 0 else max(issue_l[x], end[p])
            heapq.heappush(heap, (rx, issue_l[x], x, t))
    out = [(k, *_chain_family(chains[k])) for k in kinds]
    if dev.replaced:
        out.append(("zone_write",
                    *_chain_family([zchains[z] for z in sorted(zchains)])))
    return out


def _reorder_pools(dev: _DeviceLowering) -> list:
    """Rebuild every reordered family by exact greedy replay
    (:func:`_replay_pools`)."""
    return _replay_pools(dev)


def _family_lists(devs: Sequence[_DeviceLowering], *, include_reordered: bool
                  ) -> List[list]:
    """Per-device ``[(label, perm, heads)]`` for assembly.  Devices that
    never needed refinement keep their base families verbatim (bitwise
    compatibility with the pre-compiler engine)."""
    out = []
    for dev in devs:
        fams = []
        for kind, perm, heads in dev.base:
            if dev.needs_refine and kind in REORDERED_KINDS:
                continue        # replaced by the reordered versions
            if include_reordered and dev.needs_refine and dev.reordered \
                    and kind in dev.replaced:
                continue        # re-emitted in pop order by the replay
            fams.append((kind, perm, heads))
        if include_reordered and dev.needs_refine and dev.reordered:
            fams.extend(dev.reordered)
        out.append(fams)
    return out


def _label_rank(label: str) -> Tuple[int, str]:
    from .engine import FAMILY_ORDER
    base = label.split("/", 1)[0]
    try:
        return FAMILY_ORDER.index(base), label
    except ValueError:
        return len(FAMILY_ORDER), label


#: Benchmark escape hatch: ``True`` routes block assembly through the
#: per-chain reference fill (:func:`_blocks_from_chains_ref`) instead of
#: the vectorized scatter path, so ``benchmarks/mega_fleet.py`` can
#: measure the lowering speedup against the historical implementation.
_USE_REFERENCE_FILL = False


def _blocks_from_chains_ref(chains: "OrderedDict[str, list]", n_flat: int
                            ) -> Tuple[FamilyBlock, ...]:
    """Reference block fill: one Python loop iteration per chain.

    Kept (a) as the baseline leg of the lowering benchmark and (b) as
    the executable specification the vectorized fill is tested against.
    """
    blocks = []
    for label in sorted(chains, key=_label_rank):
        chs = chains[label]
        for bucket in length_buckets([len(c) for c in chs],
                                     ratio=CHAIN_BUCKET_RATIO):
            sub = [chs[i] for i in bucket]
            R = len(sub)
            L = max(len(c) for c in sub)
            if R >= POSLOOP_MIN_CHAINS and \
                    R * np.log2(max(L, 2)) >= POSLOOP_COST_CUTOVER:
                gidx = np.full((L, R), n_flat, dtype=np.int64)
                heads = np.ones((L, R), dtype=bool)
                for r, c in enumerate(sub):
                    gidx[:len(c), r] = c
                    heads[1:len(c), r] = False
                blocks.append(FamilyBlock(label=label, gidx=gidx,
                                          heads=heads, layout="cols"))
            else:
                gidx = np.full((R, L), n_flat, dtype=np.int64)
                heads = np.ones((R, L), dtype=bool)
                for r, c in enumerate(sub):
                    gidx[r, :len(c)] = c
                    heads[r, 1:len(c)] = False
                blocks.append(FamilyBlock(label=label, gidx=gidx,
                                          heads=heads, layout="rows"))
    return tuple(blocks)


def _blocks_from_segments(segments: "OrderedDict[str, tuple]", n_flat: int
                          ) -> Tuple[FamilyBlock, ...]:
    """Vectorized block fill from segment form.

    ``segments`` maps label -> ``(vals, lens)`` where ``vals`` is the
    concatenation of every chain of the family (chain order preserved)
    and ``lens`` the per-chain lengths.  Each bucket is laid out with
    one fancy-index scatter instead of a per-chain Python loop — the
    hot path that dominated fleet lowering at >=64 devices.
    """
    blocks = []
    for label in sorted(segments, key=_label_rank):
        vals, lens = segments[label]
        if len(lens) == 0:
            continue
        starts = np.zeros(len(lens), dtype=np.int64)
        np.cumsum(lens[:-1], out=starts[1:])
        for bucket in length_buckets(lens.tolist(),
                                     ratio=CHAIN_BUCKET_RATIO):
            sel = np.asarray(bucket, dtype=np.int64)
            sl = lens[sel]
            R = len(sel)
            L = int(sl.max())
            tot = int(sl.sum())
            # lane/position coordinates of every real event in the
            # padded (R, L) bucket, then one gather + one scatter
            lane = np.repeat(np.arange(R, dtype=np.int64), sl)
            lane_start = np.zeros(R, dtype=np.int64)
            np.cumsum(sl[:-1], out=lane_start[1:])
            pos = np.arange(tot, dtype=np.int64) - np.repeat(lane_start, sl)
            cvals = vals[np.repeat(starts[sel], sl) + pos]
            if R >= POSLOOP_MIN_CHAINS and \
                    R * np.log2(max(L, 2)) >= POSLOOP_COST_CUTOVER:
                gidx = np.full((L, R), n_flat, dtype=np.int64)
                heads = np.ones((L, R), dtype=bool)
                gidx[pos, lane] = cvals
                heads[pos, lane] = pos == 0
                blocks.append(FamilyBlock(label=label, gidx=gidx,
                                          heads=heads, layout="cols"))
            else:
                gidx = np.full((R, L), n_flat, dtype=np.int64)
                heads = np.ones((R, L), dtype=bool)
                gidx[lane, pos] = cvals
                heads[lane, pos] = pos == 0
                blocks.append(FamilyBlock(label=label, gidx=gidx,
                                          heads=heads, layout="rows"))
    return tuple(blocks)


def _segments_from_chains(chains: "OrderedDict[str, list]"
                          ) -> "OrderedDict[str, tuple]":
    segments: "OrderedDict[str, tuple]" = OrderedDict()
    for label, chs in chains.items():
        vals = np.concatenate(chs) if chs else np.zeros(0, dtype=np.int64)
        lens = np.asarray([len(c) for c in chs], dtype=np.int64)
        segments[label] = (vals, lens)
    return segments


def _blocks_from_chains(chains: "OrderedDict[str, list]", n_flat: int
                        ) -> Tuple[FamilyBlock, ...]:
    """Length-bucket + lay out ``{label: [chain index arrays]}`` into
    padded :class:`FamilyBlock` tensors addressing a flat vector of
    ``n_flat`` events (padding points at the dead slot ``n_flat``).
    Labels are emitted in :data:`repro.core.engine.FAMILY_ORDER`-first
    rank (unknown labels sort after, alphabetically) — the Gauss-Seidel
    application order."""
    if _USE_REFERENCE_FILL:
        return _blocks_from_chains_ref(chains, n_flat)
    return _blocks_from_segments(_segments_from_chains(chains), n_flat)


def _assemble(devs: Sequence[_DeviceLowering], fam_lists: Sequence[list], *,
              exact: bool, refine_used: int, order_stable: bool,
              unstable_pools: Tuple[str, ...] = (),
              svc_seeds: Optional[Tuple[int, ...]] = None) -> ChainProgram:
    offsets, off = [], 0
    for dev in devs:
        offsets.append(off)
        off += dev.n
    n_flat = off
    issue_flat = np.concatenate([dev.issue for dev in devs]) if devs else \
        np.zeros(0)
    svc0_flat = np.concatenate([dev.svc0 for dev in devs]) if devs else \
        np.zeros(0)
    # split every (device, family) into its chains; chains are the
    # batching unit: bucketed by length across devices so one block
    # solves all similar-length chains of a family fleet-wide
    if _USE_REFERENCE_FILL:
        chains: "OrderedDict[str, list]" = OrderedDict()
        for d, fams in enumerate(fam_lists):
            for label, perm, heads in fams:
                if len(perm) == 0:
                    continue
                cuts = np.flatnonzero(heads)
                for c in np.split(offsets[d] + perm, cuts[1:]):
                    chains.setdefault(label, []).append(c)
        blocks = _blocks_from_chains(chains, n_flat)
    else:
        # segment form: a family's ``perm`` already IS its chains
        # concatenated in order, so one offset-shift per (device,
        # family) replaces a per-chain ``np.split`` loop.  Chain
        # lengths are memoized per heads array — replicated devices
        # share ``_DeviceLowering`` objects, so lengths compute once
        # per *unique* device.
        segs: "OrderedDict[str, list]" = OrderedDict()
        lens_memo: Dict[int, np.ndarray] = {}
        for d, fams in enumerate(fam_lists):
            for label, perm, heads in fams:
                if len(perm) == 0:
                    continue
                lens = lens_memo.get(id(heads))
                if lens is None:
                    cuts = np.flatnonzero(heads)
                    lens = np.diff(np.r_[0, cuts[1:], len(perm)])
                    lens_memo[id(heads)] = lens
                segs.setdefault(label, ([], []))
                segs[label][0].append(offsets[d] + perm)
                segs[label][1].append(lens)
        segments: "OrderedDict[str, tuple]" = OrderedDict(
            (label, (np.concatenate(vs), np.concatenate(ls)))
            for label, (vs, ls) in segs.items())
        blocks = _blocks_from_segments(segments, n_flat)
    multiclass = tuple(sorted({k for dev in devs for k in dev.multiclass}))
    return ChainProgram(
        n_flat=n_flat, offsets=tuple(offsets),
        orders=tuple(dev.order for dev in devs),
        invs=tuple(dev.inv for dev in devs),
        issue_flat=issue_flat, svc0_flat=svc0_flat,
        families=tuple(blocks), exact=exact,
        multiclass_pools=multiclass, refine_used=refine_used,
        order_stable=order_stable, unstable_pools=tuple(unstable_pools),
        svc_seeds=svc_seeds)


def compile_fleet_program(traces: Sequence[Trace],
                          specs: Sequence[ZNSDeviceSpec],
                          lats: Sequence, *,
                          refine: int = DEFAULT_REFINE,
                          cache: bool = True,
                          dedup: bool = True,
                          jitter: bool = False,
                          seeds: Optional[Sequence[int]] = None
                          ) -> ChainProgram:
    """Lower N devices' traces into one fused :class:`ChainProgram`.

    ``lats[i]`` may be a :class:`repro.core.LatencyModel` or a bare
    :class:`repro.core.LatencyParams` pytree.  Compilation is
    deterministic in ``(traces, specs, params, refine, jitter, seeds)``
    and cached in a module-level LRU on exactly that key (plus a
    persistent on-disk cache when :func:`set_program_cache_dir` or
    ``REPRO_PROGRAM_CACHE_DIR`` points somewhere).

    Pop-order refinement sorts and replays against jitter-free service
    times by default.  With ``jitter=True`` it uses the *sampled*
    service vector of ``compute_service_times(trace, params,
    seed=seeds[i], jitter=True)`` instead — the pop order, class
    splits, and greedy pool replay then match the jittered run the
    caller is about to solve, which is what makes jittered saturated
    pools exact (``svc_seeds`` records the binding; ``seeds`` defaults
    to ``0`` per device, matching ``simulate``'s default).

    With ``dedup`` (default), devices with identical (trace content,
    spec, params) — and, under ``jitter``, the same seed — lower and
    refine once and share the result: the fleet solve is block-diagonal
    per device, so replicas follow identical refinement trajectories.
    Mega-fleets replicating one workload over thousands of devices
    lower in O(unique) time.
    """
    global _LAST_STATS
    t0 = time.perf_counter()
    B = len(traces)
    if not (len(specs) == len(lats) == B):
        raise ValueError(f"fleet shape mismatch: {B} traces, {len(specs)} "
                         f"specs, {len(lats)} latency models")
    params = [resolve_params(l) for l in lats]
    jitter = bool(jitter)
    if seeds is None:
        seeds = [0] * B
    else:
        seeds = [int(s) for s in seeds]
        if len(seeds) != B:
            raise ValueError(f"fleet shape mismatch: {B} traces, "
                             f"{len(seeds)} seeds")
    skey = tuple(seeds) if jitter else None
    key = None
    digests: Optional[list] = None
    if cache:
        ikey = (tuple(id(t) for t in traces), tuple(specs), tuple(params),
                int(refine), skey)
        ihit = _IDENTITY_CACHE.get(ikey)
        if ihit is not None and all(a is b for a, b in
                                    zip(ihit[0], traces)):
            _IDENTITY_CACHE.move_to_end(ikey)
            _CACHE_STATS["hits"] += 1
            _LAST_STATS = CompileStats(hits=1, n_devices=B)
            return ihit[1]
        # replicated workloads pass the same trace object many times;
        # digest each object once (and memoize on the trace itself)
        digests = [_trace_digest(t) for t in traces]
        key = (tuple(digests), tuple(specs), tuple(params), int(refine),
               skey)
        hit = _cache_get(key)
        disk = 0
        if hit is None:
            hit = _disk_cache_get(key)
            if hit is not None:
                disk = 1
                _cache_put(key, hit)
        if hit is not None:
            _IDENTITY_CACHE[ikey] = (tuple(traces), hit)
            while len(_IDENTITY_CACHE) > _IDENTITY_CACHE_MAX:
                _IDENTITY_CACHE.popitem(last=False)
            _LAST_STATS = CompileStats(
                hits=1 - disk, misses=disk, disk_hits=disk, n_devices=B,
                lowering_ms=(time.perf_counter() - t0) * 1e3)
            return hit

    # --- replica dedup: lower + refine only the unique devices -------
    if dedup and B > 1:
        if digests is None:
            digests = [_trace_digest(t) for t in traces]
        slot: Dict[tuple, int] = {}
        urep: List[int] = []            # unique slot -> first device idx
        rep: List[int] = []             # device idx -> unique slot
        for b in range(B):
            k = (digests[b], specs[b], params[b],
                 seeds[b] if jitter else 0)
            s = slot.get(k)
            if s is None:
                s = slot[k] = len(urep)
                urep.append(b)
            rep.append(s)
    else:
        urep = list(range(B))
        rep = list(range(B))
    udevs = [_lower_device(traces[b], specs[b], params[b],
                           jitter=jitter, seed=seeds[b]) for b in urep]
    refine_used = 0
    order_stable = True
    unstable: List[str] = []
    if refine <= 0:
        # no refinement budget: keep the issue-ordered base pool chains.
        # This is the budget-exhaustion path — warn with the affected
        # pool labels and record them on the program so RunResult /
        # FleetRunResult diagnostics can surface which pools degraded.
        unstable = sorted({f"dev{urep[d]}:{kind}"
                           for d, dev in enumerate(udevs)
                           if dev.needs_refine for kind in dev.members})
        for dev in udevs:
            dev.needs_refine = False
        if unstable:
            order_stable = False
            warnings.warn(
                f"pop-order refinement disabled (refine={int(refine)}) "
                f"with server pools present; pool chains keep their "
                f"issue-ordered bootstrap approximation; affected "
                f"pools: {', '.join(unstable)}. Completions stay a "
                f"convergent lower bound (exact=False); raise refine= "
                f"to tighten.", RuntimeWarning, stacklevel=2)
    elif any(dev.needs_refine for dev in udevs):

        def _rebuild() -> List[str]:
            """Re-derive every refined pool's chains by greedy replay;
            returns the ``dev{i}:{label}`` names of families that
            changed since the previous rebuild."""
            changed: List[str] = []
            for d, dev in enumerate(udevs):
                if not dev.needs_refine:
                    continue
                new = _reorder_pools(dev)
                old = dev.reordered
                if old is None or len(new) != len(old):
                    changed.extend(f"dev{urep[d]}:{lab}"
                                   for lab, _, _ in new)
                else:
                    changed.extend(
                        f"dev{urep[d]}:{a[0]}" for a, b in zip(new, old)
                        if not np.array_equal(a[1], b[1]))
                dev.reordered = new
            return changed

        # the greedy replay derives each pop order dynamically under the
        # refinement service vector, so a single rebuild freezes; the
        # second rebuild is the stability certificate (it must reproduce
        # the frozen chains — the replay is deterministic)
        _rebuild()
        refine_used = 1
        unstable = sorted(set(_rebuild()))
        order_stable = not unstable
        if not order_stable:
            warnings.warn(
                f"pop-order refinement did not freeze "
                f"(refine={int(refine)}): the greedy replay failed to "
                f"reproduce its own chains; unstable pools: "
                f"{', '.join(unstable)}. Completions stay a convergent "
                f"lower bound (exact=False).",
                RuntimeWarning, stacklevel=2)
    exact = order_stable
    devs = [udevs[s] for s in rep]
    prog = _assemble(devs, _family_lists(devs, include_reordered=True),
                     exact=exact, refine_used=refine_used,
                     order_stable=order_stable,
                     unstable_pools=tuple(unstable), svc_seeds=skey)
    if cache and key is not None:
        _cache_put(key, prog)
        _disk_cache_put(key, prog)
        _IDENTITY_CACHE[ikey] = (tuple(traces), prog)
        while len(_IDENTITY_CACHE) > _IDENTITY_CACHE_MAX:
            _IDENTITY_CACHE.popitem(last=False)
    _LAST_STATS = CompileStats(
        misses=1, n_devices=B, n_unique=len(urep),
        lowering_ms=(time.perf_counter() - t0) * 1e3)
    return prog


def compile_program(trace: Trace, spec: ZNSDeviceSpec, lat, *,
                    refine: int = DEFAULT_REFINE,
                    cache: bool = True, jitter: bool = False,
                    seed: int = 0) -> ChainProgram:
    """Single-device convenience wrapper of :func:`compile_fleet_program`.

    ``jitter=True`` refines against the jittered service draw of
    ``seed`` (see :func:`compile_fleet_program`), making the matching
    jittered solve exact.

    Example (a saturated two-thread append pool — exact on the fast
    backend because its pop order stabilizes during refinement)::

        >>> from repro.core import (KiB, WorkloadSpec, ZnsDevice,
        ...                         compile_program, solve_program)
        >>> dev = ZnsDevice()
        >>> wl = (WorkloadSpec()
        ...       .appends(n=64, size=8 * KiB, qd=4, zone=0, nzones=4)
        ...       .appends(n=64, size=8 * KiB, qd=4, zone=4, nzones=4))
        >>> prog = compile_program(wl.build(), dev.spec, dev.lat)
        >>> prog.n_flat, prog.n_devices, prog.exact
        (128, 1, True)
        >>> comp, sweeps_used, converged = solve_program(
        ...     prog, prog.svc0_flat)
        >>> converged and sweeps_used >= 1
        True
    """
    return compile_fleet_program([trace], [spec], [lat], refine=refine,
                                 cache=cache, jitter=jitter, seeds=[seed])


# ---------------------------------------------------------------------------
# Generic program construction: custom chain families + concatenation
# ---------------------------------------------------------------------------
def _validate_family_chains(families, n_flat: int) -> None:
    for label, chs in families:
        seen = np.concatenate([np.asarray(c) for c in chs]) if chs else \
            np.zeros(0, dtype=np.int64)
        if len(seen) and (seen.min() < 0 or seen.max() >= n_flat):
            raise ValueError(
                f"family {label!r}: chain index out of range for "
                f"{n_flat} events")
        if len(np.unique(seen)) != len(seen):
            raise ValueError(
                f"family {label!r}: an event appears in more than one "
                f"chain of the same family (scatter would be ambiguous); "
                f"split the family into sub-labels")


def build_program(issue, svc0, families: Sequence[Tuple[str, Sequence]], *,
                  exact: bool = True,
                  multiclass_pools: Sequence[str] = (),
                  refine_used: int = 0,
                  order_stable: bool = True,
                  unstable_pools: Sequence[str] = ()) -> ChainProgram:
    """Build a :class:`ChainProgram` from explicit chain families.

    The device compiler (:func:`compile_fleet_program`) derives its
    families from a :class:`Trace`; higher tiers — the cluster layer's
    network/NIC/CPU hops — construct theirs directly.  ``issue`` and
    ``svc0`` are flat per-event arrays (the program's event order *is*
    the given order); ``families`` is ``[(label, [chain, ...]), ...]``
    where each chain is an index array into the event vector and the
    chain semantics are the max-plus recurrence
    ``c_i >= c_{i-1} + svc_i`` (c initialized to ``issue + svc``).  An
    event may appear in many families but at most once per family
    (scatter-uniqueness); violations raise ``ValueError``.

    The result is a single-pseudo-device program: ``solve_program``
    accepts it unchanged, and :func:`concat_programs` stacks it with
    other programs (device-compiled or custom) into one fused fixpoint.
    """
    issue = np.ascontiguousarray(issue, dtype=np.float64)
    svc0 = np.ascontiguousarray(svc0, dtype=np.float64)
    if len(issue) != len(svc0):
        raise ValueError(f"issue/svc0 length mismatch: "
                         f"{len(issue)} vs {len(svc0)}")
    n = len(issue)
    fams = [(label, [np.ascontiguousarray(c, dtype=np.int64) for c in chs
                     if len(c)]) for label, chs in families]
    fams = [(label, chs) for label, chs in fams if chs]
    _validate_family_chains(fams, n)
    chains: "OrderedDict[str, list]" = OrderedDict()
    for label, chs in fams:
        chains.setdefault(label, []).extend(chs)
    order = np.arange(n, dtype=np.int64)
    return ChainProgram(
        n_flat=n, offsets=(0,), orders=(order,), invs=(order.copy(),),
        issue_flat=issue, svc0_flat=svc0,
        families=_blocks_from_chains(chains, n),
        exact=bool(exact), multiclass_pools=tuple(multiclass_pools),
        refine_used=int(refine_used), order_stable=bool(order_stable),
        unstable_pools=tuple(unstable_pools))


def program_chains(program: ChainProgram) -> "OrderedDict[str, list]":
    """Recover ``{label: [chain index arrays]}`` from a program's padded
    family blocks (each block lane is one chain; padding stripped).
    Inverse of the block assembly up to length bucketing."""
    chains: "OrderedDict[str, list]" = OrderedDict()
    for blk in program.families:
        gidx, _ = blk.rows_view()
        for lane in gidx:
            c = lane[lane != program.n_flat]
            if len(c):
                chains.setdefault(blk.label, []).append(c)
    return chains


def concat_programs(programs: Sequence[ChainProgram]) -> ChainProgram:
    """Concatenate compiled programs into ONE fused fixpoint.

    Event vectors stack (each input program's flat indices shift by its
    offset), same-label families merge into shared length-bucketed
    blocks, and per-device unpacking metadata concatenates — so N
    independently compiled programs (one per cluster config, say) solve
    as a single :func:`solve_program` call with block-diagonal coupling
    (no cross-program constraints are added).  ``device_slice(i)``
    indexes devices in input order: a 3-device program followed by a
    1-device program yields devices 0-2 and 3.
    """
    programs = list(programs)
    if not programs:
        raise ValueError("concat_programs needs at least one program")
    if len(programs) == 1:
        return programs[0]
    chains: "OrderedDict[str, list]" = OrderedDict()
    offsets: List[int] = []
    orders: List[np.ndarray] = []
    invs: List[np.ndarray] = []
    off = 0
    for p in programs:
        for label, chs in program_chains(p).items():
            chains.setdefault(label, []).extend(
                [c + off for c in chs] if off else chs)
        offsets.extend(o + off for o in p.offsets)
        orders.extend(p.orders)
        invs.extend(p.invs)
        off += p.n_flat
    return ChainProgram(
        n_flat=off, offsets=tuple(offsets), orders=tuple(orders),
        invs=tuple(invs),
        issue_flat=np.concatenate([p.issue_flat for p in programs]),
        svc0_flat=np.concatenate([p.svc0_flat for p in programs]),
        families=_blocks_from_chains(chains, off),
        exact=all(p.exact for p in programs),
        multiclass_pools=tuple(sorted({k for p in programs
                                       for k in p.multiclass_pools})),
        refine_used=max(p.refine_used for p in programs),
        order_stable=all(p.order_stable for p in programs),
        unstable_pools=tuple(sorted({k for p in programs
                                     for k in p.unstable_pools})),
        svc_seeds=None if all(p.svc_seeds is None for p in programs)
        else tuple(s for p in programs
                   for s in (p.svc_seeds if p.svc_seeds is not None
                             else (None,) * p.n_devices)))


def extend_program(program: ChainProgram,
                   families: Sequence[Tuple[str, Sequence]],
                   *, exact: Optional[bool] = None,
                   multiclass_pools: Optional[Sequence[str]] = None
                   ) -> ChainProgram:
    """Return a program with extra chain families merged in.

    ``families`` uses *global* flat-event indices, so cross-cutting
    constraints may span events of different devices (the cluster
    compiler links network stages to device I/O this way).  Existing
    families are preserved; a label collision merges chain lists (the
    combined family must still satisfy scatter-uniqueness).  ``exact``
    defaults to the input program's flag.
    """
    fams = [(label, [np.ascontiguousarray(c, dtype=np.int64) for c in chs
                     if len(c)]) for label, chs in families]
    fams = [(label, chs) for label, chs in fams if chs]
    _validate_family_chains(fams, program.n_flat)
    chains = program_chains(program)
    for label, chs in fams:
        merged = chains.setdefault(label, [])
        merged.extend(chs)
        flat = np.concatenate(merged)
        if len(np.unique(flat)) != len(flat):
            raise ValueError(
                f"extend_program: family {label!r} would contain a "
                f"duplicate event after merging; use a fresh label")
    return dataclasses.replace(
        program, families=_blocks_from_chains(chains, program.n_flat),
        exact=program.exact if exact is None else bool(exact),
        multiclass_pools=program.multiclass_pools
        if multiclass_pools is None else tuple(multiclass_pools))


def force_layout(program: ChainProgram, layout: str) -> ChainProgram:
    """Return the program with every family block stored in ``layout``.

    ``"cols"`` (position loop) and ``"rows"`` (doubling scan) solve the
    same chains with different arithmetic schedules; the compiler picks
    per bucket by a cost model.  The exactness matrix and the layout
    equivalence tests pin one layout for a whole solve.  The index
    tensors are transposed copies — chain contents are unchanged.
    """
    if layout not in ("rows", "cols"):
        raise ValueError(f"unknown layout {layout!r}; expected rows | cols")
    blocks = []
    for blk in program.families:
        if blk.layout == layout:
            blocks.append(blk)
        elif layout == "rows":
            g, h = blk.rows_view()
            blocks.append(FamilyBlock(label=blk.label, gidx=g, heads=h,
                                      layout="rows"))
        else:
            blocks.append(FamilyBlock(
                label=blk.label, gidx=np.ascontiguousarray(blk.gidx.T),
                heads=np.ascontiguousarray(blk.heads.T), layout="cols"))
    return dataclasses.replace(program, families=tuple(blocks))


# ---------------------------------------------------------------------------
# Fused fixpoint solve
# ---------------------------------------------------------------------------
def _posloop_scan(cur: np.ndarray, svc: np.ndarray) -> np.ndarray:
    """Exact chain recurrence, sequential over positions (rows of the
    (L, R) matrices), vectorized across the R chains:
    ``c_j = max(c_{j-1} + svc_j, cur_j)`` — identical arithmetic to the
    event engine's per-chain loop, O(n) work."""
    out = np.empty_like(cur)
    out[0] = cur[0]
    prev = out[0]
    for j in range(1, cur.shape[0]):
        o = out[j]
        np.add(prev, svc[j], out=o)
        np.maximum(o, cur[j], out=o)
        prev = o
    return out


def block_adjacency(program: ChainProgram) -> np.ndarray:
    """Symmetric ``(F, F)`` bool matrix: ``adj[i, j]`` iff family blocks
    ``i`` and ``j`` gather overlapping flat-event slots (dead/padding
    slot excluded), i.e. a scatter by one can change the other's inputs.

    This is the dependency structure the active-set sweep driver uses to
    decide which converged blocks a moving block re-activates.  The
    diagonal is False: a block is at its own fixpoint immediately after
    its scan, so it never re-activates itself.  Memoized on the program
    (frozen but not slotted, same trick as the trace digest memo).
    """
    cached = getattr(program, "_adjacency_memo", None)
    if cached is not None:
        return cached
    nf = len(program.families)
    adj = np.zeros((nf, nf), dtype=bool)
    if nf > 1:
        dead = program.n_flat
        parts, owners = [], []
        for f, blk in enumerate(program.families):
            flat = blk.gidx.ravel()
            flat = flat[flat != dead]
            parts.append(flat)
            owners.append(np.full(len(flat), f, dtype=np.int32))
        idx = np.concatenate(parts)
        own = np.concatenate(owners)
        order = np.argsort(idx, kind="stable")
        idx, own = idx[order], own[order]
        # Runs of equal index mark every pair of owning blocks adjacent.
        # An index appears at most once per block, so run length <= F and
        # comparing each shift k < F covers all within-run pairs.
        for k in range(1, nf):
            same = idx[k:] == idx[:-k]
            if not same.any():
                break
            a, b = own[k:][same], own[:-k][same]
            adj[a, b] = True
            adj[b, a] = True
        np.fill_diagonal(adj, False)
    try:
        object.__setattr__(program, "_adjacency_memo", adj)
    except Exception:        # pragma: no cover - slotted subclass
        pass
    return adj


#: Benchmark baseline escape hatch: ``False`` restores the pre-active-set
#: full sweep loop (every block gathered + edge-checked every sweep).
#: The active-set path is bit-identical; this exists only so
#: ``benchmarks/mega_fleet.py`` can measure the win.
_ACTIVE_SET = True


def _solve_numpy(program: ChainProgram, svc_flat: np.ndarray, *,
                 sweeps: int, scan_backend: str,
                 comp0: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, int, bool]:
    comp = np.append(program.issue_flat + svc_flat, -np.inf)
    warm = comp0 is not None
    if warm:
        comp[:-1] = np.maximum(comp[:-1], comp0)
    svc_ext = np.append(svc_flat, 0.0)
    svc_mats = [svc_ext[blk.gidx] for blk in program.families]
    used, converged = 0, True
    budget = max(int(sweeps), 1)
    nf = len(program.families)
    adj = block_adjacency(program)
    # Active-set sweeps: a block is processed only while "dirty" — its
    # gather slots may have changed since its last fixpoint check.  A
    # moving block re-dirties its neighbours (shared flat slots): those
    # later in the sweep order immediately (Gauss–Seidel sees the update
    # this sweep, exactly as the full loop would), earlier ones for the
    # next sweep.  Skipping a clean block is bit-identical to checking
    # it: its inputs did not change, so the edge check would find no
    # violated lanes and fall through.
    dirty_now = np.ones(nf, dtype=bool)
    dirty_next = np.zeros(nf, dtype=bool)
    active_counts: List[int] = []
    residuals: List[float] = []
    for s in range(budget):
        if not _ACTIVE_SET:
            # benchmark baseline: pre-active-set full sweeps (every
            # block gathered + edge-checked every sweep)
            dirty_now[:] = True
        if not dirty_now.any():
            # Nothing can have moved since every block's last check:
            # this sweep is the full loop's no-op verification sweep.
            used, converged = s + 1, True
            active_counts.append(0)
            residuals.append(0.0)
            break
        moved = False
        n_active = 0
        residual = 0.0
        dirty_next[:] = False
        for f, (blk, svc_m) in enumerate(zip(program.families, svc_mats)):
            if not dirty_now[f]:
                continue
            n_active += 1
            cur = comp[blk.gidx]
            cols = blk.layout == "cols"
            if s == 0 and not warm:
                # first sweep: everything is a fresh lower bound — scan
                # all lanes, skip the fixpoint pre-check.  With more
                # budget, assume movement (the next sweep's O(L) checks
                # settle it cheaply); on a one-sweep budget, movement
                # must be measured or an already-converged trace would
                # be misreported as truncated.
                lanes = None
                moved = moved or budget > 1
                full = True
            else:
                # A chain is at its fixpoint iff every intra-chain edge
                # satisfies c_i >= c_{i-1} + svc_i (heads/padding
                # excluded) — an O(L) check, ~log(run) cheaper than the
                # scan it guards.  Only violated chains are re-solved;
                # convergence sweeps (and chains untouched by other
                # families' updates) cost one shifted compare instead
                # of a scan.
                if cols:
                    viol = (cur[1:] * (1.0 + 1e-12) + 1e-9
                            < cur[:-1] + svc_m[1:]) & ~blk.heads[1:]
                    lanes = viol.any(axis=0)
                else:
                    viol = (cur[:, 1:] * (1.0 + 1e-12) + 1e-9
                            < cur[:, :-1] + svc_m[:, 1:]) \
                        & ~blk.heads[:, 1:]
                    lanes = viol.any(axis=1)
                if not lanes.any():
                    continue
                moved = True
                full = bool(lanes.all())
            if cols:
                cur_s = cur if full else np.ascontiguousarray(cur[:, lanes])
                svc_s = svc_m if full else \
                    np.ascontiguousarray(svc_m[:, lanes])
                upd = _posloop_scan(cur_s, svc_s)
                gidx_s = blk.gidx if full else blk.gidx[:, lanes]
            else:
                cur_s = cur if full else cur[lanes]
                svc_s = svc_m if full else svc_m[lanes]
                heads_s = blk.heads if full else blk.heads[lanes]
                out = zone_sequential_completions_batched(
                    cur_s - svc_s, svc_s, heads_s, backend=scan_backend)
                upd = np.maximum(cur_s, out)
                gidx_s = blk.gidx if full else blk.gidx[lanes]
            if s == 0 and budget == 1:
                # one-sweep budget: measure real progress (mask padding
                # — the position loop carries finite values through it)
                moved = moved or bool(
                    ((upd > cur_s * (1.0 + 1e-12) + 1e-9)
                     & (gidx_s != len(comp) - 1)).any())
            # each real index appears at most once per family block, so
            # fancy assignment is a well-defined scatter; the padding
            # slots all collapse onto the dead slot, reset below.
            comp[gidx_s] = upd
            comp[-1] = -np.inf
            # Residual + dirty propagation.  A violated lane strictly
            # increases at least one slot, so any processed block in the
            # check path moved; the first full sweep measures movement
            # directly (padding masked — it gathers the -inf sentinel).
            nonpad = gidx_s != len(comp) - 1
            diff = upd[nonpad] - cur_s[nonpad]
            if diff.size:
                residual = max(residual, float(diff.max()))
            blk_moved = bool((diff > 0.0).any()) if full and s == 0 \
                else True
            if blk_moved and nf > 1:
                nbr = adj[f]
                # neighbours later in the sweep order see this scatter
                # within the current sweep (Gauss–Seidel), earlier ones
                # on the next sweep.
                dirty_now[f + 1:] |= nbr[f + 1:]
                dirty_next[:f] |= nbr[:f]
        used = s + 1
        active_counts.append(n_active)
        residuals.append(residual)
        dirty_now, dirty_next = dirty_next, dirty_now
        if not moved:
            converged = True
            break
        converged = False
    global _LAST_SOLVE_STATS
    _LAST_SOLVE_STATS = SolveStats(
        driver="loop", sweeps=used, converged=converged, n_blocks=nf,
        active_blocks=tuple(active_counts), residuals=tuple(residuals))
    return comp[:-1], used, converged


def _solve_kernel(program: ChainProgram, svc_flat: np.ndarray, *,
                  sweeps: int, impl: str,
                  comp0: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, int, bool]:
    from repro.kernels import ops as kops
    init = program.issue_flat + svc_flat
    if comp0 is not None:
        init = np.maximum(init, comp0)
    comp, used, converged = kops.zns_fixpoint(
        init, svc_flat,
        tuple(blk.rows_view() for blk in program.families),
        sweeps=max(int(sweeps), 1), impl=impl,
        adj=block_adjacency(program))
    return (np.asarray(comp, dtype=np.float64), int(used), bool(converged))


def verify_fixpoint(program: ChainProgram, svc_flat: np.ndarray,
                    comp: np.ndarray, *, rtol: float = 1e-12,
                    atol: float = 1e-9) -> bool:
    """True iff ``comp`` is (to tolerance) the *least* fixpoint of the
    program at ``svc_flat`` — i.e. every event is **tight**: its
    completion equals the max of its own init (``issue + svc``) and its
    incoming chain-edge lower bounds (``comp[pred] + svc``), with no
    slack.

    A converged solve warm-started from a valid lower bound is always
    tight; one warm-started from an *invalid* ``comp0`` (e.g. a
    previous capacity-ladder rung whose greedy schedule anomalously
    completed some op later) keeps the unjustified value and fails this
    check — the caller then falls back to a cold solve.  The tightness
    ⇒ least-fixpoint argument needs every justifying chain to
    terminate, which strictly positive service times guarantee; with
    any ``svc <= 0`` the check conservatively returns False.
    """
    if program.n_flat == 0:
        return True
    svc = np.asarray(svc_flat, dtype=np.float64)
    if not np.all(svc > 0.0):
        return False
    comp = np.asarray(comp, dtype=np.float64)
    target = _fixpoint_target(program, svc, comp)
    tol = np.maximum(np.abs(target) * rtol, atol)
    return bool(np.all(np.abs(comp - target) <= tol))


def _fixpoint_target(program: ChainProgram, svc: np.ndarray,
                     comp: np.ndarray) -> np.ndarray:
    """Per-event justification: ``max(issue + svc, comp[pred] + svc)``
    over every incoming chain edge — what each completion *should* be
    if the rest of ``comp`` is taken as given."""
    ext = np.append(comp, -np.inf)
    svc_ext = np.append(svc, 0.0)
    text = np.append(program.issue_flat + svc, -np.inf)
    for blk in program.families:
        g, h = blk.gidx, blk.heads
        if blk.layout == "cols":
            pred, me, hh = g[:-1], g[1:], h[1:]
        else:
            pred, me, hh = g[:, :-1], g[:, 1:], h[:, 1:]
        mask = ~hh
        cand = ext[pred[mask]] + svc_ext[me[mask]]
        np.maximum.at(text, me[mask], cand)
    return text[:-1]


def unjustified_slots(program: ChainProgram, svc_flat: np.ndarray,
                      comp: np.ndarray, *, rtol: float = 1e-12,
                      atol: float = 1e-9) -> np.ndarray:
    """Indices whose completion exceeds its justification (init and
    every incoming edge) — the slots an invalid warm start ``comp0``
    pushed above the least fixpoint.  In a *converged* warm solve only
    candidate-dominated slots can be unjustified (everything else is
    explained by its predecessors), so a caller can drop exactly these
    slots from the candidate and re-solve — each round either ends
    tight or strictly shrinks the candidate (see
    :func:`repro.cluster.compiler.compile_graph`)."""
    if program.n_flat == 0:
        return np.zeros(0, dtype=np.int64)
    svc = np.asarray(svc_flat, dtype=np.float64)
    comp = np.asarray(comp, dtype=np.float64)
    target = _fixpoint_target(program, svc, comp)
    tol = np.maximum(np.abs(target) * rtol, atol)
    return np.nonzero(comp - target > tol)[0]


def _auto_sharded() -> bool:
    """True when the ``auto`` driver should shard: jax is already
    loaded with >1 local devices on an accelerator platform.  Never on
    CPU hosts — the single-chip numpy loop stays the (bit-identical)
    default there.  ``REPRO_SHARD_EXECUTOR=mesh|host`` forces sharding
    on; ``=off`` forces it off."""
    forced = os.environ.get("REPRO_SHARD_EXECUTOR", "").lower()
    if forced in ("mesh", "host"):
        return True
    if forced in ("off", "none", "0"):
        return False
    if "jax" not in sys.modules:
        return False
    try:
        import jax
        devs = jax.local_devices()
        return len(devs) > 1 and devs[0].platform != "cpu"
    except Exception:
        return False


def solve_program(program: ChainProgram, svc_flat: np.ndarray, *,
                  sweeps: int = 8, scan_backend: str = "auto",
                  fixpoint: str = "auto", warn: bool = True,
                  comp0: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, int, bool]:
    """Run the fused Gauss-Seidel fixpoint; returns ``(completions,
    sweeps_used, converged)`` in flat event order.

    ``fixpoint`` selects the driver: ``"loop"`` iterates family blocks
    in Python around the batched scan (float64; ``scan_backend`` as in
    :func:`repro.core.engine.zone_sequential_completions_batched`),
    ``"xla"`` / ``"pallas"`` run all sweeps x families in one jitted
    ``lax.while_loop`` / Pallas kernel (float32,
    ``repro.kernels.zns_fixpoint``); ``"sharded"`` partitions the
    entry axis across shards (:mod:`repro.core.shard`) — the mesh
    executor spreads them over local jax devices via ``shard_map``,
    the host executor groups them into signature buckets with
    independent convergence; ``"windowed"`` partitions the *request*
    axis of a single mega-entry into issue-time windows solved as a
    pipeline (:func:`repro.core.shard.solve_program_windowed`) with
    per-window bounded memory; ``"auto"`` picks the kernel on TPU, the
    sharded driver on multi-chip accelerator hosts for multi-device
    programs, and the float64 loop elsewhere.  Every driver records
    :class:`SolveStats` telemetry, readable via
    :func:`last_solve_stats`.  When the sweep budget
    is exhausted while constraints are still moving the result is a
    documented under-approximation -- a :class:`RuntimeWarning` is
    emitted unless ``warn=False``.

    ``comp0`` warm-starts the fixpoint from per-event completion lower
    bounds (flat event order).  The iteration is monotone from below,
    so any valid lower bound is safe; passing the solved completions of
    the member programs of a :func:`concat_programs` merge (their
    blocks share no constraints, so their fixpoints ARE the merged
    fixpoint) reduces the fleet-level solve to one cheap verification
    sweep of O(chain-length) edge checks.
    """
    if program.n_flat == 0:
        return np.zeros(0, dtype=np.float64), 0, True
    if len(svc_flat) != program.n_flat:
        raise ValueError(f"service vector has {len(svc_flat)} entries for a "
                         f"{program.n_flat}-request program")
    if fixpoint == "auto":
        fixpoint = "pallas" if _on_tpu() else "loop"
        if fixpoint == "loop" and program.n_devices > 1 \
                and _auto_sharded():
            fixpoint = "sharded"
    if comp0 is not None and len(comp0) != program.n_flat:
        raise ValueError(f"comp0 has {len(comp0)} entries for a "
                         f"{program.n_flat}-request program")
    if fixpoint == "loop":
        comp, used, converged = _solve_numpy(
            program, np.asarray(svc_flat, dtype=np.float64),
            sweeps=sweeps, scan_backend=scan_backend, comp0=comp0)
    elif fixpoint == "sharded":
        from .shard import solve_program_sharded
        comp, used, converged = solve_program_sharded(
            program, np.asarray(svc_flat, dtype=np.float64),
            sweeps=sweeps, scan_backend=scan_backend, comp0=comp0,
            warn=False)
    elif fixpoint == "windowed":
        from .shard import solve_program_windowed
        comp, used, converged = solve_program_windowed(
            program, np.asarray(svc_flat, dtype=np.float64),
            sweeps=sweeps, scan_backend=scan_backend, comp0=comp0,
            warn=False)
    elif fixpoint in ("xla", "pallas", "interpret"):
        comp, used, converged = _solve_kernel(
            program, np.asarray(svc_flat, dtype=np.float64),
            sweeps=sweeps, impl=fixpoint, comp0=comp0)
        global _LAST_SOLVE_STATS
        _LAST_SOLVE_STATS = SolveStats(
            driver=fixpoint, sweeps=used, converged=converged,
            n_blocks=len(program.families))
    else:
        raise ValueError(f"unknown fixpoint driver {fixpoint!r}; expected "
                         f"auto | loop | sharded | windowed | xla | "
                         f"pallas | interpret")
    if not converged and warn:
        warnings.warn(
            f"chain-program fixpoint exhausted its sweep budget "
            f"({sweeps}) while still moving; completions are a lower "
            f"bound. Raise ZnsDevice.run(..., sweeps=...) or inspect "
            f"SimResult.converged.", RuntimeWarning, stacklevel=3)
    return comp, used, converged


def unpack_results(program: ChainProgram, comp_flat: np.ndarray,
                   svc_flat: np.ndarray, svc_origs: Sequence[np.ndarray]
                   ) -> List["SimResult"]:
    """Split a flat solve back into per-device trace-order results."""
    from .engine import SimResult
    out = []
    for d in range(program.n_devices):
        sl = program.device_slice(d)
        if sl.stop == sl.start:
            z = np.zeros(0, dtype=np.float64)
            out.append(SimResult(start=z, complete=z.copy(),
                                 service=svc_origs[d]))
            continue
        comp = comp_flat[sl]
        svc = svc_flat[sl]
        inv = program.invs[d]
        out.append(SimResult(start=(comp - svc)[inv].copy(),
                             complete=comp[inv].copy(),
                             service=svc_origs[d]))
    return out
