"""Batched multi-device simulation engine behind ``DeviceFleet``.

A fleet sweep (N devices x one workload each) used to be a Python loop of
single-device runs.  This module runs the vectorized backend's
chain-decomposed max-plus scans *batched across devices*: each device's
trace is decomposed into the same serialized chain families as
:func:`repro.core.engine.simulate_vectorized` (per-thread closed-loop
lag-qd chains, per-zone write chains, metadata engine, lag-capacity pool
chains), and every Gauss–Seidel sweep solves one family for *all* devices
with a single (B, L) segmented max-plus scan —
:func:`repro.core.engine.zone_sequential_completions_batched`, i.e. the
Pallas kernel's batch grid dimension on TPU and the batched numpy doubling
scan elsewhere.

Per-device results are bit-compatible with single-device runs: service
times draw from per-device seeds in the same rng order, chain families are
identical, the batched scan computes the same per-segment compositions
(padding rows only append isolated segments), and sweeps apply families in
the same :data:`repro.core.engine.FAMILY_ORDER`.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .engine import (
    FAMILY_ORDER, SimResult, Trace, compute_service_times,
    trace_chain_families, zone_sequential_completions_batched,
)
from .latency import resolve_params
from .spec import ZNSDeviceSpec


def _pad_rows(rows: List[np.ndarray], fill: float, dtype) -> np.ndarray:
    """Stack variable-length 1-D arrays into a padded (R, L) matrix."""
    L = max(len(r) for r in rows)
    out = np.full((len(rows), L), fill, dtype=dtype)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


#: Rows whose lengths differ by more than this factor go to separate
#: padded batches (see :func:`length_buckets`).
BUCKET_RATIO = 4.0


def length_buckets(lens: Sequence[int], *, ratio: float = BUCKET_RATIO
                   ) -> List[List[int]]:
    """Group row indices so each padded batch wastes bounded work.

    Sweep-point stacking across experiments (``repro.experiments``) mixes
    chains of wildly different lengths in one fleet call — a 40-request
    occupancy sweep next to a 100k-request I/O trace.  Padding all rows
    to the global max makes the scan do O(R * Lmax) work; bucketing rows
    whose max/min length ratio stays under ``ratio`` keeps the padding
    overhead a constant factor while still batching similar-length rows.
    Returns index lists, each sorted, covering ``range(len(lens))``.
    """
    order = sorted(range(len(lens)), key=lambda i: (lens[i], i))
    buckets: List[List[int]] = []
    base = None
    for i in order:
        if base is not None and lens[i] <= base * ratio:
            buckets[-1].append(i)
        else:
            buckets.append([i])
            base = max(lens[i], 1)
    return [sorted(b) for b in buckets]


def simulate_fleet_vectorized(traces: Sequence[Trace],
                              specs: Sequence[ZNSDeviceSpec],
                              lats: Sequence,
                              *, seeds: Optional[Sequence[int]] = None,
                              jitter: bool = True, sweeps: int = 8,
                              scan_backend: str = "auto") -> List[SimResult]:
    """Vectorized simulation of N heterogeneous devices at once.

    ``lats[i]`` may be a :class:`LatencyModel` or bare
    :class:`LatencyParams`.  ``seeds[i]`` defaults to ``i`` so device ``i``
    draws the jitter stream of a single-device run with ``seed=i``.
    Returns one :class:`SimResult` per device, equal (to float tolerance)
    to a Python loop of per-device ``simulate_vectorized`` calls.
    """
    B = len(traces)
    if not (len(specs) == len(lats) == B):
        raise ValueError(f"fleet shape mismatch: {B} traces, {len(specs)} "
                         f"specs, {len(lats)} latency models")
    seeds = list(range(B)) if seeds is None else list(seeds)
    params = [resolve_params(l) for l in lats]

    # -- per-device prep: event order, service times, chain families --------
    dev = []
    for b in range(B):
        tr = traces[b]
        n = len(tr)
        svc_orig = compute_service_times(tr, params[b], seed=seeds[b],
                                         jitter=jitter)
        if n == 0:
            dev.append(dict(empty=True, svc_orig=svc_orig))
            continue
        order = np.argsort(tr.issue, kind="stable")
        inv = np.empty(n, dtype=np.int64)
        inv[order] = np.arange(n)
        svc = svc_orig[order]
        fams = dict()
        for kind, perm, heads in trace_chain_families(
                tr.op[order], tr.zone[order].astype(np.int64),
                tr.thread[order].astype(np.int64),
                np.maximum(tr.qd[order].astype(np.int64), 1),
                specs[b],
                meta_on_io_path=bool(params[b].reset_on_io_path)):
            fams[kind] = (perm, heads)
        dev.append(dict(n=n, inv=inv, svc=svc, svc_orig=svc_orig,
                        comp=tr.issue[order] + svc, fams=fams))

    # -- batched per-kind matrices (constant across sweeps) -----------------
    # Rows are length-bucketed so stacking short mgmt sweeps next to long
    # I/O traces (heterogeneous experiment batches) doesn't pad every row
    # to the global max chain length.
    batched = {}
    for kind in FAMILY_ORDER:
        members = [(b, *dev[b]["fams"][kind]) for b in range(B)
                   if "fams" in dev[b] and kind in dev[b]["fams"]]
        if not members:
            continue
        groups = []
        for idx in length_buckets([len(perm) for _, perm, _ in members]):
            sub = [members[i] for i in idx]
            lens = [len(perm) for _, perm, _ in sub]
            svc_mat = _pad_rows([dev[b]["svc"][perm] for b, perm, _ in sub],
                                0.0, np.float64)
            # padded tail: isolated empty segments at t=0, masked on scatter
            head_mat = _pad_rows([heads for _, _, heads in sub], True, bool)
            groups.append((sub, lens, svc_mat, head_mat))
        batched[kind] = groups

    # -- Gauss–Seidel sweeps, one batched scan per family bucket ------------
    for _ in range(max(sweeps, 1)):
        moved = False
        for kind in FAMILY_ORDER:
            for members, lens, svc_mat, head_mat in batched.get(kind, ()):
                cur = np.zeros_like(svc_mat)
                for r, (b, perm, _) in enumerate(members):
                    cur[r, :lens[r]] = dev[b]["comp"][perm]
                out = zone_sequential_completions_batched(
                    cur - svc_mat, svc_mat, head_mat, backend=scan_backend)
                for r, (b, perm, _) in enumerate(members):
                    o, c = out[r, :lens[r]], cur[r, :lens[r]]
                    # anything beyond float noise counts as progress
                    if (o > c * (1.0 + 1e-12) + 1e-9).any():
                        moved = True
                        dev[b]["comp"][perm] = np.maximum(c, o)
        if not moved:
            break

    # -- unpack per-device results ------------------------------------------
    results = []
    for b in range(B):
        if dev[b].get("empty"):
            z = np.zeros(0, dtype=np.float64)
            results.append(SimResult(start=z, complete=z.copy(),
                                     service=dev[b]["svc_orig"]))
            continue
        inv = dev[b]["inv"]
        comp = dev[b]["comp"]
        svc = dev[b]["svc"]
        results.append(SimResult(start=(comp - svc)[inv].copy(),
                                 complete=comp[inv].copy(),
                                 service=dev[b]["svc_orig"]))
    return results


def batched_sequential_completions(issues: Sequence[np.ndarray],
                                   svcs: Sequence[np.ndarray],
                                   segs: Sequence[np.ndarray], *,
                                   backend: str = "auto") -> List[np.ndarray]:
    """Ragged batched max-plus scan: per-device 1-D arrays in, per-device
    completion times out, computed as one (B, L) padded scan."""
    if not (len(issues) == len(svcs) == len(segs)):
        raise ValueError("ragged batch length mismatch")
    if not issues:
        return []
    lens = [len(i) for i in issues]
    issue_mat = _pad_rows([np.asarray(i, dtype=np.float64) for i in issues],
                          0.0, np.float64)
    svc_mat = _pad_rows([np.asarray(s, dtype=np.float64) for s in svcs],
                        0.0, np.float64)
    seg_mat = _pad_rows([np.asarray(s, dtype=bool) for s in segs], True, bool)
    out = zone_sequential_completions_batched(issue_mat, svc_mat, seg_mat,
                                              backend=backend)
    return [out[i, :lens[i]] for i in range(len(lens))]
