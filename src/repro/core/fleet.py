"""Batched multi-device simulation engine behind ``DeviceFleet``.

A fleet sweep (N devices x one workload each) used to be a Python loop of
single-device runs.  This module lowers all devices' traces into one
fleet-level :class:`repro.core.ChainProgram`
(:func:`repro.core.chain_program.compile_fleet_program`): per-device
chain families — per-thread closed-loop lag-qd chains, per-zone write
chains, metadata engine, pop-ordered per-service-class pool chains —
concatenate into fleet-wide length-bucketed ``(R, L)`` family blocks
addressing one flat completion vector, and the whole fleet solves as a
single fused Gauss–Seidel fixpoint of batched segmented max-plus scans
(the Pallas ``zns_fixpoint`` kernel on TPU, the batched float64 numpy
doubling scan elsewhere).

Per-device results are bit-compatible with single-device runs: service
times draw from per-device seeds in the same rng order, lowering is
per-device (fleet assembly only concatenates and pads; padding rows
append isolated segments the scan treats as exact no-ops), and sweeps
apply family blocks in the same canonical order.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional, Sequence

import numpy as np

from .engine import (
    SimResult, Trace, compute_service_times,
    zone_sequential_completions_batched,
)
from .latency import resolve_params
from .spec import ZNSDeviceSpec


def _pad_rows(rows: List[np.ndarray], fill: float, dtype) -> np.ndarray:
    """Stack variable-length 1-D arrays into a padded (R, L) matrix."""
    L = max(len(r) for r in rows)
    out = np.full((len(rows), L), fill, dtype=dtype)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


#: Rows whose lengths differ by more than this factor go to separate
#: padded batches (see :func:`length_buckets`).
BUCKET_RATIO = 4.0


def length_buckets(lens: Sequence[int], *, ratio: float = BUCKET_RATIO
                   ) -> List[List[int]]:
    """Group row indices so each padded batch wastes bounded work.

    Sweep-point stacking across experiments (``repro.experiments``) mixes
    chains of wildly different lengths in one fleet call — a 40-request
    occupancy sweep next to a 100k-request I/O trace.  Padding all rows
    to the global max makes the scan do O(R * Lmax) work; bucketing rows
    whose max/min length ratio stays under ``ratio`` keeps the padding
    overhead a constant factor while still batching similar-length rows.
    Returns index lists, each sorted, covering ``range(len(lens))``.
    """
    order = sorted(range(len(lens)), key=lambda i: (lens[i], i))
    buckets: List[List[int]] = []
    base = None
    for i in order:
        if base is not None and lens[i] <= base * ratio:
            buckets[-1].append(i)
        else:
            buckets.append([i])
            base = max(lens[i], 1)
    return [sorted(b) for b in buckets]


def _warn_fleet_budget(program, svc_flat: np.ndarray, comp: np.ndarray,
                       used: int, budget: int) -> None:
    """One aggregated sweep-budget RuntimeWarning per fleet solve.

    The per-device warning of :func:`repro.core.solve_program` would
    fire once per fleet call anyway (one fused solve), but it names no
    devices; this one lists the entry indices whose completions are
    still moving (found by one Bellman-target evaluation of the final
    iterate) together with the sweeps used and the budget.
    """
    from . import chain_program as cp
    target = cp._fixpoint_target(program, np.asarray(svc_flat), comp)
    moving = np.nonzero(target > comp + 1e-9)[0]
    if len(moving):
        edges = np.asarray(program.offsets + (program.n_flat,))
        devs = np.unique(np.searchsorted(edges, moving, side="right") - 1)
        detail = (f"completions are still moving on {len(devs)} of "
                  f"{program.n_devices} entries (indices {devs.tolist()}) "
                  f"and are a lower bound there")
    else:
        detail = ("the final iterate verifies as the fixpoint post-hoc "
                  "on every entry; the budget only precluded in-solve "
                  "verification")
    warnings.warn(
        f"fleet chain-program fixpoint exhausted its sweep budget "
        f"(sweeps_used={used}, budget={budget}): {detail}. Raise "
        f"sweeps= or inspect FleetRunResult.converged.",
        RuntimeWarning, stacklevel=3)


def simulate_fleet_vectorized(traces: Sequence[Trace],
                              specs: Sequence[ZNSDeviceSpec],
                              lats: Sequence,
                              *, seeds: Optional[Sequence[int]] = None,
                              jitter: bool = True, sweeps: int = 8,
                              scan_backend: str = "auto",
                              fixpoint: str = "auto",
                              refine: Optional[int] = None,
                              program=None) -> List[SimResult]:
    """Vectorized simulation of N heterogeneous devices at once.

    All devices' traces are lowered (once, cached) into a single
    fleet-level :class:`repro.core.ChainProgram` — per-device programs
    concatenated into one flat completion vector with fleet-wide
    length-bucketed family blocks — and solved by one fused fixpoint
    (:func:`repro.core.chain_program.solve_program`): one kernel launch
    for N heterogeneous devices instead of ``sweeps × families ×
    devices`` dispatches.  On hosts with more than one local jax
    accelerator device, ``fixpoint="auto"`` routes the solve through
    the entry-sharded driver (:mod:`repro.core.shard`) — per-shard
    convergence budgets, ``shard_map`` over the local mesh — so fleet
    callers (``DeviceFleet.run``, the experiment runner, the capacity
    planner) scale out transparently; pass ``fixpoint="loop"`` to pin
    the single-chip solve, or ``"sharded"`` to force the sharded one.

    ``lats[i]`` may be a :class:`LatencyModel` or bare
    :class:`LatencyParams`.  ``seeds[i]`` defaults to ``i`` so device ``i``
    draws the jitter stream of a single-device run with ``seed=i``.
    Returns one :class:`SimResult` per device, equal (to float tolerance)
    to a Python loop of per-device ``simulate_vectorized`` calls.
    ``program`` reuses a pre-compiled fleet program (must match the
    traces); ``refine`` overrides the pop-order refinement budget.
    """
    from . import chain_program as cp
    B = len(traces)
    if not (len(specs) == len(lats) == B):
        raise ValueError(f"fleet shape mismatch: {B} traces, {len(specs)} "
                         f"specs, {len(lats)} latency models")
    seeds = list(range(B)) if seeds is None else list(seeds)
    params = [resolve_params(l) for l in lats]
    if program is None:
        program = cp.compile_fleet_program(
            traces, specs, params,
            refine=cp.DEFAULT_REFINE if refine is None else refine,
            jitter=jitter, seeds=seeds)
    if jitter:
        svc_origs = [compute_service_times(traces[b], params[b],
                                           seed=seeds[b], jitter=True)
                     for b in range(B)]
        svc_flat = np.concatenate(
            [svc_origs[b][program.orders[b]] for b in range(B)]) \
            if B else np.zeros(0)
    else:
        # jitter-free service times are part of the lowering output
        svc_flat = program.svc0_flat
        svc_origs = [svc_flat[program.device_slice(b)][program.invs[b]]
                     for b in range(B)]
    comp, used, converged = cp.solve_program(
        program, svc_flat, sweeps=sweeps, scan_backend=scan_backend,
        fixpoint=fixpoint, warn=False)
    if not converged:
        _warn_fleet_budget(program, svc_flat, comp, used, sweeps)
    results = cp.unpack_results(program, comp, svc_flat, svc_origs)
    # the compile-time exactness claim binds to the refinement service
    # vector; a jittered solve of a jitter-free program (or a seed
    # mismatch on a pre-compiled one) voids it
    seeds_bind = tuple(int(s) for s in seeds) if jitter else None
    claimed = bool(program.exact) and program.svc_seeds == seeds_bind
    return [dataclasses.replace(
        r, sweeps_used=used, converged=converged, exact=claimed,
        order_stable=bool(program.order_stable),
        unstable_pools=tuple(program.unstable_pools))
        for r in results]


def batched_sequential_completions(issues: Sequence[np.ndarray],
                                   svcs: Sequence[np.ndarray],
                                   segs: Sequence[np.ndarray], *,
                                   backend: str = "auto") -> List[np.ndarray]:
    """Ragged batched max-plus scan: per-device 1-D arrays in, per-device
    completion times out, computed as one (B, L) padded scan."""
    if not (len(issues) == len(svcs) == len(segs)):
        raise ValueError("ragged batch length mismatch")
    if not issues:
        return []
    lens = [len(i) for i in issues]
    issue_mat = _pad_rows([np.asarray(i, dtype=np.float64) for i in issues],
                          0.0, np.float64)
    svc_mat = _pad_rows([np.asarray(s, dtype=np.float64) for s in svcs],
                        0.0, np.float64)
    seg_mat = _pad_rows([np.asarray(s, dtype=bool) for s in segs], True, bool)
    out = zone_sequential_completions_batched(issue_mat, svc_mat, seg_mat,
                                              backend=backend)
    return [out[i, :lens[i]] for i in range(len(lens))]
