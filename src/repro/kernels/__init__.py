"""Pallas TPU kernels for the framework's compute hot-spots plus the
paper-domain event-scan kernel.  See ops.py for the dispatching API and
ref.py for the pure-jnp oracles."""
from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    attention, linear_recurrence, rmsnorm, ssd_scan, zns_event_scan,
)
