"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes and
dtypes and ``assert_allclose`` against these.  They are also the XLA
fallback paths used on CPU (e.g. for the multi-pod dry-run, where Pallas
TPU kernels cannot lower).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None, kv_length=None):
    """Dense attention oracle.

    q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D).  GQA handled by head repeat.
    ``window``: local-attention window (keys within [pos-window+1, pos]).
    ``kv_length``: optional (B,) valid KV lengths (decode with cache).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    tk = k.shape[2]
    qpos = jnp.arange(tq)[:, None] + (tk - tq)   # align ends (decode offset)
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_length is not None:
        lmask = kpos[None] < kv_length[:, None, None]   # (B, 1q, Tk)
        logits = jnp.where(lmask[:, None], logits, NEG_INF)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_xla_chunked(q, k, v, *, causal: bool = True,
                          window: int | None = None,
                          scale: float | None = None,
                          q_chunk: int = 512):
    """Flash-style chunked attention in pure XLA (memory-bounded fallback).

    Matches the Pallas kernel's memory behaviour on backends where Pallas
    cannot lower (the CPU dry-run): the (B, H, Tq, Tk) logits tensor is
    never materialized — queries are processed in chunks of ``q_chunk``
    with the chunk body rematerialized in the backward pass.  GQA handled
    by head grouping, not repetition.
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    nc = -(-tq // q_chunk)
    tq_p = nc * q_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
    # GQA: repeat KV to full heads.  A (hkv, rep) grouped einsum would be
    # cheaper on paper, but it splits the sharded head dim and GSPMD then
    # un-shards the batch (measured: a 4 GiB/chip stray all-reduce on
    # llama3-405b).  The repeated KV shards cleanly over heads.
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    offset = tk - tq

    @jax.checkpoint
    def chunk(ci, qc):
        # qc: (B, H, cq, D)
        logits = jnp.einsum("bhqd,bhsd->bhqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = (ci * q_chunk + jnp.arange(q_chunk))[:, None] + offset
        kpos = jnp.arange(tk)[None, :]
        mask = jnp.ones((q_chunk, tk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqs,bhsd->bhqd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    def body(_, ci):
        qc = jax.lax.dynamic_slice_in_dim(qp, ci * q_chunk, q_chunk, axis=2)
        return None, chunk(ci, qc)

    _, chunks = jax.lax.scan(body, None, jnp.arange(nc))
    # chunks: (nc, B, H, cq, D)
    out = jnp.moveaxis(chunks, 0, 2).reshape(b, hq, tq_p, d)
    return out[:, :, :tq]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Diagonal linear recurrence (RG-LRU core): h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------
def linear_recurrence_ref(a, b, h0=None):
    """a, b: (B, T, D) -> h: (B, T, D); float32 internally."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(a32, 1, 0), jnp.moveaxis(b32, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) scan
# ---------------------------------------------------------------------------
def ssd_ref(x, dt, A, B, C, *, init_state=None):
    """Sequential SSD oracle (Mamba2 eq. form).

    x:  (Bb, T, H, P)   inputs per head
    dt: (Bb, T, H)      positive step sizes
    A:  (H,)            negative scalars per head (decay = exp(dt*A))
    B:  (Bb, T, G, N)   input projections (G groups broadcast over heads)
    C:  (Bb, T, G, N)   output projections
    returns y: (Bb, T, H, P), final_state: (Bb, H, P, N)
    """
    Bb, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)    # (Bb,T,H,N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    if init_state is None:
        init_state = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp      # (Bb,H,P), (Bb,H), (Bb,H,N), (Bb,H,N)
        decay = jnp.exp(dtt * Af)[..., None, None]          # (Bb,H,1,1)
        S = S * decay + jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bhn->bhp", S, Ct)
        return S, y

    inputs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
              jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    S, ys = jax.lax.scan(step, init_state.astype(jnp.float32), inputs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S


# ---------------------------------------------------------------------------
# ZNS event scan: c_i = max(c_{i-1}, s_i) + v_i with segment resets
# ---------------------------------------------------------------------------
def zns_event_scan_ref(issue, svc, seg_start):
    """Max-plus linear recurrence oracle (numpy loop semantics in jnp).

    issue/svc: (N,) float; seg_start: (N,) bool marking segment heads.
    """
    issue = issue.astype(jnp.float32)
    svc = svc.astype(jnp.float32)

    def step(c, inp):
        s, v, head = inp
        c = jnp.where(head, jnp.float32(NEG_INF), c)
        c = jnp.maximum(c, s) + v
        return c, c

    _, out = jax.lax.scan(step, jnp.float32(NEG_INF), (issue, svc, seg_start))
    return out


def zns_event_scan_batched_ref(issue, svc, seg_start):
    """Batched oracle: vmap of the 1-D scan over a leading device axis."""
    return jax.vmap(zns_event_scan_ref)(issue, svc, seg_start)


def zns_fixpoint_ref(comp0, svc, blocks, *, sweeps: int = 8):
    """Chain-program fixpoint oracle (eager Gauss–Seidel sweeps).

    ``comp0``/``svc``: flat (n,) vectors; ``blocks``: tuple of
    ``(gidx, heads)`` (R, L) index/head matrices with padding indexed
    at ``n`` (a dead slot).  Each sweep gathers completions per block,
    runs the *sequential* batched scan oracle, and scatter-maxes back;
    stops when nothing moved.  Ground truth for
    ``repro.kernels.zns_fixpoint``.  Family semantics (which chains a
    block encodes — thread loops, zone chains, greedy-replay pool
    couplings) live entirely in the compiler; every block is just
    segmented max-plus to this oracle and the kernels alike.
    """
    rtol, atol = 1e-5, 1e-3          # float32 progress thresholds
    comp = jnp.append(comp0.astype(jnp.float32), jnp.float32(NEG_INF))
    svc_e = jnp.append(svc.astype(jnp.float32), jnp.float32(0.0))
    dead = comp.shape[0] - 1
    used, moved = 0, True
    for s in range(max(int(sweeps), 1)):
        moved = False
        for gidx, heads in blocks:
            gidx = jnp.asarray(gidx)
            svc_m = svc_e[gidx]
            cur = comp[gidx]
            out = zns_event_scan_batched_ref(cur - svc_m, svc_m,
                                             jnp.asarray(heads))
            # mask padding: it gathers the finite NEG_INF sentinel and
            # would trivially pass the relative-progress test
            moved = moved or bool(jnp.any(
                (out > cur * (1.0 + rtol) + atol) & (gidx < dead)))
            comp = comp.at[gidx].max(jnp.maximum(cur, out))
            comp = comp.at[-1].set(jnp.float32(NEG_INF))
        used = s + 1
        if not moved:
            break
    return comp[:-1], used, not moved


# ---------------------------------------------------------------------------
# shared helper: affine scans as (a, b) pair composition
# ---------------------------------------------------------------------------
def affine_scan_pairs_ref(a, b, *, semiring: str):
    """Inclusive scan of affine maps f_i(c) = a_i (*) c (+) b_i.

    semiring='mul_add':  f(c) = a*c + b        (linear recurrence)
    semiring='max_plus': f(c) = max(c + a, b)  (ZNS event recurrence)
    Returns composed (A_i, B_i) such that c_i = f_i(...f_1(c_0)).
    """
    if semiring == "mul_add":
        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, b1 * a2 + b2
    elif semiring == "max_plus":
        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 + a2, jnp.maximum(b1 + a2, b2)
    else:
        raise ValueError(semiring)
    return jax.lax.associative_scan(comb, (a, b), axis=0)
