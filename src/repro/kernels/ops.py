"""Public jit'd wrappers for the kernel package.

Every op takes ``impl`` (or infers it): 'pallas' runs the Pallas kernel
compiled for TPU, 'interpret' runs the kernel body in interpret mode
(CPU correctness), 'xla' runs the pure-jnp oracle from ref.py.  The
default 'auto' picks 'pallas' on TPU backends and 'xla' elsewhere — the
multi-pod dry-run therefore lowers the XLA path, while kernel tests pin
'interpret' to exercise the kernel bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _fa
from .linear_recurrence import linear_recurrence as _lr
from .rmsnorm import rmsnorm as _rms
from .ssd_chunk_scan import ssd_chunk_scan as _ssd
from .zns_event_scan import zns_event_scan as _zns
from .zns_event_scan import zns_event_scan_batched as _zns_batched
from .zns_fixpoint import zns_fixpoint as _zns_fixpoint
from .zns_fixpoint import zns_fixpoint_xla as _zns_fixpoint_xla


def _default_impl() -> str:
    try:
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    except Exception:
        return "xla"


def _resolve(impl: str | None) -> str:
    return impl if impl not in (None, "auto") else _default_impl()


def attention(q, k, v, *, causal=True, window=None, scale=None,
              kv_length=None, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla" or kv_length is not None:
        tq, tk = q.shape[2], k.shape[2]
        if kv_length is None and tq * tk > 1024 * 1024:
            # memory-bounded flash-style path (mirrors the Pallas kernel)
            return ref.attention_xla_chunked(q, k, v, causal=causal,
                                             window=window, scale=scale)
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, kv_length=kv_length)
    return _fa(q, k, v, causal=causal, window=window, scale=scale,
               interpret=(impl == "interpret"))


def rmsnorm(x, w, *, eps=1e-6, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.rmsnorm_ref(x, w, eps=eps)
    return _rms(x, w, eps=eps, interpret=(impl == "interpret"))


def linear_recurrence(a, b, *, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.linear_recurrence_ref(a, b)
    return _lr(a, b, interpret=(impl == "interpret"))


def ssd_scan(x, dt, A, B, C, *, chunk=128, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ssd_ref(x, dt, A, B, C)
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=(impl == "interpret"))


def zns_event_scan(issue, svc, seg_start, *, impl: str | None = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.zns_event_scan_ref(issue, svc, seg_start)
    return _zns(issue, svc, seg_start, interpret=(impl == "interpret"))


def zns_event_scan_batched(issue, svc, seg_start, *, impl: str | None = None):
    """(B, N) device-batched max-plus scan (the DeviceFleet hot loop)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.zns_event_scan_batched_ref(issue, svc, seg_start)
    return _zns_batched(issue, svc, seg_start, interpret=(impl == "interpret"))


def zns_fixpoint(comp0, svc, blocks, *, sweeps: int = 8,
                 impl: str | None = None, adj=None):
    """Fused chain-program fixpoint: all sweeps × family blocks in one
    compiled call (the ``ZnsDevice``/``DeviceFleet`` vectorized-backend
    hot loop on TPU).

    ``blocks``: tuple of ``(gidx, heads)`` padded index/head matrices
    from :class:`repro.core.ChainProgram`.  ``adj`` is the symmetric
    block-adjacency matrix (``repro.core.chain_program.block_adjacency``)
    driving the in-kernel active-set mask; computed from the blocks when
    omitted.  Returns ``(completions, sweeps_used, converged)``.
    ``impl='xla'`` runs the jitted ``lax.while_loop`` form,
    ``'pallas'``/``'interpret'`` the Pallas kernel (compiled / interpret
    mode).
    """
    from .zns_fixpoint import blocks_adjacency
    impl = _resolve(impl)
    blocks = tuple((jnp.asarray(g, dtype=jnp.int32), jnp.asarray(h, bool))
                   for g, h in blocks)
    comp0 = jnp.asarray(comp0, dtype=jnp.float32)
    svc = jnp.asarray(svc, dtype=jnp.float32)
    if adj is None:
        adj = blocks_adjacency([g for g, _ in blocks], comp0.shape[0])
    adj = jnp.asarray(adj, dtype=bool)
    if impl == "xla":
        return _zns_fixpoint_xla(comp0, svc, blocks, adj, sweeps=int(sweeps))
    return _zns_fixpoint(comp0, svc, blocks, adj, sweeps=int(sweeps),
                         interpret=(impl == "interpret"))
