"""Fused chain-program fixpoint — all sweeps × families in one kernel.

The trace-compilation layer (:mod:`repro.core.chain_program`) lowers a
fleet of traces into family blocks: padded ``(R, L)`` gather-index +
segment-head tensors addressing one flat completion vector (dead slot at
index ``n``).  One Gauss–Seidel sweep applies, per block, a segmented
max-plus scan to the gathered completions and scatter-maxes the result
back; sweeps repeat until an early-exit ``moved`` reduction clears.

The blocks carry *all* of the compiler's chain families through one
uniform metadata shape — per-thread closed-loop lag chains, per-zone
write chains, the metadata engine, and the greedy-replay server-pool
coupling chains (per-server pop sequences, multi-class and jittered
alike).  Nothing pool-specific reaches this layer: exactness is decided
entirely at compile time (``ChainProgram.exact``), and the kernels just
run whatever segmented scans they are handed — which is what lets the
fused solver replace the event engine everywhere outside tests.

This module runs that whole fixpoint as one compiled artifact instead of
``sweeps × families`` host dispatches:

* :func:`zns_fixpoint_xla` — a jitted ``lax.while_loop`` whose body
  unrolls the (static) family blocks; the per-block scan is the same
  Hillis–Steele doubling ladder as ``zns_event_scan``, vectorized over
  rows, and the scatter is ``comp.at[gidx].max(...)`` (duplicate dead
  indices max-reduce harmlessly).
* :func:`zns_fixpoint` — the Pallas form: the fixpoint core runs inside
  a single ``pallas_call`` with the flat completion vector resident in
  kernel memory, so sweep iteration never round-trips to the host.
  (Like the other kernels in this package it defaults to interpret mode
  off-TPU; on TPU the blocks map to VMEM tiles with the while-loop
  carried in-kernel.)

The semantic ground truth is ``repro.kernels.ref.zns_fixpoint_ref``
(sequential per-row scans).  Production CPU solves use the float64
numpy driver in :func:`repro.core.chain_program.solve_program`; these
float32 kernels are the TPU path and are equivalence-tested against the
oracle at float32 tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30
#: Progress thresholds of the early-exit ``moved`` reduction (float32:
#: looser than the numpy driver's 1e-12/1e-9).
MOVED_RTOL = 1e-5
MOVED_ATOL = 1e-3


def _pad_value(dtype):
    """Padding sentinel: the historical finite ``NEG_INF`` for float32,
    true ``-inf`` for float64 — with ``-inf``, all-padding lanes can
    never satisfy the progress test (``-inf < -inf`` is false), which
    matches the numpy driver's ``-np.inf`` semantics exactly."""
    if dtype == jnp.float64:
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(NEG_INF, dtype)


def _moved_tol(dtype):
    """Early-exit progress tolerances: the float64 path mirrors the
    numpy driver's rel 1e-12 / abs 1e-9; float32 keeps the looser
    kernel thresholds."""
    if dtype == jnp.float64:
        return 1e-12, 1e-9
    return MOVED_RTOL, MOVED_ATOL


def _rows_maxplus(start, svc, heads):
    """Segmented max-plus scan over the rows of (R, L) matrices.

    Same affine-map composition as ``zns_event_scan`` — ``a = svc``
    (``-inf`` at segment heads), ``b = start + svc`` — as a doubling
    ladder of ``log2(L)`` shifted composes, vectorized over rows.
    dtype-generic: float32 keeps the finite ``NEG_INF`` sentinel,
    float64 uses true ``-inf``.
    """
    r, n = start.shape
    dt = start.dtype
    ninf = _pad_value(dt)
    a = jnp.where(heads, ninf, svc)
    b = start + svc
    k = 1
    while k < n:
        a_prev = jnp.concatenate(
            [jnp.zeros((r, k), dt), a[:, :-k]], axis=1)
        b_prev = jnp.concatenate(
            [jnp.full((r, k), ninf, dt), b[:, :-k]], axis=1)
        # compose earlier (shifted) map, then current: (a_p,b_p) . (a,b)
        a, b = a_prev + a, jnp.maximum(b_prev + a, b)
        k *= 2
    return b


def blocks_adjacency(gidxs, n: int) -> np.ndarray:
    """Symmetric ``(F, F)`` bool block adjacency from raw gather-index
    matrices: ``adj[i, j]`` iff blocks ``i`` and ``j`` address a common
    flat slot (padding at ``n`` excluded).  Diagonal False — a block is
    at its own fixpoint right after its scan.  Host-side numpy; the
    kernels consume the result as a traced bool array."""
    nf = len(gidxs)
    adj = np.zeros((nf, nf), dtype=bool)
    if nf > 1:
        parts, owners = [], []
        for f, g in enumerate(gidxs):
            flat = np.asarray(g).ravel()
            flat = flat[flat != n]
            parts.append(flat)
            owners.append(np.full(len(flat), f, dtype=np.int32))
        idx = np.concatenate(parts)
        own = np.concatenate(owners)
        order = np.argsort(idx, kind="stable")
        idx, own = idx[order], own[order]
        # an index appears at most once per block, so runs of equal
        # index are <= F long; shifted compares cover all in-run pairs
        for k in range(1, nf):
            same = idx[k:] == idx[:-k]
            if not same.any():
                break
            adj[own[k:][same], own[:-k][same]] = True
            adj[own[:-k][same], own[k:][same]] = True
        np.fill_diagonal(adj, False)
    return adj


def _fixpoint_core(comp_ext, svc_ext, blocks, sweeps: int, adj=None):
    """``lax.while_loop`` fixpoint shared by the XLA and Pallas forms.

    ``comp_ext``/``svc_ext``: flat ``(n + 1,)`` vectors (dead slot
    last); ``blocks``: static tuple of ``(gidx, heads)`` pairs; ``adj``
    the ``(F, F)`` bool block adjacency driving the active-set mask (a
    converged block costs one predicate evaluation instead of a full
    gather + scan until a neighbour's scatter re-activates it; ``None``
    keeps every block active every sweep).  Returns ``(comp_ext,
    sweeps_used, moved)`` where ``moved`` means "blocks still active at
    exit" — its negation is the convergence flag.
    """

    dead = comp_ext.shape[0] - 1
    dt = comp_ext.dtype
    ninf = _pad_value(dt)
    rtol, atol = _moved_tol(dt)
    nf = len(blocks)
    if adj is None:
        adj = jnp.zeros((nf, nf), dtype=bool) if nf == 0 \
            else jnp.ones((nf, nf), bool) & ~jnp.eye(nf, dtype=bool)
    later_f = [jnp.arange(nf) > f for f in range(nf)]

    def body(carry):
        comp, s, active = carry
        act_now = active
        act_next = jnp.zeros_like(active)
        for f, (gidx, heads) in enumerate(blocks):

            def run(comp, gidx=gidx, heads=heads):
                svc_m = svc_ext[gidx]
                cur = comp[gidx]
                out = _rows_maxplus(cur - svc_m, svc_m, heads)
                # padding gathers the sentinel, which would trivially
                # satisfy the relative-progress test — mask it out
                mv = jnp.any((out > cur * (1.0 + rtol) + atol)
                             & (gidx < dead))
                comp = comp.at[gidx].max(jnp.maximum(cur, out))
                comp = comp.at[-1].set(ninf)
                return comp, mv

            comp, mv = jax.lax.cond(
                act_now[f], run, lambda c: (c, jnp.bool_(False)), comp)
            # a moving block re-activates neighbours: later blocks see
            # the scatter within this sweep (Gauss–Seidel order),
            # earlier ones on the next sweep
            nbr = adj[f] & mv
            act_now = act_now | (nbr & later_f[f])
            act_next = act_next | (nbr & ~later_f[f])
        return comp, s + 1, act_next

    comp, used, active = jax.lax.while_loop(
        lambda c: (c[1] < sweeps) & jnp.any(c[2]),
        body, (comp_ext, jnp.int32(0), jnp.ones((max(nf, 1),), bool)))
    return comp, used, jnp.any(active)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def zns_fixpoint_xla(comp0, svc, blocks, adj=None, *, sweeps: int = 8):
    """Fused fixpoint as a jitted ``lax.while_loop`` (no Pallas).

    ``comp0``: (n,) initial completions (``issue + svc``); ``svc``: (n,)
    service times; ``blocks``: tuple of ``(gidx int32 (R, L), heads
    bool (R, L))`` with padding indexed at ``n``; ``adj``: optional
    ``(F, F)`` bool block adjacency for the active-set mask.  Returns
    ``(comp (n,), sweeps_used, converged)``.
    """
    comp_ext = jnp.append(comp0.astype(jnp.float32),
                          jnp.float32(NEG_INF))
    svc_ext = jnp.append(svc.astype(jnp.float32), jnp.float32(0.0))
    comp, used, moved = _fixpoint_core(comp_ext, svc_ext, blocks, sweeps,
                                       adj)
    return comp[:-1], used, ~moved


def _kernel(comp_ref, svc_ref, adj_ref, *rest, sweeps: int):
    """Single-program Pallas kernel: the whole fixpoint in-kernel.

    ``rest`` interleaves the per-block ``gidx``/``heads`` refs and ends
    with the three output refs (completions, sweeps_used, converged).
    """
    n_out = 3
    block_refs, out_refs = rest[:-n_out], rest[-n_out:]
    blocks = tuple((block_refs[i][...], block_refs[i + 1][...])
                   for i in range(0, len(block_refs), 2))
    comp, used, moved = _fixpoint_core(
        comp_ref[...], svc_ref[...], blocks, sweeps, adj_ref[...])
    out_refs[0][...] = comp
    out_refs[1][...] = used[None]
    out_refs[2][...] = (~moved)[None]


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def zns_fixpoint(comp0, svc, blocks, adj=None, *, sweeps: int = 8,
                 interpret: bool = True):
    """Pallas form of :func:`zns_fixpoint_xla` (one ``pallas_call``).

    The flat completion vector stays resident across all sweeps ×
    family blocks; sweep iteration, the active-set block mask, and the
    early-exit ``moved`` reduction run in-kernel.
    """
    n = comp0.shape[0]
    nf = len(blocks)
    comp_ext = jnp.append(comp0.astype(jnp.float32), jnp.float32(NEG_INF))
    svc_ext = jnp.append(svc.astype(jnp.float32), jnp.float32(0.0))
    if adj is None:
        adj = jnp.ones((nf, nf), bool) & ~jnp.eye(nf, dtype=bool)
    ins = [comp_ext, svc_ext, jnp.asarray(adj, dtype=bool)]
    for gidx, heads in blocks:
        ins += [gidx.astype(jnp.int32), heads.astype(bool)]
    comp, used, conv = pl.pallas_call(
        functools.partial(_kernel, sweeps=max(int(sweeps), 1)),
        out_shape=(
            jax.ShapeDtypeStruct((n + 1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.bool_),
        ),
        interpret=interpret,
    )(*ins)
    return comp[:-1], used[0], conv[0]


# ---------------------------------------------------------------------------
# Mesh-sharded form: independent per-shard fixpoints across local chips
# ---------------------------------------------------------------------------
def _stack_solve(comp0, svc, adj, *flat_blocks, sweeps: int):
    """Solve a stack of independent shard fixpoints (leading axis).

    ``comp0``/``svc``: ``(s, n_max + 1)``; ``adj``: ``(s, F, F)``
    per-shard block adjacency; ``flat_blocks`` interleaves ``gidx
    (s, R_f, L_f)`` / ``heads (s, R_f, L_f)`` per family slot.
    ``lax.map`` runs one ``while_loop`` per shard, so every shard keeps
    its own trip count (early convergence on one shard never pays for a
    slower sibling's sweeps).
    """

    def one(args):
        c, v, a, *bl = args
        blocks = tuple((bl[i], bl[i + 1]) for i in range(0, len(bl), 2))
        comp, used, moved = _fixpoint_core(c, v, blocks, sweeps, a)
        return comp, used, ~moved

    return jax.lax.map(one, (comp0, svc, adj) + tuple(flat_blocks))


@functools.lru_cache(maxsize=8)
def _sharded_fn(devices, n_arrays: int, sweeps: int):
    """Build (and cache) the jitted ``shard_map`` solver for a device
    tuple.  ``check_rep=False`` is required: the per-shard
    ``lax.while_loop`` trip count is data-dependent, which the
    replication checker cannot track."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(devices), ("shard",))
    fn = shard_map(
        functools.partial(_stack_solve, sweeps=sweeps),
        mesh=mesh,
        in_specs=(P("shard"),) * n_arrays,
        out_specs=(P("shard"), P("shard"), P("shard")),
        check_rep=False)
    # donate the completion buffer: it is overwritten every sweep and
    # the stacked (s, n_max + 1) float64 arrays are the footprint.
    # (CPU backends don't implement donation and warn; skip there.)
    donate = tuple(
        () if all(d.platform == "cpu" for d in devices) else (0,))
    return jax.jit(fn, donate_argnums=donate)


def zns_fixpoint_sharded(comp0, svc, blocks, *, sweeps: int = 8,
                         devices=None, adj=None):
    """Shard independent fixpoints across every local chip.

    ``comp0``/``svc``: ``(S, n_max + 1)`` stacked extended vectors (one
    row per shard, dead slot last, rows beyond a shard's real length
    padded with the dtype sentinel / 0); ``blocks``: tuple of
    ``(gidx (S, R_f, L_f), heads (S, R_f, L_f))`` stacked family slots
    with padding indexed at ``n_max``.  ``S`` must be a multiple of
    ``len(devices)`` (pad with empty shards).  The shard axis is
    embarrassingly parallel — shards share no chains — so ``shard_map``
    over a 1-D :class:`jax.sharding.Mesh` places ``S / n_dev`` shards
    per chip and each runs its own early-exiting ``while_loop``.
    Returns ``(comp (S, n_max + 1), sweeps_used (S,), converged (S,))``.
    """
    if devices is None:
        devices = tuple(jax.local_devices())
    else:
        devices = tuple(devices)
    if comp0.shape[0] % len(devices):
        raise ValueError(f"shard count {comp0.shape[0]} not a multiple "
                         f"of device count {len(devices)}")
    flat = []
    for gidx, heads in blocks:
        flat += [gidx, heads]
    if adj is None:
        n_max = comp0.shape[1] - 1
        adj = np.stack([
            blocks_adjacency([np.asarray(g)[s] for g, _ in blocks], n_max)
            for s in range(comp0.shape[0])]) if blocks else \
            np.zeros((comp0.shape[0], 0, 0), dtype=bool)
    fn = _sharded_fn(devices, 3 + len(flat), max(int(sweeps), 1))
    return fn(comp0, svc, np.asarray(adj, dtype=bool), *flat)
