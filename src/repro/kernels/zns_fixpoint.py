"""Fused chain-program fixpoint — all sweeps × families in one kernel.

The trace-compilation layer (:mod:`repro.core.chain_program`) lowers a
fleet of traces into family blocks: padded ``(R, L)`` gather-index +
segment-head tensors addressing one flat completion vector (dead slot at
index ``n``).  One Gauss–Seidel sweep applies, per block, a segmented
max-plus scan to the gathered completions and scatter-maxes the result
back; sweeps repeat until an early-exit ``moved`` reduction clears.

This module runs that whole fixpoint as one compiled artifact instead of
``sweeps × families`` host dispatches:

* :func:`zns_fixpoint_xla` — a jitted ``lax.while_loop`` whose body
  unrolls the (static) family blocks; the per-block scan is the same
  Hillis–Steele doubling ladder as ``zns_event_scan``, vectorized over
  rows, and the scatter is ``comp.at[gidx].max(...)`` (duplicate dead
  indices max-reduce harmlessly).
* :func:`zns_fixpoint` — the Pallas form: the fixpoint core runs inside
  a single ``pallas_call`` with the flat completion vector resident in
  kernel memory, so sweep iteration never round-trips to the host.
  (Like the other kernels in this package it defaults to interpret mode
  off-TPU; on TPU the blocks map to VMEM tiles with the while-loop
  carried in-kernel.)

The semantic ground truth is ``repro.kernels.ref.zns_fixpoint_ref``
(sequential per-row scans).  Production CPU solves use the float64
numpy driver in :func:`repro.core.chain_program.solve_program`; these
float32 kernels are the TPU path and are equivalence-tested against the
oracle at float32 tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
#: Progress thresholds of the early-exit ``moved`` reduction (float32:
#: looser than the numpy driver's 1e-12/1e-9).
MOVED_RTOL = 1e-5
MOVED_ATOL = 1e-3


def _rows_maxplus(start, svc, heads):
    """Segmented max-plus scan over the rows of (R, L) matrices.

    Same affine-map composition as ``zns_event_scan`` — ``a = svc``
    (``-inf`` at segment heads), ``b = start + svc`` — as a doubling
    ladder of ``log2(L)`` shifted composes, vectorized over rows.
    """
    r, n = start.shape
    a = jnp.where(heads, jnp.float32(NEG_INF), svc)
    b = start + svc
    k = 1
    while k < n:
        a_prev = jnp.concatenate(
            [jnp.zeros((r, k), jnp.float32), a[:, :-k]], axis=1)
        b_prev = jnp.concatenate(
            [jnp.full((r, k), jnp.float32(NEG_INF)), b[:, :-k]], axis=1)
        # compose earlier (shifted) map, then current: (a_p,b_p) . (a,b)
        a, b = a_prev + a, jnp.maximum(b_prev + a, b)
        k *= 2
    return b


def _fixpoint_core(comp_ext, svc_ext, blocks, sweeps: int):
    """``lax.while_loop`` fixpoint shared by the XLA and Pallas forms.

    ``comp_ext``/``svc_ext``: flat ``(n + 1,)`` vectors (dead slot
    last); ``blocks``: static tuple of ``(gidx, heads)`` pairs.
    Returns ``(comp_ext, sweeps_used, moved)``.
    """

    dead = comp_ext.shape[0] - 1

    def body(carry):
        comp, s, _ = carry
        moved = jnp.bool_(False)
        for gidx, heads in blocks:
            svc_m = svc_ext[gidx]
            cur = comp[gidx]
            out = _rows_maxplus(cur - svc_m, svc_m, heads)
            # padding gathers the finite NEG_INF sentinel, which would
            # trivially satisfy the relative-progress test — mask it out
            moved = moved | jnp.any(
                (out > cur * (1.0 + MOVED_RTOL) + MOVED_ATOL)
                & (gidx < dead))
            comp = comp.at[gidx].max(jnp.maximum(cur, out))
            comp = comp.at[-1].set(jnp.float32(NEG_INF))
        return comp, s + 1, moved

    return jax.lax.while_loop(
        lambda c: (c[1] < sweeps) & c[2],
        body, (comp_ext, jnp.int32(0), jnp.bool_(True)))


@functools.partial(jax.jit, static_argnames=("sweeps",))
def zns_fixpoint_xla(comp0, svc, blocks, *, sweeps: int = 8):
    """Fused fixpoint as a jitted ``lax.while_loop`` (no Pallas).

    ``comp0``: (n,) initial completions (``issue + svc``); ``svc``: (n,)
    service times; ``blocks``: tuple of ``(gidx int32 (R, L), heads
    bool (R, L))`` with padding indexed at ``n``.  Returns ``(comp (n,),
    sweeps_used, converged)``.
    """
    comp_ext = jnp.append(comp0.astype(jnp.float32),
                          jnp.float32(NEG_INF))
    svc_ext = jnp.append(svc.astype(jnp.float32), jnp.float32(0.0))
    comp, used, moved = _fixpoint_core(comp_ext, svc_ext, blocks, sweeps)
    return comp[:-1], used, ~moved


def _kernel(comp_ref, svc_ref, *rest, sweeps: int):
    """Single-program Pallas kernel: the whole fixpoint in-kernel.

    ``rest`` interleaves the per-block ``gidx``/``heads`` refs and ends
    with the three output refs (completions, sweeps_used, converged).
    """
    n_out = 3
    block_refs, out_refs = rest[:-n_out], rest[-n_out:]
    blocks = tuple((block_refs[i][...], block_refs[i + 1][...])
                   for i in range(0, len(block_refs), 2))
    comp, used, moved = _fixpoint_core(
        comp_ref[...], svc_ref[...], blocks, sweeps)
    out_refs[0][...] = comp
    out_refs[1][...] = used[None]
    out_refs[2][...] = (~moved)[None]


@functools.partial(jax.jit, static_argnames=("sweeps", "interpret"))
def zns_fixpoint(comp0, svc, blocks, *, sweeps: int = 8,
                 interpret: bool = True):
    """Pallas form of :func:`zns_fixpoint_xla` (one ``pallas_call``).

    The flat completion vector stays resident across all sweeps ×
    family blocks; sweep iteration and the early-exit ``moved``
    reduction run in-kernel.
    """
    n = comp0.shape[0]
    comp_ext = jnp.append(comp0.astype(jnp.float32), jnp.float32(NEG_INF))
    svc_ext = jnp.append(svc.astype(jnp.float32), jnp.float32(0.0))
    ins = [comp_ext, svc_ext]
    for gidx, heads in blocks:
        ins += [gidx.astype(jnp.int32), heads.astype(bool)]
    comp, used, conv = pl.pallas_call(
        functools.partial(_kernel, sweeps=max(int(sweeps), 1)),
        out_shape=(
            jax.ShapeDtypeStruct((n + 1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.bool_),
        ),
        interpret=interpret,
    )(*ins)
    return comp[:-1], used[0], conv[0]
