"""Fused RMSNorm Pallas kernel.

One pass over a (rows, D) view: the row block is normalized in f32 and
scaled by (1 + w) without materializing the intermediate variance tensor
in HBM.  Row blocks of 256 keep (256, D<=16384) f32 within VMEM budget
for every assigned architecture width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x: (..., D), w: (D,) -> (..., D)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    rows_p = (rows + br - 1) // br * br
    x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows_p // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)


import numpy as np  # noqa: E402  (used in jit-static shape math only)
