"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD recurrence S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T,
y_t = S_t C_t is evaluated chunk-wise (chunk length L): an intra-chunk
quadratic term (C B^T ⊙ decay-masked, like a tiny attention over the
chunk) plus an inter-chunk term that threads the (P, N) state through the
sequential chunk-grid dimension in VMEM scratch.  All three matmuls are
(L×N)·(N×L), (L×L)·(L×P) and (P×L)·(L×N) — MXU-shaped for
L = 128, N = 128, P = 64.

Grid: (batch, heads, chunks); chunks is the sequential carry dimension.
KV groups (G < H) are handled by the B/C index_map (h -> h // rep), as in
the attention kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref, *,
            nchunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    a = a_ref[0]                                  # scalar A_h (negative)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (L, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (L, N)

    da = dt * a                                   # (L,) decay log-increments
    cum = jnp.cumsum(da)                          # (L,) inclusive
    l_len = x.shape[0]

    # Intra-chunk: scores[i, j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, j<=i
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (l_len, l_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l_len, l_len), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    scores = jnp.where(jj <= ii, scores * decay * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # Inter-chunk: y_i += (C_i exp(cum_i)) . S_prev^T
    s_prev = s_ref[...]                           # (P, N)
    c_dec = cmat * jnp.exp(cum)[:, None]          # (L, N)
    y_inter = jax.lax.dot_general(c_dec, s_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: S = exp(cum_L) S_prev + sum_j exp(cum_L - cum_j) dt_j x_j B_j^T
    w = jnp.exp(cum[l_len - 1] - cum) * dt        # (L,)
    xw = x * w[:, None]                           # (L, P)
    s_new = s_prev * jnp.exp(cum[l_len - 1]) + jax.lax.dot_general(
        xw, bmat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(ci == nchunks - 1)
    def _emit_state():
        sfin_ref[0, 0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x:(Bb,T,H,P) dt:(Bb,T,H) A:(H,) B,C:(Bb,T,G,N) -> y:(Bb,T,H,P), S:(Bb,H,P,N).

    T must be a multiple of ``chunk`` (the model pads sequences).
    """
    bb, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert t % chunk == 0, "pad T to a chunk multiple"
    nchunks = t // chunk
    # head-major layouts
    xh = jnp.moveaxis(x, 2, 1)          # (Bb,H,T,P)
    dth = jnp.moveaxis(dt, 2, 1)        # (Bb,H,T)
    bh = jnp.moveaxis(B, 2, 1)          # (Bb,G,T,N)
    ch = jnp.moveaxis(C, 2, 1)
    y, sfin = pl.pallas_call(
        functools.partial(_kernel, nchunks=nchunks),
        grid=(bb, h, nchunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, j, c: (i, j, c)),
            pl.BlockSpec((1,), lambda i, j, c: (j,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda i, j, c, rep=rep: (i, j // rep, c, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda i, j, c, rep=rep: (i, j // rep, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, h, t, p), x.dtype),
            jax.ShapeDtypeStruct((bb, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xh, dth, A.astype(jnp.float32), bh, ch)
    return jnp.moveaxis(y, 1, 2), sfin
