"""Max-plus segmented scan — the ZNS device model's hot loop, as a
TPU Pallas kernel.

The per-zone sequential-write completion recurrence
``c_i = max(c_{i-1}, s_i) + v_i`` (engine.py) is linear in the max-plus
semiring: with ``a_i = v_i`` and ``b_i = s_i + v_i``,
``c_i = max(c_{i-1} + a_i, b_i)``.  Composition of two such maps is
``(a1, b1) . (a2, b2) = (a1 + a2, max(b1 + a2, b2))`` — associative, so the
recurrence parallelizes as a scan.  Segment heads (first request of each
zone) set ``a_i = -inf``, which resets the carry exactly like the
sequential oracle.

TPU adaptation (vs. a GPU warp-shuffle scan): requests are tiled into
VMEM blocks of ``block`` elements laid out as (8, block//8) vregs; the
intra-block scan is a Hillis–Steele ladder of ``log2(block)`` vector
shifts (lane/sublane rolls on the VPU), and the inter-block carry is a
scalar in SMEM threaded through the sequential grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block_scan(s, v, head, carry_ref):
    """Intra-block max-plus ladder + inter-block carry (shared by the 1-D
    and batched kernels; the carry lives in SMEM and is updated in place).
    """
    n = s.shape[0]
    # Elementwise affine maps in the max-plus semiring.
    a = jnp.where(head, jnp.float32(NEG_INF), v)   # segment heads drop carry
    b = s + v

    # Hillis–Steele inclusive scan over the block (log2(n) ladder steps).
    # shift-by-k via iota select: positions < k keep the composition
    # identity (a=0, b=-inf): f(c) = max(c + 0, -inf) = c.
    idx = jax.lax.iota(jnp.int32, n)
    k = 1
    while k < n:
        a_shift = jnp.where(idx >= k, jnp.roll(a, k), jnp.float32(0.0))
        b_shift = jnp.where(idx >= k, jnp.roll(b, k), jnp.float32(NEG_INF))
        # compose earlier (shifted) then current: (a_s,b_s) . (a,b)
        a, b = a_shift + a, jnp.maximum(b_shift + a, b)
        k *= 2

    # Apply the inter-block carry: c_i = max(carry + A_i, B_i).
    c = jnp.maximum(carry_ref[0] + a, b)
    carry_ref[0] = c[n - 1]
    return c


def _kernel(issue_ref, svc_ref, head_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.float32(NEG_INF)

    out_ref[...] = _block_scan(issue_ref[...].astype(jnp.float32),
                               svc_ref[...].astype(jnp.float32),
                               head_ref[...], carry_ref)


def _kernel_batched(issue_ref, svc_ref, head_ref, out_ref, carry_ref):
    # Grid is (batch, blocks); the block axis is minor (sequential on TPU),
    # so the SMEM carry threads through one device row at a time and is
    # re-initialized at each row's first block.
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.float32(NEG_INF)

    out_ref[0, :] = _block_scan(issue_ref[0, :].astype(jnp.float32),
                                svc_ref[0, :].astype(jnp.float32),
                                head_ref[0, :], carry_ref)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def zns_event_scan_batched(issue, svc, seg_start, *, block: int = 1024,
                           interpret: bool = True):
    """Batched completion times over a device axis: (B, N) inputs.

    The device-fleet counterpart of :func:`zns_event_scan` — one kernel
    launch scans every device's serialized chains by adding a leading
    batch grid dimension (rows are independent: each row's carry starts
    fresh, exactly like ``jax.vmap`` of the 1-D scan).
    """
    bsz, n = issue.shape
    npad = max((n + block - 1) // block * block, block)
    pad = npad - n
    issue_p = jnp.pad(issue.astype(jnp.float32), ((0, 0), (0, pad)))
    svc_p = jnp.pad(svc.astype(jnp.float32), ((0, 0), (0, pad)))
    head_p = jnp.pad(seg_start.astype(bool), ((0, 0), (0, pad)),
                     constant_values=True)   # padded tail = its own segment

    grid = (bsz, npad // block)
    spec = pl.BlockSpec((1, block), lambda b, i: (b, i))
    out = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, npad), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(issue_p, svc_p, head_p)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def zns_event_scan(issue, svc, seg_start, *, block: int = 1024,
                   interpret: bool = True):
    """Completion times for per-zone serialized requests.

    issue/svc: (N,) float32; seg_start: (N,) bool.  N is padded to a
    multiple of ``block`` internally.
    """
    n = issue.shape[0]
    npad = (n + block - 1) // block * block
    pad = npad - n
    issue_p = jnp.pad(issue.astype(jnp.float32), (0, pad))
    svc_p = jnp.pad(svc.astype(jnp.float32), (0, pad))
    head_p = jnp.pad(seg_start.astype(bool), (0, pad),
                     constant_values=True)   # padded tail = its own segment

    grid = npad // block
    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(issue_p, svc_p, head_p)
    return out[:n]
