"""Blocked (flash) attention forward kernel for TPU.

Online-softmax attention with causal and local-window masking, tiled for
VMEM: the (bq, D) query block stays resident while (bk, D) key/value
blocks stream through the innermost (sequential) grid dimension, with the
running max/denominator/accumulator held in f32 VMEM scratch.  GQA is
handled without materializing repeated KV heads: the K/V BlockSpec
index_map maps query-head ``h`` to KV head ``h // rep``.

MXU alignment: D is the lane dimension (pad to 128 in the wrapper if
needed); bq/bk default to 128/256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, bq, bk, tq, tk, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # Global positions; query positions are aligned to the *end* of the KV
    # sequence (decode convention: tq <= tk).
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (tk - tq)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (qpos < tk) & (kpos < tk)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (bq, bk)
    corr = jnp.exp(m_prev - m_new)               # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, bq: int = 128, bk: int = 256,
                    interpret: bool = True):
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D) -> (B, Hq, Tq, D)."""
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / float(np.sqrt(d))
    bq = min(bq, tq)
    bk = min(bk, tk)
    # pad seq dims to block multiples
    tq_p = (tq + bq - 1) // bq * bq
    tk_p = (tk + bk - 1) // bk * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tk_p - tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tk_p - tk), (0, 0)))
    nq, nk = tq_p // bq, tk_p // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, tq=tq, tk=tk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, rep=rep: (b_, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki, rep=rep: (b_, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :tq, :]
