"""Blocked diagonal linear recurrence (RG-LRU core) Pallas kernel.

Computes h_t = a_t * h_{t-1} + b_t over (B, T, D) with a Hillis–Steele
intra-block scan over time (composition of affine maps (a, b), identity
(1, 0)) and an inter-block carry of the hidden state held in VMEM scratch
across the sequential time-grid dimension.  Time blocks of 256 keep three
(256, D) f32 buffers in VMEM for D ≤ 8192.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bt):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (bt, D)
    b = b_ref[0].astype(jnp.float32)
    n = a.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    k = 1
    while k < n:
        a_s = jnp.where(idx >= k, pltpu.roll(a, k, 0), jnp.float32(1.0))
        b_s = jnp.where(idx >= k, pltpu.roll(b, k, 0), jnp.float32(0.0))
        a, b = a_s * a, b_s * a + b
        k *= 2
    h = h_ref[...]                          # (1, D) carry
    out = a * h + b
    o_ref[0] = out.astype(o_ref.dtype)
    h_ref[...] = out[n - 1:n]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def linear_recurrence(a, b, *, block_t: int = 256, interpret: bool = True):
    """a, b: (B, T, D) -> h: (B, T, D) with h_t = a_t h_{t-1} + b_t."""
    bb, t, d = a.shape
    bt = min(block_t, t)
    t_p = (t + bt - 1) // bt * bt
    # pad with identity maps (a=1, b=0)
    a_p = jnp.pad(a, ((0, 0), (0, t_p - t), (0, 0)), constant_values=1)
    b_p = jnp.pad(b, ((0, 0), (0, t_p - t), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(bb, t_p // bt),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bt, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, t_p, d), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a_p, b_p)
    return out[:, :t, :]
