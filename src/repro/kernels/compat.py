"""Small jax-version compatibility shims for the Pallas TPU kernels.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
jax releases; resolve whichever this environment provides once, here.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
