from .elastic import ReshardPlan, largest_mesh, make_reshard_plan, validate_plan  # noqa: F401
from .failures import (  # noqa: F401
    FailureDetector, HostState, RestartBudget, StragglerPolicy,
)
from .zns_store import ZnsHostDevice, ZonedCheckpointStore  # noqa: F401
