"""Elastic scaling: deterministic resharding plans when the healthy host
set changes.

The data pipeline is pure-functional in (step, shard, num_shards), so
elasticity reduces to (1) choosing a new data-shard layout, (2) remapping
checkpoint shard ownership, and (3) picking the largest feasible mesh for
the surviving chips.  All three are deterministic given the healthy set,
so every surviving host computes the identical plan with no coordinator.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    old_hosts: tuple
    new_hosts: tuple
    # data pipeline: host -> (shard, num_shards)
    data_shards: dict
    # checkpoint restore: new host -> list of old shard ids to load
    shard_ownership: dict
    # mesh proposal: (data, model) extents for the surviving chip count
    mesh_shape: tuple


def largest_mesh(n_chips: int, *, model_parallel: int = 16,
                 chips_per_host: int = 4) -> tuple:
    """Largest (data, model) mesh using at most n_chips, keeping TP fixed
    (model-parallel degree is a property of the model fit, not the fleet)."""
    usable = (n_chips // model_parallel) * model_parallel
    if usable == 0:
        raise ValueError(f"fewer than {model_parallel} chips left")
    return (usable // model_parallel, model_parallel)


def make_reshard_plan(old_hosts, new_hosts, *, model_parallel: int = 16,
                      chips_per_host: int = 4) -> ReshardPlan:
    old_hosts = tuple(sorted(old_hosts))
    new_hosts = tuple(sorted(new_hosts))
    if not new_hosts:
        raise ValueError("cannot reshard onto an empty healthy host set")
    n = len(new_hosts)
    data_shards = {h: (i, n) for i, h in enumerate(new_hosts)}
    # old shard ids were 0..len(old)-1; round-robin them over new hosts
    ownership = {h: [] for h in new_hosts}
    for old_shard in range(len(old_hosts)):
        ownership[new_hosts[old_shard % n]].append(old_shard)
    mesh = largest_mesh(n * chips_per_host, model_parallel=model_parallel,
                        chips_per_host=chips_per_host)
    return ReshardPlan(old_hosts, new_hosts, data_shards, ownership, mesh)


def validate_plan(plan: ReshardPlan) -> None:
    shards = [s for lst in plan.shard_ownership.values() for s in lst]
    if sorted(shards) != list(range(len(plan.old_hosts))):
        raise AssertionError("shard ownership must cover every old shard once")
    ranks = sorted(s for s, _ in plan.data_shards.values())
    if ranks != list(range(len(plan.new_hosts))):
        raise AssertionError("data shards must be a permutation of ranks")
