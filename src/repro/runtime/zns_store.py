"""ZonedCheckpointStore — the paper's recommendations deployed as the
framework's checkpoint engine.

Every host owns one ZNS device (the per-host NVMe of a TPU pod slice).
Checkpoint bytes are persisted to the local filesystem (restore is real);
*timing* comes from the calibrated ZN540 model (`repro.core`) — which is
precisely the artifact the paper contributes.

Paper-recommendation mapping (see DESIGN.md §2):
  R1  manifest/commit records -> small `write` ops at QD1 on a dedicated
      metadata zone (write beats append by up to 23%; SPDK-class stack).
  R2  shard payloads -> large appends (default 1 MiB >= 8 KiB) at QD<=4
      per zone (Obs#6: append concurrency saturates at 4); prefer deep
      intra-zone queues over opening more zones.
  R3  shards are bin-packed to zone capacity so data zones are *filled*,
      never finished; finish only on emergency drain (host eviction).
  R4  the planner budgets against the measured 1,155 MiB/s peak; no GC
      headroom needed (Obs#11/#12).
  R5  expired checkpoint zones are reset by the GC thread concurrently
      with ongoing I/O; reset latency inflation (+78% p95, Obs#13) is
      charged to reclaim throughput, not to the write path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core import DeviceFleet, KiB, MiB, OpType, Stack, ZNSDeviceSpec, \
    ZnsDevice
from repro.host import Extent, ReclaimScheduler, ZoneAllocator

#: A write-plan entry IS a host-layer extent (zone, offset, nbytes); the
#: alias survives for manifest/readers of the pre-host-layer API.
WritePlanEntry = Extent


@dataclasses.dataclass
class HostWriteReport:
    host: int
    nbytes: int
    n_appends: int
    zones_used: list
    sim_seconds: float      # modeled device time for the payload
    manifest_us: float      # modeled commit-record latency (R1 write)
    bandwidth_mibs: float


class ZnsHostDevice:
    """One host's ZNS device session: a client of the host storage
    layer (`repro.host`) + calibrated timing.

    Placement and reclaim policy live behind :class:`ZoneAllocator`
    (``greedy-open`` = the paper's R3 bin-packing) and
    :class:`ReclaimScheduler` (R5 concurrent resets, Obs#13 charged to
    reclaim); ``zm``/``lat``/``tm`` remain as aliases for existing
    callers.
    """

    def __init__(self, host: int, spec: ZNSDeviceSpec = ZNSDeviceSpec(),
                 *, stripe_bytes: int = 1 * MiB, append_qd: int = 4,
                 concurrent_zones: int = 1, policy: str = "greedy-open"):
        self.host = host
        self.device = ZnsDevice(spec)
        self.spec = self.device.spec
        self.zm = self.device.zones
        self.lat = self.device.lat
        self.tm = self.device.throughput
        self.stripe = stripe_bytes
        self.append_qd = append_qd
        self.concurrent_zones = concurrent_zones
        # zone 0 reserved: metadata/manifest zone (R1 writes at QD1)
        self.meta_zone = 0
        self.zm.open(self.meta_zone)
        self.allocator = ZoneAllocator(zones=self.zm, policy=policy,
                                       reserved=(self.meta_zone,),
                                       stripe_bytes=stripe_bytes)
        self.reclaim = ReclaimScheduler(self.device,
                                        allocator=self.allocator,
                                        io_ctx=OpType.APPEND,
                                        relocation_stripe=stripe_bytes,
                                        relocation_qd=append_qd)
        self.clock_us = 0.0

    @property
    def reset_backlog(self) -> list:
        return self.reclaim.backlog

    # -- placement (R2/R3) ---------------------------------------------------
    def plan(self, nbytes: int) -> list[WritePlanEntry]:
        """Bin-pack a payload into zones, filling each to capacity (R3),
        via the host layer's ``greedy-open`` placement policy.  Planning
        shadows write pointers, so multi-zone payloads reserve zones
        without mutating device state."""
        return self.allocator.plan(nbytes, stream=self.host)

    # -- timing (R2/R4) ---------------------------------------------------------
    def payload_scan_args(self, nbytes: int
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(issue, svc, seg) of the payload-append chain for ``nbytes``.

        Appends run at QD=append_qd against the device-level throughput
        cap (R4): appends of >=32 KiB run at the bandwidth limit; the
        max-plus scan over these arrays captures per-request serialization
        at the saturated service rate.
        """
        n_appends = max(int(np.ceil(nbytes / self.stripe)), 1)
        eff_rate = self.tm.steady_state(
            OpType.APPEND, self.stripe, qd=self.append_qd,
            zones=self.concurrent_zones).bandwidth_bytes
        svc_eff = self.stripe / eff_rate * 1e6 * self.append_qd
        issue = np.arange(n_appends, dtype=np.float64) * (svc_eff / self.append_qd)
        seg = np.zeros(n_appends, dtype=bool)
        seg[0] = True
        return issue, np.full(n_appends, svc_eff / self.append_qd), seg

    def simulate_payload_write(self, nbytes: int) -> tuple[float, int]:
        """Modeled seconds to append ``nbytes`` via the per-zone max-plus
        scan (Pallas kernel path) at QD=append_qd.  Returns (s, n_appends).

        Single-device shim; the checkpoint store batches all hosts'
        chains through one :class:`DeviceFleet` call instead.
        """
        issue, svc, seg = self.payload_scan_args(nbytes)
        done = self.device.sequential_completions(issue, svc, seg)
        return float(done[-1]) / 1e6, len(issue)

    def apply_writes(self, entries: list[WritePlanEntry]) -> None:
        """Commit planned extents through the allocator (the zone state
        machine enforces legality and limits)."""
        self.allocator.commit(entries, append=True)

    def manifest_write_us(self, nbytes: int = 4 * KiB) -> float:
        return float(self.lat.io_service_us(OpType.WRITE, nbytes,
                                            Stack.SPDK))

    # -- reclaim (R5) -----------------------------------------------------------
    def schedule_reset(self, zones: list[int]) -> None:
        self.reclaim.schedule(zones)

    def run_gc(self, *, concurrent_io: bool = True) -> float:
        """Drain the reclaim backlog; returns modeled seconds.
        Concurrent I/O inflates reset latency (Obs#13) but resets never
        delay writes (Obs#12), so this cost is reclaim-throughput only —
        see :class:`repro.host.ReclaimScheduler`."""
        return self.reclaim.drain(concurrent_io=concurrent_io).seconds


class ZonedCheckpointStore:
    """Distributed checkpoint store over per-host ZNS devices.

    save(): each host persists its shard bytes + computes modeled device
    time; the checkpoint wall time is the straggler (max over hosts),
    optionally mitigated by backup writes.  commit is a tiny manifest
    `write` + atomic rename (R1).
    """

    def __init__(self, root: str, n_hosts: int,
                 spec: ZNSDeviceSpec = ZNSDeviceSpec(), *,
                 stripe_bytes: int = 1 * MiB, append_qd: int = 4,
                 concurrent_zones: int = 1, redundancy: int = 1,
                 straggler_factor: float = 1.5):
        self.root = root
        self.n_hosts = n_hosts
        self.redundancy = redundancy
        self.straggler_factor = straggler_factor
        self.devices = [
            ZnsHostDevice(h, spec, stripe_bytes=stripe_bytes,
                          append_qd=append_qd,
                          concurrent_zones=concurrent_zones)
            for h in range(n_hosts)
        ]
        # All hosts' payload-write simulations run as one batched fleet
        # computation (device-axis max-plus scans) instead of a host loop.
        self.fleet = DeviceFleet([d.device for d in self.devices])
        os.makedirs(root, exist_ok=True)

    # -- sharding ---------------------------------------------------------------
    def shard_tree(self, tree) -> list[dict]:
        """Split every leaf along axis 0 across hosts (replicate smalls)."""
        import jax
        leaves, treedef = jax.tree.flatten(tree)
        shards = [dict() for _ in range(self.n_hosts)]
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            if arr.ndim >= 1 and arr.shape[0] % self.n_hosts == 0 and \
                    arr.shape[0] >= self.n_hosts:
                parts = np.split(arr, self.n_hosts, axis=0)
                for h in range(self.n_hosts):
                    shards[h][f"leaf{i}"] = parts[h]
            else:
                shards[0][f"leaf{i}.repl"] = arr
        self._treedef = treedef
        self._nleaves = len(leaves)
        return shards

    def unshard_tree(self, shards: list[dict], like_tree):
        import jax
        leaves, treedef = jax.tree.flatten(like_tree)
        out = []
        for i, leaf in enumerate(leaves):
            if f"leaf{i}.repl" in shards[0]:
                out.append(shards[0][f"leaf{i}.repl"])
            else:
                out.append(np.concatenate(
                    [shards[h][f"leaf{i}"] for h in range(self.n_hosts)],
                    axis=0))
        return jax.tree.unflatten(treedef, out)

    # -- save / restore ------------------------------------------------------------
    def save(self, step: int, tree, *, extra_meta: Optional[dict] = None
             ) -> dict:
        shards = self.shard_tree(tree)
        ckpt_dir = os.path.join(self.root, f"step_{step:08d}")
        os.makedirs(ckpt_dir + ".tmp", exist_ok=True)
        reports = []
        manifest = {"step": step, "hosts": {}, "meta": extra_meta or {},
                    "nleaves": self._nleaves}
        # Persist shards + plan zone placement per host (real filesystem +
        # zone-state work), collecting each host's payload-append chain.
        host_bytes, scan_issue, scan_svc, scan_seg = [], [], [], []
        for h, shard in enumerate(shards):
            path = os.path.join(ckpt_dir + ".tmp", f"host_{h:05d}.npz")
            np.savez(path, **shard)
            nbytes = os.path.getsize(path)
            dev = self.devices[h]
            entries = dev.plan(nbytes)
            dev.apply_writes(entries)
            issue, svc, seg = dev.payload_scan_args(nbytes)
            scan_issue.append(issue)
            scan_svc.append(svc)
            scan_seg.append(seg)
            host_bytes.append(nbytes)
            manifest["hosts"][str(h)] = {
                "file": os.path.basename(path), "bytes": nbytes,
                "sha256": _digest(path),
                "zones": [dataclasses.asdict(e) for e in entries],
            }
        # One batched fleet computation models every host's device time
        # (device-axis-parallel max-plus scans; R2/R4 timing).
        done = self.fleet.sequential_completions(scan_issue, scan_svc,
                                                 scan_seg)
        host_times = [float(d[-1]) / 1e6 for d in done]
        for h, (nbytes, sim_s) in enumerate(zip(host_bytes, host_times)):
            dev = self.devices[h]
            reports.append(HostWriteReport(
                host=h, nbytes=nbytes, n_appends=len(scan_issue[h]),
                zones_used=[e["zone"] for e in
                            manifest["hosts"][str(h)]["zones"]],
                sim_seconds=sim_s, manifest_us=dev.manifest_write_us(),
                bandwidth_mibs=nbytes / max(sim_s, 1e-9) / MiB))
        # Straggler mitigation: hosts slower than factor x median get a
        # backup write on the next host (redundancy), bounding the tail.
        med = float(np.median(host_times))
        mitigated = [min(t, med * self.straggler_factor) if
                     self.redundancy > 1 else t for t in host_times]
        wall = max(mitigated) if mitigated else 0.0
        manifest["modeled_wall_seconds"] = wall
        manifest["modeled_host_seconds"] = host_times
        with open(os.path.join(ckpt_dir + ".tmp", "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(ckpt_dir + ".tmp", ckpt_dir)     # atomic commit
        return {"manifest": manifest, "reports": reports,
                "wall_seconds": wall}

    def restore(self, step: int, like_tree, *, failed_hosts=()):
        ckpt_dir = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(ckpt_dir, "manifest.json")) as f:
            manifest = json.load(f)
        shards = []
        for h in range(self.n_hosts):
            info = manifest["hosts"][str(h)]
            path = os.path.join(ckpt_dir, info["file"])
            if h in failed_hosts:
                raise IOError(f"host {h} shard unavailable (no redundancy)")
            if _digest(path) != info["sha256"]:
                raise IOError(f"checksum mismatch for host {h}")
            with np.load(path) as z:
                shards.append({k: z[k] for k in z.files})
        return self.unshard_tree(shards, like_tree), manifest

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def gc(self, keep_last: int = 2) -> float:
        """Delete old checkpoints; reset their zones concurrently (R5)."""
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp"))
        total_s = 0.0
        for s in steps[:-keep_last] if keep_last else steps:
            ckpt_dir = os.path.join(self.root, f"step_{s:08d}")
            with open(os.path.join(ckpt_dir, "manifest.json")) as f:
                manifest = json.load(f)
            for h, info in manifest["hosts"].items():
                zones = sorted({e["zone"] for e in info["zones"]})
                dev = self.devices[int(h)]
                resettable = [z for z in zones
                              if dev.zm.state(z).name in
                              ("FULL", "IMPLICIT_OPEN", "EXPLICIT_OPEN",
                               "CLOSED")]
                dev.schedule_reset(resettable)
                total_s += dev.run_gc(concurrent_io=True)
            import shutil
            shutil.rmtree(ckpt_dir)
        return total_s


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()
