"""Failure detection and straggler mitigation for the multi-host runtime.

No real cluster exists in this container, so the control plane operates
on a simulated clock; the *policies* (lease-based failure detection,
deadline-based straggler mitigation with backup tasks, bounded restart
storms) are the production logic and are unit-tested directly.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional

import numpy as np


class HostState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class HostInfo:
    host: int
    last_heartbeat: float = 0.0
    state: HostState = HostState.HEALTHY
    incarnation: int = 0


class FailureDetector:
    """Lease-based detector: miss one lease -> SUSPECT, two -> DEAD.

    SUSPECT hosts keep participating but their checkpoint shards get
    backup copies; DEAD hosts trigger elastic resharding.
    """

    def __init__(self, n_hosts: int, *, lease_s: float = 10.0):
        self.lease_s = lease_s
        self.hosts = {h: HostInfo(h) for h in range(n_hosts)}

    def heartbeat(self, host: int, now: float) -> None:
        info = self.hosts[host]
        info.last_heartbeat = now
        if info.state is HostState.DEAD:
            info.incarnation += 1      # rejoin with a new incarnation
        info.state = HostState.HEALTHY

    def tick(self, now: float) -> dict:
        """Advance the detector; returns {host: HostState} transitions."""
        changes = {}
        for info in self.hosts.values():
            age = now - info.last_heartbeat
            new = info.state
            if age > 2 * self.lease_s:
                new = HostState.DEAD
            elif age > self.lease_s:
                new = HostState.SUSPECT
            else:
                new = HostState.HEALTHY
            if new is not info.state:
                info.state = new
                changes[info.host] = new
        return changes

    def healthy_hosts(self) -> list[int]:
        return [h for h, i in self.hosts.items()
                if i.state is not HostState.DEAD]


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline = factor x rolling median; over deadline -> backup task."""

    factor: float = 1.5
    window: int = 32

    def __post_init__(self):
        self._history: list[float] = []

    def observe(self, duration_s: float) -> None:
        self._history.append(duration_s)
        self._history = self._history[-self.window:]

    def deadline(self) -> Optional[float]:
        if len(self._history) < 4:
            return None
        return float(np.median(self._history)) * self.factor

    def mitigate(self, host_durations: dict) -> dict:
        """Given {host: projected_duration}, return {host: backup_host}
        for hosts over deadline (backup = next healthy host)."""
        dl = self.deadline()
        if dl is None:
            return {}
        hosts = sorted(host_durations)
        out = {}
        for i, h in enumerate(hosts):
            if host_durations[h] > dl:
                out[h] = hosts[(i + 1) % len(hosts)]
        return out


@dataclasses.dataclass
class RestartBudget:
    """Bounded restart storms: at most ``max_restarts`` in ``window_s``."""

    max_restarts: int = 5
    window_s: float = 3600.0

    def __post_init__(self):
        self._times: list[float] = []

    def allow(self, now: float) -> bool:
        self._times = [t for t in self._times if now - t < self.window_s]
        if len(self._times) >= self.max_restarts:
            return False
        self._times.append(now)
        return True
