"""Train-step factory: loss -> grads -> AdamW, with optional microbatch
gradient accumulation.  Pure function of (state, batch); distribution is
imposed from outside via jit in/out shardings (launch/dryrun.py,
launch/train.py)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import models as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: dict
    opt: dict

    @staticmethod
    def create(cfg: ModelConfig, key) -> "TrainState":
        params = M.init_params(cfg, key)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt=init_opt_state(params))


def state_spec(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct skeleton of TrainState (no allocation)."""
    spec = M.model_spec(cfg)
    import numpy as np
    from repro.models.common import P as PSpec

    def sds(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(cfg.param_dtype))

    def sds32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    leaf = lambda x: isinstance(x, PSpec)
    params = jax.tree.map(sds, spec, is_leaf=leaf)
    opt = {"m": jax.tree.map(sds32, spec, is_leaf=leaf),
           "v": jax.tree.map(sds32, spec, is_leaf=leaf)}
    return TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      params=params, opt=opt)


def state_logical_axes(cfg: ModelConfig) -> TrainState:
    axes = M.logical_axes(cfg)
    return TrainState(step=None, params=axes, opt={"m": axes, "v": axes})


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    *, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches > 1`` accumulates grads over a lax.scan of microbatch
    slices (batch dim must divide evenly).
    """

    def loss_of(params, batch):
        loss, metrics = M.loss_fn(cfg, params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulate(params, batch):
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mbatch = jax.tree.map(split, batch)

        def body(acc, mb):
            loss, metrics, grads = single(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 acc_g, grads)
            return (acc_g, acc_l + loss), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.float32(0.0)), mbatch)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum * inv, metrics, grads

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            loss, metrics, grads = accumulate(state.params, batch)
        else:
            loss, metrics, grads = single(state.params, batch)
        params, opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt, state.step)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        new_state = TrainState(step=state.step + 1, params=params, opt=opt)
        return new_state, metrics

    return train_step
