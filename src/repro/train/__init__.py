from .step import TrainState, make_train_step, state_logical_axes, state_spec  # noqa: F401
