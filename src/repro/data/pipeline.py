"""Deterministic, sharded, resumable synthetic-token data pipeline.

Production shape without external deps: an index-based sampler over a
synthetic corpus (seeded Zipf-ish token model), sharded by (host, data
rank), with O(1) checkpointable state (step counter + seed) so training
resumes bit-exactly after restart or elastic resharding.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    num_codebooks: int = 0      # audio archs
    zipf_a: float = 1.2


@dataclasses.dataclass
class DataState:
    """Checkpointable pipeline state."""
    step: int = 0

    def as_dict(self):
        return {"step": self.step}

    @staticmethod
    def from_dict(d):
        return DataState(step=int(d["step"]))


class TokenPipeline:
    """Per-host view of the global batch.

    ``batch_at(step)`` is a pure function of (config, step, shard), which
    makes resume and elastic re-sharding trivial: a host picks up any
    shard at any step and produces exactly the tokens every other host
    would have produced for that shard.
    """

    def __init__(self, cfg: DataConfig, *, shard: int = 0, num_shards: int = 1):
        if num_shards > cfg.global_batch:
            raise ValueError("more shards than global batch rows")
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.state = DataState()
        # Zipf-ish unigram distribution, fixed by seed.
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** cfg.zipf_a
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def _sample(self, rng, shape):
        flat = rng.choice(self.cfg.vocab_size, size=int(np.prod(shape)),
                          p=self._probs)
        return self._perm[flat].reshape(shape).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        # uneven layouts (elastic host loss): first `rem` shards carry one
        # extra row, so the global batch is preserved exactly
        base, rem = divmod(cfg.global_batch, self.num_shards)
        per_shard = base + (1 if self.shard < rem else 0)
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.shard)
        if cfg.num_codebooks > 1:
            toks = self._sample(rng, (per_shard, cfg.seq_len,
                                      cfg.num_codebooks))
        else:
            toks = self._sample(rng, (per_shard, cfg.seq_len))
        return {"tokens": toks}

    def __next__(self) -> dict:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self):
        return self

    # -- checkpoint/resume -----------------------------------------------------
    def state_dict(self) -> dict:
        return self.state.as_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState.from_dict(d)

    def reshard(self, shard: int, num_shards: int) -> "TokenPipeline":
        """Elastic re-sharding: same stream, new shard layout."""
        p = TokenPipeline(self.cfg, shard=shard, num_shards=num_shards)
        p.state = DataState(step=self.state.step)
        return p


def global_batch_at(cfg: DataConfig, step: int) -> dict:
    """The full global batch (all shards concatenated) — test oracle."""
    pipes = [TokenPipeline(cfg, shard=s, num_shards=1) for s in range(1)]
    return pipes[0].batch_at(step)
