from .pipeline import DataConfig, DataState, TokenPipeline  # noqa: F401
