"""Family dispatch: one API over all ten architectures.

    init_params(cfg, key)          -> param pytree
    logical_axes(cfg)              -> matching pytree of logical axis tuples
    forward(cfg, params, batch)    -> (logits, aux_loss)
    loss_fn(cfg, params, batch)    -> scalar loss (next-token CE + aux)
    cache_spec / init_cache        -> decode-state pytrees
    prefill / decode_step          -> serving entry points
    count_params(cfg)              -> exact (from the spec tree, no alloc)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import common as cm
from . import mamba2, rglru, transformer
from .common import P
from .config import ModelConfig

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


def _module(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "ssm":
        return mamba2
    if cfg.family == "hybrid":
        return rglru
    raise ValueError(f"unknown family {cfg.family}")


def model_spec(cfg: ModelConfig):
    return _module(cfg).model_spec(cfg)


def init_params(cfg: ModelConfig, key):
    return _module(cfg).init_params(cfg, key)


def logical_axes(cfg: ModelConfig):
    return _module(cfg).logical_axes(cfg)


def forward(cfg: ModelConfig, params, tokens, frontend_inputs=None):
    return _module(cfg).forward(cfg, params, tokens, frontend_inputs)


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    return _module(cfg).cache_spec(cfg, batch, max_seq)


def cache_logical_axes(cfg: ModelConfig):
    return _module(cfg).cache_logical_axes(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return _module(cfg).init_cache(cfg, batch, max_seq)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    return _module(cfg).decode_step(cfg, params, cache, tokens, pos)


def prefill(cfg: ModelConfig, params, tokens, max_seq: int,
            frontend_inputs=None):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.prefill(cfg, params, tokens, max_seq,
                                   frontend_inputs)
    # Recurrent families: prefill == forward; decode state is produced by
    # stepping (integration tests use short prompts); for the dry-run the
    # prefill cell lowers forward().
    logits, _ = forward(cfg, params, tokens, frontend_inputs)
    return logits[:, -1:], init_cache(cfg, tokens.shape[0], tokens.shape[1])


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux loss).

    batch: {"tokens": (B, S) or (B, S, Cb)} — labels are tokens shifted.
    """
    tokens = batch["tokens"]
    frontend_inputs = batch.get("frontend_inputs")
    logits, aux = forward(cfg, params, tokens, frontend_inputs)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    # Cross-entropy without gathering along the vocab axis: the logits'
    # vocab dim stays model-sharded (logsumexp + one-hot contraction both
    # reduce over it with small psums instead of an all-gather of the
    # (B, S, V) tensor).
    logits = logits.astype(jnp.float32)
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + lmax[..., 0]
    onehot = jax.nn.one_hot(targets, cfg.vocab_size, dtype=logits.dtype)
    tgt = jnp.einsum("...v,...v->...", logits, onehot)
    loss = jnp.mean(lse - tgt)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Param counting (exact, from the spec tree; no allocation)
# ---------------------------------------------------------------------------
def _spec_leaves(cfg: ModelConfig):
    spec = model_spec(cfg)
    return jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))


def count_params(cfg: ModelConfig) -> int:
    return int(sum(np.prod(p.shape) for p in _spec_leaves(cfg)))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE experts scaled by top_k/E)."""
    total = 0
    for p in _spec_leaves(cfg):
        n = int(np.prod(p.shape))
        if "experts" in p.axes:
            n = int(n * cfg.moe_top_k / cfg.moe_num_experts)
        total += n
    return total


def model_flops(cfg: ModelConfig, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS for the roofline: 6·N_active·D for train, 2·N·D fwd."""
    n = count_active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
