from .config import (  # noqa: F401
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES_BY_NAME, TRAIN_4K,
    ModelConfig, ShapeConfig, shapes_for,
)
from .model import (  # noqa: F401
    cache_logical_axes, cache_spec, count_active_params, count_params,
    decode_step, forward, init_cache, init_params, logical_axes, loss_fn,
    model_flops, model_spec, prefill,
)
