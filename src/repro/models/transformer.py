"""Dense / MoE / VLM / audio decoder-only transformer.

One implementation covers the dense family (tinyllama, qwen3-4b/8b,
llama3-405b), the MoE family (arctic-480b, qwen2-moe), the VLM backbone
(internvl2-26b: vision frontend is a stub providing precomputed patch
embeddings) and the audio backbone (musicgen-large: EnCodec-codebook
token embeddings summed, per-codebook output heads).

Layers are stacked and iterated with ``lax.scan`` (MaxText-style) so that
a 126-layer model lowers to a compact HLO and compiles tractably on a
512-device mesh.  Each scan body is wrapped in ``jax.checkpoint`` per the
config remat policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.tree import scan_or_loop
from . import common as cm
from .config import ModelConfig
from .moe import moe_block, moe_spec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def layer_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    spec = {
        "ln1": cm.P((D,), ("embed",), "zeros"),
        "attn": cm.attn_spec(cfg),
        "ln2": cm.P((D,), ("embed",), "zeros"),
    }
    if cfg.moe_num_experts:
        spec["moe"] = moe_spec(cfg)
        if cfg.moe_dense_parallel:
            spec["dense_mlp"] = cm.mlp_spec(cfg)
    else:
        spec["mlp"] = cm.mlp_spec(cfg)
    return spec


def model_spec(cfg: ModelConfig) -> dict:
    spec = {
        "embed": cm.embed_spec(cfg),
        "layers": cm.stack_spec(layer_spec(cfg), cfg.num_layers),
    }
    return spec


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------
def decoder_layer(cfg: ModelConfig, p, x, positions):
    x = cm.constrain_act(x, cfg)
    h = cm.attention(cfg, p["attn"], cm.rmsnorm(cfg, p["ln1"], x), positions,
                     window=cfg.window)
    x = x + h
    hn = cm.rmsnorm(cfg, p["ln2"], x)
    if cfg.moe_num_experts:
        h, aux = moe_block(cfg, p, hn)
    else:
        h, aux = cm.mlp(p["mlp"], hn), jnp.float32(0.0)
    return x + h, aux


def decoder_layer_decode(cfg: ModelConfig, p, x, cache_k, cache_v, pos):
    h, ck, cv = cm.attention_decode(
        cfg, p["attn"], cm.rmsnorm(cfg, p["ln1"], x), cache_k, cache_v, pos,
        window=cfg.window)
    x = x + h
    hn = cm.rmsnorm(cfg, p["ln2"], x)
    if cfg.moe_num_experts:
        h, _ = moe_block(cfg, p, hn)
    else:
        h = cm.mlp(p["mlp"], hn)
    return x + h, ck, cv


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, tokens, frontend_inputs=None):
    """tokens: (B, S) int32 (or (B, S, Cb) for audio) -> logits."""
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens, dtype)
    x = cm.apply_frontend(cfg, params["embed"], x, frontend_inputs)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, lp):
        h, aux = decoder_layer(cfg, lp, carry, positions)
        return h, aux

    x, auxs = cm.stacked_apply(cfg, body, x, params["layers"],
                               cfg.num_layers)
    aux = jnp.sum(auxs) if auxs is not None else jnp.float32(0.0)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    return cm.lm_logits(cfg, params["embed"], x), aux


def init_params(cfg: ModelConfig, key):
    return cm.init_from_spec(model_spec(cfg), key, jnp.dtype(cfg.param_dtype))


def logical_axes(cfg: ModelConfig):
    return cm.axes_from_spec(model_spec(cfg))


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """KV cache pytree.  Windowed models keep a rolling window buffer."""
    s = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
        "v": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct version of init_cache (no allocation)."""
    s = min(max_seq, cfg.window) if cfg.window else max_seq
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, s, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)),
        "v": jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)),
    }


def cache_logical_axes(cfg: ModelConfig):
    axes = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    return {"k": axes, "v": axes}


def prefill(cfg: ModelConfig, params, tokens, max_seq: int,
            frontend_inputs=None):
    """Run the full prompt, returning (last_logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens, dtype)
    x = cm.apply_frontend(cfg, params["embed"], x, frontend_inputs)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache_len = min(max_seq, cfg.window) if cfg.window else max_seq

    def body(carry, lp):
        h = carry
        xn = cm.rmsnorm(cfg, lp["ln1"], h)
        q, k, v = cm.attn_qkv(cfg, lp["attn"], xn, positions)
        qh, kh, vh = (jnp.moveaxis(t, 2, 1) for t in (q, k, v))
        att = cm.full_attention(cfg, qh, kh, vh, window=cfg.window)
        att = jnp.moveaxis(att, 1, 2)
        h = h + jnp.einsum("bshk,hkd->bsd", att,
                           lp["attn"]["wo"].astype(h.dtype))
        hn = cm.rmsnorm(cfg, lp["ln2"], h)
        if cfg.moe_num_experts:
            f, _ = moe_block(cfg, lp, hn)
        else:
            f = cm.mlp(lp["mlp"], hn)
        h = h + f
        # cache: pad/crop keys to the cache window
        if cache_len >= S:
            kc = jnp.pad(kh, ((0, 0), (0, 0), (0, cache_len - S), (0, 0)))
            vc = jnp.pad(vh, ((0, 0), (0, 0), (0, cache_len - S), (0, 0)))
        else:
            kc = kh[:, :, S - cache_len:, :]
            vc = vh[:, :, S - cache_len:, :]
        return h, {"k": kc.astype(jnp.dtype(cfg.dtype)),
                   "v": vc.astype(jnp.dtype(cfg.dtype))}

    body = cm.maybe_checkpoint(cfg, body)
    x, cache = scan_or_loop(cfg.scan_layers, body, x, params["layers"],
                            cfg.num_layers)   # no bwd: plain scan suffices
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    logits = cm.lm_logits(cfg, params["embed"], x[:, -1:])
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens: (B,) or (B, Cb); pos: scalar int32.

    Returns (logits, new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)

    def body(carry, inp):
        lp, ck, cv = inp
        h, ck, cv = decoder_layer_decode(cfg, lp, carry, ck, cv, pos)
        return h, {"k": ck, "v": cv}

    x, new_cache = scan_or_loop(cfg.scan_layers, body, x,
                                (params["layers"], cache["k"], cache["v"]),
                                cfg.num_layers)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits[:, 0], new_cache
