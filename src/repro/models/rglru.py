"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention
in a 1:2 pattern (rec, rec, attn), each followed by a SwiGLU MLP.

The RG-LRU gate structure follows Griffin: per-block-diagonal recurrence
and input gates, a learned per-channel decay ``a = sigmoid(Lambda)``
raised to ``c * r_t``, and input scaled by sqrt(1 - a_t^2).  The diagonal
linear recurrence runs through kernels.ops.linear_recurrence (Pallas
blocked scan on TPU, lax.scan oracle elsewhere).

Layer-stack organization: the 38-layer model is 12 scanned pattern groups
of (rec, rec, attn) + 2 trailing rec layers, each group scanned with
``lax.scan`` so the HLO stays compact.  Local attention uses a rolling
window cache, which bounds decode state and enables ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.utils.tree import scan_or_loop
from . import common as cm
from .config import ModelConfig


def _rec_dims(cfg: ModelConfig):
    di = cfg.d_model            # lru width = d_model (recurrentgemma)
    nb = cfg.num_heads          # gate block-diagonal blocks
    return di, nb, di // nb


def rec_block_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    di, nb, bs = _rec_dims(cfg)
    return {
        "ln": cm.P((D,), ("embed",), "zeros"),
        "proj_x": cm.P((D, di), ("embed", "rnn")),
        "proj_gate": cm.P((D, di), ("embed", "rnn")),
        "conv_w": cm.P((cfg.conv_width, di), ("conv", "rnn"), "normal", 0.5),
        "conv_b": cm.P((di,), ("rnn",), "zeros"),
        "w_a": cm.P((nb, bs, bs), ("rnn_blocks", "rnn_in", "rnn_out")),
        "b_a": cm.P((di,), ("rnn",), "zeros"),
        "w_i": cm.P((nb, bs, bs), ("rnn_blocks", "rnn_in", "rnn_out")),
        "b_i": cm.P((di,), ("rnn",), "zeros"),
        "lam": cm.P((di,), ("rnn",), "ones"),
        "out_proj": cm.P((di, D), ("rnn", "embed")),
        "ln2": cm.P((D,), ("embed",), "zeros"),
        "mlp": cm.mlp_spec(cfg),
    }


def attn_block_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    return {
        "ln": cm.P((D,), ("embed",), "zeros"),
        "attn": cm.attn_spec(cfg),
        "ln2": cm.P((D,), ("embed",), "zeros"),
        "mlp": cm.mlp_spec(cfg),
    }


def _pattern_counts(cfg: ModelConfig):
    plen = len(cfg.block_pattern)
    groups = cfg.num_layers // plen
    tail = cfg.num_layers - groups * plen
    return plen, groups, tail


def model_spec(cfg: ModelConfig) -> dict:
    plen, groups, tail = _pattern_counts(cfg)
    group_spec = {}
    for i, kind in enumerate(cfg.block_pattern):
        sp = rec_block_spec(cfg) if kind == "rec" else attn_block_spec(cfg)
        group_spec[f"b{i}_{kind}"] = sp
    spec = {
        "embed": cm.embed_spec(cfg),
        "groups": cm.stack_spec(group_spec, groups, "layer_groups"),
    }
    for t in range(tail):
        spec[f"tail{t}"] = rec_block_spec(cfg)
    return spec


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------
def _block_linear(w, x):
    """Block-diagonal linear: w (nb, bs, bs); x (..., nb*bs)."""
    nb, bs, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (nb, bs))
    return jnp.einsum("...ni,nij->...nj", xs, w.astype(x.dtype)).reshape(x.shape)


def rglru(cfg: ModelConfig, p, u, h0=None):
    """u: (B, S, di) -> (B, S, di).  h0 optional initial state (B, di)."""
    r = jax.nn.sigmoid(_block_linear(p["w_a"], u)
                       + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(_block_linear(p["w_i"], u)
                       + p["b_i"].astype(u.dtype))
    log_a0 = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))   # log a
    log_a = cfg.rglru_c * r.astype(jnp.float32) * log_a0        # (B,S,di)
    a = jnp.exp(log_a)
    gated = (i * u).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    h = kops.linear_recurrence(a, b, impl=cfg.kernel_impl)
    return h.astype(u.dtype)


def rec_block(cfg: ModelConfig, p, x):
    x = cm.constrain_act(x, cfg)
    xn = cm.rmsnorm(cfg, p["ln"], x)
    u = jnp.einsum("bsd,de->bse", xn, p["proj_x"].astype(x.dtype))
    from .mamba2 import _causal_conv
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    h = rglru(cfg, p, u)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xn,
                                  p["proj_gate"].astype(x.dtype)))
    y = jnp.einsum("bse,ed->bsd", h * gate, p["out_proj"].astype(x.dtype))
    x = x + y
    x = x + cm.mlp(p["mlp"], cm.rmsnorm(cfg, p["ln2"], x))
    return x


def attn_block(cfg: ModelConfig, p, x, positions):
    x = cm.constrain_act(x, cfg)
    h = cm.attention(cfg, p["attn"], cm.rmsnorm(cfg, p["ln"], x), positions,
                     window=cfg.window)
    x = x + h
    x = x + cm.mlp(p["mlp"], cm.rmsnorm(cfg, p["ln2"], x))
    return x


def forward(cfg: ModelConfig, params, tokens, frontend_inputs=None):
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens, dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def group_body(carry, gp):
        h = carry
        for i, kind in enumerate(cfg.block_pattern):
            p = gp[f"b{i}_{kind}"]
            h = rec_block(cfg, p, h) if kind == "rec" else attn_block(
                cfg, p, h, positions)
        return h, None

    _, groups, tail = _pattern_counts(cfg)
    x, _ = cm.stacked_apply(cfg, group_body, x, params["groups"], groups)
    for t in range(tail):
        x = rec_block(cfg, params[f"tail{t}"], x)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    return cm.lm_logits(cfg, params["embed"], x), jnp.float32(0.0)


def init_params(cfg: ModelConfig, key):
    return cm.init_from_spec(model_spec(cfg), key, jnp.dtype(cfg.param_dtype))


def logical_axes(cfg: ModelConfig):
    return cm.axes_from_spec(model_spec(cfg))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    di, _, _ = _rec_dims(cfg)
    plen, groups, tail = _pattern_counts(cfg)
    n_rec_per_group = sum(1 for k in cfg.block_pattern if k == "rec")
    n_att_per_group = plen - n_rec_per_group
    w = min(cfg.window or max_seq, max_seq)
    dt = jnp.dtype(cfg.dtype)
    return {
        "rec_h": jax.ShapeDtypeStruct(
            (groups, n_rec_per_group, batch, di), jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (groups, n_rec_per_group, batch, cfg.conv_width - 1, di), dt),
        "k": jax.ShapeDtypeStruct(
            (groups, n_att_per_group, batch, cfg.num_kv_heads, w,
             cfg.head_dim), dt),
        "v": jax.ShapeDtypeStruct(
            (groups, n_att_per_group, batch, cfg.num_kv_heads, w,
             cfg.head_dim), dt),
        "tail_rec_h": jax.ShapeDtypeStruct((max(tail, 1), batch, di),
                                           jnp.float32),
        "tail_conv": jax.ShapeDtypeStruct(
            (max(tail, 1), batch, cfg.conv_width - 1, di), dt),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {
        "rec_h": ("layer_groups", None, "batch", "rnn"),
        "conv": ("layer_groups", None, "batch", "conv", "rnn"),
        "k": ("layer_groups", None, "batch", "kv_heads", "cache_seq",
              "head_dim"),
        "v": ("layer_groups", None, "batch", "kv_heads", "cache_seq",
              "head_dim"),
        "tail_rec_h": (None, "batch", "rnn"),
        "tail_conv": (None, "batch", "conv", "rnn"),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq))


def _rec_block_decode(cfg, p, x, h_prev, conv_st):
    """x: (B, 1, D); h_prev: (B, di); conv_st: (B, W-1, di)."""
    xn = cm.rmsnorm(cfg, p["ln"], x)
    u = jnp.einsum("bsd,de->bse", xn, p["proj_x"].astype(x.dtype))[:, 0]
    hist = jnp.concatenate([conv_st, u[:, None, :]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    u = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    new_conv = hist[:, 1:, :]
    r = jax.nn.sigmoid(_block_linear(p["w_a"], u) + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(_block_linear(p["w_i"], u) + p["b_i"].astype(u.dtype))
    log_a0 = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    a = jnp.exp(cfg.rglru_c * r.astype(jnp.float32) * log_a0)
    h = a * h_prev + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (
        i * u).astype(jnp.float32)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", xn,
                                  p["proj_gate"].astype(x.dtype)))[:, 0]
    y = jnp.einsum("be,ed->bd", h.astype(x.dtype) * gate,
                   p["out_proj"].astype(x.dtype))
    x = x + y[:, None, :]
    x = x + cm.mlp(p["mlp"], cm.rmsnorm(cfg, p["ln2"], x))
    return x, h, new_conv


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
    plen, groups, tail = _pattern_counts(cfg)
    del plen
    rec_ids = [i for i, k in enumerate(cfg.block_pattern) if k == "rec"]
    att_ids = [i for i, k in enumerate(cfg.block_pattern) if k == "attn"]

    def group_body(carry, inp):
        gp, rec_h, conv, ck, cv = inp
        h = carry
        new_rh, new_cv_st, new_k, new_v = [], [], [], []
        ri = ai = 0
        for i, kind in enumerate(cfg.block_pattern):
            p = gp[f"b{i}_{kind}"]
            if kind == "rec":
                h, hh, cst = _rec_block_decode(cfg, p, h, rec_h[ri], conv[ri])
                new_rh.append(hh); new_cv_st.append(cst)
                ri += 1
            else:
                hn = cm.rmsnorm(cfg, p["ln"], h)
                att, k1, v1 = cm.attention_decode(
                    cfg, p["attn"], hn, ck[ai], cv[ai], pos,
                    window=cfg.window)
                h = h + att
                h = h + cm.mlp(p["mlp"], cm.rmsnorm(cfg, p["ln2"], h))
                new_k.append(k1); new_v.append(v1)
                ai += 1
        return h, (jnp.stack(new_rh), jnp.stack(new_cv_st),
                   jnp.stack(new_k), jnp.stack(new_v))

    x, (rh, cst, k, v) = scan_or_loop(
        cfg.scan_layers, group_body, x,
        (params["groups"], cache["rec_h"], cache["conv"], cache["k"],
         cache["v"]), groups)
    new_cache = dict(cache)
    new_cache.update(rec_h=rh, conv=cst, k=k, v=v)
    tail_h, tail_c = [], []
    for t in range(tail):
        x, hh, cc = _rec_block_decode(cfg, params[f"tail{t}"], x,
                                      cache["tail_rec_h"][t],
                                      cache["tail_conv"][t])
        tail_h.append(hh); tail_c.append(cc)
    if tail:
        new_cache["tail_rec_h"] = jnp.stack(tail_h)
        new_cache["tail_conv"] = jnp.stack(tail_c)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits[:, 0], new_cache
