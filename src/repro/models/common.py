"""Shared model components: parameter specs, initializers, attention,
MLP, RoPE, norms.

Parameters are plain nested-dict pytrees.  Every module is described by a
spec tree of :class:`P` entries (shape + logical axis names + init); the
same spec produces both the initialized parameters and the logical-axis
tree consumed by ``distributed.sharding`` — they cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape, logical axes (one name per dim), init."""

    shape: tuple
    axes: tuple
    init: str = "normal"      # normal | zeros | ones | small_normal
    scale: float = 1.0

    def initialize(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "const_std":
            std = self.scale
        else:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = self.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


def init_from_spec(spec, key, dtype):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [p.initialize(k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_from_spec(spec):
    return jax.tree.map(lambda p: p.axes, spec,
                        is_leaf=lambda x: isinstance(x, P))


def stack_spec(spec, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension to every param in a spec tree."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        spec, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------
def rmsnorm(cfg: ModelConfig, w, x):
    return kops.rmsnorm(x, w, eps=cfg.rms_eps, impl=cfg.kernel_impl)


def rope(x, positions, theta: float):
    """x: (..., T, H, Dh); positions: (..., T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / dh))
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                         # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# -- attention ----------------------------------------------------------------
def attn_spec(cfg: ModelConfig) -> dict:
    D, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": P((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": P((D, K, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": P((D, K, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, Dh, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P((Dh,), ("head_dim",), "zeros")
        spec["k_norm"] = P((Dh,), ("head_dim",), "zeros")
    return spec


def attn_qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = kops.rmsnorm(q, p["q_norm"], eps=cfg.rms_eps, impl="xla")
        k = kops.rmsnorm(k, p["k_norm"], eps=cfg.rms_eps, impl="xla")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def full_attention(cfg: ModelConfig, qh, kh, vh, *, window=None):
    """Head-major full-sequence attention core: (B, H/K, S, Dh) -> (B, H, S, Dh).

    Dispatch: shard_map ring attention when enabled (sequence-parallel
    exact attention over the model axis), else the kernel/XLA path.
    """
    if cfg.ring_attention and window is None:
        from repro.distributed import ctx as dctx
        c = dctx.current()
        if c is not None and "model" in c[0].axis_names \
                and qh.shape[2] % c[0].shape["model"] == 0:
            mesh = c[0]
            from repro.distributed.ring_attention import ring_attention
            data_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
            return ring_attention(mesh, qh, kh, vh, causal=True,
                                  batch_axes=data_axes)
    return kops.attention(qh, kh, vh, causal=True, window=window,
                          impl=cfg.kernel_impl)


def attention(cfg: ModelConfig, p, x, positions, *, window=None):
    """Full-sequence (train/prefill) attention.  x: (B, S, D)."""
    q, k, v = attn_qkv(cfg, p, x, positions)
    qh = jnp.moveaxis(q, 2, 1)     # (B, H, S, Dh)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    out = full_attention(cfg, qh, kh, vh, window=window)
    out = jnp.moveaxis(out, 1, 2)  # (B, S, H, Dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attention_decode(cfg: ModelConfig, p, x, cache_k, cache_v, pos, *,
                     window=None):
    """Single-token decode.  x: (B, 1, D); cache_{k,v}: (B, K, S, Dh);
    ``pos``: scalar int32 — current position (tokens written so far).

    Returns (out, new_cache_k, new_cache_v).  For windowed attention the
    cache is a rolling buffer of size ``window``; insertion position is
    pos % window and key positions are reconstructed for masking.
    """
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q, k, v = attn_qkv(cfg, p, x, positions)
    kh = jnp.moveaxis(k, 2, 1)     # (B, K, 1, Dh)
    vh = jnp.moveaxis(v, 2, 1)
    s = cache_k.shape[2]
    slot = pos % s if window is not None else pos
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, kh.astype(cache_k.dtype), (0, 0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, vh.astype(cache_v.dtype), (0, 0, slot, 0))
    # Grouped-query attention without materializing repeated KV heads:
    # q heads are reshaped to (B, K, rep, Dh) against the (B, K, S, Dh)
    # cache.  Accumulation in f32 via preferred_element_type.
    rep = cfg.num_heads // cfg.num_kv_heads
    b = x.shape[0]
    qg = q.reshape(b, cfg.num_kv_heads, rep, cfg.head_dim)
    logits = jnp.einsum("bkrd,bksd->bkrs", qg, cache_k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(cfg.head_dim)
    kpos = jnp.arange(s)
    if window is None:
        valid = kpos <= pos
    else:
        # rolling buffer: slot i holds absolute position pos - ((slot - i)
        # mod window); valid if within the window and not in the future.
        age = (slot - kpos) % s
        abs_pos = pos - age
        valid = (abs_pos >= 0) & (abs_pos > pos - window)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bksd->bkrd", w.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# -- MLP -----------------------------------------------------------------------
def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    return {
        "w_gate": P((D, F), ("embed", "mlp")),
        "w_up": P((D, F), ("embed", "mlp")),
        "w_down": P((F, D), ("mlp", "embed")),
    }


def mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["w_down"].astype(x.dtype))


# -- embeddings / head -----------------------------------------------------------
def embed_spec(cfg: ModelConfig) -> dict:
    spec = {
        "embedding": P((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                       "const_std", scale=0.02),
        "final_norm": P((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = P((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.num_codebooks > 1:
        spec["codebook_embed"] = P(
            (cfg.num_codebooks - 1, cfg.vocab_size, cfg.d_model),
            ("codebooks", "vocab", "embed"), "const_std", scale=0.02)
        spec["codebook_head"] = P(
            (cfg.num_codebooks - 1, cfg.d_model, cfg.vocab_size),
            ("codebooks", "embed", "vocab"))
    if cfg.frontend == "vision_stub":
        # projection from precomputed (stub) patch embeddings to d_model
        spec["patch_proj"] = P((cfg.d_model, cfg.d_model), ("embed_in", "embed"))
    return spec


def embed_tokens(cfg: ModelConfig, p, tokens, dtype):
    """tokens: (B, S) or (B, S, n_codebooks) -> (B, S, D)."""
    if cfg.num_codebooks > 1:
        x = p["embedding"][tokens[..., 0]]
        for c in range(cfg.num_codebooks - 1):
            x = x + p["codebook_embed"][c][tokens[..., c + 1]]
    else:
        x = p["embedding"][tokens]
    return x.astype(dtype)


def lm_logits(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (B, S, V) (or (B, S, n_codebooks, V) for audio)."""
    head = (p["embedding"].T if cfg.tie_embeddings else p["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.num_codebooks > 1:
        extra = jnp.einsum("bsd,cdv->bscv", x,
                           p["codebook_head"].astype(x.dtype))
        logits = jnp.concatenate([logits[:, :, None, :], extra], axis=2)
    if cfg.logits_softcap:
        cap = cfg.logits_softcap
        logits = jnp.tanh(logits / cap) * cap
    return logits.astype(jnp.float32)


def apply_frontend(cfg: ModelConfig, p, x, frontend_inputs):
    """Splice stub modality embeddings into the token embedding sequence.

    vision_stub: ``frontend_inputs`` is (B, num_patches, D) precomputed
    patch embeddings (the ViT is an assignment-mandated stub); they are
    projected and overwrite the first ``num_patches`` positions
    (image-placeholder tokens).
    """
    if cfg.frontend == "vision_stub" and frontend_inputs is not None:
        patches = jnp.einsum("bpe,ed->bpd", frontend_inputs.astype(x.dtype),
                             p["patch_proj"].astype(x.dtype))
        npatch = patches.shape[1]
        x = jnp.concatenate([patches, x[:, npatch:]], axis=1)
    return x


def constrain_act(x, cfg: "ModelConfig | None" = None):
    """Pin the residual stream sharding.

    Default: batch-sharded only (keeps GSPMD from inventing exotic
    scan-carry shardings).  With ``cfg.seq_parallel`` the sequence dim is
    sharded over the model axis in the norm/residual regions
    (Megatron-SP): GSPMD then all-gathers into the TP matmuls and
    reduce-scatters out, cutting activation memory by the TP degree.
    """
    from repro.distributed.ctx import constrain
    seq_axis = "seq_sp" if (cfg is not None and cfg.seq_parallel) else "seq"
    return constrain(x, ("batch", seq_axis, "act_embed"))


def _auto_block(n_layers: int) -> int:
    """Largest divisor of n_layers not exceeding ~sqrt(n_layers)."""
    limit = int(np.ceil(np.sqrt(n_layers))) + 1
    best = 1
    for k in range(1, limit + 1):
        if n_layers % k == 0:
            best = k
    return best


def stacked_apply(cfg: ModelConfig, body, x, layers, n_layers: int):
    """Apply ``body(carry, layer_params) -> (carry, y)`` over a stacked
    layer pytree with two-level rematerialization.

    Inner level: each layer body is checkpointed (recompute in bwd).
    Outer level: layers are grouped into blocks of ``cfg.remat_block``
    (auto ~sqrt(L)); the block is checkpointed too, so the bwd pass keeps
    only L/k block carries live plus k transient inner carries — the
    classic O(sqrt(L)) activation-memory schedule.
    """
    from repro.utils.tree import scan_or_loop

    if cfg.remat == "none":
        return scan_or_loop(cfg.scan_layers, body, x, layers, n_layers)
    inner = jax.checkpoint(body, policy=remat_policy(cfg))
    block = cfg.remat_block or _auto_block(n_layers)
    if block <= 1 or n_layers % block:
        return scan_or_loop(cfg.scan_layers, inner, x, layers, n_layers)
    nblocks = n_layers // block
    blocked = jax.tree.map(
        lambda a: a.reshape((nblocks, block) + a.shape[1:]), layers)

    def outer(carry, bp):
        carry, ys = scan_or_loop(cfg.scan_layers, inner, carry, bp, block)
        return carry, ys

    outer = jax.checkpoint(outer, policy=remat_policy(cfg))
    carry, ys = scan_or_loop(cfg.scan_layers, outer, x, blocked, nblocks)
    if ys is not None:
        ys = jax.tree.map(
            lambda a: a.reshape((n_layers,) + a.shape[2:]), ys)
    return carry, ys


def remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots_saveable":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def maybe_checkpoint(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(cfg))
