"""Model and shape configuration dataclasses.

One :class:`ModelConfig` covers all ten assigned architecture families;
family-specific fields are simply unused elsewhere.  :class:`ShapeConfig`
describes one cell of the (architecture × input-shape) grid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # -- MoE ------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden
    moe_shared_d_ff: int = 0     # shared-expert hidden (qwen2-moe)
    moe_dense_parallel: bool = False   # dense-FFN residual ∥ MoE (arctic)
    moe_capacity_factor: float = 1.25
    moe_impl: str = "gspmd"            # gspmd | ep (shard_map all_to_all)
    moe_expert_pad: int = 0            # dummy experts so E divides EP degree

    # -- SSM (mamba2) ------------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128

    # -- hybrid (recurrentgemma) -------------------------------------------
    block_pattern: Sequence[str] = ("attn",)   # e.g. ("rec","rec","attn")
    window: Optional[int] = None               # local attention window
    rglru_c: float = 8.0

    # -- modality frontends (STUBS per assignment) ---------------------------
    frontend: Optional[str] = None   # "vision_stub" | "audio_stub"
    num_patches: int = 256           # vision stub: patch embeddings per image
    num_codebooks: int = 0           # audio: EnCodec codebooks

    # -- numerics / implementation -------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kernel_impl: str = "auto"        # see kernels.ops
    remat: str = "full"              # none | full | dots_saveable
    remat_block: int = 0             # layers per remat block; 0 = auto ~sqrt(L)
    scan_layers: bool = True
    seq_parallel: bool = False       # Megatron-SP: seq-shard norm regions
    ring_attention: bool = False     # shard_map ring attention (prefill/train)
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def full_attention(self) -> bool:
        """True if the arch has at least one unwindowed attention layer."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return self.window is None
        return self.window is None

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    # Exact parameter counts come from the spec tree: models.count_params /
    # models.count_active_params (no allocation, cannot drift from init).


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> tuple[ShapeConfig, ...]:
    """The runnable shape set for an architecture.

    ``long_500k`` requires sub-quadratic attention: it runs only for
    ssm/hybrid families (see DESIGN.md §Arch-applicability); pure
    full-attention archs skip it by design.
    """
    if cfg.family in ("ssm", "hybrid"):
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
