"""Mamba2 (state-space duality / SSD) decoder — attention-free family.

Block structure follows the Mamba2 paper: a fused input projection emits
(z, x, B, C, dt); (x, B, C) pass through a causal depthwise conv; the SSD
scan (kernels.ops.ssd_scan: Pallas chunked kernel on TPU, lax.scan oracle
on CPU) evolves the (heads, headdim, state) recurrence; the output is
gate-normalized (RMSNorm(y * silu(z))) and projected back.

Decode keeps O(1) state per layer: a (conv_width-1) conv tail plus the
(H, P, N) SSM state — which is why this arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.utils.tree import scan_or_loop
from . import common as cm
from .config import ModelConfig


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return di, nh, g, n, conv_dim


def layer_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    di, nh, g, n, conv_dim = _dims(cfg)
    d_in_proj = 2 * di + 2 * g * n + nh
    return {
        "ln": cm.P((D,), ("embed",), "zeros"),
        "in_proj": cm.P((D, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": cm.P((cfg.conv_width, conv_dim), ("conv", "ssm_inner"),
                       "normal", scale=0.5),
        "conv_b": cm.P((conv_dim,), ("ssm_inner",), "zeros"),
        "a_log": cm.P((nh,), ("ssm_heads",), "ones"),
        "d_skip": cm.P((nh,), ("ssm_heads",), "ones"),
        "dt_bias": cm.P((nh,), ("ssm_heads",), "zeros"),
        "norm": cm.P((di,), ("ssm_inner",), "zeros"),
        "out_proj": cm.P((di, D), ("ssm_inner", "embed")),
    }


def model_spec(cfg: ModelConfig) -> dict:
    return {
        "embed": cm.embed_spec(cfg),
        "layers": cm.stack_spec(layer_spec(cfg), cfg.num_layers),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv.  xbc: (B, S, C); w: (W, C)."""
    wdt = w.astype(xbc.dtype)
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * wdt[i] for i in range(width))
    return out + b.astype(xbc.dtype)


def _split_proj(cfg, proj):
    di, nh, g, n, _ = _dims(cfg)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    return z, xs, bmat, cmat, dt


def mamba_layer(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, nh, g, n, conv_dim = _dims(cfg)
    x = cm.constrain_act(x, cfg)
    xn = cm.rmsnorm(cfg, p["ln"], x)
    proj = jnp.einsum("bsd,de->bse", xn, p["in_proj"].astype(x.dtype))
    z, xs, bmat, cmat, dt_raw = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, bmat, cmat = jnp.split(xbc, [di, di + g * n], axis=-1)
    xh = xs.reshape(B, S, nh, cfg.ssm_headdim)
    bh = bmat.reshape(B, S, g, n)
    ch = cmat.reshape(B, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    # pad S to chunk multiple for the Pallas path
    pad = (-S) % cfg.ssm_chunk
    if pad and cfg.kernel_impl not in ("xla",):
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, _ = kops.ssd_scan(xh, dt, a, bh, ch, chunk=cfg.ssm_chunk,
                         impl=cfg.kernel_impl)
    y = y[:, :S]
    y = y + xh[:, :S] * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = cm.rmsnorm(cfg, p["norm"], y * jax.nn.silu(z))
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))


def forward(cfg: ModelConfig, params, tokens, frontend_inputs=None):
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens, dtype)

    body = lambda c, q: (mamba_layer(cfg, q, c), None)
    x, _ = cm.stacked_apply(cfg, body, x, params["layers"], cfg.num_layers)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    return cm.lm_logits(cfg, params["embed"], x), jnp.float32(0.0)


def init_params(cfg: ModelConfig, key):
    return cm.init_from_spec(model_spec(cfg), key, jnp.dtype(cfg.param_dtype))


def logical_axes(cfg: ModelConfig):
    return cm.axes_from_spec(model_spec(cfg))


# ---------------------------------------------------------------------------
# Serving: O(1) recurrent state
# ---------------------------------------------------------------------------
def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    di, nh, g, n, conv_dim = _dims(cfg)
    L = cfg.num_layers
    return {
        "conv": jax.ShapeDtypeStruct(
            (L, batch, cfg.conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "ssm": jax.ShapeDtypeStruct(
            (L, batch, nh, cfg.ssm_headdim, n), jnp.float32),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {
        "conv": ("layers", "batch", "conv", "ssm_inner"),
        "ssm": ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq))


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One token for the whole stack.  tokens: (B,)."""
    del pos  # state is position-free
    dtype = jnp.dtype(cfg.dtype)
    x = cm.embed_tokens(cfg, params["embed"], tokens[:, None], dtype)
    di, nh, g, n, conv_dim = _dims(cfg)
    B = x.shape[0]

    def body(carry, inp):
        lp, conv_st, ssm_st = inp
        h = carry
        xn = cm.rmsnorm(cfg, lp["ln"], h)
        proj = jnp.einsum("bsd,de->bse", xn, lp["in_proj"].astype(h.dtype))
        z, xs, bmat, cmat, dt_raw = _split_proj(cfg, proj)
        xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)[:, 0]   # (B, C)
        hist = jnp.concatenate([conv_st, xbc[:, None, :]], axis=1)
        w = lp["conv_w"].astype(h.dtype)
        conv_out = jnp.einsum("bwc,wc->bc", hist, w) + lp["conv_b"].astype(h.dtype)
        conv_out = jax.nn.silu(conv_out)
        new_conv = hist[:, 1:, :]
        xs1, b1, c1 = jnp.split(conv_out, [di, di + g * n], axis=-1)
        xh = xs1.reshape(B, nh, cfg.ssm_headdim)
        bh = jnp.repeat(b1.reshape(B, g, n), nh // g, axis=1)
        ch = jnp.repeat(c1.reshape(B, g, n), nh // g, axis=1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + lp["dt_bias"].astype(jnp.float32))
        a = -jnp.exp(lp["a_log"].astype(jnp.float32))
        decay = jnp.exp(dt * a)[..., None, None]
        ssm_new = ssm_st * decay + jnp.einsum(
            "bhp,bhn->bhpn", (xh * dt[..., None]).astype(jnp.float32),
            bh.astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", ssm_new, ch.astype(jnp.float32))
        y = y.astype(h.dtype) + xh * lp["d_skip"].astype(h.dtype)[None, :, None]
        y = y.reshape(B, 1, di)
        y = cm.rmsnorm(cfg, lp["norm"], y * jax.nn.silu(z))
        h = h + jnp.einsum("bse,ed->bsd", y, lp["out_proj"].astype(h.dtype))
        return h, (new_conv.astype(conv_st.dtype), ssm_new)

    x, (new_conv, new_ssm) = scan_or_loop(
        cfg.scan_layers, body, x,
        (params["layers"], cache["conv"], cache["ssm"]), cfg.num_layers)
    x = cm.rmsnorm(cfg, params["embed"]["final_norm"], x)
    logits = cm.lm_logits(cfg, params["embed"], x)
    return logits[:, 0], {"conv": new_conv, "ssm": new_ssm}
