"""Mixture-of-Experts FFN with sort-based (dropless-style) dispatch.

TPU-native dispatch: tokens are argsorted by expert id, scattered into a
static (E, capacity, D) buffer, processed with a single batched expert
einsum ('ecd,edf->ecf' — MXU-shaped and shardable over the expert dim =
expert parallelism), and combined back with top-k gate weighting.
Overflowing tokens beyond the static capacity are dropped (standard
capacity-factor semantics); the aux load-balancing loss keeps the router
near-uniform so drops stay rare.

Variants covered:
* arctic-480b   — 128 experts, top-2, dense FFN residual in parallel
  (``moe_dense_parallel``),
* qwen2-moe-a2.7b — 60 routed experts, top-4, plus an always-on shared
  expert (``moe_shared_d_ff``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import P, mlp, mlp_spec
from .config import ModelConfig


def moe_spec(cfg: ModelConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    Et = E + cfg.moe_expert_pad      # padded experts never receive tokens
    spec = {
        "router": P((D, E), ("embed", "experts_r")),
        "w_gate": P((Et, D, Fe), ("experts", "embed", "expert_mlp")),
        "w_up": P((Et, D, Fe), ("experts", "embed", "expert_mlp")),
        "w_down": P((Et, Fe, D), ("experts", "expert_mlp", "embed")),
    }
    if cfg.moe_shared_d_ff:
        spec["shared"] = mlp_spec(cfg, cfg.moe_shared_d_ff)
    return spec


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(np.ceil(n_tokens * cfg.moe_top_k / cfg.moe_num_experts
                      * cfg.moe_capacity_factor))
    return max(int(np.ceil(cap / 8)) * 8, 8)   # pad for TPU tiling


def moe_ffn(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    k = cfg.moe_top_k
    E = cfg.moe_num_experts
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Aux load-balancing loss (Switch-style): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)                               # (E,)
    one_hot = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    fe = jnp.mean(one_hot, axis=0)
    aux = E * jnp.sum(me * fe)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = expert_idx.reshape(-1)                            # (T*k,)
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k         # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                                # stable
    e_s = flat_e[order]
    tok_s = flat_tok[order]
    gate_s = flat_gate[order]
    counts = jnp.bincount(flat_e, length=E)                    # (E,)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[e_s]

    cap = _capacity(cfg, T)
    Et = E + cfg.moe_expert_pad
    valid = rank < cap
    slot = jnp.where(valid, e_s * cap + rank, Et * cap)        # drop row
    buf = jnp.zeros((Et * cap + 1, D), x.dtype).at[slot].set(xf[tok_s])
    h = buf[: Et * cap].reshape(Et, cap, D)

    # ---- expert compute (EP-shardable over E) -----------------------------
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                     p["w_down"].astype(x.dtype))

    # ---- combine -----------------------------------------------------------
    out_flat = out.reshape(Et * cap, D)
    gathered = jnp.where(valid[:, None], out_flat[jnp.minimum(slot, Et * cap - 1)], 0.0)
    contrib = gathered * gate_s[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok_s].add(contrib)
    return y.reshape(B, S, D), aux


def moe_block(cfg: ModelConfig, p, x):
    """The full FFN half of an MoE layer (routed + shared/dense paths)."""
    if cfg.moe_impl == "ep":
        from repro.distributed import ctx as dctx
        c = dctx.current()
        if c is not None:
            mesh, _ = c
            from repro.distributed.moe_parallel import moe_ffn_ep
            data_axes = tuple(a for a in ("pod", "data")
                              if a in mesh.axis_names)
            y, aux = moe_ffn_ep(cfg, mesh, p["moe"], x,
                                data_axes=data_axes)
        else:
            y, aux = moe_ffn(cfg, p["moe"], x)
    else:
        y, aux = moe_ffn(cfg, p["moe"], x)
    if cfg.moe_shared_d_ff:
        y = y + mlp(p["moe"]["shared"], x)
    if cfg.moe_dense_parallel:
        y = y + mlp(p["dense_mlp"], x)
    return y, aux
