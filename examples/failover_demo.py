"""Failure detection -> elastic reshard -> resume, on a simulated fleet.

  PYTHONPATH=src python examples/failover_demo.py
"""
import numpy as np

from repro.data import DataConfig, TokenPipeline
from repro.runtime import (
    FailureDetector, HostState, StragglerPolicy, make_reshard_plan,
    validate_plan,
)


def main():
    n_hosts = 8
    fd = FailureDetector(n_hosts, lease_s=10.0)
    sp = StragglerPolicy(factor=1.5)
    dcfg = DataConfig(vocab_size=1024, seq_len=32, global_batch=64)
    pipes = {h: TokenPipeline(dcfg, shard=h, num_shards=n_hosts)
             for h in range(n_hosts)}

    clock = 0.0
    for step in range(6):
        clock += 12.0
        for h in range(n_hosts):
            if h == 5 and step >= 2:
                continue            # host 5 stops heartbeating
            fd.heartbeat(h, clock)
        changes = fd.tick(clock + 1.0)
        durations = {h: 1.0 + 0.1 * np.random.default_rng(h).random()
                     for h in fd.healthy_hosts()}
        if step == 4:
            durations[2] = 5.0      # host 2 straggles
        for d in durations.values():
            sp.observe(d)
        backups = sp.mitigate(durations)
        for h, st in changes.items():
            print(f"t={clock:5.1f}s host {h} -> {st.value}")
        if backups:
            print(f"t={clock:5.1f}s straggler backups: {backups}")
        dead = [h for h, i in fd.hosts.items() if i.state is HostState.DEAD]
        if dead:
            healthy = fd.healthy_hosts()
            plan = make_reshard_plan(list(range(n_hosts)), healthy,
                                     model_parallel=4)
            validate_plan(plan)
            print(f"t={clock:5.1f}s RESHARD: {len(healthy)} hosts, "
                  f"mesh {plan.mesh_shape}, "
                  f"shard ownership {plan.shard_ownership}")
            pipes = {h: pipes[h].reshard(plan.data_shards[h][0],
                                         len(healthy))
                     for h in healthy}
            # every host resumes at the same step with the new layout
            steps = {h: p.state.step for h, p in pipes.items()}
            assert len(set(steps.values())) == 1
            print(f"t={clock:5.1f}s pipelines resharded at step "
                  f"{next(iter(steps.values()))}; resuming")
            break
    print("failover demo complete")


if __name__ == "__main__":
    main()
