"""Quickstart: build a reduced model, run a few train steps, then decode.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig
from repro.serve import make_serve_step
from repro.train import TrainState, make_train_step


def main():
    cfg = get_smoke_config("qwen3-4b")
    print(f"model: {cfg.name} ({M.count_params(cfg)/1e6:.2f}M params, "
          f"family={cfg.family})")

    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8))
    state = TrainState.create(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3,
                                                    warmup_steps=10)))
    for i in range(20):
        state, metrics = step(state, jax.tree.map(jnp.asarray, next(data)))
        if i % 5 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.4f}")

    # serve a few greedy tokens
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    cache = M.init_cache(cfg, 2, 64)
    tok = jnp.array([1, 2], jnp.int32)
    out = []
    for pos in range(8):
        tok, cache = serve(state.params, cache, tok, jnp.int32(pos))
        out.append(np.asarray(tok))
    print("greedy tokens:", np.stack(out, 1))


if __name__ == "__main__":
    main()
