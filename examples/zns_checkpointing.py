"""The paper's technique in action: checkpoint-policy comparison on the
calibrated ZN540 model + conventional-SSD contrast (Obs#11).

  PYTHONPATH=src python examples/zns_checkpointing.py
"""
import numpy as np

from repro.core import MiB, ConvDevice, OpType, ZnsDevice
from repro.core.calibration import PEAK_WRITE_BW_MIBS
from repro.runtime.zns_store import ZnsHostDevice

SHARD = 4 * 1024 * MiB      # 4 GiB per-host checkpoint shard


def main():
    print("== ZNS checkpoint write policies (per-host, 4 GiB shard) ==")
    policies = {
        "R2: 1MiB appends @QD4 (paper)": dict(stripe_bytes=1 * MiB,
                                              append_qd=4),
        "4KiB appends @QD1 (naive)": dict(stripe_bytes=4 * 1024,
                                          append_qd=1),
        "64KiB appends @QD4": dict(stripe_bytes=64 * 1024, append_qd=4),
        "4MiB appends @QD4 (tuned)": dict(stripe_bytes=4 * MiB,
                                          append_qd=4),
    }
    for name, kw in policies.items():
        dev = ZnsHostDevice(0, **kw)
        t, n = dev.simulate_payload_write(SHARD)
        print(f"  {name:38s} wall={t:6.2f}s  bw={SHARD/t/MiB:7.0f} MiB/s "
              f"({n} appends)")

    print("\n== reclaim (reset) vs refill cost — R5 ==")
    dev = ZnsHostDevice(0)
    entries = dev.plan(SHARD)
    dev.apply_writes(entries)
    full = [e.zone for e in entries if dev.zm.state(e.zone).name == "FULL"]
    dev.schedule_reset(full)
    gc_s = dev.run_gc(concurrent_io=True)
    fill_s = SHARD / (PEAK_WRITE_BW_MIBS * MiB)
    print(f"  reset {len(full)} zones under I/O: {gc_s*1e3:.1f} ms "
          f"(~{gc_s/fill_s*100:.1f}% of fill time; paper says ~1%)")

    print("\n== why not a conventional SSD? (Obs#11) ==")
    conv = ConvDevice().run_write_pressure(rate_mibs=PEAK_WRITE_BW_MIBS,
                                           duration_s=60)
    zns = ZnsDevice().run_write_pressure(rate_mibs=PEAK_WRITE_BW_MIBS,
                                         duration_s=60)
    print(f"  write-throughput CV:  conv={conv.write_cv:.2f}"
          f"  zns={zns.write_cv:.2f}")
    print(f"  read p95 under writes: conv={conv.read_lat_p95_us/1e3:.0f} ms"
          f"  zns={zns.read_lat_p95_us/1e3:.0f} ms")
    print("  -> training-data reads next to checkpoint writes need ZNS-class"
          " isolation")


if __name__ == "__main__":
    main()
