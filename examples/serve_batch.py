"""Serve a small model with batched requests: continuous-batching-style
loop where finished sequences are replaced by queued prompts.

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import get_smoke_config
from repro.serve import make_serve_step

BATCH = 4
MAX_SEQ = 64
EOS = 0
N_REQUESTS = 12
MAX_NEW = 24


def main():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12))
             for _ in range(N_REQUESTS)]
    # slot state
    cache = M.init_cache(cfg, BATCH, MAX_SEQ)
    cur = jnp.zeros((BATCH,), jnp.int32)
    age = np.zeros(BATCH, int)
    active = [None] * BATCH
    outputs = {}
    done = 0
    step_count = 0

    def admit(slot):
        nonlocal cur
        if not queue:
            active[slot] = None
            return
        req_id = N_REQUESTS - len(queue)
        prompt = queue.pop(0)
        active[slot] = (req_id, list(prompt), [])
        age[slot] = 0
        cur = cur.at[slot].set(int(prompt[0]))

    for s in range(BATCH):
        admit(s)

    while done < N_REQUESTS and step_count < 2000:
        pos = int(age.max())
        tok, cache = serve(params, cache, cur, jnp.int32(pos))
        tok = np.asarray(tok)
        step_count += 1
        for s in range(BATCH):
            if active[s] is None:
                continue
            req_id, prompt, gen = active[s]
            age[s] += 1
            if age[s] < len(prompt):           # still force-feeding prompt
                cur = cur.at[s].set(int(prompt[age[s]]))
                continue
            gen.append(int(tok[s]))
            if int(tok[s]) == EOS or len(gen) >= MAX_NEW:
                outputs[req_id] = gen
                done += 1
                admit(s)
            else:
                cur = cur.at[s].set(int(tok[s]))
    print(f"served {done}/{N_REQUESTS} requests in {step_count} decode steps "
          f"(batch={BATCH})")
    for rid in sorted(outputs)[:4]:
        print(f"  req {rid}: {len(outputs[rid])} tokens "
              f"{outputs[rid][:8]}...")
    assert done == N_REQUESTS


if __name__ == "__main__":
    main()
