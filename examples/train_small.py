"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with ZNS checkpointing, then kill/restore to prove
fault-tolerant resume.

The default (--fast) trims width so CPU finishes in minutes; pass
--full-100m for the full ~100M variant.

  PYTHONPATH=src python examples/train_small.py
"""
import argparse
import dataclasses
import shutil
import sys
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.optim import AdamWConfig
from repro.runtime import ZonedCheckpointStore
from repro.train import TrainState, make_train_step


def model_config(full_100m: bool):
    base = get_config("tinyllama-1.1b", kernel_impl="xla")
    if full_100m:
        # ~100M params: 12L x 768 with a 16k vocab
        return dataclasses.replace(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=16384)
    return dataclasses.replace(
        base, num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=688, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = model_config(args.full_100m)
    print(f"params: {M.count_params(cfg)/1e6:.1f}M")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    opt = AdamWConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps)
    ckpt_dir = tempfile.mkdtemp(prefix="zns_ckpt_")
    store = ZonedCheckpointStore(ckpt_dir, n_hosts=2)

    data = TokenPipeline(dcfg)
    state = TrainState.create(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    half = args.steps // 2
    losses = []
    for i in range(half):
        state, metrics = step(state, jax.tree.map(jnp.asarray, next(data)))
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            print(f"step {i}: loss={losses[-1]:.4f}")
    out = store.save(half, {
        "params": jax.tree.map(np.asarray, state.params),
        "opt": jax.tree.map(np.asarray, state.opt),
        "step": np.asarray(state.step)},
        extra_meta={"data": data.state_dict()})
    print(f"checkpoint@{half}: modeled ZNS wall {out['wall_seconds']:.2f}s, "
          f"host bw {out['reports'][0].bandwidth_mibs:.0f} MiB/s")

    # --- simulate a crash: rebuild everything from the store ------------
    del state, data
    fresh = TrainState.create(cfg, jax.random.PRNGKey(123))
    like = {"params": jax.tree.map(np.asarray, fresh.params),
            "opt": jax.tree.map(np.asarray, fresh.opt),
            "step": np.asarray(fresh.step)}
    restored, manifest = store.restore(half, like)
    state = TrainState(step=jnp.asarray(restored["step"]),
                       params=jax.tree.map(jnp.asarray, restored["params"]),
                       opt=jax.tree.map(jnp.asarray, restored["opt"]))
    data = TokenPipeline(dcfg)
    data.load_state_dict(manifest["meta"]["data"])
    print(f"restored at step {int(state.step)}; resuming")

    for i in range(half, args.steps):
        state, metrics = step(state, jax.tree.map(jnp.asarray, next(data)))
        losses.append(float(metrics["loss"]))
        if i % 25 == 0:
            print(f"step {i}: loss={losses[-1]:.4f}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")
    shutil.rmtree(ckpt_dir)
    sys.exit(0 if last < first else 1)


if __name__ == "__main__":
    main()
