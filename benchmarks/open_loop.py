"""Open-loop lowering overhead gate.

The arrival-process layer (``StreamSpec.arrival``, qd=0 open loop) must
stay a lowering-time detail: stamping explicit issue times and raising
the closed-loop gate to ``qd=n`` may not make the compile+solve path
measurably slower than an equivalent closed-loop stream.  The gate
compares cold (cache-cleared) vectorized runs of a 100k-request
open-loop workload against its closed-loop twin and fails the row
(``=FAIL``, picked up by CI's benchmark smoke) when the open-loop side
is more than 10% slower.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (KiB, OpType, PoissonArrivals, WorkloadSpec,
                        ZnsDevice, clear_program_cache)

OVERHEAD_GATE = 1.10    # open loop may cost at most 10% over closed loop
REPEATS = 5


def _streams(wl: WorkloadSpec, n: int, *, open_loop: bool) -> WorkloadSpec:
    """Four-thread mixed workload; the open-loop variant swaps the
    closed-loop qd=64 threads for qd=0 Poisson streams of the same size
    and count, so both lower to one pool/zone chain structure."""
    kw = (dict(qd=0, arrival=PoissonArrivals(rate_per_s=2e5, seed=5))
          if open_loop else dict(qd=64))
    return (wl
            .writes(n=n, size=4 * KiB, zone=0, **kw)
            .reads(n=n, size=4 * KiB, zone=100, nzones=64, **kw)
            .appends(n=n // 2, size=8 * KiB, zone=300, nzones=8, **kw)
            .resets(n=max(n // 100, 2), occupancy=1.0,
                    nzones=max(n // 100, 2), io_ctx=OpType.READ, **kw))


def _cold_run_pair_s(dev: ZnsDevice, closed: WorkloadSpec,
                     opened: WorkloadSpec):
    """Cold (cache-cleared) runs, *interleaved* so machine drift hits
    both variants equally; returns (best_closed_s, best_open_s,
    median_per_rep_overhead).  The gate uses the median of per-rep
    ratios — each rep's pair runs back to back, so the ratio cancels
    slow drift that best-of-N block timing cannot."""
    times = [[], []]
    for _ in range(REPEATS):
        for i, wl in enumerate((closed, opened)):
            clear_program_cache()
            t0 = time.perf_counter()
            dev.run(wl, backend="vectorized", jitter=False)
            times[i].append(time.perf_counter() - t0)
    ratios = sorted(o / max(c, 1e-9) for c, o in zip(*times))
    return min(times[0]), min(times[1]), ratios[len(ratios) // 2]


def run(quick: bool = False):
    n = 8_000 if quick else 40_000      # 4 streams -> 20k / 100k requests
    dev = ZnsDevice()
    closed = _streams(WorkloadSpec(), n, open_loop=False)
    opened = _streams(WorkloadSpec(), n, open_loop=True)
    n_req = len(opened.build())
    assert len(closed.build()) == n_req

    t_closed, t_open, overhead = _cold_run_pair_s(dev, closed, opened)
    gate_ok = overhead <= OVERHEAD_GATE

    # the arrival stamping itself, isolated (pure lowering, no engine)
    proc = PoissonArrivals(rate_per_s=2e5, seed=5)
    proc.issue_times(n)                  # warmup
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        times = proc.issue_times(n)
    t_stamp = (time.perf_counter() - t0) / REPEATS
    assert bool(np.all(np.diff(times) >= 0.0))

    return [
        (f"open_loop/closed_cold/n{n_req}", t_closed * 1e6,
         f"{n_req / t_closed:.0f}req_per_s"),
        (f"open_loop/open_cold/n{n_req}", t_open * 1e6,
         f"overhead_x={overhead:.3f};gate<={OVERHEAD_GATE:.2f}"
         + ("" if gate_ok else "=FAIL")),
        (f"open_loop/issue_times/n{n}", t_stamp * 1e6,
         f"{n / max(t_stamp, 1e-9) / 1e6:.1f}Mreq_per_s"),
    ]


if __name__ == "__main__":
    from benchmarks.common import fmt_rows
    print(fmt_rows(run()))
