"""ChainProgram compiler microbench: the fused fixpoint vs the
pre-refactor per-chain sweep loop.

Acceptance gate for the trace-compilation layer (ISSUE 5): at 16
devices x 100k requests, a warm fused fleet solve
(``DeviceFleet.run(backend="vectorized")`` with the compiled
:class:`repro.core.ChainProgram` cached) must run >=2x faster than the
pre-refactor path — a Python loop of per-device per-chain sweep loops
(``repro.core.engine._simulate_vectorized_unfused``, which re-lowers
the trace and re-scans every chain family on every sweep of every
call) — while agreeing on completion times to float tolerance.

Reported rows:

* ``chain_program/fused_warm``  — warm fused solve (program cached); the
  gated row.
* ``chain_program/fused_cold``  — first call including compilation
  (lowering + pop-order refinement when pools saturate).
* ``chain_program/sweep_loop``  — the per-chain sweep-loop baseline.
* ``chain_program/append_pool`` — the newly-exact saturated multi-thread
  append pool: fused vs the *event engine* (the only previously-correct
  backend for that shape), with the equivalence error that the compiler
  closes.

``run(quick=True)`` is the CI smoke configuration (8 devices x 20k).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceFleet, KiB, OpType, WorkloadSpec, ZnsDevice, \
    clear_program_cache
from repro.core.engine import _simulate_vectorized_unfused, simulate

SPEEDUP_GATE = 2.0


def _mixed_workload(scale: int) -> WorkloadSpec:
    """~100*scale requests; stays inside the pre-refactor engine's
    exactness envelope so baseline and fused compute the same answer."""
    return (WorkloadSpec()
            .writes(n=36 * scale, size=4 * KiB, qd=4, zone=0)
            .reads(n=44 * scale, size=4 * KiB, qd=16, zone=100, nzones=100)
            .appends(n=18 * scale, size=8 * KiB, qd=2, zone=300)
            .resets(n=2 * scale, occupancy=1.0, nzones=200,
                    io_ctx=OpType.READ))


def _append_pool_workload(scale: int) -> WorkloadSpec:
    """Saturated multi-thread append pool (Obs#5-#7): exact only on the
    event engine before this layer."""
    wl = WorkloadSpec()
    for t in range(8):
        wl = wl.appends(n=2 * scale, size=8 * KiB, qd=4, zone=t * 8,
                        nzones=8)
    return wl


def run(quick: bool = False):
    n_dev = 8 if quick else 16
    scale = 200 if quick else 1000      # 20k / 100k requests per device
    traces = [_mixed_workload(scale).build()] * n_dev
    n_per_dev = len(traces[0])
    fleet = DeviceFleet.homogeneous(n_dev)

    clear_program_cache()
    t0 = time.perf_counter()
    fres = fleet.run(traces, backend="vectorized", jitter=False)
    t_cold = time.perf_counter() - t0

    # warm: program cached; best-of-2 so the gate measures the engine,
    # not scheduler noise
    t_warm = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        fres = fleet.run(traces, backend="vectorized", jitter=False)
        t_warm = min(t_warm, time.perf_counter() - t0)

    # pre-refactor baseline: per-device per-chain sweep loops (best-of-2
    # as well — both sides get the same treatment)
    t_loop = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        base = [_simulate_vectorized_unfused(
            traces[i], fleet[i].spec, fleet[i].lat, seed=i, jitter=False)
            for i in range(n_dev)]
        t_loop = min(t_loop, time.perf_counter() - t0)

    rel = max(
        float(np.max(np.abs(base[i].complete - fres[i].sim.complete)
                     / np.maximum(base[i].complete, 1.0)))
        for i in range(n_dev))
    speedup = t_loop / max(t_warm, 1e-9)
    gate = "PASS" if speedup >= SPEEDUP_GATE else "FAIL"
    rows = [
        (f"chain_program/fused_warm/n{n_dev}x{n_per_dev}", t_warm * 1e6,
         f"speedup_vs_sweep_loop_x={speedup:.2f};"
         f"max_rel_err={rel:.1e};ge{SPEEDUP_GATE:.0f}x={gate}"),
        (f"chain_program/fused_cold/n{n_dev}x{n_per_dev}", t_cold * 1e6,
         f"compile_overhead_x={t_cold / max(t_warm, 1e-9):.2f}"),
        (f"chain_program/sweep_loop/n{n_dev}x{n_per_dev}", t_loop * 1e6,
         "baseline=pre-refactor per-chain sweep loop"),
    ]

    # The closed gap: saturated multi-thread append pool, fused vs event.
    ap = _append_pool_workload(max(scale // 4, 25)).build()
    dev = ZnsDevice()
    t0 = time.perf_counter()
    ev = simulate(ap, dev.spec, dev.lat, seed=0, jitter=False)
    t_event = time.perf_counter() - t0
    t0 = time.perf_counter()
    vc = dev.run(ap, backend="vectorized", seed=0, jitter=False)
    t_vec = time.perf_counter() - t0
    err = float(np.max(np.abs(vc.sim.complete - ev.complete)
                       / np.maximum(ev.complete, 1.0)))
    exact = "PASS" if err < 1e-9 else "FAIL"
    rows.append(
        (f"chain_program/append_pool/n{len(ap)}", t_vec * 1e6,
         f"speedup_vs_event_x={t_event / max(t_vec, 1e-9):.1f};"
         f"max_rel_err_vs_event={err:.1e};exact={exact}"))
    return rows
