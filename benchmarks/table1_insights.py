"""Table I: the five key insights, validated quantitatively against the
model (each row states the paper's claim and the model's number).
Also emits the §IV emulator-fidelity matrix.
"""
from __future__ import annotations

import numpy as np

from repro.core import KiB, MiB, OpType, Stack, ZnsDevice
from repro.core.emulator_models import ALL_MODELS, FIDELITY_MATRIX
from repro.core.workloads import reset_interference


def run():
    dev = ZnsDevice()
    lm = dev.lat
    rows = []
    # Insight 1: write up to 23% lower latency than append
    w = float(dev.io_latency_us(OpType.WRITE, 4 * KiB))
    a = float(dev.io_latency_us(OpType.APPEND, 8 * KiB))
    rows.append(("table1/append_vs_write", 0.0,
                 f"gap_pct={(a - w) / a * 100:.2f} (paper<=23.42)"))
    # Insight 2: prefer intra-zone scalability
    intra = dev.steady_state(OpType.WRITE, 4 * KiB, qd=32,
                             stack=Stack.KERNEL_MQ_DEADLINE).iops
    inter = dev.steady_state(OpType.WRITE, 4 * KiB, zones=14).iops
    rows.append(("table1/intra_vs_inter_write", 0.0,
                 f"intra_kiops={intra/1e3:.0f};inter_kiops={inter/1e3:.0f}"))
    # Insight 3: finish most expensive (hundreds of ms)
    f0 = float(dev.finish_latency_us(0.001)) / 1e3
    rows.append(("table1/finish_cost", 0.0,
                 f"finish_ms_at_0pct={f0:.1f} (paper 907.51)"))
    # Insight 4: ZNS ~3x higher read throughput under concurrent I/O
    #   (from the Obs#11 p95 anchors: 299.89 / 98.04 = 3.06x)
    from repro.core.calibration import (
        CONV_READ_P95_UNDER_WRITES_MS, ZNS_READ_P95_UNDER_WRITES_MS)
    rows.append(("table1/zns_read_advantage", 0.0,
                 f"x={CONV_READ_P95_UNDER_WRITES_MS / ZNS_READ_P95_UNDER_WRITES_MS:.2f}"))
    # Insight 5: reset latency +<=78% under I/O; resets don't hurt I/O
    res = dev.run(reset_interference(OpType.WRITE, n_resets=200),
                  backend="event", seed=11)
    p95_w = res.latency_stats(OpType.RESET).p95_us / 1e3
    res0 = dev.run(reset_interference(None, n_resets=200),
                   backend="event", seed=11)
    p95_0 = res0.latency_stats().p95_us / 1e3
    rows.append(("table1/reset_inflation", 0.0,
                 f"pct={(p95_w / p95_0 - 1) * 100:.1f} (paper 78.42)"))
    # §IV emulator fidelity matrix
    for name, obs in FIDELITY_MATRIX.items():
        ok = sum(obs.values())
        rows.append((f"sec4/emulator/{name}", 0.0,
                     f"observations_reproduced={ok}/10"))
    # concrete emulator deltas: append==write in NVMeVirt, ~0 in FEMU
    for name, m in ALL_MODELS.items():
        wl = float(np.asarray(m.io_service_us(OpType.WRITE, 4 * KiB)))
        al = float(np.asarray(m.io_service_us(OpType.APPEND, 8 * KiB)))
        rst = float(np.mean(np.asarray(m.reset_us(0.5))))
        fin = float(np.mean(np.asarray(m.finish_us(0.01))))
        rows.append((f"sec4/{name}/latencies", 0.0,
                     f"write_us={wl:.2f};append_us={al:.2f};"
                     f"reset50_us={rst:.0f};finish1pct_us={fin:.0f}"))
    return rows
