"""Table I: the five key insights, each derived from its observation
registry entry (`repro.experiments`) so the table, the figures, and the
docs share one source of truth.  Also emits the §IV emulator-fidelity
matrix (registry-independent: it compares latency *profiles*).
"""
from __future__ import annotations

import numpy as np

from repro.core import KiB, OpType
from repro.core.emulator_models import ALL_MODELS, FIDELITY_MATRIX
from repro.experiments import ExperimentRunner


def run():
    rows = []
    res = {r.obs: r for r in ExperimentRunner(
        ["obs4", "obs7", "obs10", "obs11", "obs13"]).run()}
    # Insight 1: write up to 23% lower latency than append (Obs#4)
    rows.append(("table1/append_vs_write", 0.0,
                 f"gap_pct={res[4].metrics['gap_pct']:.2f} (paper<=23.42)"))
    # Insight 2: prefer intra-zone scalability (Obs#7)
    rows.append(("table1/intra_vs_inter_write", 0.0,
                 f"intra_kiops={res[7].metrics['write_intra_mq_kiops']:.0f};"
                 f"inter_kiops={res[7].metrics['write_inter_kiops']:.0f}"))
    # Insight 3: finish most expensive (hundreds of ms) (Obs#10)
    rows.append(("table1/finish_cost", 0.0,
                 f"finish_ms_at_0pct={res[10].metrics['finish_ms_low']:.1f} "
                 f"(paper 907.51)"))
    # Insight 4: ZNS ~3x higher read throughput under concurrent I/O (Obs#11)
    rows.append(("table1/zns_read_advantage", 0.0,
                 f"x={res[11].metrics['zns_read_advantage']:.2f}"))
    # Insight 5: reset latency +<=78% under I/O; resets don't hurt I/O (Obs#13)
    rows.append(("table1/reset_inflation", 0.0,
                 f"pct={res[13].metrics['write_inflation_pct']:.1f} "
                 f"(paper 78.42)"))
    # §IV emulator fidelity matrix
    for name, obs in FIDELITY_MATRIX.items():
        ok = sum(obs.values())
        rows.append((f"sec4/emulator/{name}", 0.0,
                     f"observations_reproduced={ok}/10"))
    # concrete emulator deltas: append==write in NVMeVirt, ~0 in FEMU
    for name, m in ALL_MODELS.items():
        wl = float(np.asarray(m.io_service_us(OpType.WRITE, 4 * KiB)))
        al = float(np.asarray(m.io_service_us(OpType.APPEND, 8 * KiB)))
        rst = float(np.mean(np.asarray(m.reset_us(0.5))))
        fin = float(np.mean(np.asarray(m.finish_us(0.01))))
        rows.append((f"sec4/{name}/latencies", 0.0,
                     f"write_us={wl:.2f};append_us={al:.2f};"
                     f"reset50_us={rst:.0f};finish1pct_us={fin:.0f}"))
    return rows
