"""Fig. 8 (appendix): throughput/latency trade-off vs queue depth for
append (SPDK, intra-zone) and write (io_uring + mq-deadline, intra-zone).

Shim over the Obs#6 (append saturates at concurrency 4) and Obs#8
(large requests saturate bandwidth) registry entries
(`repro.experiments`), plus the figure's closed-form QD grid from the
same ``ZnsDevice`` session: append latency grows slower than write
latency until ~QD4, so appends should be issued at low QD for latency.
"""
from __future__ import annotations

from repro.core import KiB, OpType, Stack, ZnsDevice

from .common import rows_from_experiments


def run():
    rows = rows_from_experiments("fig8", ["obs6", "obs8"])
    dev = ZnsDevice()
    for size_k in (4, 16, 32):
        for qd in (1, 2, 4, 8, 16):
            a = dev.steady_state(OpType.APPEND, size_k * KiB, qd=qd)
            w = dev.steady_state(OpType.WRITE, size_k * KiB, qd=qd,
                                 stack=Stack.KERNEL_MQ_DEADLINE)
            rows.append((
                f"fig8/{size_k}KiB/qd{qd}", 0.0,
                f"append_kiops={a.iops/1e3:.0f};append_lat_us={a.mean_latency_us:.1f};"
                f"write_kiops={w.iops/1e3:.0f};write_lat_us={w.mean_latency_us:.1f}"))
    return rows
