"""Benchmark harness helpers.

Every benchmark module exposes ``run() -> list[Row]``; a Row is
(name, us_per_call, derived) where ``derived`` is a short string of the
figure-relevant derived quantity (IOPS, MiB/s, percentile, ...).
run.py prints them all as CSV.
"""
from __future__ import annotations

import time

Row = tuple  # (name: str, us_per_call: float, derived: str)


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Wall-time a callable; returns (result, us_per_call)."""
    fn(*args, **kwargs)  # warmup (jit etc.)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def fmt_rows(rows) -> str:
    lines = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        lines.append(f"{name},{us:.3f},{derived}")
    return "\n".join(lines)
