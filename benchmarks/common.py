"""Benchmark harness helpers.

Every benchmark module exposes ``run() -> list[Row]``; a Row is
(name, us_per_call, derived) where ``derived`` is a short string of the
figure-relevant derived quantity (IOPS, MiB/s, percentile, ...).
run.py prints them all as CSV.
"""
from __future__ import annotations

import time

Row = tuple  # (name: str, us_per_call: float, derived: str)


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Wall-time a callable; returns (result, us_per_call)."""
    fn(*args, **kwargs)  # warmup (jit etc.)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def fmt_rows(rows) -> str:
    lines = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        lines.append(f"{name},{us:.3f},{derived}")
    return "\n".join(lines)


def rows_from_experiments(prefix: str, keys, *, backend: str = "vectorized"):
    """Rows for a figure module that is a thin shim over observation
    registry entries (`repro.experiments`): one batched fleet run of the
    named experiments, then one row per extracted metric and per check.

    The timing row ``<prefix>/experiments_run`` carries the wall time of
    the whole batched sweep.
    """
    from repro.experiments import ExperimentRunner

    runner = ExperimentRunner(keys, backend=backend)
    results, us = timed(runner.run, repeats=1)
    rows = [(f"{prefix}/experiments_run", us,
             f"experiments={len(results)};backend={backend}")]
    for r in results:
        for k, v in sorted(r.metrics.items()):
            rows.append((f"{prefix}/{r.name}/{k}", 0.0, f"{v:.4g}"))
        for c in r.checks:
            rows.append((f"{prefix}/{r.name}/check/{c.name}", 0.0,
                         f"ok={bool(c.ok)}"))
    return rows
