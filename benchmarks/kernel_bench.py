"""Kernel micro-benchmarks (interpret-mode wall time is NOT a TPU number;
the derived column reports the shape + allclose-vs-oracle check so the
harness doubles as a correctness gate)."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import timed


def run():
    rng = np.random.default_rng(0)
    rows = []
    # zns_event_scan: the device-model hot loop
    n = 16384
    issue = jnp.array(np.sort(rng.uniform(0, 1e6, n)), jnp.float32)
    svc = jnp.array(rng.uniform(10, 120, n), jnp.float32)
    seg = jnp.array(rng.uniform(size=n) < 0.01)
    (out,), us = timed(lambda: (ops.zns_event_scan(issue, svc, seg,
                                                   impl="interpret"),))
    oref = ref.zns_event_scan_ref(issue, svc, seg)
    ok = bool(jnp.max(jnp.abs(out - oref)) < 1e-2 * float(jnp.max(jnp.abs(oref))))
    rows.append((f"kernel/zns_event_scan/n{n}", us, f"allclose={ok}"))
    # flash attention
    q = jnp.array(rng.standard_normal((1, 8, 512, 64)), jnp.float32)
    k = jnp.array(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.array(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    (out,), us = timed(lambda: (ops.attention(q, k, v, impl="interpret"),),
                       repeats=1)
    ok = bool(jnp.max(jnp.abs(out - ref.attention_ref(q, k, v))) < 2e-4)
    rows.append(("kernel/flash_attention/b1h8s512", us, f"allclose={ok}"))
    # rmsnorm
    x = jnp.array(rng.standard_normal((4096, 1024)), jnp.float32)
    w = jnp.array(rng.standard_normal(1024), jnp.float32)
    (out,), us = timed(lambda: (ops.rmsnorm(x, w, impl="interpret"),))
    ok = bool(jnp.max(jnp.abs(out - ref.rmsnorm_ref(x, w))) < 1e-4)
    rows.append(("kernel/rmsnorm/4096x1024", us, f"allclose={ok}"))
    # linear recurrence
    a = jnp.array(rng.uniform(0.8, 0.999, (2, 1024, 256)), jnp.float32)
    b = jnp.array(rng.standard_normal((2, 1024, 256)), jnp.float32)
    (out,), us = timed(lambda: (ops.linear_recurrence(a, b, impl="interpret"),),
                       repeats=1)
    ok = bool(jnp.max(jnp.abs(out - ref.linear_recurrence_ref(a, b))) < 1e-2)
    rows.append(("kernel/linear_recurrence/2x1024x256", us, f"allclose={ok}"))
    # ssd chunk scan
    x = jnp.array(rng.standard_normal((1, 256, 4, 64)) * 0.4, jnp.float32)
    dt = jnp.array(rng.uniform(0.001, 0.1, (1, 256, 4)), jnp.float32)
    A = jnp.array(-rng.uniform(0.5, 2.0, 4), jnp.float32)
    B = jnp.array(rng.standard_normal((1, 256, 1, 64)) * 0.3, jnp.float32)
    C = jnp.array(rng.standard_normal((1, 256, 1, 64)) * 0.3, jnp.float32)
    (y, s), us = timed(lambda: ops.ssd_scan(x, dt, A, B, C, chunk=128,
                                            impl="interpret"), repeats=1)
    yr, sr = ref.ssd_ref(x, dt, A, B, C)
    ok = bool(jnp.max(jnp.abs(y - yr)) < 1e-3)
    rows.append(("kernel/ssd_chunk_scan/1x256x4x64", us, f"allclose={ok}"))
    return rows
