"""Fig. 4: intra-zone (QD) vs inter-zone (zones) scalability.

Paper anchors (Obs#5–#8): read 424 KIOPS @QD128; write(mq-deadline)
293 KIOPS @QD32 intra-zone; inter-zone write saturates 186 KIOPS
(726.74 MiB/s at 4 KiB); append ~132 KIOPS at concurrency 4 regardless
of layout; >=8 KiB requests reach the ~1.2 GiB/s device limit with 2-4
concurrent zones.
"""
from __future__ import annotations

from repro.core import KiB, MiB, OpType, Stack, ZnsDevice

from .common import timed


def run():
    dev = ZnsDevice()
    rows = []
    # Fig 4a: intra-zone, 4 KiB
    for qd in (1, 2, 4, 8, 16, 32, 64, 128):
        r = dev.steady_state(OpType.READ, 4 * KiB, qd=qd)
        a = dev.steady_state(OpType.APPEND, 4 * KiB, qd=qd)
        w = dev.steady_state(OpType.WRITE, 4 * KiB, qd=qd,
                            stack=Stack.KERNEL_MQ_DEADLINE)
        rows.append((f"fig4a/intra/qd{qd}", 0.0,
                     f"read={r.iops/1e3:.0f}K;write_mq={w.iops/1e3:.0f}K;"
                     f"append={a.iops/1e3:.0f}K"))
    # Fig 4b: inter-zone, 4 KiB, QD1 per zone
    for zones in (1, 2, 4, 8, 14):
        r = dev.steady_state(OpType.READ, 4 * KiB, zones=zones)
        a = dev.steady_state(OpType.APPEND, 4 * KiB, zones=zones)
        w = dev.steady_state(OpType.WRITE, 4 * KiB, zones=zones)
        rows.append((f"fig4b/inter/z{zones}", 0.0,
                     f"read={r.iops/1e3:.0f}K;write={w.iops/1e3:.0f}K;"
                     f"append={a.iops/1e3:.0f}K"))
    # Fig 4c: bandwidth, larger requests
    for size_k in (4, 8, 16):
        for conc in (1, 2, 4, 8):
            a = dev.steady_state(OpType.APPEND, size_k * KiB, qd=conc)
            w = dev.steady_state(OpType.WRITE, size_k * KiB, zones=conc)
            rows.append((
                f"fig4c/{size_k}KiB/conc{conc}", 0.0,
                f"append_intra={a.bandwidth_bytes/MiB:.0f}MiB/s;"
                f"write_inter={w.bandwidth_bytes/MiB:.0f}MiB/s"))
    return rows
