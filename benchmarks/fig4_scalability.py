"""Fig. 4: intra-zone (QD) vs inter-zone (zones) scalability.

Thin shim over the Obs#5/#6/#7 registry entries (`repro.experiments`):
read 424 KIOPS @QD128; write(mq-deadline) 293 KIOPS @QD32 intra-zone;
inter-zone write saturates 186 KIOPS; append ~132 KIOPS at concurrency
4 regardless of layout.
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig4", ["obs5", "obs6", "obs7"])
