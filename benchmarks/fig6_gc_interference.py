"""Fig. 6: write/read throughput stability under write pressure —
conventional SSD (FTL GC) vs ZNS (host GC) (Obs#11).

Paper anchors: conventional write throughput fluctuates a-few-MiB/s..
~1,200 MiB/s at full-rate writes while ZNS stays flat; QD1 4 KiB read
p95 under full-rate writes: 299.89 ms (conv) vs 98.04 ms (ZNS) vs
81.41 us idle.
"""
from __future__ import annotations

from repro.core import ConvDevice, ZnsDevice
from repro.core.calibration import PEAK_WRITE_BW_MIBS

from .common import timed


def run():
    rows = []
    conv = ConvDevice()
    zns = ZnsDevice()
    for rate in (0.0, 250.0, 750.0, PEAK_WRITE_BW_MIBS):
        (c,), us = timed(lambda rate=rate: (conv.run_write_pressure(
            rate_mibs=rate, duration_s=60),), repeats=1)
        z = zns.run_write_pressure(rate_mibs=rate, duration_s=60)
        rows.append((
            f"fig6/rate{rate:g}MiBs", us,
            f"conv_write_cv={c.write_cv:.2f};zns_write_cv={z.write_cv:.2f};"
            f"conv_read_p95_ms={c.read_lat_p95_us/1e3:.2f};"
            f"zns_read_p95_ms={z.read_lat_p95_us/1e3:.2f}"))
    return rows
