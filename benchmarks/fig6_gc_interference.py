"""Fig. 6: write/read throughput stability under write pressure —
conventional SSD (FTL GC) vs ZNS (host GC).

Thin shim over the Obs#11 registry entry (`repro.experiments`):
conventional write throughput sawtooths under FTL GC while ZNS stays
flat; QD1 4 KiB read p95 under full-rate writes is 299.89 ms (conv) vs
98.04 ms (ZNS) vs 81.41 us idle.
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig6", ["obs11"])
