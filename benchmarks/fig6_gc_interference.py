"""Fig. 6: write/read throughput stability under write pressure —
conventional SSD (FTL GC) vs ZNS (host GC) (Obs#11).

Paper anchors: conventional write throughput fluctuates a-few-MiB/s..
~1,200 MiB/s at full-rate writes while ZNS stays flat; QD1 4 KiB read
p95 under full-rate writes: 299.89 ms (conv) vs 98.04 ms (ZNS) vs
81.41 us idle.
"""
from __future__ import annotations

import numpy as np

from repro.core import ConventionalSSD, ThroughputModel, zns_write_pressure_series
from repro.core.calibration import PEAK_WRITE_BW_MIBS

from .common import timed


def run():
    rows = []
    conv = ConventionalSSD()
    tm = ThroughputModel()
    for rate in (0.0, 250.0, 750.0, PEAK_WRITE_BW_MIBS):
        (sim,), us = timed(lambda rate=rate: (conv.simulate_write_pressure(
            rate_mibs=rate, duration_s=60),), repeats=1)
        t, w_zns = zns_write_pressure_series(rate_mibs=rate, duration_s=60)
        u = rate / PEAK_WRITE_BW_MIBS
        zns_mean, zns_p95 = tm.read_latency_under_write_pressure_us(u)
        cv_conv = float(np.std(sim.write_mibs) / max(np.mean(sim.write_mibs), 1e-9))
        cv_zns = float(np.std(w_zns) / max(np.mean(w_zns), 1e-9))
        rows.append((
            f"fig6/rate{rate:g}MiBs", us,
            f"conv_write_cv={cv_conv:.2f};zns_write_cv={cv_zns:.2f};"
            f"conv_read_p95_ms={sim.read_lat_p95_us/1e3:.2f};"
            f"zns_read_p95_ms={zns_p95/1e3:.2f}"))
    return rows
