"""Fig. 7: p95 reset latency under concurrent read/write/append (Obs#12/13).

Paper anchors: 17.94 ms isolated -> 28.00 (read, +56.11%), 32.00
(write, +78.42%), 31.48 ms (append, +75.50%); resets do not perturb I/O.
"""
from __future__ import annotations

import numpy as np

from repro.core import OpType, ZnsDevice
from repro.core.workloads import reset_interference

from .common import timed


def run():
    dev = ZnsDevice()
    rows = []
    for io_op, label in ((None, "isolated"), (OpType.READ, "read"),
                         (OpType.WRITE, "write"), (OpType.APPEND, "append")):
        tr = reset_interference(io_op, n_resets=300)
        (res,), us = timed(lambda tr=tr: (dev.run(tr, backend="event",
                                                  seed=7),), repeats=1)
        p95 = res.latency_stats(OpType.RESET).p95_us / 1e3
        derived = f"reset_p95_ms={p95:.2f}"
        if io_op is not None:
            iomask = tr.op != OpType.RESET
            io_lat = float(np.mean(res.sim.service[iomask]))
            derived += f";io_svc_us={io_lat:.2f}"
        rows.append((f"fig7/reset_under_{label}", us, derived))
    return rows
