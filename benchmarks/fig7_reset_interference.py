"""Fig. 7: reset/I-O interference (Obs#12/#13).

Thin shim over the Obs#12 (resets never delay I/O) and Obs#13
(concurrent I/O inflates reset latency: +56.11% read, +78.42% write,
+75.50% append) registry entries (`repro.experiments`).
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig7", ["obs12", "obs13"])
