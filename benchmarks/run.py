"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig5,device_bench]
[--quick] [--json out.json]``
Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes them as a JSON list (CI uploads this as an artifact).  ``--quick``
runs benchmarks that support it in a reduced smoke configuration.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

MODULES = (
    "fig2_latency", "fig3_reqsize", "fig4_scalability", "fig5_state_costs",
    "fig6_gc_interference", "fig7_reset_interference", "fig8_qd",
    "table1_insights", "device_bench", "fleet_bench", "chain_program",
    "checkpoint_bench", "host_policies", "kernel_bench", "cluster_bench",
    "mega_fleet", "exactness_matrix", "open_loop",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substring filters on module names")
    ap.add_argument("--quick", action="store_true",
                    help="reduced smoke configuration (CI)")
    ap.add_argument("--json", default="",
                    help="also write rows to this JSON file")
    args = ap.parse_args()
    import importlib

    filters = [f for f in args.only.split(",") if f]
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name in MODULES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kwargs = {}
            if args.quick and \
                    "quick" in inspect.signature(mod.run).parameters:
                kwargs["quick"] = True
            rows = mod.run(**kwargs)
            for row in rows:
                n, us, derived = row
                print(f"{n},{us:.3f},{derived}")
                all_rows.append({"name": n, "us_per_call": us,
                                 "derived": derived})
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=1)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
