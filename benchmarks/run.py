"""Benchmark harness entry point: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig5]``
Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = (
    "fig2_latency", "fig3_reqsize", "fig4_scalability", "fig5_state_costs",
    "fig6_gc_interference", "fig7_reset_interference", "fig8_qd",
    "table1_insights", "device_bench", "checkpoint_bench", "kernel_bench",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on module")
    args = ap.parse_args()
    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
            for row in rows:
                n, us, derived = row
                print(f"{n},{us:.3f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
