"""Fig. 2: append/write I/O latency across storage stacks & LBA formats.

Paper anchors: write 11.36 us (SPDK/4KiB), 12.62 (kernel none),
14.47 (mq-deadline); append 14.02 us (SPDK/8KiB); 512B format up to 2x
slower (Obs#1/#2/#4).
"""
from __future__ import annotations

from repro.core import KiB, LBAFormat, OpType, Stack, ZnsDevice

from .common import timed


def run():
    dev = ZnsDevice()
    rows = []
    # Fig 2a: 512B vs 4KiB formats, request size = block size
    for stack in (Stack.SPDK, Stack.KERNEL_NONE, Stack.KERNEL_MQ_DEADLINE):
        for fmt, size in ((LBAFormat.LBA_512, 512), (LBAFormat.LBA_4K, 4 * KiB)):
            for op in (OpType.WRITE, OpType.APPEND):
                (lat,), us = timed(
                    lambda: (float(dev.io_latency_us(op, size, stack=stack,
                                                     fmt=fmt)),))
                rows.append((
                    f"fig2a/{op.name.lower()}/{stack.name.lower()}/{fmt.name}",
                    us, f"latency_us={lat:.2f}"))
    # Fig 2b: best request sizes (write 4KiB / append 8KiB) per format
    for fmt in (LBAFormat.LBA_512, LBAFormat.LBA_4K):
        w = float(dev.io_latency_us(OpType.WRITE, 4 * KiB, fmt=fmt))
        a = float(dev.io_latency_us(OpType.APPEND, 8 * KiB, fmt=fmt))
        rows.append((f"fig2b/write4k/{fmt.name}", 0.0, f"latency_us={w:.2f}"))
        rows.append((f"fig2b/append8k/{fmt.name}", 0.0, f"latency_us={a:.2f}"))
        if fmt == LBAFormat.LBA_4K:
            diff = (a - w) / w * 100
            rows.append(("fig2b/append_vs_write_gap", 0.0,
                         f"pct={diff:.2f} (paper: 23.42)"))
    return rows
