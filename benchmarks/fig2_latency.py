"""Fig. 2: append/write I/O latency across storage stacks & LBA formats.

Thin shim over the observation registry (`repro.experiments`): Obs#1
(LBA format), Obs#2 (storage stack), and Obs#4 (append vs write) carry
the Fig. 2 anchors — write 11.36 us (SPDK/4KiB), 12.62 (kernel none),
14.47 (mq-deadline); append 14.02 us (SPDK/8KiB); 512B format up to 2x
slower.  Figures, CI checks, and docs all derive from the same entries.
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig2", ["obs1", "obs2", "obs4"])
