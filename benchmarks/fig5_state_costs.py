"""Fig. 5: reset/finish latency vs zone occupancy (Obs#9/#10).

Paper anchors: reset 11.60 ms @50%, 16.19 ms @100%; finished-zone reset
26.58% cheaper @50%; finish 907.51 ms @<0.1% -> 3.07 ms @100%; open
9.56 us / close 11.01 us; implicit-open penalties 2.02/2.83 us.
"""
from __future__ import annotations

import numpy as np

from repro.core import LatencyModel, OpType, simulate
from repro.core.workloads import finish_sweep, reset_sweep

from .common import timed


OCCS = (0.0, 0.0005, 0.0625, 0.125, 0.25, 0.5, 1.0)


def run():
    lm = LatencyModel()
    rows = []
    rows.append(("fig5/open", 0.0, f"latency_us={lm.open_us():.2f}"))
    rows.append(("fig5/close", 0.0, f"latency_us={lm.close_us():.2f}"))
    rows.append(("fig5/implicit_write_penalty", 0.0,
                 f"us={lm.implicit_open_penalty_us(OpType.WRITE):.2f}"))
    rows.append(("fig5/implicit_append_penalty", 0.0,
                 f"us={lm.implicit_open_penalty_us(OpType.APPEND):.2f}"))
    # Fig 5a: reset latency sweep via the event engine
    tr = reset_sweep(OCCS, finished_first=False, n_per_level=40)
    (res,), us = timed(lambda: (simulate(tr, seed=1),), repeats=1)
    lat = (res.complete - res.start) / 1e3
    for occ in OCCS:
        sel = np.isclose(tr.occupancy, occ) & (tr.op == OpType.RESET)
        rows.append((f"fig5a/reset/occ{occ:g}", us / len(tr),
                     f"ms={float(np.mean(lat[sel])):.2f}"))
    # finished-then-reset variant
    tr2 = reset_sweep(OCCS, finished_first=True, n_per_level=40)
    res2 = simulate(tr2, seed=2)
    lat2 = (res2.complete - res2.start) / 1e3
    sel = (tr2.op == OpType.RESET) & np.isclose(tr2.occupancy, 0.5)
    rows.append(("fig5a/reset_finished/occ0.5", 0.0,
                 f"ms={float(np.mean(lat2[sel])):.2f} (26.58% below plain)"))
    # Fig 5b: finish latency sweep
    tr3 = finish_sweep((0.001, 0.0625, 0.125, 0.25, 0.5, 0.999),
                       n_per_level=40)
    res3 = simulate(tr3, seed=3)
    lat3 = (res3.complete - res3.start) / 1e3
    for occ in (0.001, 0.0625, 0.125, 0.25, 0.5, 0.999):
        sel = np.isclose(tr3.occupancy, occ) & (tr3.op == OpType.FINISH)
        rows.append((f"fig5b/finish/occ{occ:g}", 0.0,
                     f"ms={float(np.mean(lat3[sel])):.2f}"))
    return rows
