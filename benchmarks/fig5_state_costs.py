"""Fig. 5: reset/finish latency vs zone occupancy (Obs#9/#10).

Paper anchors: reset 11.60 ms @50%, 16.19 ms @100%; finished-zone reset
26.58% cheaper @50%; finish 907.51 ms @<0.1% -> 3.07 ms @100%; open
9.56 us / close 11.01 us; implicit-open penalties 2.02/2.83 us.
"""
from __future__ import annotations

import numpy as np

from repro.core import OpType, WorkloadSpec, ZnsDevice

from .common import timed


OCCS = (0.0, 0.0005, 0.0625, 0.125, 0.25, 0.5, 1.0)


def run():
    dev = ZnsDevice()
    lm = dev.lat
    rows = []
    rows.append(("fig5/open", 0.0, f"latency_us={lm.open_us():.2f}"))
    rows.append(("fig5/close", 0.0, f"latency_us={lm.close_us():.2f}"))
    rows.append(("fig5/implicit_write_penalty", 0.0,
                 f"us={lm.implicit_open_penalty_us(OpType.WRITE):.2f}"))
    rows.append(("fig5/implicit_append_penalty", 0.0,
                 f"us={lm.implicit_open_penalty_us(OpType.APPEND):.2f}"))
    # Fig 5a: reset latency sweep via the device session
    wl = WorkloadSpec().reset_sweep(OCCS, n_per_level=40)
    (res,), us = timed(lambda: (dev.run(wl, backend="event", seed=1),),
                       repeats=1)
    tr = res.trace
    lat = res.sim.in_device_latency / 1e3
    for occ in OCCS:
        sel = np.isclose(tr.occupancy, occ) & (tr.op == OpType.RESET)
        rows.append((f"fig5a/reset/occ{occ:g}", us / len(tr),
                     f"ms={float(np.mean(lat[sel])):.2f}"))
    # finished-then-reset variant
    res2 = dev.run(WorkloadSpec().reset_sweep(OCCS, n_per_level=40,
                                              finish_first=True),
                   backend="event", seed=2)
    tr2 = res2.trace
    lat2 = res2.sim.in_device_latency / 1e3
    sel = (tr2.op == OpType.RESET) & np.isclose(tr2.occupancy, 0.5)
    rows.append(("fig5a/reset_finished/occ0.5", 0.0,
                 f"ms={float(np.mean(lat2[sel])):.2f} (26.58% below plain)"))
    # Fig 5b: finish latency sweep
    foccs = (0.001, 0.0625, 0.125, 0.25, 0.5, 0.999)
    res3 = dev.run(WorkloadSpec().finish_sweep(foccs, n_per_level=40),
                   backend="event", seed=3)
    tr3 = res3.trace
    lat3 = res3.sim.in_device_latency / 1e3
    for occ in foccs:
        sel = np.isclose(tr3.occupancy, occ) & (tr3.op == OpType.FINISH)
        rows.append((f"fig5b/finish/occ{occ:g}", 0.0,
                     f"ms={float(np.mean(lat3[sel])):.2f}"))
    return rows
