"""Fig. 5: zone state-machine costs (Obs#9/#10).

Thin shim over the Obs#9 (open/close transitions) and Obs#10
(occupancy-dependent reset/finish) registry entries
(`repro.experiments`): reset 11.60 ms @50% / 16.19 ms @100%,
finished-zone reset 26.58% cheaper, finish 907.51 ms @<0.1% -> 3.07 ms
@100%, open 9.56 us / close 11.01 us; implicit penalties 2.02/2.83 us.
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig5", ["obs9", "obs10"])
