"""Fig. 3: QD=1 throughput (KIOPS) vs request size for write/append.

Paper anchors: write 85 KIOPS @ 4/8 KiB; append 66 -> 69 KIOPS @ 4 -> 8
KiB; >=32 KiB requests approach the ~1.2 GiB/s device limit (Obs#3).
"""
from __future__ import annotations

from repro.core import KiB, MiB, OpType, ZnsDevice

from .common import timed


def run():
    dev = ZnsDevice()
    rows = []
    for op in (OpType.WRITE, OpType.APPEND):
        for size_k in (4, 8, 16, 32, 64, 128):
            (res,), us = timed(
                lambda op=op, size_k=size_k:
                (dev.steady_state(op, size_k * KiB),))
            rows.append((
                f"fig3/{op.name.lower()}/{size_k}KiB", us,
                f"kiops={res.iops/1e3:.1f};mibs={res.bandwidth_bytes/MiB:.0f}"))
    return rows
