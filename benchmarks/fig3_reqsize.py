"""Fig. 3: QD=1 throughput (KIOPS) vs request size for write/append.

Thin shim over the Obs#3 registry entry (`repro.experiments`): write 85
KIOPS @ 4/8 KiB; append 66 -> 69 KIOPS @ 4 -> 8 KiB; >=32 KiB requests
approach the ~1.2 GiB/s device limit.
"""
from __future__ import annotations

from .common import rows_from_experiments


def run():
    return rows_from_experiments("fig3", ["obs3"])
