"""Checkpoint-engine benchmark: the paper's recommendations as a
checkpoint planner, measured end-to-end on the device model.

Compares policy variants on a synthetic multi-host checkpoint:
  * paper-faithful  — R1..R5 as written (1 MiB appends, QD4, 1 zone,
    bin-packed, GC concurrent)
  * naive-small-io  — 4 KiB appends at QD1 (violates R2)
  * finish-happy    — finishes every zone after writing (violates R3)
  * write-qd1       — sequential writes instead of appends (host-side
    ordering; limits concurrency per zone to 1)
plus the beyond-paper tuned variant used by the framework.
"""
from __future__ import annotations

import numpy as np

from repro.core import KiB, MiB, LatencyModel, OpType, ThroughputModel
from repro.runtime.zns_store import ZnsHostDevice

from .common import timed

CKPT_BYTES_PER_HOST = 8 * 1024 * MiB   # 8 GiB/host shard (405B-class / 512)


def _policy_time(stripe, qd, zones, *, finish_every_zone=False,
                 use_write=False):
    dev = ZnsHostDevice(0, stripe_bytes=stripe, append_qd=qd,
                        concurrent_zones=zones)
    lm = dev.lat
    tm = dev.tm
    if use_write:
        bw = tm.steady_state(OpType.WRITE, stripe, zones=max(zones, 1)
                             ).bandwidth_bytes
        t = CKPT_BYTES_PER_HOST / bw
        n_req = CKPT_BYTES_PER_HOST // stripe
    else:
        t, n_req = dev.simulate_payload_write(CKPT_BYTES_PER_HOST)
    if finish_every_zone:
        nz = int(np.ceil(CKPT_BYTES_PER_HOST / dev.spec.zone_cap_bytes))
        # the final zone is partially full; paper Fig 5b cost
        frac = (CKPT_BYTES_PER_HOST % dev.spec.zone_cap_bytes) \
            / dev.spec.zone_cap_bytes
        t += float(lm.finish_us(frac)) / 1e6
        t += (nz - 1) * float(lm.finish_us(0.999)) / 1e6
    t += dev.manifest_write_us() / 1e6
    return t, n_req


def run():
    rows = []
    policies = {
        "paper_faithful_R1-R5": dict(stripe=1 * MiB, qd=4, zones=1),
        "naive_small_io": dict(stripe=4 * KiB, qd=1, zones=1),
        "finish_happy": dict(stripe=1 * MiB, qd=4, zones=1,
                             finish_every_zone=True),
        "write_qd1_per_zone": dict(stripe=1 * MiB, qd=1, zones=1,
                                   use_write=True),
        "beyond_paper_tuned": dict(stripe=4 * MiB, qd=4, zones=2),
    }
    for name, kw in policies.items():
        (t, n_req), us = timed(lambda kw=kw: _policy_time(**kw), repeats=1)
        rows.append((
            f"ckpt/{name}", us,
            f"wall_s={t:.2f};bw_mibs={CKPT_BYTES_PER_HOST / t / MiB:.0f};"
            f"requests={n_req}"))
    # reclaim cost: resetting one expired checkpoint's zones under I/O
    dev = ZnsHostDevice(0)
    entries = dev.plan(CKPT_BYTES_PER_HOST)
    dev.apply_writes(entries)
    full = [e.zone for e in entries
            if dev.zm.state(e.zone).name == "FULL"]
    dev.schedule_reset(full)
    import time
    t0 = time.perf_counter()
    gc_s = dev.run_gc(concurrent_io=True)       # stateful: no warmup call
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("ckpt/gc_reclaim", us,
                 f"reset_s={gc_s:.3f};zones={len(full)}"))
    return rows
