"""Mega-fleet solver: entry-sharded fixpoint vs the single-chip fused
solve vs the per-device event-engine oracle, plus the jitted lowering
path (replica dedup + vectorized block fill).

``python -m benchmarks.run --only mega_fleet [--quick]``

Fleet shape: a large replicated ZNS device tier (each device's refined
program converges in ~2 Gauss-Seidel sweeps) plus one contended rack
entry — a closed-loop cluster program (16 gateways' worth of users on 4
servers) that needs ~90 sweeps to reach its fixpoint.  The fused
single-chip solve pays the straggler's sweep count across the whole
fleet: every idle sweep still gathers and edge-checks every family
block of every converged device.  The entry-sharded executor
(:func:`repro.core.solve_program_sharded`) gives each signature group
its own convergence budget, so the device tier stops after 2 sweeps and
only the straggler keeps sweeping.  The win is algorithmic — per-entry
budgets, not parallel hardware — so it holds on a single CPU core and
multiplies further when the mesh executor spreads shards across real
chips.

Gates:

* ``speedup`` — sharded (host executor) >= ``SPEEDUP_GATE`` x the
  single-chip fused solve at the largest fleet size;
* ``equal``   — sharded completions match single-chip to ``REL_TOL``
  relative (the ISSUE acceptance bar), and both converge;
* ``mesh``    — when >= 2 jax devices are visible (CI forces two
  virtual host devices via ``XLA_FLAGS``), the ``shard_map`` executor
  matches to ``REL_TOL`` as well;
* ``lowering`` — dedup + vectorized fill compiles a 64-device x 100k
  event few-unique fleet >= ``LOWERING_GATE`` x faster than the
  reference per-chain fill without dedup.

Full (non-quick) mode additionally runs the 1k-device x 1M-request
end-to-end acceptance row through ``DeviceFleet.run``.
"""
from __future__ import annotations

import warnings

from .common import timed

#: Sharded (host executor) must beat the single-chip fused solve by
#: this much at the largest fleet size.
SPEEDUP_GATE = 3.0
#: Dedup + vectorized fill vs reference per-chain fill at 64 x 100k.
LOWERING_GATE = 2.0
#: Relative tolerance of the sharded-vs-single-chip equality gates.
REL_TOL = 1e-12

#: Device-tier shape: 8 closed-loop append threads, qd 2, n per thread.
DEV_THREADS, DEV_QD, DEV_N = 8, 2, 500


def _device_trace():
    from repro.core import KiB, WorkloadSpec

    wl = WorkloadSpec()
    for t in range(DEV_THREADS):
        wl = wl.appends(n=DEV_N, size=8 * KiB, qd=DEV_QD, zone=t * 4,
                        nzones=4)
    return wl.build()


def _straggler_rack():
    """One contended rack: 8 users x 20 closed-loop object ops on a
    1-gateway / 4-server ec2+1 cluster — ~3.7k events, ~90 sweeps."""
    from repro.cluster import Cluster, ClusterSpec, ClusterWorkload, erasure

    spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    wl = ClusterWorkload(n_users=8, ops_per_user=20, object_bytes=1 << 20,
                         seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return Cluster(spec).compile(wl)


def _fleet(ndev, rack):
    """Concat ``ndev`` replicated device programs + the straggler."""
    import numpy as np

    from repro.core import (ZNSDeviceSpec, ZnsDevice, compile_fleet_program,
                            concat_programs)

    spec = ZNSDeviceSpec()
    lat = ZnsDevice(spec).lat
    tr = _device_trace()
    dprog = compile_fleet_program([tr] * ndev, [spec] * ndev, [lat] * ndev,
                                  cache=False)
    prog = concat_programs([dprog, rack.program])
    svc = np.concatenate([dprog.svc0_flat, rack.graph.svc])
    return prog, svc, tr, spec, lat


def _relerr(a, b):
    import numpy as np

    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0)))


def run(quick: bool = False) -> list:
    from repro.cluster import simulate_graph
    from repro.core import (last_compile_stats, solve_program,
                            solve_program_sharded)
    from repro.core import chain_program as cp
    from repro.core.engine import simulate

    rack = _straggler_rack()
    sizes = (16, 96) if quick else (16, 64, 128, 256)
    out: list = []
    speedup = 0.0
    rel = float("inf")
    conv = False

    # --- scaling curve: single-chip vs entry-sharded vs event oracle ---
    for ndev in sizes:
        prog, svc, tr, spec, lat = _fleet(ndev, rack)
        (c1, u1, k1), one_us = timed(
            lambda: solve_program(prog, svc, sweeps=1024, fixpoint="loop",
                                  warn=False), repeats=2)
        (c2, u2, k2), sh_us = timed(
            lambda: solve_program_sharded(prog, svc, sweeps=1024,
                                          executor="host", warn=False),
            repeats=2)
        speedup = one_us / sh_us if sh_us > 0 else float("inf")
        rel = _relerr(c2, c1)
        conv = bool(k1) and bool(k2)
        out.append((f"mega_fleet/single_chip/{ndev}dev", one_us,
                    f"events={prog.n_flat};sweeps={u1}"))
        out.append((f"mega_fleet/sharded_host/{ndev}dev", sh_us,
                    f"events={prog.n_flat};sweeps={u2}"))
        out.append((f"mega_fleet/speedup/{ndev}dev", 0.0,
                    f"{speedup:.2f}x"))

    # gates evaluate at the largest size (loop leaves it bound)
    out.append(("mega_fleet/gate_speedup", 0.0,
                f"{speedup:.2f}x"
                + ("" if speedup >= SPEEDUP_GATE and conv else "=FAIL")))
    out.append(("mega_fleet/gate_equal", 0.0,
                f"rel={rel:.2e}"
                + ("" if rel <= REL_TOL and conv else "=FAIL")))

    # event-engine oracle at the largest size: the pre-compiler way of
    # producing fleet completions (one greedy event heap per device +
    # the rack oracle)
    ndev = sizes[-1]

    def oracle():
        for _ in range(ndev):
            simulate(tr, spec, lat, seed=0, jitter=False)
        return simulate_graph(rack.graph)

    _, or_us = timed(oracle, repeats=1)
    out.append((f"mega_fleet/event_oracle/{ndev}dev", or_us,
                f"devices={ndev}"))

    # --- mesh executor (shard_map) when >= 2 jax devices are visible ---
    mesh_row = "skipped;jax_devices<2"
    try:
        import jax

        ndevs = len(jax.local_devices())
    except Exception:
        ndevs = 0
    if ndevs >= 2:
        prog, svc, _, _, _ = _fleet(8, rack)
        ref, _, k_ref = solve_program(prog, svc, sweeps=1024,
                                      fixpoint="loop", warn=False)
        (cm, um, km), mesh_us = timed(
            lambda: solve_program_sharded(prog, svc, sweeps=1024,
                                          executor="mesh", warn=False),
            repeats=1)
        relm = _relerr(cm, ref)
        ok = relm <= REL_TOL and bool(km) and bool(k_ref)
        mesh_row = (f"devices={ndevs};rel={relm:.2e}"
                    + ("" if ok else "=FAIL"))
        out.append(("mega_fleet/sharded_mesh/8dev", mesh_us,
                    f"events={prog.n_flat};sweeps={um}"))
    out.append(("mega_fleet/gate_mesh", 0.0, mesh_row))

    # --- jitted lowering: dedup + vectorized fill vs reference fill ----
    from repro.core import (ZNSDeviceSpec, ZnsDevice, compile_fleet_program)
    from repro.core import KiB, WorkloadSpec

    nlow, per = 64, 1560                               # ~100k events
    spec = ZNSDeviceSpec()
    lat = ZnsDevice(spec).lat
    wl = WorkloadSpec()
    for t in range(8):
        wl = wl.appends(n=per // 8, size=8 * KiB, qd=2, zone=t * 4,
                        nzones=4)
    tiers = [wl.build(),
             WorkloadSpec().writes(n=per, qd=4, zone=7).build(),
             WorkloadSpec().reads(n=per, size=4 * KiB, qd=4,
                                  nzones=64).build()]
    traces = [tiers[i % 3] for i in range(nlow)]
    specs, lats = [spec] * nlow, [lat] * nlow
    nev = sum(len(t) for t in traces)
    _, fast_us = timed(lambda: compile_fleet_program(
        traces, specs, lats, cache=False, dedup=True), repeats=2)
    st = last_compile_stats()
    cp._USE_REFERENCE_FILL = True
    try:
        _, ref_us = timed(lambda: compile_fleet_program(
            traces, specs, lats, cache=False, dedup=False), repeats=2)
    finally:
        cp._USE_REFERENCE_FILL = False
    low_speed = ref_us / fast_us if fast_us > 0 else float("inf")
    out.append(("mega_fleet/lowering_fast", fast_us,
                f"devices={nlow};events={nev};unique={st.n_unique}"))
    out.append(("mega_fleet/lowering_reference", ref_us,
                f"devices={nlow};events={nev}"))
    out.append(("mega_fleet/gate_lowering", 0.0,
                f"{low_speed:.2f}x"
                + ("" if low_speed >= LOWERING_GATE else "=FAIL")))

    # --- full mode: 1k devices x 1M requests end-to-end ----------------
    if not quick:
        from repro.core import DeviceFleet

        wl_1k = WorkloadSpec()
        for t in range(4):
            wl_1k = wl_1k.appends(n=250, size=8 * KiB, qd=2, zone=t * 4,
                                  nzones=4)
        fleet = DeviceFleet.homogeneous(1000, spec)
        fres, e2e_us = timed(
            lambda: fleet.run(wl_1k, policy="replicate",
                              backend="vectorized", jitter=False),
            repeats=1)
        cst = fres.compile_stats
        total = sum(len(r.trace) for r in fres)
        out.append(("mega_fleet/end_to_end_1k_x_1M", e2e_us,
                    f"devices=1000;events={total};conv={fres.converged};"
                    f"unique={cst.n_unique if cst else '?'}"
                    + ("" if fres.converged and total == 1_000_000
                       else "=FAIL")))
    return out


if __name__ == "__main__":
    from .common import fmt_rows

    print(fmt_rows(run(quick=True)))
