"""Mega-fleet solver: entry-sharded fixpoint vs the single-chip fused
solve vs the per-device event-engine oracle, plus the jitted lowering
path (replica dedup + vectorized block fill).

``python -m benchmarks.run --only mega_fleet [--quick]``

Fleet shape: a large replicated ZNS device tier (each device's refined
program converges in ~2 Gauss-Seidel sweeps) plus one contended rack
entry — a closed-loop cluster program (16 gateways' worth of users on 4
servers) that needs ~90 sweeps to reach its fixpoint.  A *full* sweep
solve pays the straggler's sweep count across the whole fleet: every
idle sweep still gathers and edge-checks every family block of every
converged device.  Two independent escapes are gated against that
baseline (``chain_program._ACTIVE_SET = False``):

* the entry-sharded executor (:func:`repro.core.solve_program_sharded`)
  gives each signature group its own convergence budget, so the device
  tier stops after 2 sweeps and only the straggler keeps sweeping;
* the active-set fused solve (the in-process default) tracks per-block
  residuals and drops converged blocks from later sweeps — same
  algorithmic win without leaving the single chip, bit-identical to
  the full sweep.

Both are algorithmic — per-entry/per-block budgets, not parallel
hardware — so they hold on a single CPU core, and the sharded path
multiplies further when the mesh executor spreads shards across real
chips.

Gates:

* ``speedup`` — sharded (host executor) >= ``SPEEDUP_GATE`` x the
  full-sweep single-chip solve at the largest fleet size;
* ``active_set`` — the active-set fused solve >= ``ACTIVE_SET_GATE`` x
  the full-sweep solve at the largest fleet size, and bit-identical
  to it;
* ``equal``   — sharded completions match single-chip to ``REL_TOL``
  relative (the ISSUE acceptance bar), and both converge;
* ``mesh``    — when >= 2 jax devices are visible (CI forces two
  virtual host devices via ``XLA_FLAGS``), the ``shard_map`` executor
  matches to ``REL_TOL`` as well;
* ``lowering`` — dedup + vectorized fill compiles a 64-device x 100k
  event few-unique fleet >= ``LOWERING_GATE`` x faster than the
  reference per-chain fill without dedup;
* ``windowed`` — a 1M-request (quick; 10M full) open-loop Poisson
  mega-entry solved as an issue-time window pipeline
  (:func:`repro.core.solve_program_windowed`) matches the full solve
  to ``REL_TOL`` while its traced peak solver memory is at most
  ``1/WINDOW_MEM_GATE`` of the full solve's;
* ``warm_ladder`` — ``plan_capacity(..., warm_ladder=True)`` on a
  six-rung open-loop rate ladder >= ``WARM_GATE`` x the cold ladder
  (median of ``WARM_REPEATS`` interleaved cold/warm pair ratios, the
  ``open_loop`` benchmark's drift-cancelling idiom), with identical
  curves and at least one verified warm rung seed.

Full (non-quick) mode additionally runs the 1k-device x 1M-request
end-to-end acceptance row through ``DeviceFleet.run``.
"""
from __future__ import annotations

import warnings

from .common import timed

#: Sharded (host executor) must beat the full-sweep single-chip solve
#: by this much at the largest fleet size.  Recalibrated when the
#: active-set sweeps landed: the sharded executor's per-bucket solves
#: use them too, so its straggler bucket converges faster than it did
#: against the original all-blocks-every-sweep default, and both
#: escapes are now held to the same 2x bar against the restored
#: full-sweep baseline.
SPEEDUP_GATE = 2.0
#: Active-set fused solve vs the full-sweep solve at the largest size.
ACTIVE_SET_GATE = 2.0
#: Dedup + vectorized fill vs reference per-chain fill at 64 x 100k.
LOWERING_GATE = 2.0
#: Relative tolerance of the sharded-vs-single-chip equality gates.
REL_TOL = 1e-12
#: Windowed pipeline peak solver memory must be at most ``1/this`` of
#: the full solve's traced peak on the open-loop mega-entry.
WINDOW_MEM_GATE = 2.0
#: Warm capacity ladder vs cold, median of interleaved pair ratios.
WARM_GATE = 1.5
WARM_REPEATS = 5

#: Device-tier shape: 8 closed-loop append threads, qd 2, n per thread.
DEV_THREADS, DEV_QD, DEV_N = 8, 2, 500


def _device_trace():
    from repro.core import KiB, WorkloadSpec

    wl = WorkloadSpec()
    for t in range(DEV_THREADS):
        wl = wl.appends(n=DEV_N, size=8 * KiB, qd=DEV_QD, zone=t * 4,
                        nzones=4)
    return wl.build()


def _straggler_rack():
    """One contended rack: 8 users x 20 closed-loop object ops on a
    1-gateway / 4-server ec2+1 cluster — ~3.7k events, ~90 sweeps."""
    from repro.cluster import Cluster, ClusterSpec, ClusterWorkload, erasure

    spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    wl = ClusterWorkload(n_users=8, ops_per_user=20, object_bytes=1 << 20,
                         seed=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return Cluster(spec).compile(wl)


def _fleet(ndev, rack):
    """Concat ``ndev`` replicated device programs + the straggler."""
    import numpy as np

    from repro.core import (ZNSDeviceSpec, ZnsDevice, compile_fleet_program,
                            concat_programs)

    spec = ZNSDeviceSpec()
    lat = ZnsDevice(spec).lat
    tr = _device_trace()
    dprog = compile_fleet_program([tr] * ndev, [spec] * ndev, [lat] * ndev,
                                  cache=False)
    prog = concat_programs([dprog, rack.program])
    svc = np.concatenate([dprog.svc0_flat, rack.graph.svc])
    return prog, svc, tr, spec, lat


def _relerr(a, b):
    import numpy as np

    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0)))


def _open_loop_mega(per):
    """Four qd=0 Poisson streams (write/read alternating) -> one
    ``4*per``-request open-loop mega-entry.  Open-loop issue times
    spread monotonically, so issue-time windows cut cleanly; a
    closed-loop trace (issue ~= 0 everywhere) would not."""
    from repro.core import KiB, PoissonArrivals, WorkloadSpec

    wl = WorkloadSpec()
    for t in range(4):
        kw = dict(n=per, size=4 * KiB, qd=0, zone=t * 16, nzones=16,
                  arrival=PoissonArrivals(rate_per_s=2e5, seed=t))
        wl = wl.writes(**kw) if t % 2 == 0 else wl.reads(**kw)
    return wl.build()


def _ladder_pair_s():
    """One cold/warm capacity-ladder pair, run back to back so machine
    drift cancels in the per-pair ratio (the ``open_loop`` benchmark's
    interleaved median-of-ratios idiom)."""
    import time

    from repro.cluster import (ClusterConfig, ClusterSpec, ClusterWorkload,
                               erasure, plan_capacity)

    configs = [ClusterConfig(scheme=erasure(2, 1), placement="round-robin")]
    spec = ClusterSpec(n_gateways=1, n_servers=4, scheme=erasure(2, 1))
    rates = [20000.0, 26000.0, 34000.0, 46000.0, 60000.0, 80000.0]
    wl = ClusterWorkload(n_users=48, ops_per_user=96,
                         object_bytes=1 << 20, get_fraction=0.5)
    kw = dict(base_spec=spec, workload=wl, degraded=False,
              rate_ladder=rates, sweeps=512)
    t0 = time.perf_counter()
    cold = plan_capacity(configs, [48], warm_ladder=False, **kw)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = plan_capacity(configs, [48], warm_ladder=True, **kw)
    t_warm = time.perf_counter() - t0
    return t_cold, t_warm, cold, warm


def run(quick: bool = False) -> list:
    from repro.cluster import simulate_graph
    from repro.core import (last_compile_stats, solve_program,
                            solve_program_sharded)
    from repro.core import chain_program as cp
    from repro.core.engine import simulate

    import numpy as np

    rack = _straggler_rack()
    sizes = (16, 96) if quick else (16, 64, 128, 256)
    out: list = []
    speedup = active_speed = 0.0
    rel = float("inf")
    conv = bitident = False

    # --- scaling curve: full-sweep vs active-set vs entry-sharded -----
    # Each repeat times the three variants back to back and the gates
    # take the median per-rep ratio, so slow machine drift cancels.
    for ndev in sizes:
        prog, svc, tr, spec, lat = _fleet(ndev, rack)
        t_full, t_active, t_shard = [], [], []
        for _ in range(3):
            cp._ACTIVE_SET = False
            try:
                (c0, u0, k0), full_us = timed(
                    lambda: solve_program(prog, svc, sweeps=1024,
                                          fixpoint="loop", warn=False),
                    repeats=1)
            finally:
                cp._ACTIVE_SET = True
            (c1, u1, k1), one_us = timed(
                lambda: solve_program(prog, svc, sweeps=1024,
                                      fixpoint="loop", warn=False),
                repeats=1)
            (c2, u2, k2), sh_us = timed(
                lambda: solve_program_sharded(prog, svc, sweeps=1024,
                                              executor="host", warn=False),
                repeats=1)
            t_full.append(full_us)
            t_active.append(one_us)
            t_shard.append(sh_us)
        speedup = sorted(f / max(s, 1e-9)
                         for f, s in zip(t_full, t_shard))[1]
        active_speed = sorted(f / max(a, 1e-9)
                              for f, a in zip(t_full, t_active))[1]
        full_us, one_us, sh_us = min(t_full), min(t_active), min(t_shard)
        bitident = bool(np.array_equal(c1, c0))
        rel = _relerr(c2, c1)
        conv = bool(k0) and bool(k1) and bool(k2)
        out.append((f"mega_fleet/single_chip_full/{ndev}dev", full_us,
                    f"events={prog.n_flat};sweeps={u0}"))
        out.append((f"mega_fleet/single_chip/{ndev}dev", one_us,
                    f"events={prog.n_flat};sweeps={u1}"))
        out.append((f"mega_fleet/sharded_host/{ndev}dev", sh_us,
                    f"events={prog.n_flat};sweeps={u2}"))
        out.append((f"mega_fleet/speedup/{ndev}dev", 0.0,
                    f"{speedup:.2f}x"))
        out.append((f"mega_fleet/active_set/{ndev}dev", 0.0,
                    f"{active_speed:.2f}x"))

    # gates evaluate at the largest size (loop leaves it bound)
    out.append(("mega_fleet/gate_speedup", 0.0,
                f"{speedup:.2f}x"
                + ("" if speedup >= SPEEDUP_GATE and conv else "=FAIL")))
    out.append(("mega_fleet/gate_active_set", 0.0,
                f"{active_speed:.2f}x;bit_identical={bitident}"
                + ("" if active_speed >= ACTIVE_SET_GATE and bitident
                   and conv else "=FAIL")))
    out.append(("mega_fleet/gate_equal", 0.0,
                f"rel={rel:.2e}"
                + ("" if rel <= REL_TOL and conv else "=FAIL")))

    # event-engine oracle at the largest size: the pre-compiler way of
    # producing fleet completions (one greedy event heap per device +
    # the rack oracle)
    ndev = sizes[-1]

    def oracle():
        for _ in range(ndev):
            simulate(tr, spec, lat, seed=0, jitter=False)
        return simulate_graph(rack.graph)

    _, or_us = timed(oracle, repeats=1)
    out.append((f"mega_fleet/event_oracle/{ndev}dev", or_us,
                f"devices={ndev}"))

    # --- mesh executor (shard_map) when >= 2 jax devices are visible ---
    mesh_row = "skipped;jax_devices<2"
    try:
        import jax

        ndevs = len(jax.local_devices())
    except Exception:
        ndevs = 0
    if ndevs >= 2:
        prog, svc, _, _, _ = _fleet(8, rack)
        ref, _, k_ref = solve_program(prog, svc, sweeps=1024,
                                      fixpoint="loop", warn=False)
        (cm, um, km), mesh_us = timed(
            lambda: solve_program_sharded(prog, svc, sweeps=1024,
                                          executor="mesh", warn=False),
            repeats=1)
        relm = _relerr(cm, ref)
        ok = relm <= REL_TOL and bool(km) and bool(k_ref)
        mesh_row = (f"devices={ndevs};rel={relm:.2e}"
                    + ("" if ok else "=FAIL"))
        out.append(("mega_fleet/sharded_mesh/8dev", mesh_us,
                    f"events={prog.n_flat};sweeps={um}"))
    out.append(("mega_fleet/gate_mesh", 0.0, mesh_row))

    # --- jitted lowering: dedup + vectorized fill vs reference fill ----
    from repro.core import (ZNSDeviceSpec, ZnsDevice, compile_fleet_program)
    from repro.core import KiB, WorkloadSpec

    nlow, per = 64, 1560                               # ~100k events
    spec = ZNSDeviceSpec()
    lat = ZnsDevice(spec).lat
    wl = WorkloadSpec()
    for t in range(8):
        wl = wl.appends(n=per // 8, size=8 * KiB, qd=2, zone=t * 4,
                        nzones=4)
    tiers = [wl.build(),
             WorkloadSpec().writes(n=per, qd=4, zone=7).build(),
             WorkloadSpec().reads(n=per, size=4 * KiB, qd=4,
                                  nzones=64).build()]
    traces = [tiers[i % 3] for i in range(nlow)]
    specs, lats = [spec] * nlow, [lat] * nlow
    nev = sum(len(t) for t in traces)
    _, fast_us = timed(lambda: compile_fleet_program(
        traces, specs, lats, cache=False, dedup=True), repeats=2)
    st = last_compile_stats()
    cp._USE_REFERENCE_FILL = True
    try:
        _, ref_us = timed(lambda: compile_fleet_program(
            traces, specs, lats, cache=False, dedup=False), repeats=2)
    finally:
        cp._USE_REFERENCE_FILL = False
    low_speed = ref_us / fast_us if fast_us > 0 else float("inf")
    out.append(("mega_fleet/lowering_fast", fast_us,
                f"devices={nlow};events={nev};unique={st.n_unique}"))
    out.append(("mega_fleet/lowering_reference", ref_us,
                f"devices={nlow};events={nev}"))
    out.append(("mega_fleet/gate_lowering", 0.0,
                f"{low_speed:.2f}x"
                + ("" if low_speed >= LOWERING_GATE else "=FAIL")))

    # --- windowed pipeline: open-loop mega-entry in bounded memory -----
    import tracemalloc

    from repro.core import solve_program_windowed, window_program

    per = 250_000 if quick else 2_500_000
    trw = _open_loop_mega(per)
    progw = compile_fleet_program([trw], [spec], [lat], cache=False)
    svcw = progw.svc0_flat
    wev = 131_072
    nwin = window_program(progw, window_events=wev).n_windows
    (cf, ufull, kfull), full_t = timed(
        lambda: solve_program(progw, svcw, sweeps=64, fixpoint="loop",
                              warn=False), repeats=1)
    (cw, uwin, kwin), win_t = timed(
        lambda: solve_program_windowed(progw, svcw, sweeps=64,
                                       window_events=wev, warn=False),
        repeats=1)
    # Peak solver scratch, traced separately so the timing rows stay
    # untraced.  The window partition is memoized above, so both traces
    # see only per-solve allocations.
    tracemalloc.start()
    solve_program(progw, svcw, sweeps=64, fixpoint="loop", warn=False)
    full_peak = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()
    tracemalloc.start()
    solve_program_windowed(progw, svcw, sweeps=64, window_events=wev,
                           warn=False)
    win_peak = tracemalloc.get_traced_memory()[1]
    tracemalloc.stop()
    relw = _relerr(cw, cf)
    mem_ratio = full_peak / max(win_peak, 1)
    okw = (relw <= REL_TOL and bool(kfull) and bool(kwin)
           and win_peak * WINDOW_MEM_GATE <= full_peak)
    nreq = 4 * per
    out.append((f"mega_fleet/windowed_full/{nreq // 1000}k", full_t,
                f"events={progw.n_flat};sweeps={ufull};"
                f"peak_mb={full_peak / 1e6:.0f}"))
    out.append((f"mega_fleet/windowed_pipeline/{nreq // 1000}k", win_t,
                f"windows={nwin};sweeps={uwin};"
                f"peak_mb={win_peak / 1e6:.0f}"))
    out.append(("mega_fleet/gate_windowed", 0.0,
                f"rel={relw:.2e};mem_ratio={mem_ratio:.1f}x"
                + ("" if okw else "=FAIL")))

    # --- warm-started capacity ladder vs cold --------------------------
    times: list = [[], []]
    identical = True
    hits = attempts = 0
    for _ in range(WARM_REPEATS):
        t_cold, t_warm, cold_rep, warm_rep = _ladder_pair_s()
        times[0].append(t_cold)
        times[1].append(t_warm)
        hits, attempts = warm_rep.warm_hits, warm_rep.warm_attempts
        identical = identical and all(
            pc.lat.p99_us == pw.lat.p99_us
            for cc, cw in zip(cold_rep.curves, warm_rep.curves)
            for pc, pw in zip(cc.points, cw.points))
    ratios = sorted(c / max(w, 1e-9) for c, w in zip(*times))
    warm_x = ratios[len(ratios) // 2]
    okl = warm_x >= WARM_GATE and identical and hits > 0
    out.append(("mega_fleet/ladder_cold", min(times[0]) * 1e6,
                "rungs=6;users=48;ops=96"))
    out.append(("mega_fleet/ladder_warm", min(times[1]) * 1e6,
                f"hits={hits}/{attempts}"))
    out.append(("mega_fleet/gate_warm_ladder", 0.0,
                f"{warm_x:.2f}x;hits={hits}/{attempts};"
                f"identical={identical}"
                + ("" if okl else "=FAIL")))

    # --- full mode: 1k devices x 1M requests end-to-end ----------------
    if not quick:
        from repro.core import DeviceFleet

        wl_1k = WorkloadSpec()
        for t in range(4):
            wl_1k = wl_1k.appends(n=250, size=8 * KiB, qd=2, zone=t * 4,
                                  nzones=4)
        fleet = DeviceFleet.homogeneous(1000, spec)
        fres, e2e_us = timed(
            lambda: fleet.run(wl_1k, policy="replicate",
                              backend="vectorized", jitter=False),
            repeats=1)
        cst = fres.compile_stats
        total = sum(len(r.trace) for r in fres)
        out.append(("mega_fleet/end_to_end_1k_x_1M", e2e_us,
                    f"devices=1000;events={total};conv={fres.converged};"
                    f"unique={cst.n_unique if cst else '?'}"
                    + ("" if fres.converged and total == 1_000_000
                       else "=FAIL")))
    return out


if __name__ == "__main__":
    from .common import fmt_rows

    print(fmt_rows(run(quick=True)))
