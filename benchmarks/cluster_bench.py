"""Cluster tier: one fleet-level concatenated solve vs the per-server
Python composition loop (the greedy event-engine oracle), plus the
capacity-planner ranking sanity gates.

``python -m benchmarks.run --only cluster_bench [--quick]``

The speed gate compiles a (scheme x placement) sweep at 4 gateways x 16
storage servers, then times (a) ONE ``solve_program`` call over the
concatenated rack program against (b) a Python loop running the
event-engine oracle per configuration — the pre-cluster way of
composing per-server results.  Gates: >=3x speedup, engines agree to
float tolerance, and the capacity ranking is sane (every degraded-mode
curve's p99 is no better than its normal-mode row).
"""
from __future__ import annotations

from .common import Row, timed

#: The one-call path must beat the per-config oracle loop by this much.
SPEEDUP_GATE = 3.0
TOL_US = 1e-6


def run(quick: bool = False) -> list:
    import numpy as np

    from repro.cluster import (Cluster, ClusterConfig, ClusterSpec,
                               ClusterWorkload, erasure, plan_capacity,
                               replication, simulate_graph)
    from repro.core import concat_programs, solve_program

    n_gateways, n_servers = (2, 8) if quick else (4, 16)
    configs = [ClusterConfig(erasure(2, 1), "round-robin"),
               ClusterConfig(replication(2, 2), "hashed")]
    if not quick:
        configs += [ClusterConfig(erasure(4, 2), "strided"),
                    ClusterConfig(erasure(3, 1), "grouped")]
    wl = ClusterWorkload(n_users=4 if quick else 8,
                         ops_per_user=4 if quick else 6,
                         object_bytes=1 << 20, get_fraction=0.5, seed=0)

    # Compile each configuration once (shared by both timed paths).
    compiled = []
    for cfg in configs:
        spec = ClusterSpec(n_gateways=n_gateways, n_servers=n_servers,
                           scheme=cfg.scheme, placement=cfg.placement)
        compiled.append(Cluster(spec).compile(wl))
    n_events = sum(c.graph.n for c in compiled)

    program = concat_programs([c.program for c in compiled])
    svc = np.concatenate([c.graph.svc for c in compiled])
    comp0 = np.concatenate([c.comp for c in compiled])

    def one_call():
        # What plan_capacity runs: the fleet-level solve seeded by the
        # per-entry fixpoints found during compilation (comp0).
        return solve_program(program, svc, sweeps=512, fixpoint="loop",
                             warn=False, comp0=comp0)

    def oracle_loop():
        return [simulate_graph(c.graph) for c in compiled]

    comp, one_us = timed(one_call, repeats=3)
    oracle, loop_us = timed(oracle_loop, repeats=3)
    speedup = loop_us / one_us if one_us > 0 else float("inf")

    flat_oracle = np.concatenate(oracle)
    diff = float(np.max(np.abs(comp[0] - flat_oracle)))
    converged = bool(comp[2]) and all(c.converged for c in compiled)

    out: list = [
        ("cluster/one_call_solve", one_us,
         f"configs={len(configs)};events={n_events};"
         f"servers={n_servers};gw={n_gateways}"),
        ("cluster/oracle_loop", loop_us, f"configs={len(configs)}"),
        ("cluster/speedup", 0.0,
         f"{speedup:.2f}x" + ("" if speedup >= SPEEDUP_GATE else "=FAIL")),
        ("cluster/gate_differential", 0.0,
         f"maxdiff={diff:.2e}"
         + ("" if diff < TOL_US and converged else "=FAIL")),
    ]

    # Ranking sanity.  Erasure reconstruction (read every survivor +
    # decode) must not make the degraded curve *faster* than normal
    # mode on p99 (small slack: degraded PUTs skip the down server's
    # shard, which sheds a little load).  Replication configs are
    # exempt — failover reads can legitimately be cheaper.
    ladder = [2, 4] if quick else [4, 8]
    report = plan_capacity(
        configs, ladder, workload=wl,
        base_spec=ClusterSpec(n_gateways=n_gateways, n_servers=n_servers),
        slo_us=10_000.0)
    sane = report.converged
    for curve in report.ranking():
        deg = report.degraded_curve(curve.config)
        out.append((f"cluster/{curve.config.name}/users_at_slo", 0.0,
                    f"{curve.users_at_slo:.2f}"
                    + (f";degraded={deg.users_at_slo:.2f}" if deg else "")))
        if deg is not None and curve.config.scheme.kind == "ec":
            for p_n, p_d in zip(curve.points, deg.points):
                if p_d.lat.p99_us < 0.95 * p_n.lat.p99_us:
                    sane = False
    out.append(("cluster/gate_ranking_sane", 0.0,
                "ok" if sane else "=FAIL"))
    return out


if __name__ == "__main__":
    from .common import fmt_rows
    print(fmt_rows(run()))
