"""DeviceFleet microbench: batched multi-device simulation vs the
sequential per-device loop.

Acceptance gate for the fleet layer: a 16-device x 50k-request sweep
through ``DeviceFleet.run`` (device-axis-batched max-plus scans) must run
>=4x faster than sequentially looping the per-device reference runs
(``ZnsDevice.run(backend="event")``) while agreeing on completion times to
float tolerance.  The ratio against a loop of per-device *vectorized* runs
is reported too: on CPU the batched path mainly removes loop overhead
(scan flops are equal), while on TPU the batch grid dimension of the
Pallas kernel parallelizes across devices.

``run(quick=True)`` is the CI smoke configuration (8 devices x 20k).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceFleet, KiB, LatencyModel, OpType, WorkloadSpec, \
    ZnsDevice, ZNSDeviceSpec
from repro.core.emulator_models import EMULATOR_PROFILES

SPEEDUP_GATE = 4.0


def _mixed_workload(scale: int) -> WorkloadSpec:
    return (WorkloadSpec()
            .writes(n=18 * scale, size=4 * KiB, qd=4, zone=0)
            .reads(n=22 * scale, size=4 * KiB, qd=16, zone=100, nzones=100)
            .appends(n=9 * scale, size=8 * KiB, qd=2, zone=300)
            .resets(n=scale, occupancy=1.0, nzones=200, io_ctx=OpType.READ))


def _heterogeneous_members(n_devices: int):
    """Alternate device geometries and emulator profiles across the fleet.

    Geometries stay inside the vectorized engine's exactness envelope
    (pools slack or homogeneous) so the event-engine reference agrees to
    float tolerance and the bench measures speed, not approximation.
    """
    specs = (ZNSDeviceSpec(),
             ZNSDeviceSpec(append_parallelism=4),
             ZNSDeviceSpec(num_zones=512, max_open_zones=12))
    profiles = ("ours", "nvmevirt")
    return [(specs[i % len(specs)], EMULATOR_PROFILES[profiles[i % 2]])
            for i in range(n_devices)]


def run(quick: bool = False):
    n_devices = 8 if quick else 16
    scale = 400 if quick else 1000          # 20k / 50k requests per device
    members = _heterogeneous_members(n_devices)
    fleet = DeviceFleet(members)
    wls = [_mixed_workload(scale)] * n_devices
    traces = [w.build() for w in wls]
    n_per_dev = len(traces[0])

    # best-of-2 for the (fast) batched path: the gate measures the
    # engine, not scheduler noise on a sub-second run.
    t_fleet = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        fres = fleet.run(traces, backend="vectorized", jitter=False)
        t_fleet = min(t_fleet, time.perf_counter() - t0)

    # Sequential per-device reference loop (the pre-fleet code path).
    devs = [ZnsDevice(s, lat=LatencyModel(s, p)) for s, p in members]
    t0 = time.perf_counter()
    seq_event = [devs[i].run(traces[i], backend="event", seed=i,
                             jitter=False) for i in range(n_devices)]
    t_event = time.perf_counter() - t0

    t0 = time.perf_counter()
    seq_vec = [devs[i].run(traces[i], backend="vectorized", seed=i,
                           jitter=False) for i in range(n_devices)]
    t_vec = time.perf_counter() - t0

    rel = max(
        float(np.max(np.abs(seq_event[i].sim.complete - fres[i].sim.complete)
                     / np.maximum(seq_event[i].sim.complete, 1.0)))
        for i in range(n_devices))
    rel_vec = max(
        float(np.max(np.abs(seq_vec[i].sim.complete - fres[i].sim.complete)
                     / np.maximum(seq_vec[i].sim.complete, 1.0)))
        for i in range(n_devices))

    speedup = t_event / max(t_fleet, 1e-9)
    speedup_vec = t_vec / max(t_fleet, 1e-9)
    gate = "PASS" if speedup >= SPEEDUP_GATE else "FAIL"
    rows = [
        (f"fleet/batched/n{n_devices}x{n_per_dev}", t_fleet * 1e6,
         f"speedup_vs_event_loop_x={speedup:.1f};"
         f"speedup_vs_vectorized_loop_x={speedup_vec:.2f};"
         f"event_loop_s={t_event:.2f};vectorized_loop_s={t_vec:.2f};"
         f"max_rel_err={rel:.1e};ge{SPEEDUP_GATE:.0f}x={gate}"),
        (f"fleet/vs_vectorized_loop/n{n_devices}x{n_per_dev}", t_vec * 1e6,
         f"max_rel_err_vs_vec={rel_vec:.1e}"),
    ]
    # Emulator-profile sweep through the same batched path.
    prof_fleet = DeviceFleet.from_profiles(("femu", "nvmevirt", "ours"))
    pres = prof_fleet.run(_mixed_workload(max(scale // 10, 10)),
                          backend="vectorized", policy="replicate",
                          jitter=False)
    for name, r in zip(("femu", "nvmevirt", "ours"), pres):
        rows.append((f"fleet/profiles/{name}", 0.0,
                     f"read_p99_us={r.latency_stats(OpType.READ).p99_us:.1f};"
                     f"iops={r.iops:.0f}"))
    return rows
