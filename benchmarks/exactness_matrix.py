"""Exactness matrix: the fused solver vs the event-engine test oracle.

Every cell solves a 2-device fleet program three ways — ``cols``
(position-loop) layout, ``rows`` (doubling-scan) layout, and the
entry-sharded host driver — and compares per-device completions against
the sequential event engine.  Workload rows cover the shapes the paper's
pool observations exercise (Obs#5–#7, #12/#13): a saturated single-class
append pool, a heterogeneous multi-class pool, and a reset/IO mix that
also queues the metadata engine; each jitter-free and jittered.

The gates assert the compiler's contract, not a tolerance du jour:
``ChainProgram.exact`` must be True on every cell, jitter-free cells
must agree to rtol ``TOL_JITTER_FREE`` and jittered cells to rtol
``TOL_JITTERED`` (both with atol 1e-6 us on microsecond-scale times).
Any "=FAIL" substring in a derived column fails CI's exactness-smoke
job — a previously-exact cell regressing to approximate is a build
breaker, which is what demotes the event engine to a test oracle.

``WORKLOADS`` / ``LAYOUTS`` / the tolerances are the registry
``docs/architecture.md``'s exactness table is sync-tested against
(see ``tests/test_docs.py``).
"""
from __future__ import annotations

import time

import numpy as np

#: rtol for jitter-free cells: the replayed chains are the event
#: schedule, so disagreement is pure float64 accumulation noise.
TOL_JITTER_FREE = 1e-9
#: rtol for jittered cells: same chains, but service times come from a
#: seeded lognormal draw whose sums the two engines accumulate in
#: different orders; one decade of headroom over jitter-free.
TOL_JITTERED = 1e-8

#: Workload rows of the matrix (name -> builder kwargs), each run
#: jitter-free and jittered.
WORKLOADS = ("single_class", "multi_class", "reset_mixed")
#: Solve paths of the matrix: pinned family-block layouts + the
#: entry-sharded host executor.
LAYOUTS = ("cols", "rows", "sharded")

_SWEEPS = 256


def _build(name: str, scale: int):
    from repro.core import KiB, OpType, WorkloadSpec

    wl = WorkloadSpec()
    if name == "single_class":
        for t in range(6):
            wl = wl.appends(n=scale, size=8 * KiB, qd=4, zone=t * 4,
                            nzones=4)
    elif name == "multi_class":
        for t in range(6):
            wl = wl.appends(n=scale, size=8 * KiB, qd=4, zone=t * 4,
                            nzones=4)
            wl = wl.appends(n=scale, size=64 * KiB, qd=4, zone=t * 4,
                            nzones=4)
    elif name == "reset_mixed":
        for t in range(4):
            wl = wl.appends(n=scale, size=8 * KiB, qd=4, zone=t * 4,
                            nzones=4)
            wl = wl.appends(n=scale, size=64 * KiB, qd=4, zone=t * 4,
                            nzones=4)
        wl = wl.resets(n=max(scale // 2, 8), occupancy=1.0,
                       nzones=max(scale // 2, 8), io_ctx=OpType.APPEND,
                       zone=500)
    else:  # pragma: no cover - registry and builder kept in sync
        raise KeyError(name)
    return wl.build()


def run(quick: bool = False) -> list:
    from repro.core import (ZNSDeviceSpec, ZnsDevice, compute_service_times,
                            force_layout, simulate, solve_program,
                            solve_program_sharded)
    from repro.core import chain_program as cp

    scale = 25 if quick else 150
    spec = ZNSDeviceSpec()
    lat = ZnsDevice(spec).lat
    rows = []
    all_ok = True
    for wname in WORKLOADS:
        tr = _build(wname, scale)
        traces = [tr, tr]                       # 2 entries -> real shards
        seeds = [3, 4]
        for jitter in (False, True):
            prog = cp.compile_fleet_program(
                traces, [spec] * 2, [lat] * 2, cache=False,
                jitter=jitter, seeds=seeds)
            if jitter:
                svc_flat = np.concatenate([
                    compute_service_times(tr, lat, seed=s, jitter=True)
                    [prog.orders[b]] for b, s in enumerate(seeds)])
            else:
                svc_flat = prog.svc0_flat
            ev = [simulate(tr, spec, lat, seed=s, jitter=jitter).complete
                  for s in seeds]
            tol = TOL_JITTERED if jitter else TOL_JITTER_FREE
            jname = "jittered" if jitter else "jitter_free"
            for layout in LAYOUTS:
                t0 = time.perf_counter()
                if layout == "sharded":
                    comp, used, conv = solve_program_sharded(
                        prog, svc_flat, sweeps=_SWEEPS, executor="host",
                        warn=False)
                else:
                    comp, used, conv = solve_program(
                        force_layout(prog, layout), svc_flat,
                        sweeps=_SWEEPS, fixpoint="loop", warn=False)
                dt = time.perf_counter() - t0
                rel = max(
                    float(np.max(
                        np.abs(comp[prog.device_slice(b)][prog.invs[b]]
                               - ev[b])
                        / np.maximum(np.abs(ev[b]), 1.0)))
                    for b in range(2))
                ok = bool(prog.exact) and bool(conv) and rel <= tol
                all_ok = all_ok and ok
                rows.append((
                    f"exactness_matrix/{wname}/{jname}/{layout}",
                    dt * 1e6,
                    f"n={len(tr)}x2;max_rel_err={rel:.2e};rtol={tol:.0e};"
                    f"exact={prog.exact};order_stable={prog.order_stable};"
                    f"cell={'PASS' if ok else 'FAIL'}"))
    rows.append(("exactness_matrix/gate_all_cells", 0.0,
                 f"cells={len(WORKLOADS) * 2 * len(LAYOUTS)};"
                 f"all_exact={'PASS' if all_ok else 'FAIL'}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=True):
        print(f"{name},{us:.3f},{derived}")
