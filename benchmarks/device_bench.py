"""Device-session backend microbench: event vs vectorized on large traces.

Acceptance gate for the ``ZnsDevice`` backend registry: the vectorized
backend (chain-decomposed max-plus scans) must run a >=100k-request mixed
trace >=5x faster than the per-request event engine while agreeing on the
completion times (jitter-free) to float tolerance.
"""
from __future__ import annotations

import numpy as np

from repro.core import KiB, OpType, WorkloadSpec, ZnsDevice

from .common import timed


def _mixed_workload(scale: int) -> WorkloadSpec:
    return (WorkloadSpec()
            .writes(n=40 * scale, size=4 * KiB, qd=4, zone=0)
            .reads(n=50 * scale, size=4 * KiB, qd=16, zone=100, nzones=100)
            .appends(n=20 * scale, size=8 * KiB, qd=2, zone=300)
            .resets(n=2 * scale, occupancy=1.0, nzones=200,
                    io_ctx=OpType.READ))


def run(quick: bool = False):
    dev = ZnsDevice()
    rows = []
    # quick (CI smoke) keeps only the ~11k-request scale: large enough for
    # the >=5x gate (noise-bound below a few thousand requests), small
    # enough to skip the 112k event-engine run.
    for scale, repeats in ((100, 2),) if quick else \
            ((100, 3), (1000, 1)):
        tr = _mixed_workload(scale).build()
        n = len(tr)
        res_v, us_v = timed(lambda: dev.run(tr, backend="vectorized",
                                            jitter=False), repeats=repeats)
        res_e, us_e = timed(lambda: dev.run(tr, backend="event",
                                            jitter=False), repeats=repeats)
        rel = np.max(np.abs(res_e.sim.complete - res_v.sim.complete)
                     / np.maximum(res_e.sim.complete, 1.0))
        speedup = us_e / us_v
        rows.append((f"device/backends/n{n}", us_v,
                     f"speedup_x={speedup:.1f};event_us={us_e:.0f};"
                     f"max_rel_err={rel:.1e};"
                     f"ge5x={'PASS' if speedup >= 5.0 else 'FAIL'}"))
        if scale >= 1000:
            st = res_v.latency_stats(OpType.READ)
            rows.append((f"device/vectorized/read_p99/n{n}", 0.0,
                         f"p99_us={st.p99_us:.1f};iops={res_v.iops:.0f}"))
    return rows
