"""Host placement-policy comparison: every (scenario, policy) combo as
ONE fleet-batched simulation, ranked per scenario.

``python -m benchmarks.run --only host_policies [--quick]``

Rows: per combination — makespan, user bandwidth, write amplification,
reclaim throughput; plus one ``rank`` row per scenario (best policy
first, by makespan with WA tiebreak) and a gate asserting the expected
qualitative structure (circular-log reclaims at WA 1.0 under the
fill-don't-finish policies; mixed-lifetime scenarios pay WA > 1).
"""
from __future__ import annotations

from .common import Row, timed


def run(quick: bool = False) -> list:
    from repro.host import compare_policies, rank_policies

    scale = 0.5 if quick else 1.0
    backend = "vectorized"
    rows, us = timed(
        lambda: compare_policies(backend=backend, scale=scale), repeats=1)
    out: list = [("host_policies/compare_run", us,
                  f"combos={len(rows)};backend={backend};scale={scale}")]
    for r in rows:
        name = f"host_policies/{r['scenario']}/{r['policy']}"
        out.append((name + "/makespan", r["makespan_s"] * 1e6,
                    f"{r['user_bandwidth_mibs']:.1f}MiB/s"))
        out.append((name + "/write_amp", 0.0,
                    f"{r['write_amplification']:.3f}"))
        out.append((name + "/reclaim", 0.0,
                    f"{r['reclaim_mibs']:.1f}MiB/s;"
                    f"zones_reset={int(r['zones_reset'])}"))
    ranking = rank_policies(rows)
    for scen, order in ranking.items():
        out.append((f"host_policies/{scen}/rank", 0.0, ">".join(order)))
    # Gates: the qualitative structure the docs/host.md table promises.
    circ = [r for r in rows if r["scenario"] == "circular-log"]
    wa_ok = all(r["write_amplification"] == 1.0 for r in circ
                if r["zones_reset"] > 0)
    mixed = [r for r in rows if r["scenario"] in ("lsm", "cache")]
    mixed_ok = all(r["write_amplification"] > 1.0 for r in mixed)
    out.append(("host_policies/gate_circular_wa1", 0.0,
                "ok" if wa_ok else "=FAIL"))
    out.append(("host_policies/gate_mixed_wa_gt1", 0.0,
                "ok" if mixed_ok else "=FAIL"))
    return out


if __name__ == "__main__":
    from .common import fmt_rows
    print(fmt_rows(run()))
