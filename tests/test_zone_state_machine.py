"""Zone state machine: legality, limits, and property-based invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import OpType, ZoneError, ZoneManager, ZoneState, ZNSDeviceSpec
from repro.core.state_machine import TRANSITION_TABLE, transition_array

SMALL = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                      num_zones=32, max_open_zones=4, max_active_zones=6)


def test_write_advances_pointer_and_opens():
    zm = ZoneManager(SMALL)
    lba = zm.write(3, 4096)
    assert lba == SMALL.zone_start(3)
    assert zm.state(3) == ZoneState.IMPLICIT_OPEN
    lba2 = zm.write(3, 4096)
    assert lba2 == lba + 4096


def test_append_returns_lba():
    zm = ZoneManager(SMALL)
    lbas = [zm.write(0, 1024, append=True) for _ in range(4)]
    assert lbas == [SMALL.zone_start(0) + i * 1024 for i in range(4)]


def test_zone_overflow_rejected():
    zm = ZoneManager(SMALL)
    zm.write(0, SMALL.zone_cap_bytes - 512)
    with pytest.raises(ZoneError):
        zm.write(0, 1024)


def test_fill_to_cap_becomes_full():
    zm = ZoneManager(SMALL)
    zm.write(0, SMALL.zone_cap_bytes)
    assert zm.state(0) == ZoneState.FULL
    with pytest.raises(ZoneError):
        zm.write(0, 512)


def test_max_open_zone_limit():
    zm = ZoneManager(SMALL)
    for z in range(SMALL.max_open_zones):
        zm.open(z)
    with pytest.raises(ZoneError):
        zm.open(SMALL.max_open_zones)
    # closing one frees a slot (still active though)
    zm.close(0)
    zm.open(SMALL.max_open_zones)


def test_max_active_zone_limit():
    zm = ZoneManager(SMALL)
    for z in range(SMALL.max_open_zones):
        zm.open(z)
    for z in range(SMALL.max_open_zones):
        zm.close(z)
    for z in range(SMALL.max_open_zones, SMALL.max_active_zones):
        zm.open(z)
    with pytest.raises(ZoneError):
        zm.open(SMALL.max_active_zones + 1)


def test_finish_semantics():
    zm = ZoneManager(SMALL)
    with pytest.raises(ZoneError):
        zm.finish(0)               # empty: forbidden (§III-E)
    zm.write(0, 4096)
    occ = zm.finish(0)
    assert zm.state(0) == ZoneState.FULL
    assert 0 < occ < 0.1
    with pytest.raises(ZoneError):
        zm.finish(0)               # full: forbidden


def test_reset_returns_occupancy_and_finished_flag():
    zm = ZoneManager(SMALL)
    zm.write(0, SMALL.zone_cap_bytes // 2)
    zm.finish(0)
    occ, fin = zm.reset(0)
    assert fin and occ == 1.0      # finish fills the zone
    assert zm.state(0) == ZoneState.EMPTY
    zm.write(0, 1024)
    occ, fin = zm.reset(0)
    assert not fin


@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6)),
                min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_transition_array_matches_table(pairs):
    states = np.array([p[0] for p in pairs], dtype=np.int32)
    ops = np.array([p[1] for p in pairs], dtype=np.int32)
    nxt, ok = transition_array(states, ops)
    nxt, ok = np.asarray(nxt), np.asarray(ok)
    for s, o, n, k in zip(states, ops, nxt, ok):
        expect = TRANSITION_TABLE[s, o]
        assert k == (expect >= 0)
        assert n == (expect if expect >= 0 else s)


@given(st.lists(st.tuples(st.integers(0, 7),       # zone
                          st.sampled_from(["write", "append", "open",
                                           "close", "finish", "reset"]),
                          st.integers(1, 1 << 18)),  # nbytes
                min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_random_op_sequences_preserve_invariants(ops):
    """Whatever the op sequence, accepted ops preserve: wp <= cap,
    monotone wp between resets, open/active counts within limits."""
    zm = ZoneManager(SMALL)
    for zone, op, nbytes in ops:
        prev_wp = zm.write_pointer(zone)
        try:
            if op == "write":
                zm.write(zone, nbytes)
            elif op == "append":
                zm.write(zone, nbytes, append=True)
            elif op == "open":
                zm.open(zone)
            elif op == "close":
                zm.close(zone)
            elif op == "finish":
                zm.finish(zone)
            elif op == "reset":
                zm.reset(zone)
        except ZoneError:
            continue
        wp = zm.write_pointer(zone)
        assert 0 <= wp <= SMALL.zone_cap_bytes
        if op in ("write", "append", "finish"):
            assert wp >= prev_wp
        assert zm.open_count <= SMALL.max_open_zones
        assert zm.active_count <= SMALL.max_active_zones
        if zm.write_pointer(zone) == SMALL.zone_cap_bytes:
            assert zm.state(zone) == ZoneState.FULL
