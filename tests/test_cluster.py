"""Cluster tier: codec/placement/gateway structure, the fleet-level
ChainProgram vs the greedy event-engine oracle (differential), capacity
planning, and the CLI.  Hypothesis variants of the structural properties
live in ``tests/test_cluster_properties.py``; this module keeps
deterministic sweeps of the same invariants so they run without
hypothesis installed.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.cluster import (
    Cluster, ClusterConfig, ClusterSpec, ClusterWorkload, available_placements,
    build_graph, erasure, oracle_op_latencies, parse_scheme, placement_map,
    plan_capacity, register_placement, replication, simulate_graph,
    touched_servers, users_at_slo,
)
from repro.cluster.capacity import CapacityPoint
from repro.core.metrics import LatencyStats

TOL_US = 1e-6       # program-vs-oracle float tolerance (microseconds)

SMALL_WL = ClusterWorkload(n_users=3, ops_per_user=4, get_fraction=0.5,
                           object_bytes=1 << 20, seed=3)


def small_spec(**kw):
    kw.setdefault("n_gateways", 2)
    kw.setdefault("n_servers", 6)
    kw.setdefault("scheme", erasure(3, 1))
    return ClusterSpec(**kw)


# ---------------------------------------------------------------------------
# codec: byte layout + slot geometry
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", [erasure(1, 0), erasure(4, 2),
                                    replication(2, copies=3)])
@pytest.mark.parametrize("nbytes", [1, 7, 4096, (1 << 20) + 13])
def test_every_byte_in_exactly_one_data_shard(scheme, nbytes):
    ranges = scheme.shard_ranges(nbytes)
    assert len(ranges) == scheme.k
    # The ranges partition [0, nbytes): contiguous, disjoint, complete.
    pos = 0
    for j, (lo, hi) in enumerate(ranges):
        assert lo == pos and hi >= lo
        pos = hi
    assert pos == nbytes
    for off in {0, nbytes // 2, nbytes - 1} | ({1} if nbytes > 1 else set()):
        j = scheme.shard_of_byte(nbytes, off)
        lo, hi = ranges[j]
        assert lo <= off < hi


def test_scheme_names_roundtrip():
    for scheme in (erasure(4, 2), erasure(2, 0), replication(3, copies=2),
                   replication(1, copies=3)):
        assert parse_scheme(scheme.name) == scheme
    with pytest.raises(ValueError):
        parse_scheme("raid6")


def test_rep_failover_and_ec_reconstruction_slots():
    rep = replication(2, copies=2)          # slots: [s0 c0, s0 c1, s1 c0, s1 c1]
    servers = [0, 1, 2, 3]
    slots, decode = rep.read_slots(servers, down=None)
    assert slots == [0, 2] and not decode
    slots, decode = rep.read_slots(servers, down=0)
    assert slots == [1, 2] and not decode   # failover to surviving copy
    ec = erasure(3, 1)
    servers = [0, 1, 2, 3]
    slots, decode = ec.read_slots(servers, down=1)
    assert slots == [0, 2, 3] and decode    # full-stripe reconstruction
    assert ec.write_slots(servers, down=1) == [0, 2, 3]
    with pytest.raises(ValueError):
        erasure(2, 0).read_slots([0, 1], down=0)


# ---------------------------------------------------------------------------
# placement registry
# ---------------------------------------------------------------------------
def test_placement_maps_valid_and_distinct():
    objects = np.arange(17)
    for policy in available_placements():
        rows = placement_map(objects, n_shards=4, n_servers=9, policy=policy)
        assert rows.shape == (17, 4)
        assert rows.min() >= 0 and rows.max() < 9
        for r in rows:                       # distinct servers per object
            assert len(set(r.tolist())) == 4


def test_placement_registry_extensible():
    @register_placement("test-reversed")
    def _reversed(obj, n_shards, n_servers, seed):
        return (obj + np.arange(n_shards)[::-1]) % n_servers
    try:
        rows = placement_map(np.arange(3), 2, 5, policy="test-reversed")
        assert rows[0].tolist() == [1, 0]
    finally:
        from repro.cluster import PLACEMENTS
        PLACEMENTS.unregister("test-reversed")


# ---------------------------------------------------------------------------
# degraded mode: blast radius
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheme", [erasure(2, 1), erasure(4, 2)])
def test_ec_degraded_reconstruction_touches_exactly_m_extra(scheme):
    spec = small_spec(n_servers=8, scheme=scheme, placement="hashed")
    wl = dataclasses.replace(SMALL_WL, get_fraction=0.5)
    ops = wl.build(spec.n_gateways)
    normal = build_graph(spec, ops, qd=wl.qd, seed=wl.seed)
    # Degrade a server that holds a primary data shard of some GET.
    from repro.cluster import OP_GET
    gets = [op for op in ops if op.kind == OP_GET]
    assert gets
    checked = 0
    for down in range(spec.n_servers):
        degraded = build_graph(spec, ops, qd=wl.qd, down=down, seed=wl.seed)
        for op in gets:
            before = touched_servers(normal, op.seq)
            after = touched_servers(degraded, op.seq)
            if down not in before:
                continue                     # this op unaffected
            assert down not in after
            extra = after - before
            assert len(extra) == scheme.m    # exactly m extra servers
            checked += 1
    assert checked > 0


def test_rep_degraded_failover_touches_one_replacement():
    spec = small_spec(n_servers=8, scheme=replication(2, copies=2))
    ops = SMALL_WL.build(spec.n_gateways)
    from repro.cluster import OP_GET
    degraded = build_graph(spec, ops, qd=1, down=0, seed=SMALL_WL.seed)
    normal = build_graph(spec, ops, qd=1, seed=SMALL_WL.seed)
    for op in ops:
        if op.kind != OP_GET:
            continue
        before = touched_servers(normal, op.seq)
        after = touched_servers(degraded, op.seq)
        if 0 not in before:
            continue
        assert 0 not in after
        assert len(after - before) <= 1      # failover, no reconstruction


# ---------------------------------------------------------------------------
# differential: one fleet-level ChainProgram vs the greedy event engine
# ---------------------------------------------------------------------------
DIFF_CASES = [
    (erasure(3, 1), "round-robin", "writeback", None, 1),
    (erasure(4, 2), "hashed", "writeback", None, 2),
    (erasure(2, 1), "strided", "write-through", 0, 1),
    (replication(2, 2), "grouped", "writeback", 0, 2),
    (replication(1, 3), "round-robin", "write-through", None, 2),
    (erasure(3, 0), "hashed", "writeback", None, 1),
]


@pytest.mark.parametrize("scheme,policy,durability,down,qd", DIFF_CASES,
                         ids=lambda v: str(v))
def test_program_matches_oracle_jitter_free(scheme, policy, durability,
                                            down, qd):
    spec = small_spec(n_servers=8, scheme=scheme, placement=policy,
                      durability=durability)
    wl = dataclasses.replace(SMALL_WL, qd=qd)
    res = Cluster(spec).run(wl, down=down)
    assert res.converged
    assert res.compiled.program.order_stable
    oracle = simulate_graph(res.compiled.graph)
    assert float(np.max(np.abs(res.comp - oracle))) < TOL_US
    # Per-op latencies agree too (same readys, same completions).
    lat_p = res.op_latencies()
    lat_o = oracle_op_latencies(res.compiled.graph)
    np.testing.assert_allclose(lat_p, lat_o, atol=TOL_US)
    assert np.all(lat_p > 0)


def test_program_is_exact_single_class_and_multiclass():
    res = Cluster(small_spec()).run(SMALL_WL)
    assert res.compiled.program.exact
    assert res.compiled.program.multiclass_pools == ()
    # Mixed object sizes through a queuing cap>1 pool (a narrow device
    # read pool, write-through so GETs hit flash): the greedy replay
    # keeps the program exact; multiclass_pools stays as metadata.
    from repro.cluster import CLUSTER_DEVICE_SPEC, compile_graph
    spec = small_spec(
        durability="write-through",
        device_spec=dataclasses.replace(CLUSTER_DEVICE_SPEC,
                                        read_parallelism=2))
    wl = dataclasses.replace(SMALL_WL, n_users=4, ops_per_user=6,
                             get_fraction=0.7)
    ops = wl.build(spec.n_gateways)
    ops = [dataclasses.replace(op, nbytes=op.nbytes // (1 + op.obj % 2))
           for op in ops]
    graph = build_graph(spec, ops, qd=1, seed=0)
    compiled = compile_graph(graph)
    assert compiled.program.exact and compiled.program.order_stable
    assert compiled.program.unstable_pools == ()
    assert compiled.program.multiclass_pools
    oracle = simulate_graph(graph)
    np.testing.assert_allclose(compiled.comp, oracle, rtol=1e-9,
                               atol=1e-6)


def test_oracle_rejects_cyclic_graph():
    res = Cluster(small_spec()).compile(SMALL_WL)
    graph = res.graph
    bad = dataclasses.replace(
        graph, edges=graph.edges + [("cycle", graph.n - 1, 0)])
    with pytest.raises(ValueError, match="cycle"):
        simulate_graph(bad)


def test_writeback_shard_too_large_for_buffer_raises():
    spec = small_spec(scheme=erasure(1, 0))
    wl = dataclasses.replace(SMALL_WL, object_bytes=64 << 20)  # > 32MiB buf
    with pytest.raises(ValueError, match="writeback"):
        Cluster(spec).compile(wl)


# ---------------------------------------------------------------------------
# capacity planning: one concatenated solve
# ---------------------------------------------------------------------------
def test_plan_capacity_one_call_matches_per_config_runs():
    configs = [ClusterConfig(erasure(2, 1), "round-robin"),
               ClusterConfig(replication(2, 2), "hashed")]
    wl = dataclasses.replace(SMALL_WL, ops_per_user=3)
    report = plan_capacity(configs, [2, 4], workload=wl,
                           base_spec=small_spec(), slo_us=20e3)
    assert report.converged
    assert report.n_programs == 8            # 2 cfg x 2 rungs x 2 modes
    assert report.n_events > 0
    ranked = report.ranking()
    assert [c.degraded for c in ranked] == [False, False]
    assert ranked[0].users_at_slo >= ranked[1].users_at_slo
    for cfg in configs:                      # degraded row per config
        assert report.degraded_curve(cfg) is not None
    # The sliced one-call solve equals a standalone per-config run.
    spec = dataclasses.replace(small_spec(), scheme=configs[0].scheme,
                               placement=configs[0].placement)
    solo = Cluster(spec).run(dataclasses.replace(wl, n_users=2))
    curve = next(c for c in report.curves
                 if c.config == configs[0] and not c.degraded)
    point = next(p for p in curve.points if p.users == 2)
    assert point.lat.p99_us == pytest.approx(
        solo.latency_stats().p99_us, abs=TOL_US)


def test_users_at_slo_interpolates_and_clamps():
    def pt(users, p99):
        lat = LatencyStats(mean_us=p99, p50_us=p99, p95_us=p99, p99_us=p99,
                           p999_us=p99, n=10)
        return CapacityPoint(users=users, objects_per_sec=1.0, lat=lat,
                             slo_violation_rate=0.0, converged=True)
    assert users_at_slo([], 100.0) == 0.0
    assert users_at_slo([pt(2, 500.0)], 100.0) == 0.0        # floor violates
    assert users_at_slo([pt(2, 50.0), pt(8, 90.0)], 100.0) == 8.0
    mid = users_at_slo([pt(2, 50.0), pt(8, 200.0)], 100.0)
    assert 2.0 < mid < 8.0                                   # interpolated


# ---------------------------------------------------------------------------
# converged propagation (satellite: non-steady-state runs must be loud)
# ---------------------------------------------------------------------------
def test_runner_report_footnotes_unconverged_results():
    from repro.experiments import ExperimentRunner
    from repro.experiments.runner import render_report
    runner = ExperimentRunner(["obs4"], backend="event")
    results = runner.run()
    assert all(r.converged for r in results)
    assert "did not converge" not in render_report(results)
    stale = [dataclasses.replace(r, converged=False) for r in results]
    report = render_report(stale)
    assert "did not converge" in report
    assert f"`{stale[0].name}`" in report


def test_run_cli_exits_nonzero_when_unconverged(monkeypatch, tmp_path,
                                                capsys):
    from repro.experiments import __main__ as cli
    from repro.experiments import ExperimentRunner
    real_run = ExperimentRunner.run

    def stale_run(self):
        return [dataclasses.replace(r, converged=False)
                for r in real_run(self)]
    monkeypatch.setattr(ExperimentRunner, "run", stale_run)
    rc = cli.main(["run", "--only", "obs4", "--backend", "event",
                   "--out", str(tmp_path)])
    assert rc == 1
    assert "did not converge" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cluster_cli_list(capsys):
    from repro.experiments import __main__ as cli
    assert cli.main(["cluster", "--list"]) == 0
    out = capsys.readouterr().out
    for policy in available_placements():
        assert policy in out


def test_cluster_cli_end_to_end(tmp_path, capsys):
    from repro.experiments import __main__ as cli
    rc = cli.main(["cluster", "--schemes", "ec2+1", "--policies",
                   "round-robin", "--users", "2,3", "--objects-per-user",
                   "3", "--servers", "6", "--out", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "ec2+1/round-robin" in out and "degraded" in out
    data = json.loads((tmp_path / "capacity.json").read_text())
    assert data["converged"] is True
    assert data["n_programs"] == 4           # 1 cfg x 2 rungs x 2 modes
    assert {c["degraded"] for c in data["curves"]} == {False, True}
    csv = (tmp_path / "capacity_curves.csv").read_text().strip().splitlines()
    assert csv[0].startswith("config,degraded,users")
    assert len(csv) == 1 + 4                 # header + 2 curves x 2 rungs


def test_cluster_cli_rejects_bad_scheme(capsys):
    from repro.experiments import __main__ as cli
    assert cli.main(["cluster", "--schemes", "raid6"]) == 2
    assert cli.main(["cluster", "--schemes", "ec9+3", "--servers", "8"]) == 2


# ---------------------------------------------------------------------------
# accelerated fixpoint backends
# ---------------------------------------------------------------------------
def test_cluster_program_xla_fixpoint_matches_loop():
    pytest.importorskip("jax")
    loop = Cluster(small_spec()).run(SMALL_WL, fixpoint="loop")
    xla = Cluster(small_spec()).run(SMALL_WL, fixpoint="xla")
    assert xla.converged
    np.testing.assert_allclose(xla.comp, loop.comp, atol=1e-3)


# ---------------------------------------------------------------------------
# refinement budget exhaustion: warn + report, never silently exclude
# ---------------------------------------------------------------------------
CONTENDED_WL = ClusterWorkload(n_users=16, ops_per_user=2, get_fraction=0.5,
                               object_bytes=1 << 20, seed=7)


def test_exhausted_refine_budget_warns_and_flags_program():
    import warnings

    spec = small_spec()
    with pytest.warns(RuntimeWarning, match=r"max_refine=0"):
        res = Cluster(spec).run(CONTENDED_WL, max_refine=0)
    prog = res.compiled.program
    assert prog.order_stable is False and prog.exact is False
    assert prog.refine_used == 1
    # The warning names at least one FIFO pool that is still flapping.
    with pytest.warns(RuntimeWarning, match=r"unstable FIFO pools: \S"):
        Cluster(spec).run(CONTENDED_WL, max_refine=0)
    # Completions are still produced — reported, not dropped.
    assert len(res.comp) == res.compiled.graph.n
    assert np.all(np.isfinite(res.comp))
    # The default budget reaches the pop-order fixpoint on the same
    # contended (16 users/config) workload — and stays silent.
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message=".*order refinement.*")
        stable = Cluster(spec).run(CONTENDED_WL)
    assert stable.compiled.program.order_stable
    assert stable.converged


def test_plan_capacity_reports_order_unstable_configs():
    configs = [ClusterConfig(erasure(2, 1), "round-robin")]
    wl = dataclasses.replace(CONTENDED_WL, ops_per_user=1)
    with pytest.warns(RuntimeWarning, match="order refinement"):
        report = plan_capacity(configs, [16], workload=wl,
                               base_spec=small_spec(), slo_us=20e3,
                               degraded=False, max_refine=0)
    assert report.order_unstable == ("ec2+1/round-robin",)
    assert report.to_json()["order_unstable"] == ["ec2+1/round-robin"]
    # The unstable config's curve is still reported.
    assert [c.config.name for c in report.curves] == ["ec2+1/round-robin"]
    # With the default budget the same sweep is stable and the report
    # carries an empty listing.
    report = plan_capacity(configs, [16], workload=wl,
                           base_spec=small_spec(), slo_us=20e3,
                           degraded=False)
    assert report.order_unstable == ()


def test_cluster_cli_max_refine_flag(tmp_path, capsys):
    from repro.experiments import __main__ as cli

    with pytest.warns(RuntimeWarning, match="order refinement"):
        rc = cli.main(["cluster", "--schemes", "ec2+1", "--policies",
                       "round-robin", "--users", "16", "--objects-per-user",
                       "1", "--servers", "6", "--no-degraded",
                       "--max-refine", "0", "--out", str(tmp_path)])
    assert rc == 0
    err = capsys.readouterr().err
    assert "refinement budget exhausted" in err
    data = json.loads((tmp_path / "capacity.json").read_text())
    assert data["order_unstable"] == ["ec2+1/round-robin"]
