"""The paper's 13 observations + 5 recommendations, asserted against the
model.  Anchors marked 'exact' must round-trip the paper's number;
'trend' assertions check the direction/magnitude class."""
import numpy as np
import pytest

from repro.core import (
    KiB, MiB, LatencyModel, LBAFormat, OpType, Stack, ThroughputModel,
    simulate,
)
from repro.core import calibration as C
from repro.core.workloads import reset_interference

lm = LatencyModel()
tm = ThroughputModel()


# -- Obs#1: LBA format matters -------------------------------------------------
def test_obs1_lba_format_penalty():
    for op in (OpType.WRITE, OpType.APPEND):
        l512 = float(lm.io_service_us(op, 512, fmt=LBAFormat.LBA_512))
        l4k = float(lm.io_service_us(op, 4 * KiB, fmt=LBAFormat.LBA_4K))
        assert l512 > l4k
        assert l512 / l4k <= 2.1   # "as much as a factor of two"


# -- Obs#2: SPDK lowest latency (exact anchors) ----------------------------------
def test_obs2_stack_latencies_exact():
    assert float(lm.io_service_us(OpType.WRITE, 4 * KiB, Stack.SPDK)) == \
        pytest.approx(11.36, abs=0.01)
    assert float(lm.io_service_us(OpType.WRITE, 4 * KiB, Stack.KERNEL_NONE)) \
        == pytest.approx(12.62, abs=0.01)
    assert float(lm.io_service_us(OpType.WRITE, 4 * KiB,
                                  Stack.KERNEL_MQ_DEADLINE)) == \
        pytest.approx(14.47, abs=0.01)


# -- Obs#3: request-size dependence ---------------------------------------------
def test_obs3_throughput_vs_size():
    w4 = tm.steady_state(OpType.WRITE, 4 * KiB)
    a4 = tm.steady_state(OpType.APPEND, 4 * KiB)
    a8 = tm.steady_state(OpType.APPEND, 8 * KiB)
    assert w4.iops == pytest.approx(85_000, rel=0.05)
    assert a4.iops == pytest.approx(66_000, rel=0.02)
    assert a8.iops == pytest.approx(69_000, rel=0.05)
    # bytes-throughput highest for large requests
    w32 = tm.steady_state(OpType.WRITE, 32 * KiB)
    assert w32.bandwidth_bytes > w4.bandwidth_bytes * 3


# -- Obs#4: write < append (exact anchors) ---------------------------------------
def test_obs4_append_write_gap_exact():
    w = float(lm.io_service_us(OpType.WRITE, 4 * KiB))
    a = float(lm.io_service_us(OpType.APPEND, 8 * KiB))
    assert w == pytest.approx(11.36, abs=0.01)
    assert a == pytest.approx(14.02, abs=0.01)
    assert (a - w) / w == pytest.approx(0.2342, abs=0.005)


# -- Obs#5/#7: intra-zone scaling ------------------------------------------------
def test_obs5_obs7_intra_zone_beats_inter_zone():
    read128 = tm.steady_state(OpType.READ, 4 * KiB, qd=128)
    wr32 = tm.steady_state(OpType.WRITE, 4 * KiB, qd=32,
                           stack=Stack.KERNEL_MQ_DEADLINE)
    assert read128.iops == pytest.approx(424_000, rel=0.02)
    assert wr32.iops == pytest.approx(293_000, rel=0.02)
    inter = tm.steady_state(OpType.WRITE, 4 * KiB, zones=14)
    assert inter.iops == pytest.approx(186_000, rel=0.02)
    assert wr32.iops > inter.iops
    # read > write > append in a single zone (Obs#7)
    app = tm.steady_state(OpType.APPEND, 4 * KiB, qd=128)
    assert read128.iops > wr32.iops > app.iops


# -- Obs#6: append cap layout-agnostic -------------------------------------------
def test_obs6_append_agnostic():
    intra = tm.steady_state(OpType.APPEND, 4 * KiB, qd=4)
    inter = tm.steady_state(OpType.APPEND, 4 * KiB, zones=4)
    assert intra.iops == pytest.approx(132_000, rel=0.02)
    assert inter.iops == pytest.approx(intra.iops, rel=0.02)
    deep = tm.steady_state(OpType.APPEND, 4 * KiB, qd=64)
    assert deep.iops == pytest.approx(intra.iops, rel=0.02)


# -- Obs#8: >=8KiB reaches the device limit --------------------------------------
def test_obs8_large_requests_saturate():
    small = tm.steady_state(OpType.WRITE, 4 * KiB, zones=14)
    assert small.bandwidth_bytes / MiB == pytest.approx(726.74, rel=0.02)
    big = tm.steady_state(OpType.WRITE, 8 * KiB, zones=4)
    assert big.bandwidth_bytes / MiB == pytest.approx(1155, rel=0.02)


# -- Obs#9: open/close cheap; implicit == explicit -------------------------------
def test_obs9_open_close_costs():
    assert lm.open_us() == pytest.approx(9.56)
    assert lm.close_us() == pytest.approx(11.01)
    assert lm.implicit_open_penalty_us(OpType.WRITE) == pytest.approx(2.02)
    assert lm.implicit_open_penalty_us(OpType.APPEND) == pytest.approx(2.83)


# -- Obs#10: occupancy-dependent reset/finish ------------------------------------
def test_obs10_reset_finish_occupancy():
    assert float(lm.reset_us(0.5)) / 1e3 == pytest.approx(11.60, abs=0.05)
    assert float(lm.reset_us(1.0)) / 1e3 == pytest.approx(16.19, abs=0.05)
    assert float(lm.reset_us(0.5, was_finished=True)) == pytest.approx(
        float(lm.reset_us(0.5)) * (1 - 0.2658), rel=1e-6)
    assert float(lm.finish_us(0.001)) / 1e3 == pytest.approx(907.51, rel=0.01)
    assert float(lm.finish_us(1.0)) / 1e3 == pytest.approx(3.07, abs=0.01)
    occs = np.linspace(0.01, 0.99, 20)
    fin = np.asarray(lm.finish_us(occs))
    assert np.all(np.diff(fin) < 0)          # monotone decreasing
    rst = np.asarray(lm.reset_us(occs))
    assert np.all(np.diff(rst) > 0)          # monotone increasing


# -- Obs#11: stability anchors ----------------------------------------------------
def test_obs11_read_latency_under_pressure():
    _, p95_idle = tm.read_latency_under_write_pressure_us(0.0)
    assert p95_idle == pytest.approx(C.READONLY_READ_P95_US, rel=0.01)
    _, p95_full = tm.read_latency_under_write_pressure_us(1.0)
    assert p95_full / 1e3 == pytest.approx(98.04, rel=0.02)
    from repro.core import ConventionalSSD
    conv = ConventionalSSD().simulate_write_pressure(rate_mibs=1155.0)
    assert conv.read_lat_p95_us / 1e3 == pytest.approx(299.89, rel=0.05)
    assert conv.write_amplification > 1.0


# -- Obs#12/#13: reset interference ------------------------------------------------
def test_obs12_resets_do_not_disturb_io():
    tr = reset_interference(OpType.WRITE, n_resets=100)
    res = simulate(tr, seed=0, jitter=False)
    iomask = tr.op == OpType.WRITE
    io_svc = res.service[iomask]
    base = float(lm.io_service_us(OpType.WRITE, 4 * KiB))
    assert float(np.mean(io_svc)) == pytest.approx(base, rel=0.01)


def test_obs13_io_inflates_reset_p95():
    p95 = {}
    for io_op, label in ((None, "isolated"), (OpType.READ, "read"),
                         (OpType.WRITE, "write"), (OpType.APPEND, "append")):
        tr = reset_interference(io_op, n_resets=200)
        res = simulate(tr, seed=5)
        rmask = tr.op == OpType.RESET
        p95[label] = float(np.percentile(
            (res.complete - res.start)[rmask], 95)) / 1e3
    assert p95["isolated"] == pytest.approx(17.94, rel=0.05)
    assert p95["read"] == pytest.approx(28.00, rel=0.05)
    assert p95["write"] == pytest.approx(32.00, rel=0.05)
    assert p95["append"] == pytest.approx(31.48, rel=0.05)


# -- §IV: emulator fidelity ---------------------------------------------------------
def test_sec4_emulator_models():
    from repro.core.emulator_models import ALL_MODELS
    femu = ALL_MODELS["femu"]
    nvmev = ALL_MODELS["nvmevirt"]
    ours = ALL_MODELS["ours"]
    # FEMU: no latency model — orders of magnitude too fast
    assert float(np.asarray(femu.io_service_us(OpType.WRITE, 4 * KiB))) < 3.0
    # NVMeVirt: append == write (the §IV critique)
    assert float(np.asarray(nvmev.io_service_us(OpType.APPEND, 4 * KiB))) == \
        float(np.asarray(nvmev.io_service_us(OpType.WRITE, 4 * KiB)))
    # NVMeVirt: reset is static regardless of occupancy
    assert float(np.asarray(nvmev.reset_us(0.1))) == \
        float(np.asarray(nvmev.reset_us(1.0)))
    # ours: distinct append/write + occupancy-dependent reset
    assert float(np.asarray(ours.io_service_us(OpType.APPEND, 4 * KiB))) > \
        float(np.asarray(ours.io_service_us(OpType.WRITE, 4 * KiB)))
    assert float(np.asarray(ours.reset_us(1.0))) > \
        float(np.asarray(ours.reset_us(0.1)))
