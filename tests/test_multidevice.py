"""Multi-device tests run in subprocesses (jax device count is locked at
first init, so forced host-device pools need fresh processes)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # end-to-end suite: skipped by -m "not slow"

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """A 2x4-mesh sharded train step produces the same loss as 1 device."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
        from repro.configs import get_smoke_config
        from repro.optim import AdamWConfig
        from repro.train import TrainState, make_train_step, state_logical_axes, state_spec
        from repro.distributed import sharding as sh
        cfg = dataclasses.replace(get_smoke_config("tinyllama-1.1b"), remat="none")
        key = jax.random.PRNGKey(0)
        state = TrainState.create(cfg, key)
        toks = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
        step = make_train_step(cfg, AdamWConfig(warmup_steps=0))
        # single device
        s1, m1 = jax.jit(step)(state, {"tokens": toks})
        # sharded
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rules = sh.make_rules(data_axes=("data",))
        st_sh = sh.tree_shardings_for(state_spec(cfg), state_logical_axes(cfg), mesh, rules)
        b_sh = {"tokens": NamedSharding(mesh, PS("data"))}
        with mesh:
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state, {"tokens": toks})
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        # bf16 activations: sharded matmul reduction order shifts the loss
        # by O(1e-3) relative; the param check below is the strict gate.
        assert abs(l1 - l2) / l1 < 5e-3, (l1, l2)
        p1 = np.asarray(jax.tree.leaves(s1.params)[0], np.float32)
        p2 = np.asarray(jax.tree.leaves(s2.params)[0], np.float32)
        np.testing.assert_allclose(p1, p2, atol=2e-3)
        print("OK", l1, l2)
    """)
    assert "OK" in out


def test_seq_sharded_decode_matches_replicated():
    """Flash-decode style seq-sharded KV cache == replicated cache."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
        from repro.configs import get_smoke_config
        from repro import models as M
        cfg = get_smoke_config("qwen3-4b")
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        b, s = 4, 64
        cache = M.init_cache(cfg, b, s)
        tok = jax.random.randint(key, (b,), 0, cfg.vocab_size)
        lg0, _ = M.decode_step(cfg, params, cache, tok, jnp.int32(3))
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        c_sh = {"k": NamedSharding(mesh, PS(None, "data", None, "model")),
                "v": NamedSharding(mesh, PS(None, "data", None, "model"))}
        with mesh:
            fn = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t, jnp.int32(3)),
                         in_shardings=(None, c_sh, NamedSharding(mesh, PS("data"))))
            lg1, _ = fn(params, cache, tok)
        np.testing.assert_allclose(np.asarray(lg0, np.float32),
                                   np.asarray(lg1, np.float32), atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run driver end-to-end on an 8-device 2x4 mesh."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["REPRO_MESH_SHAPE"] = "2x4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    outdir = "/tmp/dryrun_pytest"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "tinyllama-1.1b", "--shape", "decode_32k", "--mesh", "single",
         "--mode", "full", "--out", outdir],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    with open(os.path.join(outdir, "tinyllama-1.1b_decode_32k_single.json")) as f:
        res = json.load(f)
    assert res["status"] == "ok"
    assert res["full"]["flops"] > 0
    assert res["full"]["collectives"]["count"]["all-reduce"] >= 0
