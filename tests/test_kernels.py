"""Per-kernel shape/dtype sweeps, asserted allclose against the ref.py
pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.array(RNG.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("b,hq,hkv,tq,tk,d", [
    (1, 4, 4, 128, 128, 64),
    (2, 8, 2, 100, 100, 64),
    (1, 4, 1, 64, 256, 128),
    (1, 2, 2, 1, 128, 64),        # decode-like single query
    (2, 4, 2, 37, 37, 32),        # ragged, non-multiple-of-block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, tq, tk, d, dtype):
    q, k, v = (_arr((b, hq, tq, d), dtype), _arr((b, hkv, tk, d), dtype),
               _arr((b, hkv, tk, d), dtype))
    out = ops.attention(q, k, v, impl="interpret")
    want = ref.attention_ref(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    q, k, v = _arr((1, 4, 128, 64)), _arr((1, 2, 128, 64)), _arr((1, 2, 128, 64))
    out = ops.attention(q, k, v, window=window, impl="interpret")
    want = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


def test_chunked_xla_attention_matches_dense():
    q, k, v = _arr((2, 4, 300, 64)), _arr((2, 2, 300, 64)), _arr((2, 2, 300, 64))
    out = ref.attention_xla_chunked(q, k, v, q_chunk=128)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("shape", [(4, 17, 256), (2, 128), (1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = _arr(shape, dtype)
    w = _arr((shape[-1],))
    out = ops.rmsnorm(x, w, impl="interpret")
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("b,t,d", [(2, 64, 32), (1, 300, 16), (3, 1024, 8)])
def test_linear_recurrence_sweep(b, t, d):
    a = jnp.array(RNG.uniform(0.6, 0.999, (b, t, d)), jnp.float32)
    x = _arr((b, t, d))
    out = ops.linear_recurrence(a, x, impl="interpret")
    want = ref.linear_recurrence_ref(a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("b,t,h,p,g,n,chunk", [
    (1, 128, 4, 32, 2, 64, 64),
    (2, 256, 2, 16, 1, 32, 128),
    (1, 64, 2, 64, 2, 128, 32),
])
def test_ssd_chunk_scan_sweep(b, t, h, p, g, n, chunk):
    x = _arr((b, t, h, p), scale=0.5)
    dt = jnp.array(RNG.uniform(0.001, 0.1, (b, t, h)), jnp.float32)
    A = jnp.array(-RNG.uniform(0.5, 2.0, h), jnp.float32)
    B = _arr((b, t, g, n), scale=0.3)
    C = _arr((b, t, g, n), scale=0.3)
    y, s = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, impl="interpret")
    yr, sr = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-3)


@pytest.mark.parametrize("n,block", [(7, 1024), (1000, 256), (4096, 512)])
def test_zns_event_scan_sweep(n, block):
    issue = jnp.array(np.sort(RNG.uniform(0, 1e5, n)), jnp.float32)
    svc = jnp.array(RNG.uniform(1, 50, n), jnp.float32)
    seg = jnp.array(RNG.uniform(size=n) < 0.05)
    seg = seg.at[0].set(True)
    from repro.kernels.zns_event_scan import zns_event_scan
    out = zns_event_scan(issue, svc, seg, block=block, interpret=True)
    want = ref.zns_event_scan_ref(issue, svc, seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-2)


def test_zns_event_scan_matches_numpy_engine_path():
    """engine.zone_sequential_completions numpy fallback == kernel."""
    from repro.core.engine import zone_sequential_completions
    n = 500
    issue = np.sort(RNG.uniform(0, 1e4, n))
    svc = RNG.uniform(1, 30, n)
    seg = RNG.uniform(size=n) < 0.1
    seg[0] = True
    a = zone_sequential_completions(issue, svc, seg, backend="numpy")
    b = zone_sequential_completions(issue, svc, seg, backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("bsz,n,block", [(1, 7, 256), (3, 1000, 256),
                                         (5, 2048, 512)])
def test_zns_event_scan_batched_sweep(bsz, n, block):
    """Batch grid dimension == vmap of the 1-D oracle, per device row."""
    issue = jnp.array(np.sort(RNG.uniform(0, 1e5, (bsz, n)), axis=1),
                      jnp.float32)
    svc = jnp.array(RNG.uniform(1, 50, (bsz, n)), jnp.float32)
    seg = jnp.array(RNG.uniform(size=(bsz, n)) < 0.05)
    seg = seg.at[:, 0].set(True)
    out = ops.zns_event_scan_batched(issue, svc, seg, impl="interpret")
    want = ref.zns_event_scan_batched_ref(issue, svc, seg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-2)
    # rows independent: each row equals its own 1-D kernel run
    for b in range(bsz):
        row = ops.zns_event_scan(issue[b], svc[b], seg[b], impl="interpret")
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(row),
                                   rtol=1e-5, atol=1e-2)


def test_zns_event_scan_batched_engine_dispatch():
    """engine.zone_sequential_completions_batched numpy == pallas paths."""
    from repro.core.engine import zone_sequential_completions_batched
    bsz, n = 4, 600
    issue = np.sort(RNG.uniform(0, 1e4, (bsz, n)), axis=1)
    svc = RNG.uniform(1, 30, (bsz, n))
    seg = RNG.uniform(size=(bsz, n)) < 0.1
    seg[:, 0] = True
    a = zone_sequential_completions_batched(issue, svc, seg, backend="numpy")
    b = zone_sequential_completions_batched(issue, svc, seg,
                                            backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-2)
