"""ZnsDevice session API: WorkloadSpec lowering, backend registry, and
event-vs-vectorized equivalence (including per-zone write serialization
and the Obs#12/#13 reset-interference couplings)."""
import numpy as np
import pytest

from repro.core import (
    KiB, MiB, ConvDevice, LatencyModel, OpType, RunResult, Stack, Trace,
    WorkloadSpec, ZnsDevice, available_backends, compute_service_times,
    register_backend, simulate, zone_sequential_completions,
)
from repro.core.workloads import reset_interference, reset_sweep


def _assert_equivalent(wl, *, jitter=False, seed=3, rtol=1e-9):
    dev = ZnsDevice()
    tr = wl.build() if isinstance(wl, WorkloadSpec) else wl
    ev = dev.run(tr, backend="event", seed=seed, jitter=jitter)
    vc = dev.run(tr, backend="vectorized", seed=seed, jitter=jitter)
    np.testing.assert_allclose(vc.sim.service, ev.sim.service, rtol=1e-12)
    np.testing.assert_allclose(vc.sim.complete, ev.sim.complete, rtol=rtol,
                               atol=1e-6)
    np.testing.assert_allclose(vc.sim.start, ev.sim.start, rtol=rtol,
                               atol=1e-6)
    return ev, vc


# -- backend equivalence --------------------------------------------------------
def test_equiv_intra_zone_write_serialization():
    ev, vc = _assert_equivalent(WorkloadSpec().writes(n=3000, qd=4, zone=7))
    # per-zone write serialization: intervals must not overlap
    s, c = np.sort(vc.sim.start), np.sort(vc.sim.complete)
    assert (s[1:] >= c[:-1] - 1e-6).all()


def test_equiv_inter_zone_writes():
    _assert_equivalent(WorkloadSpec().writes(n=3000, qd=1, nzones=8))


def test_equiv_mixed_read_write_append_reset():
    wl = (WorkloadSpec()
          .writes(n=1500, qd=4, zone=0)
          .reads(n=1500, qd=8, zone=100, nzones=50)
          .appends(n=1000, qd=2, zone=200)
          .resets(n=150, occupancy=1.0, nzones=64, io_ctx=OpType.READ))
    _assert_equivalent(wl)


def test_equiv_saturated_read_pool():
    _assert_equivalent(WorkloadSpec().reads(n=4000, qd=128))


def test_equiv_rate_limited_and_phased():
    wl = (WorkloadSpec()
          .writes(n=1000, size=128 * KiB, qd=8, zone=0, nzones=8,
                  rate_bytes_per_s=200 * MiB)
          .phase(at_us=5e5)
          .reads(n=1000, qd=4, zone=100, nzones=64))
    _assert_equivalent(wl)


def test_equiv_with_jitter_same_seed():
    wl = (WorkloadSpec()
          .resets(n=100, occupancy=1.0, nzones=50, io_ctx=OpType.WRITE)
          .writes(n=2000, qd=4, zone=100))
    _assert_equivalent(wl, jitter=True, seed=11)


def test_equiv_obs13_reset_inflation_applied():
    dev = ZnsDevice()
    quiet = dev.run(WorkloadSpec().resets(n=50, occupancy=1.0, nzones=50),
                    backend="vectorized", jitter=False)
    loud = dev.run(WorkloadSpec().resets(n=50, occupancy=1.0, nzones=50,
                                         io_ctx=OpType.WRITE),
                   backend="vectorized", jitter=False)
    ratio = (loud.latency_stats(OpType.RESET).mean_us
             / quiet.latency_stats(OpType.RESET).mean_us)
    assert ratio == pytest.approx(1.7842, rel=1e-3)   # Obs#13 anchor


def test_equiv_obs12_resets_do_not_delay_io():
    # same I/O stream with and without concurrent resets: I/O completions
    # are identical (structural Obs#12) on both backends.
    io = WorkloadSpec().writes(n=1500, qd=4, zone=100)
    both = WorkloadSpec().resets(n=100, occupancy=1.0, nzones=50,
                                 thread=9).writes(n=1500, qd=4, zone=100)
    for backend in ("event", "vectorized"):
        dev = ZnsDevice()
        a = dev.run(io, backend=backend, jitter=False)
        b = dev.run(both, backend=backend, jitter=False)
        wmask = b.trace.op == OpType.WRITE
        np.testing.assert_allclose(b.sim.complete[wmask], a.sim.complete,
                                   rtol=1e-12)


# -- workload lowering ----------------------------------------------------------
def test_workload_threads_auto_assigned():
    tr = (WorkloadSpec().writes(n=10).reads(n=10).appends(n=10)).build()
    assert set(np.unique(tr.thread)) == {0, 1, 2}


def test_workload_thread_pinning_respected():
    tr = (WorkloadSpec().writes(n=10, thread=5).reads(n=10)).build()
    assert set(np.unique(tr.thread)) == {0, 5}


def test_workload_stack_format_applied():
    tr = (WorkloadSpec().writes(n=4)
          .on_stack(Stack.KERNEL_MQ_DEADLINE)).build()
    assert tr.stack == Stack.KERNEL_MQ_DEADLINE


def test_workload_reset_sweep_matches_generator():
    occs = (0.0, 0.25, 0.5, 1.0)
    a = reset_sweep(occs, finished_first=True, n_per_level=10)
    b = (WorkloadSpec()
         .reset_sweep(occs, n_per_level=10, finish_first=True)).build()
    for f in ("op", "zone", "size", "issue", "occupancy", "was_finished"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))


def test_workload_empty_rejected():
    with pytest.raises(ValueError):
        WorkloadSpec().build()


# -- facade + registry ----------------------------------------------------------
def test_device_run_accepts_trace_and_spec():
    dev = ZnsDevice()
    res = dev.run(reset_interference(None, n_resets=20), backend="event")
    assert isinstance(res, RunResult)
    assert res.backend == "event"
    assert len(res) == 20


def test_device_auto_backend_threshold():
    dev = ZnsDevice()
    small = dev.run(WorkloadSpec().writes(n=64), backend="auto")
    assert small.backend == "event"


def test_unknown_backend_raises():
    dev = ZnsDevice()
    with pytest.raises(KeyError):
        dev.run(WorkloadSpec().writes(n=4), backend="nope")


def test_register_custom_backend():
    @register_backend("instant-test")
    def _instant(trace, spec, lat, *, seed=0, jitter=True, **_):
        svc = compute_service_times(trace, lat, seed=seed, jitter=jitter)
        issue = np.asarray(trace.issue, dtype=np.float64)
        from repro.core import SimResult
        return SimResult(start=issue, complete=issue + svc, service=svc)

    assert "instant-test" in available_backends()
    res = ZnsDevice().run(WorkloadSpec().writes(n=8), backend="instant-test")
    np.testing.assert_allclose(res.sim.complete,
                               res.trace.issue + res.sim.service)


def test_deprecated_simulate_matches_event_backend():
    tr = WorkloadSpec().writes(n=200, qd=2).build()
    old = simulate(tr, seed=5)
    new = ZnsDevice().run(tr, backend="event", seed=5)
    np.testing.assert_array_equal(old.complete, new.sim.complete)


def test_steady_state_facade_matches_anchor():
    res = ZnsDevice().steady_state(OpType.READ, 4 * KiB, qd=128)
    assert res.iops == pytest.approx(424_000, rel=0.02)


def test_run_result_metrics_shape():
    res = ZnsDevice().run(WorkloadSpec().writes(n=500, qd=4), jitter=False)
    st = res.latency_stats(OpType.WRITE)
    assert st.n == 500 and st.p99_us >= st.p50_us > 0
    assert res.iops > 0 and res.bandwidth_bytes > 0
    assert OpType.WRITE in res.per_op_stats()


def test_run_result_stats_absent_op_raises():
    res = ZnsDevice().run(WorkloadSpec().writes(n=10), jitter=False)
    with pytest.raises(ValueError, match="no READ requests"):
        res.latency_stats(OpType.READ)


def test_conv_device_shares_pressure_interface():
    conv = ConvDevice().run_write_pressure(rate_mibs=1155.0, duration_s=10)
    zns = ZnsDevice().run_write_pressure(rate_mibs=1155.0, duration_s=10)
    assert conv.write_cv > 5 * zns.write_cv       # Fig. 6: GC sawtooth
    assert conv.read_lat_p95_us > zns.read_lat_p95_us  # Obs#11


# -- scan kernel dispatch --------------------------------------------------------
def test_scan_numpy_matches_python_oracle():
    rng = np.random.default_rng(0)
    n = 4097
    issue = np.sort(rng.uniform(0, 1e6, n))
    svc = rng.uniform(5, 5000, n)
    seg = rng.uniform(size=n) < 0.01
    seg[0] = True
    out_np = zone_sequential_completions(issue, svc, seg, backend="numpy")
    out_py = zone_sequential_completions(issue, svc, seg, backend="python")
    np.testing.assert_allclose(out_np, out_py, rtol=1e-12)


# -- backend registry hygiene ----------------------------------------------------
def test_register_backend_collision_warns_and_unregister_roundtrip():
    from repro.core import unregister_backend

    def impl_a(trace, spec, lat, **kw):
        raise NotImplementedError

    def impl_b(trace, spec, lat, **kw):
        raise NotImplementedError

    register_backend("collide-test", impl_a)
    try:
        with pytest.warns(RuntimeWarning, match="already registered"):
            register_backend("collide-test", impl_b)
        # replace=True and same-function re-registration stay silent
        import warnings as _warnings
        with _warnings.catch_warnings():
            _warnings.simplefilter("error")
            register_backend("collide-test", impl_a, replace=True)
            register_backend("collide-test", impl_a)
        assert "collide-test" in available_backends()
    finally:
        unregister_backend("collide-test")
    assert "collide-test" not in available_backends()
    unregister_backend("collide-test")            # idempotent
    with pytest.raises(KeyError, match="unknown backend"):
        ZnsDevice().run(WorkloadSpec().writes(n=4), backend="collide-test")


def test_register_backend_decorator_collision_warns():
    from repro.core import unregister_backend

    @register_backend("collide-deco")
    def first(trace, spec, lat, **kw):
        raise NotImplementedError

    try:
        with pytest.warns(RuntimeWarning, match="already registered"):
            @register_backend("collide-deco")
            def second(trace, spec, lat, **kw):
                raise NotImplementedError
    finally:
        unregister_backend("collide-deco")


# -- metric-extractor registry ---------------------------------------------------
def test_metric_registry_roundtrip_and_summary():
    from repro.core import (available_metrics, extract_metrics,
                            register_metric, unregister_metric)

    res = ZnsDevice().run(WorkloadSpec().writes(n=32), backend="event",
                          jitter=False)
    base = res.summary()
    assert base["n_requests"] == 32.0
    assert base["iops"] > 0 and base["lat_p99_us"] >= base["lat_p50_us"]

    register_metric("answer", lambda r: 42.0)
    try:
        assert res.summary(["answer"]) == {"answer": 42.0}
        with pytest.warns(RuntimeWarning, match="already registered"):
            register_metric("answer", lambda r: 43.0)
    finally:
        unregister_metric("answer")
    assert "answer" not in available_metrics()
    with pytest.raises(KeyError, match="unknown metric"):
        extract_metrics(res, ["answer"])


def test_metrics_safe_on_empty_runs():
    from repro.core import DeviceFleet
    fleet = DeviceFleet.homogeneous(3)
    res = fleet.run(WorkloadSpec().writes(n=1), policy="split",
                    backend="event", jitter=False)
    empty = res[2]
    assert len(empty) == 0
    assert empty.iops == 0.0 and empty.bandwidth_bytes == 0.0
    assert empty.summary(["iops", "lat_mean_us", "makespan_us"]) == \
        {"iops": 0.0, "lat_mean_us": 0.0, "makespan_us": 0.0}
