"""Ring attention (shard_map sequence parallelism) vs dense oracle."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_ring_attention_matches_dense_and_integrates():
    out = _run("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.ring_attention import ring_attention
        from repro.kernels import ref
        rng = np.random.default_rng(0)
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        for (b, hq, hkv, s, d) in [(2, 4, 2, 64, 32), (2, 8, 1, 128, 16)]:
            q = jnp.array(rng.standard_normal((b, hq, s, d)), jnp.float32)
            k = jnp.array(rng.standard_normal((b, hkv, s, d)), jnp.float32)
            v = jnp.array(rng.standard_normal((b, hkv, s, d)), jnp.float32)
            with mesh:
                out = ring_attention(mesh, q, k, v, causal=True)
            want = ref.attention_ref(q, k, v, causal=True)
            err = float(jnp.max(jnp.abs(out - want)))
            assert err < 2e-5, err
        # model-level integration (flagged) == baseline forward
        from repro.configs import get_smoke_config
        from repro import models as M
        from repro.distributed import ctx as dctx
        from repro.distributed import sharding as sh
        cfg0 = get_smoke_config("qwen3-4b")
        cfg1 = dataclasses.replace(cfg0, ring_attention=True)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg0, key)
        toks = jax.random.randint(key, (4, 64), 0, cfg0.vocab_size)
        l0, _ = M.forward(cfg0, params, toks)
        rules = sh.make_rules(data_axes=("data",))
        with mesh, dctx.axis_rules(mesh, rules):
            l1, _ = jax.jit(lambda p, t: M.forward(cfg1, p, t))(params, toks)
        err = float(jnp.max(jnp.abs(l0.astype(jnp.float32)
                                    - l1.astype(jnp.float32))))
        assert err < 0.05, err
        print("OK")
    """)
    assert "OK" in out
