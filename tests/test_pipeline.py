"""GPipe pipeline: forward equals sequential stack; grads flow."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_gpipe_matches_sequential_and_differentiates():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.distributed.pipeline import (
            gpipe, stack_stage_fn, stages_from_stack)
        rng = np.random.default_rng(0)
        L, D, M, MB = 8, 16, 6, 4
        ws = jnp.array(rng.standard_normal((L, D, D)) * 0.2, jnp.float32)
        x = jnp.array(rng.standard_normal((M, MB, D)), jnp.float32)

        def layer(w, h):
            return jnp.tanh(h @ w)

        # sequential oracle
        def seq(ws, xmb):
            h = xmb
            for i in range(L):
                h = layer(ws[i], h)
            return h
        want = jnp.stack([seq(ws, x[i]) for i in range(M)])

        mesh = Mesh(np.array(jax.devices()).reshape(4,), ("pipe",))
        stages = stages_from_stack(ws, 4)
        stage_fn = stack_stage_fn(layer)
        with mesh:
            got = gpipe(mesh, stage_fn, stages, x)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, err

        # gradient flows through the pipeline (vs sequential grad)
        def loss_pipe(stages):
            with mesh:
                y = gpipe(mesh, stage_fn, stages, x)
            return jnp.sum(y ** 2)
        def loss_seq(ws):
            return jnp.sum(jnp.stack([seq(ws, x[i]) for i in range(M)]) ** 2)
        g_pipe = jax.grad(loss_pipe)(stages)
        g_seq = jax.grad(loss_seq)(ws).reshape(4, 2, D, D)
        gerr = float(jnp.max(jnp.abs(g_pipe - g_seq)))
        assert gerr < 1e-4, gerr
        print("OK", err, gerr)
    """)
    assert "OK" in out
