"""Conformance-style zone state-machine suite (cf. the pynvme ZNS
conformance checks): write at a non-WP offset, append beyond zone
capacity, open-limit exceeded, reset/finish from every state, read
across the zone boundary — asserting the ZoneError taxonomy on the
imperative manager, the vectorized transition table, and (for the
trace-level flows) both simulation backends via the differential
harness in ``repro.host.conformance``."""
import numpy as np
import pytest

from repro.core import (
    KiB, OpType, WorkloadSpec, ZnsDevice, ZoneError, ZoneManager, ZoneState,
)
from repro.core.state_machine import TRANSITION_TABLE, transition_array
from repro.host.conformance import differential_check, replay_trace, table_ok
from strategies import SMALL_SPEC

BACKENDS = ("event", "vectorized")


def _full_zone(zm, z):
    zm.write(z, SMALL_SPEC.zone_cap_bytes)
    assert zm.state(z) == ZoneState.FULL


# ---------------------------------------------------------------------------
# Write / append addressing and capacity
# ---------------------------------------------------------------------------
def test_write_at_non_wp_offset_rejected():
    zm = ZoneManager(SMALL_SPEC)
    zm.write(1, 8 * KiB, at=0)                      # at == wp: fine
    with pytest.raises(ZoneError, match="invalid write"):
        zm.write(1, 4 * KiB, at=0)                  # stale offset
    with pytest.raises(ZoneError, match="invalid write"):
        zm.write(1, 4 * KiB, at=64 * KiB)           # ahead of wp
    zm.write(1, 4 * KiB, at=8 * KiB)                # exact wp again


def test_append_ignores_offset_and_returns_lba():
    zm = ZoneManager(SMALL_SPEC)
    lba = zm.write(2, 4 * KiB, append=True, at=999)   # offset ignored
    assert lba == SMALL_SPEC.zone_start(2)


def test_append_beyond_zone_capacity_rejected():
    zm = ZoneManager(SMALL_SPEC)
    cap = SMALL_SPEC.zone_cap_bytes
    zm.write(0, cap - 4 * KiB, append=True)
    with pytest.raises(ZoneError, match="overflow"):
        zm.write(0, 8 * KiB, append=True)
    zm.write(0, 4 * KiB, append=True)               # exact fill is legal
    assert zm.state(0) == ZoneState.FULL
    with pytest.raises(ZoneError, match="FULL"):
        zm.write(0, 4 * KiB, append=True)


def test_open_limit_exceeded_taxonomy():
    zm = ZoneManager(SMALL_SPEC)
    for z in range(SMALL_SPEC.max_open_zones):
        zm.open(z)
    with pytest.raises(ZoneError, match="max open zone limit"):
        zm.open(SMALL_SPEC.max_open_zones)
    with pytest.raises(ZoneError, match="max open zone limit"):
        zm.write(SMALL_SPEC.max_open_zones, 4 * KiB)   # implicit open too
    # closing keeps the zone active: the active limit eventually bites
    for z in range(SMALL_SPEC.max_open_zones):
        zm.close(z)
    for z in range(SMALL_SPEC.max_open_zones, SMALL_SPEC.max_active_zones):
        zm.open(z)
    with pytest.raises(ZoneError, match="max active zone limit"):
        zm.write(SMALL_SPEC.max_active_zones + 1, 4 * KiB)


def test_read_across_zone_boundary_rejected():
    zm = ZoneManager(SMALL_SPEC)
    zm.read(0, 0, SMALL_SPEC.zone_size_bytes)           # whole zone: fine
    with pytest.raises(ZoneError, match="boundary"):
        zm.read(0, SMALL_SPEC.zone_size_bytes - 4 * KiB, 8 * KiB)
    with pytest.raises(ZoneError, match="boundary"):
        zm.read(0, -1, 4 * KiB)
    with pytest.raises(ZoneError, match="<= 0"):
        zm.read(0, 0, 0)


# ---------------------------------------------------------------------------
# Reset / finish from every state (manager vs vectorized table agree)
# ---------------------------------------------------------------------------
def _zone_in_state(state: ZoneState) -> ZoneManager:
    zm = ZoneManager(SMALL_SPEC)
    if state == ZoneState.IMPLICIT_OPEN:
        zm.write(0, 4 * KiB)
    elif state == ZoneState.EXPLICIT_OPEN:
        zm.open(0)
    elif state == ZoneState.CLOSED:
        zm.write(0, 4 * KiB)
        zm.close(0)
    elif state == ZoneState.FULL:
        _full_zone(zm, 0)
    assert zm.state(0) == state
    return zm


_REACHABLE = (ZoneState.EMPTY, ZoneState.IMPLICIT_OPEN,
              ZoneState.EXPLICIT_OPEN, ZoneState.CLOSED, ZoneState.FULL)


@pytest.mark.parametrize("state", _REACHABLE, ids=lambda s: s.name)
def test_reset_from_every_state(state):
    zm = _zone_in_state(state)
    occ, _ = zm.reset(0)                         # legal from all of these
    assert zm.state(0) == ZoneState.EMPTY
    assert zm.write_pointer(0) == 0
    # the vectorized table agrees
    nxt, ok = transition_array(np.array([int(state)]),
                               np.array([int(OpType.RESET)]))
    assert bool(np.asarray(ok)[0])
    assert int(np.asarray(nxt)[0]) == int(ZoneState.EMPTY)


@pytest.mark.parametrize("state", _REACHABLE, ids=lambda s: s.name)
def test_finish_from_every_state(state):
    zm = _zone_in_state(state)
    legal = state in (ZoneState.IMPLICIT_OPEN, ZoneState.EXPLICIT_OPEN,
                      ZoneState.CLOSED)
    nxt, ok = transition_array(np.array([int(state)]),
                               np.array([int(OpType.FINISH)]))
    assert bool(np.asarray(ok)[0]) == legal      # table matches manager
    if legal:
        zm.finish(0)
        assert zm.state(0) == ZoneState.FULL
        assert zm.write_pointer(0) == SMALL_SPEC.zone_cap_bytes
        assert int(np.asarray(nxt)[0]) == int(ZoneState.FULL)
    else:
        with pytest.raises(ZoneError, match="not permitted"):
            zm.finish(0)


def test_transition_table_rejects_offline_everything():
    off = int(ZoneState.OFFLINE)
    assert (TRANSITION_TABLE[off] == -1).all()


# ---------------------------------------------------------------------------
# Trace-level conformance: differential harness + both backends
# ---------------------------------------------------------------------------
def _conformance_workload() -> WorkloadSpec:
    """A legality gauntlet: fills, overflow attempt, open-limit breach,
    finish/reset cycling, mixed reads."""
    cap = SMALL_SPEC.zone_cap_bytes
    return (WorkloadSpec()
            .appends(n=3, size=cap // 2, qd=1, zone=0)       # 3rd overflows?
            .writes(n=2, size=4 * KiB, qd=1, zone=1)
            .opens(n=SMALL_SPEC.max_open_zones + 2, zone=2,
                   nzones=SMALL_SPEC.max_open_zones + 2)     # breaches limit
            .finishes(n=1, occupancy=0.1, zone=1)
            .resets(n=2, occupancy=1.0, zone=1)
            .reads(n=6, size=4 * KiB, qd=2, zone=0, nzones=3))


def test_differential_manager_vs_table_consistent():
    rep = differential_check(_conformance_workload(), SMALL_SPEC)
    # table rejections are a subset of manager rejections, and every
    # manager-only rejection is a pointer/capacity/limit concern
    assert rep["consistent"], rep["unexplained_manager_rejections"]
    assert len(rep["violations"]) > 0           # the gauntlet does violate
    kinds = " ".join(v.error for v in rep["violations"])
    assert "limit" in kinds                     # open-limit breach seen


def test_replay_collects_taxonomy_not_exceptions():
    ok, violations = replay_trace(_conformance_workload(), SMALL_SPEC)
    assert ok.dtype == bool and (~ok).sum() == len(violations)
    for v in violations:
        assert isinstance(v.op, OpType) and v.error


def test_zero_size_write_rejected_by_both_semantics():
    # Review regression: a size-0 WRITE must be rejected by the manager
    # replay AND the table replay, keeping the differential two-sided.
    import numpy as np
    from repro.core import Trace
    tr = Trace.build(op=[int(OpType.WRITE)], zone=[0], size=[0],
                     issue=[0.0])
    ok_zm, violations = replay_trace(tr, SMALL_SPEC)
    assert not ok_zm[0] and "<= 0 bytes" in violations[0].error
    assert not table_ok(tr, SMALL_SPEC)[0]
    assert differential_check(tr, SMALL_SPEC)["consistent"]


def test_table_ok_tracks_capacity_fill():
    cap = SMALL_SPEC.zone_cap_bytes
    wl = WorkloadSpec().appends(n=3, size=cap // 2, qd=1, zone=0)
    ok = table_ok(wl, SMALL_SPEC)
    # two half-cap appends fill the zone; the third must bounce
    assert ok.tolist() == [True, True, False]
    ok_pure = table_ok(wl, SMALL_SPEC, track_capacity=False)
    assert ok_pure.all()                        # pure table can't see wp


@pytest.mark.parametrize("backend", BACKENDS)
def test_legal_conformance_flows_simulate_on_both_backends(backend):
    """The *legal* subset of the state-machine cycling runs through both
    engines with identical service semantics (same seed, jitter off)."""
    wl = (WorkloadSpec()
          .appends(n=8, size=64 * KiB, qd=2, zone=0)
          .writes(n=8, size=4 * KiB, qd=1, zone=1)
          .finishes(n=1, occupancy=0.5, zone=1)
          .resets(n=4, occupancy=1.0, zone=0, nzones=4)
          .reads(n=12, size=4 * KiB, qd=4, zone=0, nzones=4))
    ok, violations = replay_trace(wl, SMALL_SPEC)
    assert ok.all(), violations                 # flow is fully legal
    dev = ZnsDevice(SMALL_SPEC)
    res = dev.run(wl, backend=backend, jitter=False)
    assert res.backend == backend
    assert (res.sim.complete >= res.sim.start).all()
    assert len(res) == len(wl.build())


def test_both_backends_agree_on_conformance_flow():
    wl = (WorkloadSpec()
          .appends(n=16, size=64 * KiB, qd=2, zone=0)
          .resets(n=4, occupancy=1.0, zone=0, nzones=4,
                  io_ctx=OpType.APPEND)
          .reads(n=16, size=4 * KiB, qd=4, zone=0, nzones=4))
    dev = ZnsDevice(SMALL_SPEC)
    ev = dev.run(wl, backend="event", jitter=False)
    vec = dev.run(wl, backend="vectorized", jitter=False)
    np.testing.assert_allclose(ev.sim.complete, vec.sim.complete,
                               rtol=1e-9, atol=1e-6)
