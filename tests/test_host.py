"""Host storage-stack layer: allocator policies (placement invariants,
limit respect, fill-don't-finish), reclaim scheduling (Obs#13 charging,
WA accounting), the LogStructuredVolume facade, scenario registry, and
the fleet-batched policy comparison."""
import warnings

import numpy as np
import pytest

from repro.core import (
    KiB, MiB, OpType, WorkloadSpec, ZnsDevice, ZoneError, ZoneState,
    ZNSDeviceSpec,
)
from repro.host import (
    Extent, HOST_SCENARIO_SPEC, LogStructuredVolume, ReclaimScheduler,
    ZoneAllocator, available_placement_policies, available_scenarios,
    build_scenario, compare_policies, rank_policies,
    register_placement_policy, register_scenario, unregister_placement_policy,
    unregister_scenario,
)
from strategies import HAVE_HYPOTHESIS, SMALL_SPEC

POLICIES = ("greedy-open", "striped", "lifetime-binned")


# ---------------------------------------------------------------------------
# ZoneAllocator
# ---------------------------------------------------------------------------
def test_builtin_policies_registered():
    assert set(POLICIES) <= set(available_placement_policies())


@pytest.mark.parametrize("policy", POLICIES)
def test_bytes_placed_equals_bytes_requested(policy):
    alloc = ZoneAllocator(SMALL_SPEC, policy=policy)
    for nbytes in (1, 4 * KiB, SMALL_SPEC.zone_cap_bytes,
                   int(2.5 * SMALL_SPEC.zone_cap_bytes)):
        extents = alloc.allocate(nbytes, stream=1, lifetime=0)
        assert sum(e.nbytes for e in extents) == nbytes
        for e in extents:
            assert 0 <= e.offset and e.end <= SMALL_SPEC.zone_cap_bytes


@pytest.mark.parametrize("policy", POLICIES)
def test_limits_never_exceeded(policy):
    alloc = ZoneAllocator(SMALL_SPEC, policy=policy, stripe_width=8,
                          lifetime_bins=8)
    # many small allocations across many streams/lifetimes
    for i in range(40):
        alloc.allocate(96 * KiB, stream=i % 5, lifetime=i % 8)
        assert alloc.open_count <= SMALL_SPEC.max_open_zones
        assert alloc.active_count <= SMALL_SPEC.max_active_zones


def test_greedy_open_fills_partial_zone_first():
    alloc = ZoneAllocator(SMALL_SPEC, policy="greedy-open")
    first = alloc.allocate(SMALL_SPEC.zone_cap_bytes // 2)
    second = alloc.allocate(SMALL_SPEC.zone_cap_bytes // 4)
    assert second[0].zone == first[0].zone            # R3: reuse, don't open
    assert second[0].offset == first[0].end
    # filling to cap yields FULL, never a FINISH
    alloc.allocate(SMALL_SPEC.zone_cap_bytes)
    assert alloc.zm.state(first[0].zone) == ZoneState.FULL
    assert not alloc.zm.zones[first[0].zone].was_finished


def test_striped_policy_rotates_zones():
    alloc = ZoneAllocator(SMALL_SPEC, policy="striped",
                          stripe_bytes=16 * KiB, stripe_width=3)
    extents = alloc.allocate(96 * KiB)
    zones = [e.zone for e in extents]
    assert len(set(zones)) == 3                       # spread over the ring
    assert all(e.nbytes <= 16 * KiB for e in extents)


def test_lifetime_binned_separates_lifetimes():
    alloc = ZoneAllocator(SMALL_SPEC, policy="lifetime-binned",
                          lifetime_bins=4)
    a = alloc.allocate(64 * KiB, lifetime=0)
    b = alloc.allocate(64 * KiB, lifetime=1)
    a2 = alloc.allocate(64 * KiB, lifetime=0)
    assert a[0].zone != b[0].zone                     # bins get own zones
    assert a2[0].zone == a[0].zone                    # bin affinity sticks


def test_lifetime_binned_respects_limits_with_many_bins():
    spec = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                         num_zones=32, max_open_zones=2, max_active_zones=2)
    alloc = ZoneAllocator(spec, policy="lifetime-binned", lifetime_bins=8)
    for lt in range(8):
        alloc.allocate(32 * KiB, lifetime=lt)
        assert alloc.open_count <= spec.max_open_zones
        assert alloc.active_count <= spec.max_active_zones


def test_reserved_zones_never_used():
    alloc = ZoneAllocator(SMALL_SPEC, policy="greedy-open", reserved=(0, 1))
    extents = alloc.allocate(3 * SMALL_SPEC.zone_cap_bytes)
    assert all(e.zone >= 2 for e in extents)


def test_device_full_raises_zone_error():
    spec = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                         num_zones=4, max_open_zones=2, max_active_zones=2)
    alloc = ZoneAllocator(spec, policy="greedy-open")
    alloc.allocate(4 * spec.zone_cap_bytes)           # fill everything
    with pytest.raises(ZoneError, match="device full"):
        alloc.allocate(4 * KiB)


def test_commit_rejects_stale_plans():
    alloc = ZoneAllocator(SMALL_SPEC)
    plan = alloc.plan(8 * KiB)
    alloc.allocate(4 * KiB)                           # moves the wp
    with pytest.raises(ZoneError, match="stale plan"):
        alloc.commit(plan)


def test_register_placement_policy_collision_warns():
    def fake(alloc, view, hint, remaining):
        raise AssertionError("never called")
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            register_placement_policy("collide-pol", fake)
            assert not w
            register_placement_policy("collide-pol",
                                      lambda *a, **k: None)
            assert len(w) == 1 and "already registered" in str(w[0].message)
    finally:
        unregister_placement_policy("collide-pol")
    assert "collide-pol" not in available_placement_policies()


# ---------------------------------------------------------------------------
# ReclaimScheduler
# ---------------------------------------------------------------------------
def _device():
    return ZnsDevice(SMALL_SPEC)


def test_reclaim_charges_obs13_inflation():
    dev = _device()
    dev.zones.write(0, SMALL_SPEC.zone_cap_bytes)
    quiet = ReclaimScheduler(ZnsDevice(SMALL_SPEC), io_ctx=OpType.APPEND)
    quiet.zm.write(0, SMALL_SPEC.zone_cap_bytes)
    loud = ReclaimScheduler(dev, io_ctx=OpType.APPEND)
    quiet.schedule([0]); loud.schedule([0])
    iso = quiet.drain(concurrent_io=False)
    conc = loud.drain(concurrent_io=True)
    infl = float(dev.lat.reset_inflation([OpType.APPEND]))
    assert infl > 1.5                                  # Obs#13: +78%-class
    assert conc.seconds == pytest.approx(iso.seconds * infl, rel=1e-9)
    assert conc.write_amplification == 1.0             # pure reset, no moves


def test_reclaim_relocation_accounts_write_amplification():
    dev = _device()
    alloc = ZoneAllocator(zones=dev.zones, policy="greedy-open")
    sched = ReclaimScheduler(dev, allocator=alloc, io_ctx=OpType.APPEND,
                             relocation_stripe=64 * KiB)
    ext = alloc.allocate(SMALL_SPEC.zone_cap_bytes)    # zone full
    sched.account(ext)
    victim = ext[0].zone
    sched.invalidate([Extent(victim, 0, SMALL_SPEC.zone_cap_bytes // 2)])
    sched.schedule([victim])
    rep = sched.drain()
    assert rep.zones_reset == 1
    assert rep.relocated_bytes == SMALL_SPEC.zone_cap_bytes // 2
    assert rep.write_amplification == pytest.approx(1.5, rel=1e-6)
    assert rep.reclaim_mibs > 0


def test_pick_victims_prefers_least_valid():
    dev = _device()
    sched = ReclaimScheduler(dev)
    for z, frac in ((0, 1.0), (1, 1.0), (2, 1.0)):
        dev.zones.write(z, int(SMALL_SPEC.zone_cap_bytes * frac))
    sched.account([Extent(0, 0, SMALL_SPEC.zone_cap_bytes)])
    sched.account([Extent(2, 0, 4 * KiB)])
    # zone 1 holds no valid bytes, zone 2 a little, zone 0 everything
    assert sched.pick_victims(2) == [1, 2]
    assert sched.backlog == [1, 2]
    sched.schedule([1])                                # dedup
    assert sched.backlog == [1, 2]


def test_scheduled_zones_frozen_out_of_placement():
    dev = _device()
    alloc = ZoneAllocator(zones=dev.zones, policy="greedy-open")
    sched = ReclaimScheduler(dev, allocator=alloc)
    ext = alloc.allocate(4 * KiB)
    z = ext[0].zone
    sched.schedule([z])
    assert alloc.plan(4 * KiB)[0].zone != z            # frozen
    sched.drain()
    assert alloc.plan(4 * KiB)[0].zone == z            # thawed after reset


def test_reclaim_workload_compiles_resets_with_io_ctx():
    dev = _device()
    sched = ReclaimScheduler(dev, io_ctx=OpType.WRITE)
    dev.zones.write(3, SMALL_SPEC.zone_cap_bytes // 2)
    sched.schedule([3])
    wl = sched.reclaim_workload()
    tr = wl.build()
    assert (tr.op == int(OpType.RESET)).sum() == 1
    assert tr.occupancy[0] == pytest.approx(0.5)
    assert tr.io_ctx[0] == int(OpType.WRITE)
    assert sched.backlog == [3]                        # compile != drain


# ---------------------------------------------------------------------------
# LogStructuredVolume
# ---------------------------------------------------------------------------
def test_volume_roundtrip_and_compile():
    vol = LogStructuredVolume(SMALL_SPEC, stripe_bytes=64 * KiB,
                              append_qd=2)
    vol.write("a", 128 * KiB, stream=0)
    vol.write("b", 256 * KiB, stream=1)
    vol.read("a")
    vol.delete("a")
    wl = vol.compile()
    tr = wl.build()
    n_app = int((tr.op == int(OpType.APPEND)).sum())
    assert n_app == (128 + 256) * KiB // (64 * KiB)
    assert (tr.op == int(OpType.READ)).sum() > 0
    res = vol.run(backend="event")
    assert res.user_bytes == (128 + 256) * KiB
    assert res.write_amplification == 1.0
    assert res.makespan_s > 0


def test_volume_rejects_duplicate_keys():
    vol = LogStructuredVolume(SMALL_SPEC)
    vol.write("k", 4 * KiB)
    with pytest.raises(ZoneError, match="already exists"):
        vol.write("k", 4 * KiB)


def test_volume_collect_relocates_survivors():
    vol = LogStructuredVolume(SMALL_SPEC, stripe_bytes=64 * KiB)
    cap = SMALL_SPEC.zone_cap_bytes
    vol.write("dead", cap // 2, stream=0)
    vol.write("live", cap // 2, stream=0)              # same zone, fills it
    zone = vol.objects["live"].extents[0].zone
    vol.delete("dead")
    rep = vol.collect(1, max_valid_frac=0.6)
    assert rep.zones_reset == 1
    assert rep.relocated_bytes == cap // 2             # live half moved
    assert all(e.zone != zone for e in vol.objects["live"].extents)
    vol.read("live")                                   # still readable


def test_volume_wa_gt_one_shows_in_compiled_trace():
    vol = LogStructuredVolume(SMALL_SPEC, stripe_bytes=64 * KiB)
    cap = SMALL_SPEC.zone_cap_bytes
    vol.write("dead", cap // 2)
    vol.write("live", cap // 2)
    vol.delete("dead")
    vol.collect(1, max_valid_frac=0.6)
    tr = vol.compile().build()
    append_bytes = int(tr.size[tr.op == int(OpType.APPEND)].sum())
    assert append_bytes == vol.user_bytes + cap // 2   # relocation appended
    assert (tr.op == int(OpType.RESET)).sum() == 1


def test_collect_aborts_cleanly_when_device_too_full_to_relocate():
    # Review regression: a failed mid-GC relocation must not corrupt
    # validity accounting, strand frozen zones, or model live data as
    # destroyed by a later drain.
    spec = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                         num_zones=4, max_open_zones=4, max_active_zones=4)
    vol = LogStructuredVolume(spec, stripe_bytes=64 * KiB)
    cap = spec.zone_cap_bytes
    for i in range(8):                      # fill all 4 zones half-live
        vol.write(f"o{i}", cap // 2, stream=0)
    for i in range(0, 8, 2):
        vol.delete(f"o{i}")
    with pytest.raises(ZoneError, match="device full"):
        vol.collect(1)
    # victims thawed, backlog empty, survivors' extents + validity intact
    assert vol.allocator.frozen == set()
    assert vol.reclaim.backlog == []
    live = vol.objects["o1"]
    z = live.extents[0].zone
    assert vol.reclaim.valid_bytes(z) >= live.nbytes
    rep = vol.reclaim.drain()               # nothing scheduled: no-op
    assert rep.zones_reset == 0


def test_plan_never_proposes_closed_zone_reopen_over_open_limit():
    # Review regression: CLOSED->open transitions count against the
    # open limit during planning, so commit() can't half-apply a plan.
    spec = ZNSDeviceSpec(zone_size_bytes=1 << 20, zone_cap_bytes=1 << 19,
                         num_zones=8, max_open_zones=2, max_active_zones=4)
    alloc = ZoneAllocator(spec, policy="greedy-open")
    alloc.zm.write(0, 4 * KiB)
    alloc.zm.close(0)                       # CLOSED, partially written
    alloc.zm.open(1)
    alloc.zm.open(2)                        # at the open limit
    plan = alloc.plan(4 * KiB)
    assert plan[0].zone != 0                # reopening 0 would violate
    alloc.commit(plan)                      # and commit proves it legal
    assert alloc.open_count <= spec.max_open_zones


# ---------------------------------------------------------------------------
# Scenarios + policy comparison
# ---------------------------------------------------------------------------
def test_scenarios_registered():
    assert set(("lsm", "circular-log", "cache")) <= set(available_scenarios())


def test_scenario_registry_collision_warns_and_unregisters():
    def fake(vol, rng, scale):
        pass
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            register_scenario("collide-scen", fake)
            assert not w
            register_scenario("collide-scen", lambda *a: None)
            assert len(w) == 1 and "already registered" in str(w[0].message)
    finally:
        unregister_scenario("collide-scen")
    assert "collide-scen" not in available_scenarios()


def test_build_scenario_deterministic_per_seed():
    a = build_scenario("cache", policy="striped", seed=7)
    b = build_scenario("cache", policy="striped", seed=7)
    c = build_scenario("cache", policy="striped", seed=8)
    assert a.stats == b.stats
    np.testing.assert_array_equal(a.workload.build().size,
                                  b.workload.build().size)
    assert a.stats != c.stats


def test_circular_log_has_unit_write_amplification():
    for policy in POLICIES:
        b = build_scenario("circular-log", policy=policy)
        assert b.stats["write_amplification"] == 1.0


def test_cache_scenario_relocates():
    b = build_scenario("cache", policy="greedy-open")
    assert b.stats["write_amplification"] > 1.0
    assert b.stats["zones_reset"] > 0


def test_compare_policies_one_fleet_run_and_ranking():
    rows = compare_policies(["circular-log"], backend="event", scale=0.5)
    assert len(rows) == len(available_placement_policies())
    assert all(r["scenario"] == "circular-log" for r in rows)
    assert all(r["makespan_s"] > 0 for r in rows)
    ranking = rank_policies(rows)
    assert set(ranking) == {"circular-log"}
    assert sorted(ranking["circular-log"]) == \
        sorted(available_placement_policies())


# ---------------------------------------------------------------------------
# Property tests (hypothesis): allocator invariants under random load
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from strategies import allocation_requests, small_zns_specs

    @given(st.data(), st.sampled_from(POLICIES))
    @settings(max_examples=30, deadline=None)
    def test_allocator_invariants_property(data, policy):
        spec = data.draw(small_zns_specs())
        reqs = data.draw(allocation_requests(spec))
        alloc = ZoneAllocator(spec, policy=policy)
        for nbytes, stream, lifetime in reqs:
            extents = alloc.allocate(nbytes, stream=stream,
                                     lifetime=lifetime)
            # bytes placed == bytes requested, inside zone capacity
            assert sum(e.nbytes for e in extents) == nbytes
            for e in extents:
                assert 0 <= e.offset < e.end <= spec.zone_cap_bytes
                assert 0 <= e.zone < spec.num_zones
            # never exceeds max-open / max-active
            assert alloc.open_count <= spec.max_open_zones
            assert alloc.active_count <= spec.max_active_zones
        assert alloc.bytes_placed == sum(r[0] for r in reqs)

    @given(st.data())
    @settings(max_examples=15, deadline=None)
    def test_allocator_never_finishes_zones_property(data):
        spec = data.draw(small_zns_specs())
        reqs = data.draw(allocation_requests(spec))
        alloc = ZoneAllocator(spec, policy="greedy-open")
        for nbytes, stream, lifetime in reqs:
            alloc.allocate(nbytes, stream=stream, lifetime=lifetime)
        # R3: zones become FULL only by filling, never via FINISH
        assert not any(zi.was_finished for zi in alloc.zm.zones)
