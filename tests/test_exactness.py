"""Differential exactness suite: fused solver vs the event-engine oracle.

The compiler claims ``ChainProgram.exact`` on multi-class and jittered
saturated pools (the greedy-replay refinement); these tests are the
claim's teeth.  Random pool workloads from ``tests/strategies.py`` are
solved through every production path — both pinned family-block
layouts and the entry-sharded driver — and compared against
``repro.core.engine.simulate``, to rtol 1e-9 jitter-free and 1e-8
jittered (the tolerances ``benchmarks/exactness_matrix.py`` gates in
CI).  The event engine appears here and in benchmarks only: no
production code path falls back to it.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from repro.core import (
    KiB, OpType, WorkloadSpec, ZNSDeviceSpec, ZnsDevice,
    compute_service_times, force_layout, simulate, solve_program,
    solve_program_sharded,
)
from repro.core import chain_program as cp
from strategies import HAVE_HYPOTHESIS

from benchmarks.exactness_matrix import TOL_JITTERED, TOL_JITTER_FREE

SPEC = ZNSDeviceSpec()
LAT = ZnsDevice(SPEC).lat


def _check_all_paths(tr, *, jitter: bool, seed: int = 3,
                     spec: ZNSDeviceSpec = SPEC, lat=LAT) -> None:
    """Solve one trace through cols / rows / sharded and compare each
    against the event engine at the claimed tolerance."""
    prog = cp.compile_fleet_program([tr], [spec], [lat], cache=False,
                                    jitter=jitter, seeds=[seed])
    assert prog.exact and prog.order_stable, prog.unstable_pools
    svc_flat = compute_service_times(tr, lat, seed=seed, jitter=True)[
        prog.orders[0]] if jitter else prog.svc0_flat
    ev = simulate(tr, spec, lat, seed=seed, jitter=jitter).complete
    rtol = TOL_JITTERED if jitter else TOL_JITTER_FREE
    for path in ("cols", "rows", "sharded"):
        if path == "sharded":
            comp, _, conv = solve_program_sharded(
                prog, svc_flat, sweeps=256, executor="host", warn=False)
        else:
            comp, _, conv = solve_program(
                force_layout(prog, path), svc_flat, sweeps=256,
                fixpoint="loop", warn=False)
        assert conv
        got = comp[prog.device_slice(0)][prog.invs[0]]
        np.testing.assert_allclose(got, ev, rtol=rtol, atol=1e-6,
                                   err_msg=f"path={path} jitter={jitter}")


def _multiclass_wl(threads=4, qd=4, n=60):
    wl = WorkloadSpec()
    for t in range(threads):
        wl = wl.appends(n=n, size=8 * KiB, qd=qd, zone=t * 4, nzones=4)
        wl = wl.appends(n=n, size=64 * KiB, qd=qd, zone=t * 4, nzones=4)
    return wl.build()


# -- deterministic coverage of every matrix axis -----------------------------
@pytest.mark.parametrize("jitter", [False, True])
def test_multiclass_pool_exact_on_all_paths(jitter):
    _check_all_paths(_multiclass_wl(), jitter=jitter)


@pytest.mark.parametrize("jitter", [False, True])
def test_reset_mixed_pool_exact_on_all_paths(jitter):
    tr = (WorkloadSpec()
          .appends(n=60, size=8 * KiB, qd=4, zone=0, nzones=4)
          .appends(n=60, size=64 * KiB, qd=4, zone=8, nzones=4)
          .resets(n=30, occupancy=1.0, nzones=30, io_ctx=OpType.APPEND,
                  zone=500)).build()
    _check_all_paths(tr, jitter=jitter)


def test_wide_single_class_pool_exact():
    # cap=4 pool, homogeneous services: the shape where the retired
    # round-robin re-sort limit-cycled and silently drifted ~0.5 rel
    spec = ZNSDeviceSpec(append_parallelism=4)
    wl = WorkloadSpec()
    for t in range(3):
        wl = wl.appends(n=80, size=8 * KiB, qd=2, zone=t * 4, nzones=4)
    _check_all_paths(wl.build(), jitter=False, spec=spec,
                     lat=ZnsDevice(spec).lat)


def test_jittered_claim_binds_to_seed():
    """A program compiled for one jitter seed reuses its chains for
    another seed, but the exactness claim must be voided."""
    dev = ZnsDevice(SPEC)
    tr = _multiclass_wl()
    prog = cp.compile_program(tr, SPEC, LAT, cache=False, jitter=True,
                              seed=3)
    assert prog.svc_seeds == (3,)
    res = dev.run(tr, backend="vectorized", jitter=True, seed=3,
                  program=prog)
    assert res.exact is True
    other = dev.run(tr, backend="vectorized", jitter=True, seed=7,
                    program=prog)
    assert other.exact is False          # claim voided, run still solves
    assert other.order_stable is True    # ...and the chains stayed frozen


# -- hypothesis fuzz: random multi-class pools, all paths --------------------
if HAVE_HYPOTHESIS:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    from strategies import pool_workload_specs

    @settings(max_examples=12, deadline=None)
    @given(wl=pool_workload_specs(), seed=st.integers(0, 5))
    def test_fuzz_pool_exactness_jitter_free(wl, seed):
        _check_all_paths(wl.build(), jitter=False, seed=seed)

    @settings(max_examples=12, deadline=None)
    @given(wl=pool_workload_specs(), seed=st.integers(0, 5))
    def test_fuzz_pool_exactness_jittered(wl, seed):
        _check_all_paths(wl.build(), jitter=True, seed=seed)
